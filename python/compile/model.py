"""Layer-2: transformer language model, fwd/bwd in JAX (build-time only).

The model is expressed over a SINGLE FLAT f32 parameter vector.  That is the
contract with the Rust coordinator: CSER, PSync and the GRBS compressor all
operate on flat views of the model (paper §3.3 — GRBS partitions the flat
tensor into B blocks), so the AOT artifact's signature is

    train_step(flat_params[P], tokens[B,S] i32, targets[B,S] i32)
        -> (loss f32[], flat_grad[P])

and the entire optimizer state in Rust is a handful of Vec<f32> of length P.

Architecture: decoder-only pre-LN transformer — embeddings (+learned
positional), n_layers x (LN -> causal MHA -> residual, LN -> GELU MLP ->
residual), final LN, tied output head, mean token cross-entropy.

Attention goes through the Layer-1 Pallas flash kernel when
``use_pallas=True`` (lowered with interpret=True so the resulting HLO runs on
the CPU PJRT client); the pure-jnp path is the reference the pytest suite
checks against.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import mha as pallas_mha
from .kernels.ref import attention_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters. d_ff defaults to 4*d_model."""

    vocab: int = 512
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    seq_len: int = 64
    d_ff: int = 0
    use_pallas: bool = False

    def __post_init__(self):
        if self.d_ff == 0:
            object.__setattr__(self, "d_ff", 4 * self.d_model)
        assert self.d_model % self.n_heads == 0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# Named presets used by aot.py and the Rust launcher.  `tiny` keeps the test
# suite fast; `small` is the recorded end-to-end run; `base` is a ~100M
# configuration (emitted on demand; CPU step time makes long runs impractical
# in this environment — see EXPERIMENTS.md).
PRESETS: Dict[str, "ModelConfig"] = {
    "tiny": ModelConfig(vocab=512, d_model=64, n_layers=2, n_heads=2, seq_len=64),
    "tiny_pallas": ModelConfig(
        vocab=512, d_model=64, n_layers=2, n_heads=2, seq_len=64, use_pallas=True
    ),
    "small": ModelConfig(vocab=4096, d_model=256, n_layers=4, n_heads=8, seq_len=128),
    "medium": ModelConfig(vocab=8192, d_model=512, n_layers=8, n_heads=8, seq_len=128),
    "base": ModelConfig(vocab=32768, d_model=768, n_layers=12, n_heads=12, seq_len=256),
}


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) table defining the flat-vector layout."""
    d, f = cfg.d_model, cfg.d_ff
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, d)),
        ("pos_embed", (cfg.seq_len, d)),
    ]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        spec += [
            (p + "ln1.scale", (d,)),
            (p + "ln1.bias", (d,)),
            (p + "attn.wqkv", (d, 3 * d)),
            (p + "attn.wo", (d, d)),
            (p + "ln2.scale", (d,)),
            (p + "ln2.bias", (d,)),
            (p + "mlp.w1", (d, f)),
            (p + "mlp.b1", (f,)),
            (p + "mlp.w2", (f, d)),
            (p + "mlp.b2", (d,)),
        ]
    spec += [("ln_f.scale", (d,)), ("ln_f.bias", (d,))]
    return spec


def num_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def unflatten(flat: jax.Array, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Static slicing of the flat vector into named tensors (free for XLA)."""
    params = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    assert off == flat.shape[0], (off, flat.shape)
    return params


def init_flat(cfg: ModelConfig, key: jax.Array) -> jax.Array:
    """Scaled-normal init, emitted as one flat vector (matches param_spec)."""
    chunks = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".bias", ".b1", ".b2")):
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        elif name.endswith(".scale"):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            std = 0.02 if "embed" in name else float(1.0 / (fan_in ** 0.5))
            chunks.append((std * jax.random.normal(sub, shape, jnp.float32)).ravel())
    return jnp.concatenate(chunks)


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(x, p, prefix, cfg: ModelConfig):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    qkv = x @ p[prefix + "attn.wqkv"]  # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [b, s, d] -> [b, h, s, dh]
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    if cfg.use_pallas:
        # Collapse batch*heads into the single vmap dim: nested vmaps of an
        # interpret-mode pallas_call trip the grid-context assertion.
        bq = bk = min(64, s)
        fold = lambda t: t.reshape(b * h, s, dh)
        o = pallas_mha(
            fold(q), fold(k), fold(v), causal=True, bq=bq, bk=bk, interpret=True
        ).reshape(b, h, s, dh)
    else:
        o = jax.vmap(jax.vmap(functools.partial(attention_ref, causal=True)))(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ p[prefix + "attn.wo"]


def forward(flat: jax.Array, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits [B, S, vocab] from token ids [B, S]."""
    p = unflatten(flat, cfg)
    b, s = tokens.shape
    x = p["embed"][tokens] + p["pos_embed"][None, :s, :]
    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        h = _layer_norm(x, p[pre + "ln1.scale"], p[pre + "ln1.bias"])
        x = x + _attention(h, p, pre, cfg)
        h = _layer_norm(x, p[pre + "ln2.scale"], p[pre + "ln2.bias"])
        h = jax.nn.gelu(h @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        x = x + h @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    return x @ p["embed"].T  # tied head


def loss_fn(flat: jax.Array, tokens: jax.Array, targets: jax.Array, cfg: ModelConfig):
    """Mean next-token cross-entropy."""
    logits = forward(flat, tokens, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def train_step(flat: jax.Array, tokens: jax.Array, targets: jax.Array, cfg: ModelConfig):
    """The AOT entry point: (loss, flat_grad)."""
    loss, grad = jax.value_and_grad(loss_fn)(flat, tokens, targets, cfg)
    return loss, grad


def eval_loss(flat: jax.Array, tokens: jax.Array, targets: jax.Array, cfg: ModelConfig):
    """Forward-only loss (second AOT entry point, used for eval curves)."""
    return loss_fn(flat, tokens, targets, cfg)
