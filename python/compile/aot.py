"""AOT pipeline: lower the L2/L1 computations to HLO text for the Rust runtime.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model preset <cfg> (default: tiny, tiny_pallas, small):

    artifacts/train_step_<cfg>.hlo.txt   (flat[P], tok[B,S]i32, tgt[B,S]i32)
                                         -> (loss f32[], grad f32[P])
    artifacts/eval_loss_<cfg>.hlo.txt    same inputs -> loss f32[]
    artifacts/init_<cfg>.bin             little-endian f32 init params

plus the standalone Layer-1 kernel artifacts (runnable from Rust as an
alternate compute path and cross-checked against the Rust implementations):

    artifacts/fused_update_<d>.hlo.txt   (eta[1], x[d], e[d], g[d], r[d])
                                         -> (x'[d], e'[d])
    artifacts/block_mask_<d>_<bs>.hlo.txt (v[d], mask[B] f32) -> (kept, resid)

and artifacts/manifest.json describing all of the above for the Rust side.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--configs tiny,small] [--kernel-d 65536] [--block-size 1024]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.fused_update import fused_update
from .kernels.grbs import block_mask

BATCH = {"tiny": 4, "tiny_pallas": 4, "small": 8, "medium": 8, "base": 8}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)/1e6:.2f} MB)")


def emit_model(cfg_name: str, out_dir: str, manifest: dict) -> None:
    cfg = M.PRESETS[cfg_name]
    batch = BATCH[cfg_name]
    p = M.num_params(cfg)
    flat_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    print(f"[{cfg_name}] P={p} ({p*4/1e6:.1f} MB f32), B={batch}, S={cfg.seq_len}")

    t0 = time.time()
    step = functools.partial(M.train_step, cfg=cfg)
    lowered = jax.jit(step).lower(flat_spec, tok_spec, tok_spec)
    _write(os.path.join(out_dir, f"train_step_{cfg_name}.hlo.txt"), to_hlo_text(lowered))

    ev = functools.partial(M.eval_loss, cfg=cfg)
    lowered = jax.jit(ev).lower(flat_spec, tok_spec, tok_spec)
    _write(os.path.join(out_dir, f"eval_loss_{cfg_name}.hlo.txt"), to_hlo_text(lowered))

    init = M.init_flat(cfg, jax.random.PRNGKey(0))
    init_path = os.path.join(out_dir, f"init_{cfg_name}.bin")
    with open(init_path, "wb") as f:
        f.write(bytes(jnp.asarray(init, jnp.float32).tobytes()))
    print(f"  wrote {init_path}; lowering took {time.time()-t0:.1f}s")

    manifest["models"][cfg_name] = {
        "params": int(p),
        "batch": int(batch),
        "seq_len": int(cfg.seq_len),
        "vocab": int(cfg.vocab),
        "d_model": int(cfg.d_model),
        "n_layers": int(cfg.n_layers),
        "n_heads": int(cfg.n_heads),
        "use_pallas": bool(cfg.use_pallas),
        "train_step": f"train_step_{cfg_name}.hlo.txt",
        "eval_loss": f"eval_loss_{cfg_name}.hlo.txt",
        "init": f"init_{cfg_name}.bin",
        "param_table": [
            {"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)
        ],
    }


def emit_kernels(d: int, block_size: int, out_dir: str, manifest: dict) -> None:
    assert d % block_size == 0
    nb = d // block_size
    vec = jax.ShapeDtypeStruct((d,), jnp.float32)
    one = jax.ShapeDtypeStruct((1,), jnp.float32)
    maskspec = jax.ShapeDtypeStruct((nb,), jnp.float32)

    tile = min(4096, d)
    fu = lambda eta, x, e, g, r: fused_update(x, e, g, r, eta, tile=tile)
    lowered = jax.jit(fu).lower(one, vec, vec, vec, vec)
    name = f"fused_update_{d}.hlo.txt"
    _write(os.path.join(out_dir, name), to_hlo_text(lowered))
    manifest["kernels"]["fused_update"] = {"d": d, "tile": tile, "file": name}

    bm = lambda v, m: block_mask(v, m, block_size=block_size)
    lowered = jax.jit(bm).lower(vec, maskspec)
    name = f"block_mask_{d}_{block_size}.hlo.txt"
    _write(os.path.join(out_dir, name), to_hlo_text(lowered))
    manifest["kernels"]["block_mask"] = {
        "d": d,
        "block_size": block_size,
        "num_blocks": nb,
        "file": name,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,tiny_pallas,small")
    ap.add_argument("--kernel-d", type=int, default=65536)
    ap.add_argument("--block-size", type=int, default=1024)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest: dict = {"models": {}, "kernels": {}}
    for cfg_name in args.configs.split(","):
        cfg_name = cfg_name.strip()
        if cfg_name:
            emit_model(cfg_name, args.out_dir, manifest)
    emit_kernels(args.kernel_d, args.block_size, args.out_dir, manifest)

    # cross-language golden trajectory (see golden.py / rust/tests/golden.rs)
    from . import golden
    golden.emit(os.path.join(args.out_dir, "golden_cser.json"))

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
