"""Cross-language golden trajectories for the CSER algebra.

A small numpy implementation of M-CSER (Algorithm 4, implementation I) with
an *explicit block-mask schedule* (so no RNG has to match across languages)
generates a full trajectory; the Rust test
(`rust/tests/golden.rs`) replays the same gradients through
`optimizer::Cser` with a scheduled compressor and asserts the models match
step-by-step.  This pins the Rust hot path to an independent implementation
of the paper's equations.

Emitted by `make artifacts` into artifacts/golden_cser.json.
"""

from __future__ import annotations

import json

import numpy as np


def simulate(d=32, n=3, h=3, beta=0.9, eta=0.05, steps=9, block=8, seed=1234):
    """Run M-CSER impl I; returns everything the Rust side needs."""
    assert d % block == 0
    nb = d // block
    rng = np.random.default_rng(seed)
    init = rng.standard_normal(d).astype(np.float32)
    grads = rng.standard_normal((steps, n, d)).astype(np.float32)
    # mask schedules, indexed by 1-based round t (entry 0 unused)
    mask2 = (rng.random((steps + 1, nb)) < 0.5).astype(np.float32)
    mask1 = (rng.random((steps + 1, nb)) < 0.5).astype(np.float32)
    # guarantee at least one block selected per round (Rust sparsifiers
    # always keep >= 1 block)
    for m in (mask1, mask2):
        for t in range(steps + 1):
            if m[t].sum() == 0:
                m[t][t % nb] = 1.0

    x = np.tile(init, (n, 1)).astype(np.float32)
    e = np.zeros((n, d), np.float32)
    mom = np.zeros((n, d), np.float32)
    traj = []
    for t in range(1, steps + 1):
        g = grads[t - 1]
        mom[:] = beta * mom + g
        p = (eta * (beta * mom + g)).astype(np.float32)
        m2 = np.repeat(mask2[t], block)[None, :]
        kept = p * m2
        pbar = kept.mean(axis=0, keepdims=True)
        p_prime = pbar + (p - kept)
        x = (x - p_prime).astype(np.float32)
        e = (e - (p - kept)).astype(np.float32)
        if t % h == 0:
            m1 = np.repeat(mask1[t], block)[None, :]
            kept1 = e * m1
            ebar = kept1.mean(axis=0, keepdims=True)
            e_prime = ebar + (e - kept1)
            x = (x - e + e_prime).astype(np.float32)
            e = (e - kept1).astype(np.float32)
        traj.append(x.copy())

    return {
        "d": d,
        "n": n,
        "h": h,
        "beta": beta,
        "eta": eta,
        "steps": steps,
        "block": block,
        "init": init.tolist(),
        "grads": grads.reshape(steps * n * d).tolist(),
        "mask1": mask1.reshape(-1).tolist(),
        "mask2": mask2.reshape(-1).tolist(),
        "x_final": x.reshape(-1).tolist(),
        "x_mid": traj[len(traj) // 2].reshape(-1).tolist(),
        "mid_step": len(traj) // 2 + 1,
    }


def emit(out_path: str) -> None:
    with open(out_path, "w") as f:
        json.dump(simulate(), f)
    print(f"  wrote {out_path}")


if __name__ == "__main__":
    import sys

    emit(sys.argv[1] if len(sys.argv) > 1 else "../artifacts/golden_cser.json")
