"""GRBS block-mask compressor as a Pallas kernel (Layer 1).

The Globally-Randomized Blockwise Sparsifier (paper §3.3, Definition 2)
partitions a flat tensor into B blocks and keeps B/R of them, with the *same*
blocks chosen on every worker (shared seed).  On the wire this means the
compressed message is a set of contiguous blocks — directly AllReduce-able.
On-device the compressor itself is a single streaming pass: each grid step
loads one block of `v` plus one mask scalar into VMEM, writes the kept block
and the residual block.

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid step = one VMEM tile
(block_size * 4 bytes in, 2x out); no gather/scatter is needed because GRBS
selects *blocks*, not elements — the same property that removes index
metadata from the network messages removes it from the HBM<->VMEM schedule.

Run with interpret=True everywhere in this repo: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_mask_kernel(v_ref, m_ref, kept_ref, resid_ref):
    m = m_ref[0].astype(v_ref.dtype)
    v = v_ref[...]
    kept = v * m
    kept_ref[...] = kept
    resid_ref[...] = v - kept


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def block_mask(v: jax.Array, mask: jax.Array, *, block_size: int, interpret: bool = True):
    """Split ``v`` into (kept, residual) under a per-block 0/1 ``mask``.

    v: [B * block_size]; mask: [B] (0/1, any integer or float dtype).
    Returns (C(v), v - C(v)) with the same dtype as v.
    """
    b = mask.shape[0]
    assert v.shape == (b * block_size,), (v.shape, b, block_size)
    out = jax.ShapeDtypeStruct(v.shape, v.dtype)
    kept, resid = pl.pallas_call(
        _block_mask_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((block_size,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_size,), lambda i: (i,)),
            pl.BlockSpec((block_size,), lambda i: (i,)),
        ],
        out_shape=[out, out],
        interpret=interpret,
    )(v, mask)
    return kept, resid
