"""Layer-1 Pallas kernels + pure-jnp reference oracles.

Kernels are always invoked with interpret=True in this repo (CPU PJRT cannot
execute Mosaic custom-calls); the BlockSpecs still encode the real-TPU
HBM<->VMEM schedule, which DESIGN.md documents under Hardware-Adaptation.
"""

from .grbs import block_mask
from .fused_update import fused_update
from .attention import flash_attention, mha
from . import ref

__all__ = ["block_mask", "fused_update", "flash_attention", "mha", "ref"]
