"""Fused CSER inner step as a Pallas kernel (Layer 1).

Algorithm 2 lines 6-7 (and the M-CSER analogue, Algorithm 4 line 9) apply

    x <- x - eta * (gbar + r)        # model takes synced grad + own residual
    e <- e - eta * r                 # error accumulates the residual

to the flat parameter vector every iteration.  Done naively this is four
elementwise HLO ops and six HBM round-trips over 4*d floats; fused it is one
pass reading 4 streams and writing 2.  VMEM footprint per grid step is
6 * tile * 4 bytes (default tile 4096 -> 96 KiB), well under a TPU core's
~16 MiB VMEM, leaving room for double-buffering by the pipeline emitter.

interpret=True for CPU-PJRT execution (see grbs.py note).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_update_kernel(eta_ref, x_ref, e_ref, g_ref, r_ref, xo_ref, eo_ref):
    eta = eta_ref[0].astype(x_ref.dtype)
    r = r_ref[...]
    xo_ref[...] = x_ref[...] - eta * (g_ref[...] + r)
    eo_ref[...] = e_ref[...] - eta * r


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def fused_update(
    x: jax.Array,
    e: jax.Array,
    gbar: jax.Array,
    r: jax.Array,
    eta: jax.Array,
    *,
    tile: int = 4096,
    interpret: bool = True,
):
    """Apply the fused CSER inner step; all vector args share shape [d].

    ``d`` must be a multiple of ``tile`` (the AOT pipeline pads the flat
    parameter vector up to the tile size; see python/compile/aot.py).
    ``eta`` is a scalar (passed as shape-[1] array to stay a runtime input).
    """
    d = x.shape[0]
    assert d % tile == 0, (d, tile)
    eta = jnp.asarray(eta, x.dtype).reshape((1,))
    out = jax.ShapeDtypeStruct((d,), x.dtype)
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    xo, eo = pl.pallas_call(
        _fused_update_kernel,
        grid=(d // tile,),
        in_specs=[scalar, vec, vec, vec, vec],
        out_specs=[vec, vec],
        out_shape=[out, out],
        interpret=interpret,
    )(eta, x, e, gbar, r)
    return xo, eo
