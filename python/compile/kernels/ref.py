"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact (up to dtype rounding)
reference implementation here. pytest (python/tests/test_kernels.py) sweeps
shapes/dtypes with hypothesis and asserts allclose between the two.

These references are also the mathematical definitions used by the Rust
coordinator's unit tests (golden vectors are generated from them).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_mask_ref(v: jax.Array, mask: jax.Array, block_size: int):
    """GRBS compressor split: keep masked blocks, return (kept, residual).

    ``v`` has shape ``[B * block_size]``; ``mask`` has shape ``[B]`` with
    entries in {0, 1} (1 = block selected for synchronization, identical on
    every worker because the GRBS seed is global).  Returns ``(v', r)`` with
    ``v' = C(v)`` (selected blocks, zeros elsewhere) and ``r = v - v'``.
    """
    b = mask.shape[0]
    assert v.shape[0] == b * block_size
    m = jnp.repeat(mask.astype(v.dtype), block_size)
    kept = v * m
    return kept, v - kept


def fused_update_ref(
    x: jax.Array, e: jax.Array, gbar: jax.Array, r: jax.Array, eta: jax.Array
):
    """CSER inner step (Algorithm 2, lines 6-7), fused.

    x' = x - eta * (gbar + r)       (local model takes sync'd grad + residual)
    e' = e - eta * r                (local error accumulates the residual)
    """
    eta = jnp.asarray(eta, x.dtype)
    return x - eta * (gbar + r), e - eta * r


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True):
    """Scaled dot-product attention, one head: q,k,v are [S, D]."""
    s, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32)).astype(q.dtype)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return p @ v


def psync_ref(vs: jax.Array, mask: jax.Array, block_size: int):
    """Partial synchronization (Algorithm 3 / 6) under the GRBS compressor.

    ``vs`` is [n, d] (one row per worker).  Returns (v_primes [n, d],
    residuals [n, d]) where v'_i = mean_j C(v_j) + r_i and r_i = v_i - C(v_i).
    Mean preservation: mean_i v'_i == mean_i v_i.
    """
    kept, resid = jax.vmap(lambda v: block_mask_ref(v, mask, block_size))(vs)
    vbar = jnp.mean(kept, axis=0, keepdims=True)
    return vbar + resid, resid
