"""Flash-style causal attention as Pallas kernels (Layer 1), fwd + bwd.

This is the L2 transformer's compute hot-spot.  The paper's experiments run
WRN/ResNet on V100s; our end-to-end driver trains a transformer LM, so the
hot kernel we own is attention.  The GPU flash-attention insight (tile the
score matrix so it never materializes in HBM; keep a running max/denominator)
maps to TPU as (DESIGN.md §Hardware-Adaptation):

  * grid over query tiles (``bq`` rows each) — one VMEM-resident output tile;
  * inner loop over key tiles (``bk``) with an online-softmax carry
    (m, l, acc) — the role threadblock-local shared memory plays on GPU is
    played by VMEM here;
  * tiles shaped for the MXU: bq, bk and the head dim are multiples of 8/128
    in the real-TPU configuration (the interpret-mode tests also sweep odd
    shapes since the CPU path has no alignment constraint).

jax 0.8's ``pallas_call`` has no reverse-mode rule, and the L2 train_step
differentiates through attention, so the kernel is wrapped in a
``jax.custom_vjp`` whose backward pass is itself two Pallas kernels (the
standard flash backward): the forward saves (q, k, v, o, L) where L is the
row logsumexp; the backward recomputes P tile-by-tile and accumulates

    D  = rowsum(dO * O)
    dS = P * (dO V^T - D)
    dQ = dS K * scale          (grid over query tiles)
    dK = dS^T Q * scale        (grid over key tiles)
    dV = P^T dO                (grid over key tiles)

interpret=True for CPU-PJRT execution; real-TPU lowering would emit a Mosaic
custom-call the CPU plugin cannot run.  VMEM/MXU estimates are recorded in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, l_ref, *, bq, bk, seq, causal):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    nkb = seq // bk
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, d), jnp.float32)
    for j in range(nkb):  # static unroll; nkb is small in our configs
        k = k_ref[...][j * bk : (j + 1) * bk].astype(jnp.float32)
        v = v_ref[...][j * bk : (j + 1) * bk].astype(jnp.float32)
        s = (q @ k.T) * scale
        if causal:
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(s <= NEG_INF / 2, 0.0, p)  # fully-masked entries
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        m = m_new
    lse = m + jnp.log(jnp.where(l == 0.0, 1.0, l))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
    l_ref[...] = lse


def _bwd_dq_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, do_ref, dq_ref, *, bq, bk, seq, causal):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    o = o_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    nkb = seq // bk
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    dvec = jnp.sum(do * o, axis=-1)  # D [bq]

    dq = jnp.zeros((bq, d), jnp.float32)
    for j in range(nkb):
        k = k_ref[...][j * bk : (j + 1) * bk].astype(jnp.float32)
        v = v_ref[...][j * bk : (j + 1) * bk].astype(jnp.float32)
        s = (q @ k.T) * scale
        if causal:
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = do @ v.T
        ds = p * (dp - dvec[:, None])
        dq = dq + ds @ k * scale
    dq_ref[...] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, do_ref, dk_ref, dv_ref, *, bq, bk, seq, causal):
    ki = pl.program_id(0)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    d = k.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    nqb = seq // bq
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    dk = jnp.zeros((bk, d), jnp.float32)
    dv = jnp.zeros((bk, d), jnp.float32)
    for j in range(nqb):
        q = q_ref[...][j * bq : (j + 1) * bq].astype(jnp.float32)
        do = do_ref[...][j * bq : (j + 1) * bq].astype(jnp.float32)
        o = o_ref[...][j * bq : (j + 1) * bq].astype(jnp.float32)
        lse = lse_ref[...][j * bq : (j + 1) * bq]
        s = (q @ k.T) * scale  # [bq, bk]
        if causal:
            q_pos = j * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dvec = jnp.sum(do * o, axis=-1)
        dp = do @ v.T
        ds = p * (dp - dvec[:, None])
        dk = dk + ds.T @ q * scale
        dv = dv + p.T @ do
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _fwd_call(q, k, v, bq, bk, causal, interpret):
    s, d = q.shape
    kernel = functools.partial(_fwd_kernel, bq=bq, bk=bk, seq=s, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(s // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, d), q.dtype),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, bq, bk, causal, interpret):
    o, _ = _fwd_call(q, k, v, bq, bk, causal, interpret)
    return o


def _flash_fwd(q, k, v, bq, bk, causal, interpret):
    o, lse = _fwd_call(q, k, v, bq, bk, causal, interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(bq, bk, causal, interpret, res, do):
    q, k, v, o, lse = res
    s, d = q.shape
    full = pl.BlockSpec((s, d), lambda i: (0, 0))
    full1 = pl.BlockSpec((s,), lambda i: (0,))
    qtile = pl.BlockSpec((bq, d), lambda i: (i, 0))
    ktile = pl.BlockSpec((bk, d), lambda i: (i, 0))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, bq=bq, bk=bk, seq=s, causal=causal),
        grid=(s // bq,),
        in_specs=[qtile, full, full, qtile, pl.BlockSpec((bq,), lambda i: (i,)), qtile],
        out_specs=qtile,
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        interpret=interpret,
    )(q, k, v, o, lse, do)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, bq=bq, bk=bk, seq=s, causal=causal),
        grid=(s // bk,),
        in_specs=[full, ktile, ktile, full, full1, full],
        out_specs=[ktile, ktile],
        out_shape=[
            jax.ShapeDtypeStruct((s, d), k.dtype),
            jax.ShapeDtypeStruct((s, d), v.dtype),
        ],
        interpret=interpret,
    )(q, k, v, o, lse, do)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bq: int = 64,
    bk: int = 64,
    causal: bool = True,
    interpret: bool = True,
):
    """Single-head attention over [S, D] tensors; S divisible by bq and bk."""
    s, _ = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    return _flash(q, k, v, bq, bk, causal, interpret)


def mha(q, k, v, *, causal: bool = True, bq: int = 64, bk: int = 64, interpret: bool = True):
    """Multi-head wrapper: q,k,v are [H, S, D]; vmaps the Pallas kernel."""
    f = functools.partial(
        flash_attention, bq=bq, bk=bk, causal=causal, interpret=interpret
    )
    return jax.vmap(f)(q, k, v)
