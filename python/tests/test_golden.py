"""Sanity checks on the numpy golden simulator itself."""

import numpy as np

from compile import golden


def test_simulation_is_deterministic_and_finite():
    a = golden.simulate(seed=7)
    b = golden.simulate(seed=7)
    assert a["x_final"] == b["x_final"]
    assert np.isfinite(np.asarray(a["x_final"])).all()


def test_lemma1_in_numpy_sim():
    """Even the independent simulator must satisfy Lemma 1 — compute x - e
    at the end of a re-run and check worker agreement."""
    d, n, h, beta, eta, steps, block = 16, 3, 2, 0.9, 0.1, 6, 4
    out = golden.simulate(d=d, n=n, h=h, beta=beta, eta=eta, steps=steps, block=block, seed=3)
    # re-run retaining e
    rng = np.random.default_rng(3)
    init = rng.standard_normal(d).astype(np.float32)
    grads = rng.standard_normal((steps, n, d)).astype(np.float32)
    nb = d // block
    mask2 = (rng.random((steps + 1, nb)) < 0.5).astype(np.float32)
    mask1 = (rng.random((steps + 1, nb)) < 0.5).astype(np.float32)
    for m in (mask1, mask2):
        for t in range(steps + 1):
            if m[t].sum() == 0:
                m[t][t % nb] = 1.0
    x = np.tile(init, (n, 1)).astype(np.float32)
    e = np.zeros((n, d), np.float32)
    mom = np.zeros((n, d), np.float32)
    for t in range(1, steps + 1):
        g = grads[t - 1]
        mom[:] = beta * mom + g
        p = (eta * (beta * mom + g)).astype(np.float32)
        m2 = np.repeat(mask2[t], block)[None, :]
        kept = p * m2
        p_prime = kept.mean(axis=0, keepdims=True) + (p - kept)
        x = x - p_prime
        e = e - (p - kept)
        if t % h == 0:
            m1 = np.repeat(mask1[t], block)[None, :]
            kept1 = e * m1
            e_prime = kept1.mean(axis=0, keepdims=True) + (e - kept1)
            x = x - e + e_prime
            e = e - kept1
        consensus = x - e
        np.testing.assert_allclose(
            consensus, np.broadcast_to(consensus[0:1], consensus.shape), rtol=1e-4, atol=1e-5
        )
    # matches the packaged simulate() as well
    np.testing.assert_allclose(np.asarray(out["x_final"]).reshape(n, d), x, rtol=1e-5, atol=1e-6)
