"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_mask, flash_attention, fused_update, mha
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- block_mask
@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 16),
    bs=st.sampled_from([1, 2, 7, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
    dtype=st.sampled_from([np.float32, np.float16]),
)
def test_block_mask_matches_ref(nb, bs, seed, dtype):
    r = _rng(seed)
    v = r.standard_normal(nb * bs).astype(dtype)
    mask = (r.random(nb) < 0.5).astype(np.float32)
    kept, resid = block_mask(jnp.asarray(v), jnp.asarray(mask), block_size=bs)
    kept_r, resid_r = ref.block_mask_ref(jnp.asarray(v), jnp.asarray(mask), bs)
    np.testing.assert_allclose(kept, kept_r, rtol=0, atol=0)
    np.testing.assert_allclose(resid, resid_r, rtol=0, atol=0)


def test_block_mask_partition_identity():
    """kept + resid == v exactly, any mask."""
    r = _rng(0)
    v = r.standard_normal(64 * 8).astype(np.float32)
    mask = (r.random(64) < 0.25).astype(np.float32)
    kept, resid = block_mask(jnp.asarray(v), jnp.asarray(mask), block_size=8)
    np.testing.assert_array_equal(np.asarray(kept) + np.asarray(resid), v)


def test_block_mask_contraction():
    """delta-approximate compressor property: ||C(v)-v||^2 <= ||v||^2."""
    r = _rng(1)
    v = r.standard_normal(32 * 16).astype(np.float32)
    mask = (r.random(32) < 0.1).astype(np.float32)
    _, resid = block_mask(jnp.asarray(v), jnp.asarray(mask), block_size=16)
    assert float(jnp.sum(resid**2)) <= float(jnp.sum(jnp.asarray(v) ** 2)) + 1e-6


# -------------------------------------------------------------- fused_update
@settings(max_examples=25, deadline=None)
@given(
    logd=st.integers(0, 4),
    tile_pow=st.integers(0, 3),
    eta=st.floats(1e-4, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_update_matches_ref(logd, tile_pow, eta, seed):
    tile = 2**tile_pow * 8
    d = tile * (2**logd)
    r = _rng(seed)
    x, e, g, rr = (r.standard_normal(d).astype(np.float32) for _ in range(4))
    xo, eo = fused_update(
        jnp.asarray(x), jnp.asarray(e), jnp.asarray(g), jnp.asarray(rr),
        jnp.float32(eta), tile=tile,
    )
    xr, er = ref.fused_update_ref(
        jnp.asarray(x), jnp.asarray(e), jnp.asarray(g), jnp.asarray(rr), eta
    )
    np.testing.assert_allclose(xo, xr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(eo, er, rtol=1e-6, atol=1e-6)


def test_fused_update_zero_eta_is_identity():
    r = _rng(3)
    d = 256
    x, e, g, rr = (r.standard_normal(d).astype(np.float32) for _ in range(4))
    xo, eo = fused_update(
        jnp.asarray(x), jnp.asarray(e), jnp.asarray(g), jnp.asarray(rr),
        jnp.float32(0.0), tile=64,
    )
    np.testing.assert_array_equal(xo, x)
    np.testing.assert_array_equal(eo, e)


# ----------------------------------------------------------- flash attention
@settings(max_examples=15, deadline=None)
@given(
    s=st.sampled_from([64, 128, 192]),
    d=st.sampled_from([16, 32, 64]),
    bq=st.sampled_from([32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(s, d, bq, causal, seed):
    if s % bq != 0:
        bq = 32
    r = _rng(seed)
    q, k, v = (r.standard_normal((s, d)).astype(np.float32) * 0.5 for _ in range(3))
    out = flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), bq=bq, bk=bq, causal=causal
    )
    expect = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_flash_attention_online_softmax_stability():
    """Large score magnitudes must not overflow (online max subtraction)."""
    r = _rng(7)
    q = (r.standard_normal((64, 32)) * 30).astype(np.float32)
    k = (r.standard_normal((64, 32)) * 30).astype(np.float32)
    v = r.standard_normal((64, 32)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    assert np.isfinite(np.asarray(out)).all()
    expect = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_mha_vmap_heads():
    r = _rng(11)
    h, s, d = 4, 64, 16
    q, k, v = (r.standard_normal((h, s, d)).astype(np.float32) for _ in range(3))
    out = mha(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), bq=32, bk=32)
    for i in range(h):
        expect = ref.attention_ref(jnp.asarray(q[i]), jnp.asarray(k[i]), jnp.asarray(v[i]))
        np.testing.assert_allclose(out[i], expect, rtol=2e-5, atol=2e-5)


def test_flash_attention_causality():
    """Perturbing a future key/value must not change earlier outputs."""
    r = _rng(13)
    s, d = 64, 16
    q, k, v = (r.standard_normal((s, d)).astype(np.float32) for _ in range(3))
    out1 = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), bq=32, bk=32))
    k2, v2 = k.copy(), v.copy()
    k2[-1] += 100.0
    v2[-1] -= 100.0
    out2 = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), bq=32, bk=32))
    np.testing.assert_allclose(out1[:-1], out2[:-1], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[-1], out2[-1])


# ------------------------------------------------------------------ psync ref
def test_psync_ref_mean_preservation():
    r = _rng(17)
    n, nb, bs = 4, 16, 8
    vs = r.standard_normal((n, nb * bs)).astype(np.float32)
    mask = (r.random(nb) < 0.5).astype(np.float32)
    vps, _ = ref.psync_ref(jnp.asarray(vs), jnp.asarray(mask), bs)
    np.testing.assert_allclose(
        np.mean(np.asarray(vps), axis=0), np.mean(vs, axis=0), rtol=1e-5, atol=1e-6
    )


# ------------------------------------------------------- flash attention bwd
@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([64, 128]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_grad_matches_ref(s, d, causal, seed):
    """custom_vjp Pallas backward kernels vs jax.grad of the jnp oracle."""
    r = _rng(seed)
    q, k, v = (jnp.asarray(r.standard_normal((s, d)).astype(np.float32) * 0.5)
               for _ in range(3))
    w = jnp.asarray(r.standard_normal((s, d)).astype(np.float32))

    def loss_pallas(q, k, v):
        return jnp.sum(flash_attention(q, k, v, bq=32, bk=32, causal=causal) * w)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal) * w)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gp, gr, "qkv"):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4, err_msg=f"d{name}")
