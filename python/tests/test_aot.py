"""AOT pipeline: HLO text emission + manifest consistency."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


def test_hlo_text_emission_tiny(tmp_path):
    manifest = {"models": {}, "kernels": {}}
    aot.emit_model("tiny", str(tmp_path), manifest)
    hlo = (tmp_path / "train_step_tiny.hlo.txt").read_text()
    assert "ENTRY" in hlo and "HloModule" in hlo
    m = manifest["models"]["tiny"]
    assert m["params"] == M.num_params(M.PRESETS["tiny"])
    init = np.fromfile(tmp_path / "init_tiny.bin", dtype="<f4")
    assert init.shape[0] == m["params"]
    # param_table covers the whole flat vector
    total = sum(int(np.prod(e["shape"])) for e in m["param_table"])
    assert total == m["params"]


def test_kernel_artifacts(tmp_path):
    manifest = {"models": {}, "kernels": {}}
    aot.emit_kernels(4096, 256, str(tmp_path), manifest)
    for k in ("fused_update", "block_mask"):
        f = tmp_path / manifest["kernels"][k]["file"]
        assert f.exists()
        assert "ENTRY" in f.read_text()
    assert manifest["kernels"]["block_mask"]["num_blocks"] == 16


def test_hlo_text_is_parseable_ids():
    """The text must not contain ids that overflow 32 bits (0.5.1 gate)."""
    cfg = M.PRESETS["tiny"]
    p = M.num_params(cfg)
    import functools
    step = functools.partial(M.train_step, cfg=cfg)
    lowered = jax.jit(step).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((2, cfg.seq_len), jnp.int32),
        jax.ShapeDtypeStruct((2, cfg.seq_len), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
