"""L2 correctness: transformer over the flat parameter vector."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


def _batch(cfg, b=2, seed=0):
    r = np.random.default_rng(seed)
    tok = r.integers(0, cfg.vocab, size=(b, cfg.seq_len)).astype(np.int32)
    tgt = r.integers(0, cfg.vocab, size=(b, cfg.seq_len)).astype(np.int32)
    return jnp.asarray(tok), jnp.asarray(tgt)


def test_param_spec_flat_roundtrip():
    p = M.num_params(CFG)
    flat = jnp.arange(p, dtype=jnp.float32)
    params = M.unflatten(flat, CFG)
    # re-flatten in spec order and compare
    re = jnp.concatenate([params[n].ravel() for n, _ in M.param_spec(CFG)])
    np.testing.assert_array_equal(re, flat)


def test_init_shapes_and_stats():
    flat = M.init_flat(CFG, jax.random.PRNGKey(0))
    assert flat.shape == (M.num_params(CFG),)
    params = M.unflatten(flat, CFG)
    np.testing.assert_array_equal(params["layer0.ln1.scale"], np.ones(CFG.d_model))
    np.testing.assert_array_equal(params["layer0.mlp.b1"], np.zeros(CFG.d_ff))
    assert 0.01 < float(jnp.std(params["embed"])) < 0.04


def test_loss_finite_and_near_uniform_at_init():
    flat = M.init_flat(CFG, jax.random.PRNGKey(0))
    tok, tgt = _batch(CFG)
    loss = float(M.loss_fn(flat, tok, tgt, CFG))
    assert np.isfinite(loss)
    # At init the head is near-uniform: loss ~ log(vocab)
    assert abs(loss - np.log(CFG.vocab)) < 1.0


def test_train_step_grad_matches_fd():
    """Directional finite-difference check of the flat gradient."""
    flat = M.init_flat(CFG, jax.random.PRNGKey(1))
    tok, tgt = _batch(CFG, b=1, seed=1)
    loss, grad = M.train_step(flat, tok, tgt, CFG)
    r = np.random.default_rng(2)
    u = r.standard_normal(flat.shape[0]).astype(np.float32)
    u /= np.linalg.norm(u)
    u = jnp.asarray(u)
    eps = 1e-3
    lp = float(M.loss_fn(flat + eps * u, tok, tgt, CFG))
    lm = float(M.loss_fn(flat - eps * u, tok, tgt, CFG))
    fd = (lp - lm) / (2 * eps)
    an = float(jnp.vdot(grad, u))
    assert abs(fd - an) < 5e-3 * max(1.0, abs(fd)), (fd, an)


def test_model_causality():
    """Changing future tokens must not change earlier logits."""
    flat = M.init_flat(CFG, jax.random.PRNGKey(3))
    tok, _ = _batch(CFG, b=1, seed=3)
    logits1 = M.forward(flat, tok, CFG)
    tok2 = np.asarray(tok).copy()
    tok2[0, -1] = (tok2[0, -1] + 7) % CFG.vocab
    logits2 = M.forward(flat, jnp.asarray(tok2), CFG)
    np.testing.assert_allclose(logits1[0, :-1], logits2[0, :-1], rtol=1e-5, atol=1e-5)


def test_pallas_model_matches_jnp_model():
    """tiny_pallas (flash-attention kernel) == tiny (jnp reference) numerics."""
    cfg_p = M.PRESETS["tiny_pallas"]
    flat = M.init_flat(CFG, jax.random.PRNGKey(4))
    tok, tgt = _batch(CFG, b=2, seed=4)
    l_ref = float(M.loss_fn(flat, tok, tgt, CFG))
    l_pal = float(M.loss_fn(flat, tok, tgt, cfg_p))
    assert abs(l_ref - l_pal) < 1e-4, (l_ref, l_pal)
    _, g_ref = M.train_step(flat, tok, tgt, CFG)
    _, g_pal = M.train_step(flat, tok, tgt, cfg_p)
    np.testing.assert_allclose(g_pal, g_ref, rtol=5e-3, atol=5e-5)


def test_gradient_descends():
    flat = M.init_flat(CFG, jax.random.PRNGKey(5))
    tok, tgt = _batch(CFG, b=4, seed=5)
    step = jax.jit(lambda f: M.train_step(f, tok, tgt, CFG))
    l0, g = step(flat)
    for _ in range(5):
        flat = flat - 0.5 * g
        l1, g = step(flat)
    assert float(l1) < float(l0)


def test_preset_param_counts():
    """The named presets span the documented scale range."""
    tiny = M.num_params(M.PRESETS["tiny"])
    small = M.num_params(M.PRESETS["small"])
    base = M.num_params(M.PRESETS["base"])
    assert tiny < 2e5
    assert 3e6 < small < 6e6
    assert 9e7 < base < 1.3e8, f"base should be ~100M, got {base}"
    # pallas twin shares the layout exactly
    assert M.num_params(M.PRESETS["tiny_pallas"]) == tiny


def test_eval_loss_equals_loss_fn():
    flat = M.init_flat(CFG, jax.random.PRNGKey(9))
    tok, tgt = _batch(CFG, b=2, seed=9)
    assert float(M.eval_loss(flat, tok, tgt, CFG)) == float(M.loss_fn(flat, tok, tgt, CFG))
