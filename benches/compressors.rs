//! Micro-benchmarks: compressor selection + application over large vectors.
//!
//! Perf targets (EXPERIMENTS.md §Perf, L3): GRBS selection must be O(B)
//! (independent of d) and applying a selection O(d/R); the paper's
//! "less computation overhead" claim for GRBS vs top-k is quantified here.

use cser::compressor::{BlockTopK, Compressor, Ctx, Grbs, RandK, Scratch, TopK};
use cser::util::bench::{black_box, Bench};
use cser::util::rng::Rng;

fn main() {
    let d = 1 << 22; // 4M params, WRN-scale order of magnitude
    let mut rng = Rng::new(1);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 1.0);
    let ctx = Ctx { round: 7, worker: 0 };
    let mut b = Bench::new();

    let grbs = Grbs::new(256.0, d / 1024, 3);
    let topk = TopK::new(256.0);
    let randk = RandK::new(256.0);

    let mut round = 0u64;
    b.run("grbs_select_d4M_R256", || {
        round += 1;
        black_box(grbs.select(Ctx { round, worker: 0 }, &v));
    });
    b.run("randk_select_d4M_R256", || {
        round += 1;
        black_box(randk.select(Ctx { round, worker: 0 }, &v));
    });
    b.run("topk_select_d4M_R256", || {
        black_box(topk.select(ctx, &v));
    });

    // Scratch-reuse delta: the same selections through a persistent Scratch
    // (the engine's steady-state path) — no fresh `0..d` index vector /
    // draw-pool / block-mass allocation per call.
    let mut scratch = Scratch::new();
    b.run("topk_select_scratch", || {
        black_box(topk.select_with(ctx, &v, &mut scratch));
    });
    b.run("randk_select_scratch", || {
        round += 1;
        black_box(randk.select_with(Ctx { round, worker: 0 }, &v, &mut scratch));
    });
    let btk = BlockTopK::new(256.0, d / 1024);
    b.run("blocktopk_select", || {
        black_box(btk.select(ctx, &v));
    });
    b.run("blocktopk_select_scratch", || {
        black_box(btk.select_with(ctx, &v, &mut scratch));
    });

    let sel = grbs.select(ctx, &v);
    let mut kept = vec![0.0f32; d];
    b.run("grbs_apply_d4M_R256", || {
        sel.apply(&v, &mut kept);
        black_box(kept[0]);
    });

    let sel_dense = Grbs::new(2.0, d / 1024, 3).select(ctx, &v);
    b.run("grbs_apply_d4M_R2", || {
        sel_dense.apply(&v, &mut kept);
        black_box(kept[0]);
    });

    // headline ratio: GRBS selection vs top-k selection cost
    let g = b.results.iter().find(|r| r.name.starts_with("grbs_select")).unwrap().median_ns;
    let t = b.results.iter().find(|r| r.name.starts_with("topk_select")).unwrap().median_ns;
    println!("\ntopk/grbs selection cost ratio: {:.0}x (paper: GRBS has 'less computation overhead')", t / g);

    // scratch-reuse delta (the ISSUE-4 satellite): fresh-allocation select
    // vs the persistent-Scratch path
    let ts = b.results.iter().find(|r| r.name == "topk_select_scratch").unwrap().median_ns;
    println!("topk select scratch reuse: {:.2}x faster than per-call allocation", t / ts);
}
