//! Bench target regenerating paper Table 4 (Appendix D: extended table with
//! CSEA and CSER-PL and small ratios R_C ∈ {2..1024}).
//!
//! `cargo bench --bench table4_full` — pass `-- --quick` for a smoke run.

use cser::config::Suite;
use cser::harness::sweep::SweepCfg;
use cser::harness::tables;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let suite = Suite::cifar();
    let cfg = SweepCfg {
        seeds: if quick { 1 } else { 2 },
        quick,
        threads: cser::util::pool::default_threads(),
    };
    let t0 = std::time::Instant::now();
    let t = tables::run_table(&suite, &tables::TABLE4_FAMILIES, &tables::TABLE4_RATIOS, &cfg);
    println!("\n=== Table 4 (extended, CIFAR-100 substitute) ===");
    println!("{}", t.render(&tables::TABLE4_FAMILIES, &tables::TABLE4_RATIOS));
    println!("{}", t.shape_report());
    println!("elapsed {:.1}s", t0.elapsed().as_secs_f64());
    let _ = t.write("bench_table4_cifar");
}
