//! Bench target regenerating paper Table 2 (CIFAR-100 substitute).
//!
//! `cargo bench --bench table2_cifar` prints the full table (SGD, EF-SGD,
//! QSparse-local-SGD, CSER at R_C ∈ {16..1024}, Table 3 configs, 3 seeds)
//! and the shape verdict.  Pass `-- --quick` for a reduced smoke run.

use cser::config::Suite;
use cser::harness::sweep::SweepCfg;
use cser::harness::tables;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let suite = Suite::cifar();
    let cfg = SweepCfg {
        seeds: if quick { 1 } else { 3 },
        quick,
        threads: cser::util::pool::default_threads(),
    };
    let t0 = std::time::Instant::now();
    let t = tables::run_table(&suite, &tables::TABLE2_FAMILIES, &tables::TABLE2_RATIOS, &cfg);
    println!("\n=== Table 2 (CIFAR-100 substitute) ===");
    println!("{}", t.render(&tables::TABLE2_FAMILIES, &tables::TABLE2_RATIOS));
    println!("{}", t.shape_report());
    println!("elapsed {:.1}s", t0.elapsed().as_secs_f64());
    let _ = t.write("bench_table2_cifar");
}
