//! Bench target regenerating the time/communication figures:
//! Figures 4/8 (test-acc vs simulated training time) and 5/9 (test-acc vs
//! communicated bits), plus the §5.3 headline time-to-accuracy speedups
//! (~10x CIFAR-100, ~4.5x ImageNet).
//!
//! Defaults to reduced runs (fig_curves is the full regenerator of the
//! same cells); pass `-- --full` for full-length runs.

use cser::config::Suite;
use cser::harness::{curves, timecomm};

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    for suite in [Suite::cifar(), Suite::imagenet()] {
        for rc in curves::FIGURE_RATIOS {
            let set = curves::curves_at(&suite, rc, quick, None);
            println!("{}", timecomm::render_timecomm(&set));
            let sp = timecomm::speedups(&set, 0.98);
            println!("{}", timecomm::render_speedups(&sp, suite.paper_speedup));
            let _ = set.write();
        }
    }
}
