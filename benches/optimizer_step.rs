//! Micro-benchmarks: one full optimizer step per algorithm at WRN-scale d.
//!
//! This is the L3 hot path the paper's wall-clock claims depend on: with the
//! gradient given, the optimizer step must be bandwidth-bound elementwise
//! work (O(n·d)) plus the O(n·d/R) sync — never more.  Divergence between
//! CSER and CSER implementation II here quantifies the memory-traffic cost
//! of the e_i bookkeeping (Appendix A.4).

use cser::config::OptSpec;
use cser::engine::ErrorResetEngine;
use cser::optimizer::DistOptimizer;
use cser::util::bench::{black_box, Bench};
use cser::util::rng::Rng;

fn main() {
    let d = 1 << 20; // 1M params per step benchmark
    let n = 8;
    let mut rng = Rng::new(3);
    let init = vec![0.0f32; d];
    let grads: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();

    let mut b = Bench::new();
    for (name, spec) in [
        ("sgd", OptSpec::Sgd),
        ("ef_sgd_R256", OptSpec::EfSgd { rc1: 256.0 }),
        ("qsparse_R256", OptSpec::Qsparse { rc1: 128.0, h: 2 }),
        ("cser_R256", OptSpec::Cser { rc1: 16.0, rc2: 512.0, h: 32 }),
        ("cser2_R256", OptSpec::Cser2 { rc1: 16.0, rc2: 512.0, h: 32 }),
        ("cser_pl_R256", OptSpec::CserPl { rc1: 16.0, h: 16 }),
        ("csea_R256", OptSpec::Csea { rc1: 256.0 }),
    ] {
        let mut opt = spec.build(&init, n, 0.9, 7);
        b.run(&format!("step_{name}_n8_d1M"), || {
            black_box(opt.step(&grads, 0.01));
        });
    }

    // Worker-resident mode vs the central loop at the same work: both
    // variants run an 8-step burst per timed iteration (resident mode pays
    // one thread spawn/join per `run_resident` call, so bursts amortize it
    // the way the trainer's per-epoch calls do), with the same per-worker
    // gradient oracle on both sides.  Central still computes the gradients
    // serially before each step — that central-loop serialization is part of
    // what the worker-resident mode removes, and thus part of the measured
    // difference.
    let d_res = 1 << 18;
    let burst = 8;
    let spec = OptSpec::Cser { rc1: 16.0, rc2: 512.0, h: 32 };
    let grad = cser::engine::as_grad(|w, _x, out| {
        let mut rng = Rng::new(w as u64 + 1);
        rng.fill_normal(out, 1.0);
        0.0
    });
    let init = vec![0.0f32; d_res];
    let mut central = spec.build(&init, n, 0.9, 7);
    let mut grads_res: Vec<Vec<f32>> = vec![vec![0.0f32; d_res]; n];
    b.run("central_cser_R256_n8_d256k_x8", || {
        for _ in 0..burst {
            for (w, g) in grads_res.iter_mut().enumerate() {
                grad(w, central.worker_model(w), g.as_mut_slice());
            }
            black_box(central.step(&grads_res, 0.01));
        }
    });
    let mut resident = ErrorResetEngine::new(&init, n, 0.9, spec.plan(d_res, 7));
    b.run("resident_cser_R256_n8_d256k_x8", || {
        black_box(resident.run_resident(burst, 0.01, f64::INFINITY, &grad));
    });

    // per-element cost summary
    println!();
    for r in &b.results {
        let per = r.median_ns / (n as f64 * d as f64);
        println!("{:<28} {:.3} ns per worker-element", r.name, per);
    }

    // Batched-vs-reference MLP gradient and single-worker train-step deltas,
    // plus the machine-readable perf record — the same measurement suite
    // `cser bench` runs (schema documented in harness::perf / DESIGN.md).
    println!();
    let report = cser::harness::perf::run(false);
    cser::harness::perf::write_json(&report, "BENCH_engine.json")
        .expect("writing BENCH_engine.json");
    println!("\nperf record -> BENCH_engine.json");
    for e in report.entries.iter().filter(|e| e.speedup_vs_reference > 1.0) {
        println!("  {:<26} {:.2}x vs per-sample reference", e.name, e.speedup_vs_reference);
    }
}
