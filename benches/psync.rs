//! Micro-benchmarks: PSync rounds (the communication-path hot spot).
//!
//! Perf target (EXPERIMENTS.md §Perf, L3): the GRBS fast path must scale
//! with the *selected* volume O(n·d/R), not O(n·d); at R = 256 a PSync
//! round over 8 workers × 4M params should sit well under a millisecond.

use cser::collective::psync;
use cser::compressor::{Grbs, RandK};
use cser::util::bench::{black_box, Bench};
use cser::util::rng::Rng;

fn main() {
    let d = 1 << 22;
    let n = 8;
    let mut rng = Rng::new(2);
    let base: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();

    let mut b = Bench::new();
    let mut round = 0u64;

    for r in [16.0, 256.0, 1024.0] {
        let c = Grbs::new(r, d / 1024, 5);
        let mut vs = base.clone();
        b.run(&format!("psync_grbs_n8_d4M_R{r}"), || {
            round += 1;
            black_box(psync(&mut vs, None, &c, round));
        });
    }

    // generic (per-worker support) path for contrast
    let c = RandK::new(1024.0);
    let mut vs = base.clone();
    b.run("psync_randk_n8_d4M_R1024", || {
        round += 1;
        black_box(psync(&mut vs, None, &c, round));
    });

    // residual-tracking variant used by CSER implementation I
    let c = Grbs::new(256.0, d / 1024, 5);
    let mut vs = base.clone();
    let mut res: Vec<Vec<f32>> = vec![vec![0.0; d]; n];
    b.run("psync_grbs_with_residuals_R256", || {
        round += 1;
        black_box(psync(&mut vs, Some(&mut res), &c, round));
    });
}
