//! Transport-backend benchmark: in-process reference vs the threaded wire
//! layer (real serialized collectives), plus the serialization-accounting
//! cross-check.
//!
//! What to look for:
//! * the in-process path is the zero-copy upper bound; the threaded ring
//!   pays thread spawn + encode/decode, which amortizes as d/R grows;
//! * GRBS (ring) vs top-k (parameter server) shows the paper's systems
//!   argument as wall-clock, not just accounted bits;
//! * the final section asserts measured serialized traffic equals the
//!   α-β cost model's formulas exactly — the wire layer moves precisely the
//!   bits every figure has been charging.

use cser::collective::ring_allreduce_cost;
use cser::compressor::{payload_bits, Compressor, Ctx, Grbs, TopK};
use cser::transport::{wire, Backend, Collective};
use cser::util::bench::{black_box, Bench};
use cser::util::rng::Rng;

fn worker_vecs(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect()
}

fn main() {
    let d = 1 << 20;
    let n = 8;
    let base = worker_vecs(n, d, 2);
    let mut b = Bench::new();
    let mut round = 0u64;

    for r in [16.0, 256.0] {
        let c = Grbs::new(r, d / 1024, 5);
        for backend in [Backend::InProcess, Backend::Threaded] {
            let coll = backend.collective();
            let mut vs = base.clone();
            b.run(&format!("psync_grbs_R{r}_n8_d1M_{:?}", backend), || {
                round += 1;
                black_box(coll.psync(&mut vs, None, &c, round));
            });
        }
    }

    // Index-carrying compressor: the parameter-server path is the only
    // option — this is the ring-vs-PS contrast the paper argues for GRBS.
    let c = TopK::new(256.0);
    for backend in [Backend::InProcess, Backend::Threaded] {
        let coll = backend.collective();
        let mut vs = base.clone();
        b.run(&format!("psync_topk_R256_n8_d1M_{:?}", backend), || {
            round += 1;
            black_box(coll.psync(&mut vs, None, &c, round));
        });
    }

    // ---- serialized bytes == accounted bits ----
    // Ring (GRBS, chunk-aligned): measured per-worker traffic must equal the
    // ring-allreduce formula exactly.
    let c = Grbs::new(16.0, d / 1024, 5);
    let mut vs = base.clone();
    let info = Backend::Threaded.collective().psync(&mut vs, None, &c, 77);
    let sel = info.selections[0].clone();
    let m = sel.count(d) as u64;
    assert_eq!(info.upload_bits_per_worker, payload_bits(&sel, d));
    let wire_cost = info.wire.expect("threaded backend measures traffic");
    let expect = ring_allreduce_cost(m * 32, n);
    assert_eq!(
        (wire_cost.up_bits, wire_cost.down_bits, wire_cost.steps),
        (expect.up_bits, expect.down_bits, expect.steps),
        "ring serialized traffic != cost-model formula"
    );
    println!(
        "ring check: m={m} selected values, {} bits/worker serialized == formula ✓",
        wire_cost.total_bits()
    );

    // Parameter server (top-k): the upload is exactly the accounted
    // index+value payload; the download is the measured union aggregate.
    let c = TopK::new(256.0);
    let mut vs = base.clone();
    let info = Backend::Threaded.collective().psync(&mut vs, None, &c, 78);
    let ctx = Ctx { round: 78, worker: 0 };
    let accounted = payload_bits(&c.select(ctx, &base[0]), d);
    let wire_cost = info.wire.expect("threaded backend measures traffic");
    assert_eq!(wire_cost.up_bits, accounted, "PS upload != accounted payload bits");
    assert_eq!(info.upload_bits_per_worker, accounted);
    println!(
        "ps check: upload {} bits == payload_bits ✓; union download {} bits ({}x payload)",
        wire_cost.up_bits,
        wire_cost.down_bits,
        wire_cost.down_bits as f64 / accounted as f64
    );

    // Codec throughput: encode+decode one GRBS message at R=16.
    let c = Grbs::new(16.0, d / 1024, 5);
    let ctx = Ctx { round: 9, worker: 0 };
    let mut out = vec![0.0f32; d];
    b.run("wire_encode_decode_grbs_R16_d1M", || {
        let msg = wire::encode(&c, ctx, &base[0]);
        wire::decode(&c, ctx, &msg, &mut out);
        black_box(&out);
    });
}
