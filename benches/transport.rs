//! Transport-backend benchmark: in-process reference vs the persistent
//! threaded pool vs real loopback TCP, plus the serialization-accounting
//! cross-checks.
//!
//! What to look for:
//! * the in-process path is the zero-copy upper bound;
//! * `Threaded_persistent` vs `Threaded_fresh_pool` is the before/after of
//!   retiring the per-call thread spawns: the fresh-pool variant rebuilds
//!   (and joins) the worker fleet every round, which is what every
//!   collective used to pay — the persistent pool amortizes it away;
//! * GRBS (ring) vs top-k (parameter server) shows the paper's systems
//!   argument as wall-clock, not just accounted bits;
//! * `TcpLoopback` is the same peer-owned protocol over real sockets:
//!   8 OS threads, 8 TCP connections each, kernel round trips per ring
//!   step — the α-β cost model's α made audible;
//! * the assertion sections check measured serialized traffic equals the
//!   α-β cost model's formulas exactly, on the threaded pool **and** on
//!   the TCP path — the wires move precisely the bits every figure has
//!   been charging.

use cser::collective::ring_allreduce_cost;
use cser::compressor::{payload_bits, Compressor, Ctx, Grbs, TopK};
use cser::transport::rendezvous::free_loopback_addr;
use cser::transport::{peer, wire, Backend, Collective, TcpTransport, Threaded};
use cser::util::bench::{black_box, Bench};
use cser::util::rng::Rng;
use std::sync::mpsc::channel;
use std::sync::Arc;

fn worker_vecs(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect()
}

fn main() {
    let d = 1 << 20;
    let n = 8;
    let base = worker_vecs(n, d, 2);
    let mut b = Bench::new();
    let mut round = 0u64;

    for r in [16.0, 256.0] {
        let c: Arc<dyn Compressor> = Arc::new(Grbs::new(r, d / 1024, 5));
        {
            let coll = Backend::InProcess.collective();
            let mut vs = base.clone();
            b.run(&format!("psync_grbs_R{r}_n8_d1M_InProcess"), || {
                round += 1;
                black_box(coll.psync(&mut vs, None, &c, round));
            });
        }
        {
            // one pool, built on the first round, reused for every other
            let coll = Threaded::new();
            let mut vs = base.clone();
            b.run(&format!("psync_grbs_R{r}_n8_d1M_Threaded_persistent"), || {
                round += 1;
                black_box(coll.psync(&mut vs, None, &c, round));
            });
        }
        {
            // the retired design's cost: spawn + join the worker fleet per call
            let mut vs = base.clone();
            b.run(&format!("psync_grbs_R{r}_n8_d1M_Threaded_fresh_pool"), || {
                round += 1;
                let coll = Threaded::new();
                black_box(coll.psync(&mut vs, None, &c, round));
            });
        }
    }

    // Index-carrying compressor: the parameter-server path is the only
    // option — this is the ring-vs-PS contrast the paper argues for GRBS.
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(256.0));
    for (label, coll) in [
        ("InProcess", Backend::InProcess.collective()),
        ("Threaded_persistent", Arc::new(Threaded::new()) as Arc<dyn Collective>),
    ] {
        let mut vs = base.clone();
        b.run(&format!("psync_topk_R256_n8_d1M_{label}"), || {
            round += 1;
            black_box(coll.psync(&mut vs, None, &c, round));
        });
    }

    // ---- loopback TCP: the same peer-owned protocol over real sockets ----
    // 8 worker threads stand in for 8 processes (same code path either
    // way); the first round doubles as the accounting assertion.
    {
        let addr = free_loopback_addr().expect("loopback port");
        let (done_tx, done_rx) = channel::<(u64, u64, u64)>();
        let mut go_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for rank in 0..n {
            let (go_tx, go_rx) = channel::<u64>();
            go_txs.push(go_tx);
            let addr = addr.clone();
            let mut v = base[rank].clone();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                let c = Grbs::new(16.0, d / 1024, 5);
                let mut tp = TcpTransport::connect(&addr, rank, n).expect("tcp join");
                while let Ok(round) = go_rx.recv() {
                    if round == u64::MAX {
                        break;
                    }
                    let info = peer::psync(&mut tp, &mut v, None, &c, round).expect("tcp psync");
                    let wc = info.wire.expect("tcp measures traffic");
                    done.send((wc.up_bits, wc.down_bits, info.upload_bits_per_worker))
                        .expect("bench collector");
                }
            }));
        }
        round += 1;
        // correctness round: measured socket traffic == ring formula
        for tx in &go_txs {
            tx.send(round).unwrap();
        }
        let c = Grbs::new(16.0, d / 1024, 5);
        let m = c.select(Ctx { round, worker: 0 }, &base[0]).count(d) as u64;
        assert_eq!(m % n as u64, 0, "bench setup: chunks divide evenly");
        let expect = ring_allreduce_cost(m * 32, n);
        for _ in 0..n {
            let (up, down, acct) = done_rx.recv().unwrap();
            assert_eq!((up, down), (expect.up_bits, expect.down_bits), "TCP ring != formula");
            assert_eq!(acct, m * 32, "TCP accounted bits != payload");
        }
        println!("tcp ring check: m={m} values/peer, socket bits == ring formula ✓");
        b.run("psync_grbs_R16_n8_d1M_TcpLoopback", || {
            round += 1;
            for tx in &go_txs {
                tx.send(round).unwrap();
            }
            for _ in 0..n {
                black_box(done_rx.recv().unwrap());
            }
        });
        for tx in &go_txs {
            tx.send(u64::MAX).unwrap();
        }
        for h in handles {
            h.join().expect("tcp bench worker");
        }
    }

    // ---- serialized bytes == accounted bits (threaded pool) ----
    // Ring (GRBS, chunk-aligned): measured per-worker traffic must equal the
    // ring-allreduce formula exactly.
    let c: Arc<dyn Compressor> = Arc::new(Grbs::new(16.0, d / 1024, 5));
    let mut vs = base.clone();
    let info = Threaded::new().psync(&mut vs, None, &c, 77);
    let sel = info.selections[0].clone();
    let m = sel.count(d) as u64;
    assert_eq!(info.upload_bits_per_worker, payload_bits(&sel, d));
    let wire_cost = info.wire.expect("threaded backend measures traffic");
    let expect = ring_allreduce_cost(m * 32, n);
    assert_eq!(
        (wire_cost.up_bits, wire_cost.down_bits, wire_cost.steps),
        (expect.up_bits, expect.down_bits, expect.steps),
        "ring serialized traffic != cost-model formula"
    );
    println!(
        "ring check: m={m} selected values, {} bits/worker serialized == formula ✓",
        wire_cost.total_bits()
    );

    // Parameter server (top-k): the upload is exactly the accounted
    // index+value payload; the download is the measured union aggregate.
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(256.0));
    let mut vs = base.clone();
    let info = Threaded::new().psync(&mut vs, None, &c, 78);
    let ctx = Ctx { round: 78, worker: 0 };
    let accounted = payload_bits(&c.select(ctx, &base[0]), d);
    let wire_cost = info.wire.expect("threaded backend measures traffic");
    assert_eq!(wire_cost.up_bits, accounted, "PS upload != accounted payload bits");
    assert_eq!(info.upload_bits_per_worker, accounted);
    println!(
        "ps check: upload {} bits == payload_bits ✓; union download {} bits ({}x payload)",
        wire_cost.up_bits,
        wire_cost.down_bits,
        wire_cost.down_bits as f64 / accounted as f64
    );

    // Codec throughput: encode+decode one GRBS message at R=16.
    let c = Grbs::new(16.0, d / 1024, 5);
    let ctx = Ctx { round: 9, worker: 0 };
    let mut out = vec![0.0f32; d];
    b.run("wire_encode_decode_grbs_R16_d1M", || {
        let msg = wire::encode(&c, ctx, &base[0]);
        wire::decode(&c, ctx, &msg, &mut out).expect("valid frame");
        black_box(&out);
    });

    // ---- bucketed pipeline over TCP: socket payload bits ≡ accounting ----
    // One pipelined psync round per rank with two buckets in flight; the
    // per-bucket wire costs, summed, must equal the payload bits actually
    // counted at the sockets (the single vectored header+payload write per
    // frame moves exactly the accounted payload).
    {
        use cser::collective::SyncBuckets;
        use cser::transport::{pipelined_sync, BucketPipeline};
        let kb = 8usize;
        let buckets = SyncBuckets::even(d, kb);
        let addr = free_loopback_addr().expect("loopback port");
        let outs: Vec<(u64, u64)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let addr = addr.clone();
                    let buckets = buckets.clone();
                    let v0 = base[rank].clone();
                    s.spawn(move || {
                        let c: Arc<dyn Compressor> = Arc::new(Grbs::new(16.0, d / 1024 / kb, 5));
                        let mut tp = TcpTransport::connect(&addr, rank, n).expect("tcp join");
                        let mut pipe = BucketPipeline::new();
                        let mut v = v0;
                        let info = pipelined_sync(
                            &mut pipe,
                            &mut tp,
                            peer::Mode::Psync,
                            &mut v,
                            None,
                            &c,
                            7,
                            &buckets,
                        )
                        .expect("pipelined tcp psync");
                        let wire_total: u64 = info
                            .parts()
                            .iter()
                            .map(|p| {
                                let w = p.2.wire.expect("tcp measures traffic");
                                w.up_bits + w.down_bits
                            })
                            .sum();
                        (wire_total, tp.payload_bits_sent)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pipelined tcp worker")).collect()
        });
        for (i, (wire_total, sent)) in outs.iter().enumerate() {
            assert_eq!(
                wire_total, sent,
                "worker {i}: pipelined socket payload bits != per-bucket accounting"
            );
        }
        println!("pipelined tcp check: per-bucket wire sums == socket payload bits ✓");
    }
}
