//! Bench target regenerating the per-epoch curve figures:
//! Figures 1/3 (CIFAR test-acc vs epoch), 6 (CIFAR train-loss vs epoch),
//! 2/7 (ImageNet test-acc vs epoch), 10 (ImageNet train-loss vs epoch),
//! at R_C ∈ {32, 256, 1024}, plus (same runs) the time/bits tables and
//! speedups of Figures 4/5/8/9.
//!
//! Protocol note (paper §5.2): ImageNet configurations are NOT re-tuned —
//! the lrs tuned on the (cheap) CIFAR suite are transferred.
//!
//! Full run: `cargo bench --bench fig_curves`; smoke: `-- --quick`;
//! one suite only: `-- --suite cifar`.

use cser::config::{table3_for, Suite};
use cser::harness::{curves, timecomm, tune_lr};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let only: Option<String> = args
        .iter()
        .position(|a| a == "--suite")
        .and_then(|i| args.get(i + 1).cloned());

    let suites: Vec<Suite> = match only.as_deref() {
        Some(s) => vec![Suite::by_name(s).expect("unknown suite")],
        None => vec![Suite::cifar(), Suite::imagenet()],
    };
    let cifar = Suite::cifar();
    for suite in suites {
        for rc in curves::FIGURE_RATIOS {
            let t0 = std::time::Instant::now();
            // transfer lrs from the cheap suite when running the expensive one
            let tuned: Option<Vec<(String, f64)>> = if suite.name == "imagenet" {
                Some(
                    ["EF-SGD", "QSparse", "CSEA", "CSER", "CSER-PL"]
                        .iter()
                        .filter_map(|fam| {
                            table3_for(fam, rc)
                                .map(|spec| (fam.to_string(), tune_lr(&cifar, &spec, true)))
                        })
                        .collect(),
                )
            } else {
                None
            };
            let set = curves::curves_at(&suite, rc, quick, tuned.as_deref());
            println!("{}", set.render());
            // train-loss series (figures 6/10)
            println!("-- train loss by epoch --");
            for r in &set.runs {
                let series: Vec<String> = r
                    .points
                    .iter()
                    .step_by((r.points.len() / 8).max(1))
                    .map(|p| format!("{:.2}", p.train_loss))
                    .collect();
                println!("{:<10} {}", r.optimizer, series.join(" "));
            }
            println!("{}", timecomm::render_timecomm(&set));
            let sp = timecomm::speedups(&set, 0.98);
            println!("{}", timecomm::render_speedups(&sp, suite.paper_speedup));
            println!("[{} rc={rc}] elapsed {:.1}s\n", suite.name, t0.elapsed().as_secs_f64());
            let _ = set.write();
        }
    }
}
