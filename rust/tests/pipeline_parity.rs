//! Pipelined-vs-sequential parity for the bucketed gradient pipeline.
//!
//! The sequential reference is the **central bucketed** engine loop (every
//! collective staged bucket-by-bucket through the in-process backend under
//! the per-bucket sub-rounds).  The pipelined path — worker-resident or
//! multi-rank over real sockets, each worker overlapping bucket k+1's
//! compression with bucket k's exchange on its prepare thread — must
//! reproduce it:
//!
//! * **bit-identically** where every collective rides a parameter-server /
//!   dense-mean route (per-worker compressors, dense SGD);
//! * within the documented f32 reduction-order tolerance where buckets
//!   ride the ring (globally-synchronized sparsifiers);
//! * with **exactly equal accounting** everywhere (bits are
//!   selection-count arithmetic, not f32 sums).
//!
//! All seven plan families × the mesh backend are pinned here plus in the
//! engine's in-module tests; the TCP backend is pinned on a PS plan
//! (bit-exact) and the GRBS CSER plan (ring tolerance), and a killed rank
//! mid-pipelined-round must error peers out instead of wedging them.

use cser::compressor::{Compressor, Grbs, RandK, TopK};
use cser::engine::{CommPlan, ErrorResetEngine, SyncBuckets};
use cser::optimizer::DistOptimizer;
use cser::transport::rendezvous::free_loopback_addr;
use cser::transport::TcpTransport;
use cser::util::prop::slices_close;

type PlanFactory = Box<dyn Fn() -> CommPlan + Send + Sync>;

fn grbs(r: f64, nb: usize, seed: u64) -> Box<dyn Compressor> {
    Box::new(Grbs::new(r, nb, seed))
}

/// (name, exact, factory) — `exact` marks plans whose every collective is a
/// PS/dense route (bit-identical under the pipeline).
fn plan_factories() -> Vec<(&'static str, bool, PlanFactory)> {
    vec![
        ("sgd", true, Box::new(CommPlan::full_sgd) as PlanFactory),
        ("ef-grbs", false, Box::new(|| CommPlan::ef_sgd(grbs(4.0, 6, 3)))),
        ("ef-topk", true, Box::new(|| CommPlan::ef_sgd(Box::new(TopK::new(4.0))))),
        ("local-sgd", false, Box::new(|| CommPlan::local_sgd(2))),
        ("qsparse", false, Box::new(|| CommPlan::qsparse(grbs(2.0, 6, 5), 3))),
        ("cser", false, Box::new(|| CommPlan::cser(grbs(2.0, 6, 7), grbs(4.0, 6, 9), 2))),
        (
            "cser-perworker",
            true,
            Box::new(|| CommPlan::cser(Box::new(RandK::new(4.0)), Box::new(TopK::new(4.0)), 2)),
        ),
        ("csea", false, Box::new(|| CommPlan::csea(grbs(2.0, 6, 11)))),
        ("cser-pl", false, Box::new(|| CommPlan::cser_pl(grbs(2.0, 6, 13), 3))),
        ("cser2", false, Box::new(|| CommPlan::cser_impl2(grbs(2.0, 6, 7), grbs(4.0, 6, 9), 2))),
    ]
}

fn grad_fn(d: usize) -> impl Fn(usize, &[f32], &mut [f32]) -> f32 + Sync {
    move |w: usize, x: &[f32], out: &mut [f32]| -> f32 {
        let mut loss = 0.0f32;
        for (j, (o, xi)) in out.iter_mut().zip(x).enumerate() {
            *o = xi - 1.0 + 0.05 * ((w * 31 + j) % 7) as f32;
            loss += *o * *o;
        }
        loss / d as f32
    }
}

/// Central bucketed reference run: returns (per-worker models, per-step
/// (grad_bits, model_bits)).
fn run_central_bucketed(
    mk: &PlanFactory,
    init: &[f32],
    n: usize,
    steps: usize,
    buckets: &SyncBuckets,
) -> (ErrorResetEngine, Vec<(u64, u64)>) {
    let d = init.len();
    let gf = grad_fn(d);
    let mut eng = ErrorResetEngine::new(init, n, 0.9, mk());
    eng.set_bucketing(Some(buckets.clone()));
    let mut grads = vec![vec![0.0f32; d]; n];
    let mut stats = Vec::with_capacity(steps);
    for _ in 0..steps {
        for w in 0..n {
            gf(w, eng.worker_model(w), &mut grads[w]);
        }
        let s = eng.step(&grads, 0.05);
        stats.push((s.grad_bits, s.model_bits));
    }
    (eng, stats)
}

#[test]
fn pipelined_resident_matches_sequential_bucketed_all_plans() {
    let (n, d, steps) = (4, 31, 6);
    let init: Vec<f32> = (0..d).map(|j| (j as f32 * 0.29).sin()).collect();
    let gf = grad_fn(d);
    // Deliberately uneven, layer-boundary-style bounds.
    let buckets = SyncBuckets::from_bounds(vec![0, 11, 18, 31]);
    for (name, exact, mk) in plan_factories() {
        let (central, central_stats) = run_central_bucketed(&mk, &init, n, steps, &buckets);
        let mut resident = ErrorResetEngine::new(&init, n, 0.9, mk());
        resident.set_bucketing(Some(buckets.clone()));
        let reports = resident.run_resident(steps, 0.05, f64::INFINITY, &gf);
        assert_eq!(reports.len(), steps, "{name}");
        for i in 0..n {
            if exact {
                assert_eq!(
                    central.worker_model(i),
                    resident.worker_model(i),
                    "{name}: worker {i} must be bit-identical (PS/dense routes)"
                );
            } else {
                slices_close(central.worker_model(i), resident.worker_model(i), 1e-4)
                    .unwrap_or_else(|e| panic!("{name}: worker {i}: {e}"));
            }
        }
        for (rep, (gb, mb)) in reports.iter().zip(&central_stats) {
            assert_eq!(rep.stats.grad_bits, *gb, "{name}: grad accounting");
            assert_eq!(rep.stats.model_bits, *mb, "{name}: model accounting");
        }
    }
}

/// Run one engine per rank over real loopback TCP with bucketing enabled.
fn run_tcp_pipelined(
    mk: &PlanFactory,
    init: &[f32],
    n: usize,
    steps: usize,
    buckets: &SyncBuckets,
) -> Vec<Vec<f32>> {
    let addr = free_loopback_addr().expect("loopback port");
    let gf = grad_fn(init.len());
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let addr = addr.clone();
                let buckets = buckets.clone();
                let gf = &gf;
                s.spawn(move || {
                    let mut tp = TcpTransport::connect(&addr, rank, n).expect("tcp join");
                    let mut eng = ErrorResetEngine::new(init, 1, 0.9, mk());
                    eng.set_bucketing(Some(buckets));
                    let reports =
                        eng.run_distributed(&mut tp, steps, 0.05, f64::INFINITY, gf).unwrap();
                    assert_eq!(reports.len(), steps);
                    eng.worker_model(0).to_vec()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
    })
}

#[test]
fn pipelined_tcp_ps_plan_is_bit_identical_to_sequential() {
    // Per-worker compressors: every bucket is a PS round, so 3 ranks over
    // real sockets with two buckets in flight must equal the central
    // sequential bucketed loop bit-for-bit.
    let (n, d, steps) = (3, 26, 5);
    let init: Vec<f32> = (0..d).map(|j| (j as f32 * 0.33).cos()).collect();
    let buckets = SyncBuckets::from_bounds(vec![0, 9, 26]);
    let mk: PlanFactory =
        Box::new(|| CommPlan::cser(Box::new(RandK::new(4.0)), Box::new(TopK::new(4.0)), 2));
    let (central, _) = run_central_bucketed(&mk, &init, n, steps, &buckets);
    let models = run_tcp_pipelined(&mk, &init, n, steps, &buckets);
    for (i, m) in models.iter().enumerate() {
        assert_eq!(central.worker_model(i), m.as_slice(), "rank {i} diverged over TCP");
    }
}

#[test]
fn pipelined_tcp_grbs_ring_within_tolerance() {
    let (n, d, steps) = (3, 24, 5);
    let init: Vec<f32> = (0..d).map(|j| (j as f32 * 0.19).sin()).collect();
    let buckets = SyncBuckets::from_bounds(vec![0, 8, 16, 24]);
    let mk: PlanFactory = Box::new(|| CommPlan::cser(grbs(2.0, 4, 7), grbs(2.0, 4, 9), 2));
    let (central, _) = run_central_bucketed(&mk, &init, n, steps, &buckets);
    let models = run_tcp_pipelined(&mk, &init, n, steps, &buckets);
    for (i, m) in models.iter().enumerate() {
        slices_close(central.worker_model(i), m, 1e-4)
            .unwrap_or_else(|e| panic!("rank {i}: {e}"));
    }
}
