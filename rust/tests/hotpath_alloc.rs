//! Steady-state allocation accounting for the hot paths.
//!
//! A counting global allocator wraps `System`; after a warmup call that
//! grows every arena/scratch to its steady-state shape, the hot paths are
//! measured directly:
//!
//! * `Mlp::loss_grad_scratch` — **zero** allocations per call (the seed
//!   implementation copied `w2` and allocated three scratch vectors per
//!   minibatch);
//! * `TopK::select_with` through a reused `Scratch` — allocates only the
//!   k-element result, never the `0..d` index permutation;
//! * a central CSER engine step — allocates no dense (O(d)) buffer per
//!   step: what remains is selection results and per-round bookkeeping,
//!   bounded far below one model-sized vector;
//! * the same engine step with phase tracing ENABLED — the recorder's
//!   rings are preallocated at registration, so the per-step allocation
//!   bound must hold unchanged with spans recording;
//! * the same engine step with the metrics registry ENABLED — counters,
//!   gauges, and the step histogram are static atomic arrays, so enabled
//!   recording adds **zero** allocations, and a disabled registry costs
//!   one relaxed atomic load per site (structurally pinned: every record
//!   fn early-returns on `enabled()`).
//!
//! One `#[test]` only: the counters are process-global, so concurrent tests
//! would pollute each other's windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct Counting;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: Counting = Counting;

/// (allocation count, bytes requested) during `f`.
fn alloc_during<R>(f: impl FnOnce() -> R) -> (u64, u64) {
    let (a0, b0) = (ALLOCS.load(Ordering::SeqCst), BYTES.load(Ordering::SeqCst));
    let r = f();
    std::hint::black_box(r);
    (ALLOCS.load(Ordering::SeqCst) - a0, BYTES.load(Ordering::SeqCst) - b0)
}

#[test]
fn steady_state_hot_paths_do_not_allocate() {
    use cser::compressor::{Compressor, Ctx, Scratch, TopK};
    use cser::config::OptSpec;
    use cser::data::ClassDataset;
    use cser::models::{GradModel, Mlp, ModelScratch};
    use cser::optimizer::DistOptimizer;
    use cser::util::rng::Rng;

    // ---- batched MLP gradient: zero steady-state allocations ----
    let (train, _) = ClassDataset::gaussian_mixture(8, 24, 512, 32, 1.2, 0.8, 0.0, 3);
    let model = Mlp::new(24, 32, 8);
    let params = model.init(1);
    let mut grad = vec![0.0f32; model.dim()];
    let mut scratch = ModelScratch::new();
    let mut rng = Rng::new(7);
    // single-chunk (batch < 64) and serial multi-chunk (batch > 64) shapes
    for batch in [48usize, 150] {
        let idxs: Vec<u32> = (0..batch).map(|_| rng.below(train.len()) as u32).collect();
        // warmup: grows the arena to this batch shape
        for _ in 0..2 {
            model.loss_grad_scratch(&params, &train, &idxs, &mut grad, &mut scratch);
        }
        let (allocs, bytes) = alloc_during(|| {
            for _ in 0..10 {
                model.loss_grad_scratch(&params, &train, &idxs, &mut grad, &mut scratch);
            }
        });
        assert_eq!(
            allocs, 0,
            "loss_grad_scratch (batch {batch}): {allocs} allocations / {bytes} bytes in 10 \
             steady-state calls"
        );
    }

    // ---- top-k selection through a reused scratch: only the k-result ----
    let d = 1 << 16;
    let mut v = vec![0.0f32; d];
    Rng::new(9).fill_normal(&mut v, 1.0);
    let topk = TopK::new(256.0); // k = 256
    let mut sel_scratch = Scratch::new();
    let ctx = Ctx { round: 5, worker: 0 };
    let _ = topk.select_with(ctx, &v, &mut sel_scratch); // warmup: grows iota
    let (_, bytes_scratch) = alloc_during(|| topk.select_with(ctx, &v, &mut sel_scratch));
    let (_, bytes_fresh) = alloc_during(|| topk.select(ctx, &v));
    // fresh path rebuilds the 0..d permutation (>= 4·d bytes); the scratch
    // path allocates only the sorted k-element result
    assert!(bytes_fresh >= (d * 4) as u64, "fresh select allocated only {bytes_fresh} bytes");
    assert!(
        bytes_scratch < 8 * 1024,
        "scratch select allocated {bytes_scratch} bytes (expected ~k·4 = 1 KiB)"
    );

    // ---- central engine step: no dense per-step buffers ----
    let d = 1 << 15;
    let n = 4;
    let init = vec![0.0f32; d];
    let spec = OptSpec::Cser { rc1: 8.0, rc2: 64.0, h: 4 };
    let mut opt = spec.build(&init, n, 0.9, 7);
    let mut grads: Vec<Vec<f32>> = vec![vec![0.0f32; d]; n];
    let mut grng = Rng::new(4);
    for g in &mut grads {
        grng.fill_normal(g, 1.0);
    }
    for _ in 0..8 {
        opt.step(&grads, 0.01); // warmup: thread scratch, engine buffers
    }
    let steps = 8; // two full H-cycles: sync and non-sync steps both counted
    let (_, bytes) = alloc_during(|| {
        for _ in 0..steps {
            opt.step(&grads, 0.01);
        }
    });
    let per_step = bytes / steps;
    assert!(
        per_step < (d as u64) * 4 / 8,
        "engine step allocates {per_step} bytes/step — a dense O(d) buffer ({} bytes) is \
         being rebuilt per step",
        d * 4
    );

    // ---- the same steps with tracing enabled: the recorder must add no
    //      steady-state allocations (rings preallocate at registration) ----
    cser::obs::set_enabled(true);
    cser::obs::register_thread("alloc-test");
    for _ in 0..8 {
        opt.step(&grads, 0.01); // warmup: lazily registers any helper-thread rings
    }
    let (_, bytes_traced) = alloc_during(|| {
        for _ in 0..steps {
            opt.step(&grads, 0.01);
        }
    });
    cser::obs::set_enabled(false);
    cser::obs::reset();
    let per_step_traced = bytes_traced / steps;
    assert!(
        per_step_traced < (d as u64) * 4 / 8,
        "traced engine step allocates {per_step_traced} bytes/step (untraced: {per_step}) — \
         span recording must be allocation-free in steady state"
    );

    // ---- metrics registry enabled: recording is allocation-free ----
    // The registry is static atomic arrays; enabling it must not change the
    // engine step's allocation bound, and recording into every site kind
    // (counter, gauge, histogram, peer-lane sync) must allocate nothing.
    use cser::obs::metrics::{self, Counter, Gauge};
    metrics::reset();
    metrics::set_enabled(true);
    for _ in 0..8 {
        opt.step(&grads, 0.01); // warmup under the instrumented step path
    }
    let (_, bytes_metered) = alloc_during(|| {
        for _ in 0..steps {
            opt.step(&grads, 0.01);
        }
    });
    let per_step_metered = bytes_metered / steps;
    assert!(
        per_step_metered < (d as u64) * 4 / 8,
        "metered engine step allocates {per_step_metered} bytes/step (bare: {per_step}) — \
         metrics instrumentation must be allocation-free in steady state"
    );
    let peers = metrics::peer_counters(); // allocated once here, reused below
    let (allocs_direct, bytes_direct) = alloc_during(|| {
        for i in 0..1000u64 {
            metrics::inc(Counter::StepsTotal, 1);
            metrics::gauge_set(Gauge::GradNorm, i as f64);
            metrics::observe_step_ns(i * 997);
            metrics::sync_from_peers(&peers);
        }
    });
    assert_eq!(
        allocs_direct, 0,
        "enabled metric recording made {allocs_direct} allocations / {bytes_direct} bytes \
         in 4000 record calls"
    );
    metrics::set_enabled(false);
    // Disabled registry: the same sites are a relaxed load + early return.
    let (allocs_off, _) = alloc_during(|| {
        for i in 0..1000u64 {
            metrics::inc(Counter::StepsTotal, 1);
            metrics::gauge_set(Gauge::GradNorm, i as f64);
            metrics::observe_step_ns(i * 997);
        }
    });
    assert_eq!(allocs_off, 0, "disabled metric sites must not allocate");
    metrics::reset();
}
