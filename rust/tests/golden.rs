//! Cross-language golden test: the Rust CSER implementation must reproduce,
//! step for step, the numpy M-CSER simulator (python/compile/golden.py).
//!
//! The golden uses an explicit block-mask schedule instead of a shared RNG,
//! so the comparison pins the *algebra* (momentum, PSync, error reset) and
//! not incidental generator details.

use cser::compressor::{Compressor, Ctx, Scratch, Selection};
use cser::optimizer::{Cser, DistOptimizer};
use cser::util::json::Json;

/// Compressor whose selection comes from an explicit per-round mask table.
struct Scheduled {
    block: usize,
    nb: usize,
    /// masks[t][b] for 1-based round t.
    masks: Vec<Vec<f32>>,
}

impl Compressor for Scheduled {
    fn select_with(&self, ctx: Ctx, _v: &[f32], _s: &mut Scratch) -> Selection {
        let m = &self.masks[ctx.round as usize];
        let blocks: Vec<u32> =
            (0..self.nb as u32).filter(|&b| m[b as usize] > 0.5).collect();
        Selection::Blocks { block_size: self.block, blocks }
    }
    fn ratio(&self) -> f64 {
        2.0
    }
    fn globally_synchronized(&self) -> bool {
        true
    }
    fn name(&self) -> String {
        "scheduled".into()
    }
}

fn floats(j: &Json, key: &str) -> Vec<f32> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing {key}"))
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn rust_cser_matches_numpy_golden() {
    let Ok(text) = std::fs::read_to_string("artifacts/golden_cser.json") else {
        eprintln!("skipping: golden not built (make artifacts)");
        return;
    };
    let j = Json::parse(&text).unwrap();
    let d = j.get("d").unwrap().as_usize().unwrap();
    let n = j.get("n").unwrap().as_usize().unwrap();
    let h = j.get("h").unwrap().as_usize().unwrap() as u64;
    let steps = j.get("steps").unwrap().as_usize().unwrap();
    let block = j.get("block").unwrap().as_usize().unwrap();
    let beta = j.get("beta").unwrap().as_f64().unwrap() as f32;
    let eta = j.get("eta").unwrap().as_f64().unwrap() as f32;
    let nb = d / block;
    let init = floats(&j, "init");
    let grads_flat = floats(&j, "grads");
    let mask1_flat = floats(&j, "mask1");
    let mask2_flat = floats(&j, "mask2");
    let x_final = floats(&j, "x_final");
    let x_mid = floats(&j, "x_mid");
    let mid_step = j.get("mid_step").unwrap().as_usize().unwrap();

    let to_masks = |flat: &[f32]| -> Vec<Vec<f32>> {
        flat.chunks(nb).map(|c| c.to_vec()).collect()
    };
    let c1 = Scheduled { block, nb, masks: to_masks(&mask1_flat) };
    let c2 = Scheduled { block, nb, masks: to_masks(&mask2_flat) };
    let mut opt = Cser::new(&init, n, beta, Box::new(c1), Box::new(c2), h);

    for t in 1..=steps {
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|w| {
                let off = ((t - 1) * n + w) * d;
                grads_flat[off..off + d].to_vec()
            })
            .collect();
        opt.step(&grads, eta);
        if t == mid_step {
            for w in 0..n {
                for (jx, (a, b)) in
                    opt.worker_model(w).iter().zip(&x_mid[w * d..(w + 1) * d]).enumerate()
                {
                    assert!(
                        (a - b).abs() < 2e-5 * (1.0 + b.abs()),
                        "mid step {t} worker {w} coord {jx}: rust={a} numpy={b}"
                    );
                }
            }
        }
    }
    for w in 0..n {
        for (jx, (a, b)) in
            opt.worker_model(w).iter().zip(&x_final[w * d..(w + 1) * d]).enumerate()
        {
            assert!(
                (a - b).abs() < 5e-5 * (1.0 + b.abs()),
                "final worker {w} coord {jx}: rust={a} numpy={b}"
            );
        }
    }
}
