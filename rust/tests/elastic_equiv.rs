//! Elastic-membership equivalence: partial participation over real
//! loopback TCP must keep training live, keep the survivors bit-identical
//! to each other, and keep the wire accounting exact (DESIGN.md §8).
//!
//! Each "process" is a thread running the exact `cser worker` code path —
//! `train_classifier` with `Backend::Tcp` and `cfg.elastic`/`cfg.chaos`/
//! `cfg.join` set, a single-worker engine, the rank-0 rendezvous-v2
//! session — so everything but the PID boundary is exercised (that
//! boundary is the CI `elastic-smoke` launch job).
//!
//! Contracts pinned here (the acceptance criteria for the control plane):
//!
//! * **A killed rank censors, then evicts**: a 4-rank fleet losing rank 3
//!   mid-training finishes with valid, mutually identical survivor
//!   records, and the survivors' wire counters account *exactly* the bits
//!   the dead rank sent before dying — nothing invented, nothing lost.
//! * **Censoring cadence**: Li et al.'s transmit-when-it-matters rule over
//!   elastic TCP is bit-identical to the central in-process trainer,
//!   strictly cheaper than the dense-cadence reference, and keeps the
//!   star-topology wire perfectly balanced.
//! * **Grant blob = checkpoint v2**: the byte blob a join grant carries
//!   resumes an engine bit-exactly, and a corrupted blob is rejected.
//! * **Evicted rank rejoins a later epoch**: a chaos-killed rank re-enters
//!   through rendezvous v2, resumes at the granted epoch boundary, and
//!   from there reproduces the survivors' curves exactly.
//! * **Ring re-form**: a ring-routed GRBS fleet losing a rank mid-cycle
//!   stalls, falls back, evicts at the boundary, re-forms the ring over
//!   the survivors — and the per-link counters balance exactly across
//!   every surviving pair, stale drains and fallback included.
//! * **Elastic bucketing**: `--elastic --buckets k` (formerly rejected) is
//!   bit-identical to the central bucketed trainer — the same reference
//!   the whole-vector elastic path is pinned to.
//! * **Batch admission**: two joiners parked at the rendezvous are granted
//!   under a *single* epoch frame, and both reproduce the survivors'
//!   curves on the overlap.
//! * **Leader failover** (DESIGN.md §10): under `--failover`, killing rank
//!   0 mid-run — on the PS route and on the ring route — hands every
//!   leader role to rank 1 at the next boundary.  The survivors stay
//!   mutually bit-identical, record exactly one `LeaderChange`
//!   (`0 → 1`, generation 1) each, and their per-link wire counters
//!   balance exactly across the handover.

use cser::compressor::{Grbs, RandK, TopK};
use cser::coordinator::checkpoint::Checkpoint;
use cser::coordinator::sim_trainer::{train_classifier, ChaosSpec, TrainCfg};
use cser::coordinator::{ElasticSummary, EpochEvent, RunRecord};
use cser::data::ClassDataset;
use cser::engine::{Cadence, CommPlan, ErrorResetEngine};
use cser::membership::LeaderChange;
use cser::models::{GradModel, Mlp};
use cser::optimizer::DistOptimizer;
use cser::transport::rendezvous::free_loopback_addr;
use cser::transport::Backend;

fn workload() -> (ClassDataset, ClassDataset, Mlp) {
    let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 1024, 256, 1.2, 0.8, 0.0, 7);
    (tr, te, Mlp::new(16, 32, 10))
}

fn quick_cfg(epochs: usize) -> TrainCfg {
    let mut c = TrainCfg::new(epochs, 16, 0.1, 7);
    c.schedule = cser::config::LrSchedule::StepDecay { milestones: vec![0.5], factor: 0.2 };
    c.paper_d = 1_000_000;
    c.threads = 4;
    c
}

/// The parameter-server-routed CSER plan used throughout: per-worker
/// compressors, so every collective is a star round through rank 0 —
/// the shape censoring (and the whole elastic path) requires.
fn ps_plan() -> CommPlan {
    CommPlan::cser(Box::new(RandK::new(4.0)), Box::new(TopK::new(4.0)), 2)
}

/// The ring-routed CSER plan: both compressors are globally-synchronized
/// GRBS (shared support from a shared seed), so every sync round attempts
/// the bandwidth-optimal ring schedule instead of the rank-0 star.  874 is
/// the `Mlp::new(16, 32, 10)` parameter count; ~32-float blocks keep the
/// block draw meaningful at that size.
fn ring_plan() -> CommPlan {
    CommPlan::cser(
        Box::new(Grbs::with_block_len(4.0, 874, 32, 5)),
        Box::new(Grbs::with_block_len(4.0, 874, 32, 9)),
        2,
    )
}

/// Plan builders shared by the central and per-rank runs (`n` differs).
type MkOpt = dyn Fn(&[f32], usize) -> Box<dyn DistOptimizer> + Sync;

fn run_central(mk: &MkOpt, n: usize, cfg: &TrainCfg) -> RunRecord {
    let (tr, te, model) = workload();
    let init = model.init(cfg.seed);
    let mut opt = mk(&init, n);
    train_classifier(&model, &tr, &te, opt.as_mut(), cfg)
}

/// One thread per rank over a fresh loopback rendezvous.  A rank whose
/// chaos plan kills it panics by design, so each outcome is a `Result`:
/// `Err` marks the planned death, `Ok` carries the survivor's record.
fn run_elastic(mk: &MkOpt, n: usize, cfg: &TrainCfg) -> Vec<Result<RunRecord, ()>> {
    let addr = free_loopback_addr().expect("loopback port");
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let addr = addr.clone();
                let mut cfg = cfg.clone();
                s.spawn(move || {
                    let (tr, te, model) = workload();
                    let init = model.init(cfg.seed);
                    cfg.backend = Backend::Tcp { bind: addr, peers: n, rank };
                    let mut opt = mk(&init, 1);
                    train_classifier(&model, &tr, &te, opt.as_mut(), &cfg)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().map_err(|_| ())).collect()
    })
}

fn summary(rec: &RunRecord) -> &ElasticSummary {
    rec.elastic.as_ref().expect("elastic run must carry an ElasticSummary")
}

/// Bit-exact comparison of two epoch curves (f64 payloads compared by
/// representation, so a NaN sneaking in fails instead of vacuously passing).
fn assert_points_eq(a: &RunRecord, b: &RunRecord, what: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{what}: point counts differ");
    for (x, y) in a.points.iter().zip(&b.points) {
        assert_eq!(x.epoch, y.epoch, "{what}: epoch ids differ");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what}: epoch {}", x.epoch);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{what}: epoch {}", x.epoch);
        assert_eq!(x.cum_bits.to_bits(), y.cum_bits.to_bits(), "{what}: epoch {}", x.epoch);
        assert_eq!(x.cum_seconds.to_bits(), y.cum_seconds.to_bits(), "{what}: epoch {}", x.epoch);
    }
}

#[test]
fn elastic_fleet_survives_a_killed_rank_and_accounts_every_bit() {
    // Rank 3 dies at its very first gradient call.  Its only traffic is the
    // start-epoch agreement: one 64-bit value frame up, one 1-bit verdict
    // down.  The survivors censor it for the round, evict it at the first
    // epoch boundary, and finish the full schedule — and because every
    // collective is a star through rank 0, the wire counters must balance
    // *exactly*: what rank 0 received is what ranks 1..3 sent (the dead
    // rank's 64 bits included), what it sent is what they received.
    let n = 4;
    let mut cfg = quick_cfg(3);
    cfg.chaos = Some(ChaosSpec::parse("kill:3@0").expect("chaos spec"));
    let mk: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ps_plan())));

    let outcomes = run_elastic(&mk, n, &cfg);
    assert!(outcomes[3].is_err(), "rank 3 was chaos-killed and must have panicked");
    let recs: Vec<&RunRecord> = outcomes[..3]
        .iter()
        .enumerate()
        .map(|(r, o)| o.as_ref().unwrap_or_else(|_| panic!("survivor rank {r} panicked")))
        .collect();

    for (r, rec) in recs.iter().enumerate() {
        assert!(!rec.diverged, "survivor rank {r} diverged");
        assert_eq!(rec.points.len(), 3, "survivor rank {r} must finish all epochs");
        let s = summary(rec);
        assert_eq!(s.live_mask, 0b0111, "rank {r}: rank 3 must be out of the final view");
        assert_eq!(s.final_epoch, 1, "rank {r}: exactly one view change");
        assert_eq!(s.evictions, 1, "rank {r}: exactly one eviction");
        assert_eq!(s.joins, 0, "rank {r}: nobody rejoined");
        assert_points_eq(rec, recs[0], "survivors must agree");
    }
    let acc = recs[0].points.last().unwrap().test_acc;
    assert!(acc > 0.35, "survivors should keep converging (acc {acc})");

    // Only rank 0 talks to rank 3 in a star, so only rank 0 censors.
    let (s0, s1, s2) = (summary(recs[0]), summary(recs[1]), summary(recs[2]));
    assert!(s0.censor_events >= 1, "rank 0 must have censored the dead rank");
    assert_eq!(s1.censor_events, 0, "rank 1 never talks to rank 3");
    assert_eq!(s2.censor_events, 0, "rank 2 never talks to rank 3");

    // Exact accounting under the partial round: the dead rank sent its
    // 64-bit start-epoch value and received the 1-bit verdict, nothing else.
    assert_eq!(
        s0.payload_bits_received,
        s1.payload_bits_sent + s2.payload_bits_sent + 64,
        "rank 0 must account exactly the survivors' uploads plus the dead rank's 64-bit flag"
    );
    assert_eq!(
        s0.payload_bits_sent,
        s1.payload_bits_received + s2.payload_bits_received + 1,
        "rank 0 must account exactly the survivors' downloads plus the dead rank's 1-bit verdict"
    );
}

#[test]
fn censored_cadence_matches_central_and_undercuts_the_dense_reference() {
    // τ(t) = 64·0.5^t: the first handful of steps censor every worker
    // (updates at lr 0.1 are nowhere near norm 64), then the threshold
    // decays below the update norms and the run goes effectively dense.
    // Contracts: the elastic TCP run is bit-identical to the central
    // in-process trainer (every loss, accuracy, bit); it accounts strictly
    // fewer bits than the Always-cadence reference; its final accuracy is
    // within the documented 0.2 band of dense; and the happy-path star
    // stays perfectly balanced with zero censor events.
    let n = 4;
    let cfg = quick_cfg(3);
    let mk_censored: Box<MkOpt> = Box::new(|init, n| {
        let plan = ps_plan().with_cadence(Cadence::Censored { tau0: 64.0, gamma: 0.5 });
        Box::new(ErrorResetEngine::new(init, n, 0.9, plan))
    });
    let mk_dense: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ps_plan())));

    let central = run_central(&mk_censored, n, &cfg);
    assert!(!central.diverged);
    let dense = run_central(&mk_dense, n, &cfg);
    assert!(!dense.diverged);

    let mut ecfg = cfg.clone();
    ecfg.elastic = true;
    let outcomes = run_elastic(&mk_censored, n, &ecfg);
    let recs: Vec<&RunRecord> = outcomes
        .iter()
        .enumerate()
        .map(|(r, o)| o.as_ref().unwrap_or_else(|_| panic!("rank {r} panicked")))
        .collect();

    for (r, rec) in recs.iter().enumerate() {
        assert!(!rec.diverged, "rank {r} diverged");
        assert_points_eq(rec, &central, "elastic TCP vs central censored trainer");
        let s = summary(rec);
        assert_eq!(s.live_mask, 0b1111, "rank {r}: full fleet stays live");
        assert_eq!(s.final_epoch, 0, "rank {r}: no view change on the happy path");
        assert_eq!((s.evictions, s.joins), (0, 0), "rank {r}");
        assert_eq!(s.censor_events, 0, "rank {r}: cadence skips are not transport censoring");
    }

    // Strictly cheaper than dense (the early censored steps transmit
    // nothing), within the documented accuracy band.
    let cens_bits = central.points.last().unwrap().cum_bits;
    let dense_bits = dense.points.last().unwrap().cum_bits;
    assert!(
        cens_bits < dense_bits,
        "censoring must drop bits: {cens_bits} vs dense {dense_bits}"
    );
    let cens_acc = central.points.last().unwrap().test_acc;
    let dense_acc = dense.points.last().unwrap().test_acc;
    assert!(cens_acc > 0.35, "censored run should still learn (acc {cens_acc})");
    assert!(
        (cens_acc - dense_acc).abs() < 0.2,
        "censored acc {cens_acc} strayed from dense {dense_acc}"
    );

    // No deaths, no deadline misses: the star balances to the bit.
    let s0 = summary(recs[0]);
    let up: u64 = recs[1..].iter().map(|r| summary(r).payload_bits_sent).sum();
    let down: u64 = recs[1..].iter().map(|r| summary(r).payload_bits_received).sum();
    assert_eq!(s0.payload_bits_received, up, "rank 0 received exactly what 1..n sent");
    assert_eq!(s0.payload_bits_sent, down, "rank 0 sent exactly what 1..n received");
}

#[test]
fn grant_checkpoint_blob_resumes_bit_exactly() {
    // The join grant ships `Checkpoint::capture_engine(..).to_bytes()` as
    // an opaque blob.  Round-tripping it through `from_bytes` and
    // `restore_engine` must reproduce the engine bit-for-bit — models,
    // errors, and the continued trajectory — and a corrupted blob must be
    // rejected up front (checksum first), not half-applied.
    let (n, d) = (3usize, 24usize);
    let init: Vec<f32> = (0..d).map(|j| (j as f32 * 0.29).sin() * 0.3).collect();
    let grads_at = |o: &ErrorResetEngine| -> Vec<Vec<f32>> {
        (0..n)
            .map(|w| {
                o.worker_model(w)
                    .iter()
                    .enumerate()
                    .map(|(j, x)| x - 1.0 + 0.04 * ((w * 29 + 5 * j) % 13) as f32)
                    .collect()
            })
            .collect()
    };
    let mut full = ErrorResetEngine::new(&init, n, 0.9, ps_plan());
    for _ in 0..7 {
        let gs = grads_at(&full);
        full.step(&gs, 0.05);
    }

    let blob = Checkpoint::capture_engine(&full).to_bytes();
    let back = Checkpoint::from_bytes(&blob).expect("grant blob must parse");
    let mut resumed = ErrorResetEngine::new(&init, n, 0.9, ps_plan());
    back.restore_engine(&mut resumed).expect("grant blob must restore");
    assert_eq!(resumed.step_count(), 7, "resume at the granted step");
    for w in 0..n {
        assert_eq!(full.worker_model(w), resumed.worker_model(w), "worker {w} model at restore");
        assert_eq!(full.local_error(w), resumed.local_error(w), "worker {w} error at restore");
    }
    for _ in 0..5 {
        let gs = grads_at(&full);
        full.step(&gs, 0.05);
        let gs = grads_at(&resumed);
        resumed.step(&gs, 0.05);
    }
    for w in 0..n {
        assert_eq!(full.worker_model(w), resumed.worker_model(w), "worker {w} model diverged");
        assert_eq!(full.local_error(w), resumed.local_error(w), "worker {w} error diverged");
    }

    let mut bad = blob.clone();
    bad[blob.len() / 2] ^= 1;
    assert!(Checkpoint::from_bytes(&bad).is_err(), "a corrupted grant blob must be rejected");
}

#[test]
fn evicted_rank_rejoins_a_later_epoch_and_tracks_the_survivors() {
    // Rank 2 is chaos-killed early in epoch 1 (21 iters/epoch at this
    // workload; gradient call 23 is epoch 1's third step), evicted at that
    // epoch's boundary, then restarted with `cfg.join`: it parks a CSER-JN2
    // request at the rendezvous, rank 0 grants it at a short-handed
    // boundary with the checkpoint blob, and the joiner finishes the
    // schedule in lockstep — its per-epoch losses and accuracies must equal
    // the survivors' bit-for-bit on the overlap (fleet-level aggregates are
    // rank-independent), and every final view must be whole again.
    let n = 3;
    let epochs = 8;
    let addr = free_loopback_addr().expect("loopback port");
    let mk: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ps_plan())));
    let mut cfg = quick_cfg(epochs);
    cfg.chaos = Some(ChaosSpec::parse("kill:2@23").expect("chaos spec"));

    fn run_rank(rank: usize, n: usize, mut cfg: TrainCfg, addr: String, mk: &MkOpt) -> RunRecord {
        let (tr, te, model) = workload();
        let init = model.init(cfg.seed);
        cfg.backend = Backend::Tcp { bind: addr, peers: n, rank };
        let mut opt = mk(&init, 1);
        train_classifier(&model, &tr, &te, opt.as_mut(), &cfg)
    }

    let (rec0, rec1, recj) = std::thread::scope(|s| {
        let h0 = {
            let (cfg, addr, mk) = (cfg.clone(), addr.clone(), &mk);
            s.spawn(move || run_rank(0, n, cfg, addr, mk))
        };
        let h1 = {
            let (cfg, addr, mk) = (cfg.clone(), addr.clone(), &mk);
            s.spawn(move || run_rank(1, n, cfg, addr, mk))
        };
        let h2 = {
            let (cfg, addr, mk) = (cfg.clone(), addr.clone(), &mk);
            s.spawn(move || run_rank(2, n, cfg, addr, mk))
        };
        assert!(h2.join().is_err(), "rank 2 was chaos-killed and must have panicked");
        // The rank is dead and (once the survivors hit the boundary)
        // evicted; restart it as a joiner.  `rejoin` parks at the
        // rendezvous until a boundary grants it.
        let hj = {
            let mut jcfg = quick_cfg(epochs);
            jcfg.join = true;
            let (addr, mk) = (addr.clone(), &mk);
            s.spawn(move || run_rank(2, n, jcfg, addr, mk))
        };
        let rec0 = h0.join().expect("rank 0 panicked");
        let rec1 = h1.join().expect("rank 1 panicked");
        let recj = hj.join().expect("joiner panicked");
        (rec0, rec1, recj)
    });

    for (name, rec) in [("rank 0", &rec0), ("rank 1", &rec1), ("joiner", &recj)] {
        assert!(!rec.diverged, "{name} diverged");
        let s = summary(rec);
        assert_eq!(s.live_mask, 0b111, "{name}: the final view must be whole again");
        assert!(s.joins >= 1, "{name}: the admission must be on record");
    }
    assert_eq!(rec0.points.len(), epochs, "rank 0 must run the full schedule");
    assert!(
        rec0.points.last().unwrap().test_acc > 0.35,
        "survivors should keep converging through the churn"
    );

    let (s0, s1, sj) = (summary(&rec0), summary(&rec1), summary(&recj));
    assert_eq!(s0.evictions, 1, "rank 0 observed the one eviction");
    assert_eq!(s1.evictions, 1, "rank 1 observed the one eviction");
    assert_eq!(sj.evictions, 0, "the joiner entered after the eviction");
    assert_eq!(s0.final_epoch, s1.final_epoch, "survivors must agree on the final view");
    assert_eq!(s0.final_epoch, sj.final_epoch, "the joiner must land on the survivors' view");
    assert!(s0.final_epoch >= 1, "the eviction (and rejoin) must have advanced the epoch");

    // The joiner resumes at a granted epoch boundary strictly after the
    // death, and from there its fleet-level curve is the survivors' curve.
    assert!(!recj.points.is_empty(), "the joiner must train at least one epoch");
    let first = recj.points[0].epoch;
    assert!(
        (2..=6).contains(&first),
        "joiner resumed at epoch {first}, expected a boundary shortly after the kill"
    );
    assert_eq!(recj.points.last().unwrap().epoch, epochs - 1, "joiner finishes the schedule");
    for p in &recj.points {
        let q = &rec0.points[p.epoch];
        assert_eq!(
            p.train_loss.to_bits(),
            q.train_loss.to_bits(),
            "epoch {}: joiner loss differs from rank 0",
            p.epoch
        );
        assert_eq!(
            p.test_acc.to_bits(),
            q.test_acc.to_bits(),
            "epoch {}: joiner accuracy differs from rank 0",
            p.epoch
        );
    }
}

#[test]
fn ring_routed_fleet_survives_a_kill_and_reforms_the_ring() {
    // Rank 3 dies at gradient call 20 — mid-epoch-1, mid-ring.  The cut
    // cycle stalls every survivor at that round; they redo it over the
    // parameter-server fallback (censored, rescaled), run out the epoch
    // degraded, evict rank 3 at the step-32 boundary, and re-form a
    // three-rank ring for the rest of the schedule.  Survivor records must
    // agree bit-for-bit, and the per-link counters must balance exactly
    // across every surviving pair — through the stalled attempt, the
    // fallback, and the re-formed ring.
    let n = 4;
    let mut cfg = quick_cfg(3);
    cfg.round_deadline_ms = 300;
    cfg.chaos = Some(ChaosSpec::parse("kill:3@20").expect("chaos spec"));
    let mk: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ring_plan())));

    let outcomes = run_elastic(&mk, n, &cfg);
    assert!(outcomes[3].is_err(), "rank 3 was chaos-killed and must have panicked");
    let recs: Vec<&RunRecord> = outcomes[..3]
        .iter()
        .enumerate()
        .map(|(r, o)| o.as_ref().unwrap_or_else(|_| panic!("survivor rank {r} panicked")))
        .collect();

    for (r, rec) in recs.iter().enumerate() {
        assert!(!rec.diverged, "survivor rank {r} diverged");
        assert_eq!(rec.points.len(), 3, "survivor rank {r} must finish all epochs");
        let s = summary(rec);
        assert_eq!(s.live_mask, 0b0111, "rank {r}: rank 3 must be out of the final view");
        assert_eq!(s.final_epoch, 1, "rank {r}: exactly one view change");
        assert_eq!((s.evictions, s.joins), (1, 0), "rank {r}");
        assert_eq!(
            s.events,
            vec![EpochEvent { epoch: 1, step: 32, evicted: 0b1000, joined: 0 }],
            "rank {r}: the eviction must be the only membership event"
        );
        assert_points_eq(rec, recs[0], "ring survivors must agree");
    }
    let acc = recs[0].points.last().unwrap().test_acc;
    assert!(acc > 0.35, "survivors should keep converging (acc {acc})");

    // Somebody observed the death — the cut ring edge or a fallback
    // deadline; which rank depends on where the cycle broke.
    let censors: u64 = recs.iter().map(|r| summary(r).censor_events).sum();
    assert!(censors >= 1, "the death must be on the censor record");

    // Per-link ground truth: across every surviving pair the wire balances
    // to the bit — chunks of the old 4-ring and the re-formed 3-ring, the
    // aborted attempt's stale drains, the PS fallback, and the control
    // frames all included.  (Links touching the dead rank are not
    // cross-checkable: it left no record.)
    for (a, ra) in recs.iter().enumerate() {
        let sa = summary(ra);
        assert_eq!(sa.links.len(), n, "rank {a}: one counter slot per physical rank");
        for (b, rb) in recs.iter().enumerate() {
            if a == b {
                continue;
            }
            let sb = summary(rb);
            assert_eq!(
                sa.links[b].payload_bits_sent, sb.links[a].payload_bits_received,
                "link {a}->{b}: sent and received bits disagree"
            );
        }
    }
    // The ring actually ran: in a star, ranks 1 and 2 never speak.
    assert!(
        summary(recs[1]).links[2].payload_bits_sent > 0,
        "ring neighbors must have exchanged chunks"
    );
}

#[test]
fn bucketed_elastic_pipeline_matches_the_central_bucketed_reference() {
    // `--elastic --buckets k` used to be rejected; the bucket pipeline is
    // now view-aware.  Bucketing changes the compressor schedule
    // (per-bucket selections), so the pinned parity is against the
    // *central bucketed* trainer — the same reference the whole-vector
    // elastic path is pinned to, sliced the same way: every loss,
    // accuracy, and accounted bit identical, the star perfectly balanced,
    // zero membership churn.
    let n = 4;
    let mut cfg = quick_cfg(3);
    cfg.buckets = 4;
    let mk: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ps_plan())));

    let central = run_central(&mk, n, &cfg);
    assert!(!central.diverged);

    let mut ecfg = cfg.clone();
    ecfg.elastic = true;
    let outcomes = run_elastic(&mk, n, &ecfg);
    let recs: Vec<&RunRecord> = outcomes
        .iter()
        .enumerate()
        .map(|(r, o)| o.as_ref().unwrap_or_else(|_| panic!("rank {r} panicked")))
        .collect();

    for (r, rec) in recs.iter().enumerate() {
        assert!(!rec.diverged, "rank {r} diverged");
        assert_points_eq(rec, &central, "bucketed elastic TCP vs central bucketed trainer");
        let s = summary(rec);
        assert_eq!(s.live_mask, 0b1111, "rank {r}: full fleet stays live");
        assert_eq!(s.final_epoch, 0, "rank {r}: no view change on the happy path");
        assert_eq!((s.evictions, s.joins, s.censor_events), (0, 0, 0), "rank {r}");
        assert!(s.events.is_empty(), "rank {r}: quiet boundaries leave no events");
    }

    // Star balance, link by link: every byte flows through rank 0.
    let s0 = summary(recs[0]);
    for r in 1..n {
        let sr = summary(recs[r]);
        assert_eq!(
            s0.links[r].payload_bits_sent, sr.links[0].payload_bits_received,
            "link 0->{r}: sent and received bits disagree"
        );
        assert_eq!(
            s0.links[r].payload_bits_received, sr.links[0].payload_bits_sent,
            "link {r}->0: sent and received bits disagree"
        );
        for other in 1..n {
            if other != r {
                assert_eq!(
                    sr.links[other].payload_bits_sent, 0,
                    "rank {r} must not talk to rank {other} in a star"
                );
            }
        }
    }
}

/// Shared assertions for the two leader-kill tests below: rank 0 died as
/// planned, the survivors finished the schedule mutually bit-identical
/// over the surviving view, every survivor recorded the same lone
/// eviction and the same lone `LeaderChange` (`0 → 1`, generation 1), and
/// the surviving links balance to the bit across the handover.
fn assert_leader_handover(outcomes: &[Result<RunRecord, ()>], epochs: usize, what: &str) {
    let n = outcomes.len();
    assert!(outcomes[0].is_err(), "{what}: rank 0 was chaos-killed and must have panicked");
    let recs: Vec<(usize, &RunRecord)> = outcomes
        .iter()
        .enumerate()
        .skip(1)
        .map(|(r, o)| {
            let rec = o.as_ref().unwrap_or_else(|_| panic!("{what}: survivor rank {r} panicked"));
            (r, rec)
        })
        .collect();

    for &(r, rec) in &recs {
        assert!(!rec.diverged, "{what}: survivor rank {r} diverged");
        assert_eq!(rec.points.len(), epochs, "{what}: survivor rank {r} must finish all epochs");
        let s = summary(rec);
        assert_eq!(s.live_mask, 0b1110, "{what}: rank {r}: rank 0 must be out of the final view");
        assert_eq!(s.final_epoch, 1, "{what}: rank {r}: exactly one view change");
        assert_eq!((s.evictions, s.joins), (1, 0), "{what}: rank {r}");
        assert_eq!(
            s.events,
            vec![EpochEvent { epoch: 1, step: 32, evicted: 0b0001, joined: 0 }],
            "{what}: rank {r}: the leader's eviction must be the only membership event"
        );
        assert_eq!(
            s.leader_changes,
            vec![LeaderChange { step: 32, from: 0, to: 1, generation: 1 }],
            "{what}: rank {r}: exactly one handover, to the lowest live non-zero rank"
        );
        assert_points_eq(rec, recs[0].1, "{what}: survivors must agree across the handover");
    }
    let acc = recs[0].1.points.last().unwrap().test_acc;
    assert!(acc > 0.35, "{what}: survivors should keep converging (acc {acc})");

    // Per-link ground truth among the survivors: the interrupted round's
    // redo, the stale drains, and the post-handover star/ring all balance
    // to the bit.  (Links touching dead rank 0 left no record to check.)
    for &(a, ra) in &recs {
        let sa = summary(ra);
        assert_eq!(sa.links.len(), n, "{what}: rank {a}: one counter slot per physical rank");
        for &(b, rb) in &recs {
            if a == b {
                continue;
            }
            let sb = summary(rb);
            assert_eq!(
                sa.links[b].payload_bits_sent, sb.links[a].payload_bits_received,
                "{what}: link {a}->{b}: sent and received bits disagree"
            );
        }
    }
}

#[test]
fn leader_kill_hands_over_on_the_ps_route() {
    // Rank 0 — the rendezvous host, epoch broadcaster, and PS aggregation
    // root — dies at gradient call 20, mid-epoch-1.  The survivors' star
    // rounds error with `PeerDown(0)`, `--failover` absorbs the death and
    // redoes the interrupted round with rank 1 as PS server, the step-32
    // boundary evicts rank 0 and bumps the leader generation, and rank 1
    // carries the fleet to the end of the schedule.
    let n = 4;
    let epochs = 3;
    let mut cfg = quick_cfg(epochs);
    cfg.failover = true;
    cfg.chaos = Some(ChaosSpec::parse_with("kill:0@20", true).expect("chaos spec"));
    let mk: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ps_plan())));

    let outcomes = run_elastic(&mk, n, &cfg);
    assert_leader_handover(&outcomes, epochs, "ps route");
}

#[test]
fn leader_kill_hands_over_on_the_ring_route() {
    // The same death under ring-routed GRBS: the cut cycle stalls the
    // survivors mid-round, the PS fallback at the same round discovers the
    // leader is the casualty and retries rooted at rank 1, the epoch runs
    // out degraded, and the step-32 boundary evicts rank 0, bumps the
    // generation, and re-forms a three-rank ring under the new leader.
    let n = 4;
    let epochs = 3;
    let mut cfg = quick_cfg(epochs);
    cfg.round_deadline_ms = 300;
    cfg.failover = true;
    cfg.chaos = Some(ChaosSpec::parse_with("kill:0@20", true).expect("chaos spec"));
    let mk: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ring_plan())));

    let outcomes = run_elastic(&mk, n, &cfg);
    assert_leader_handover(&outcomes, epochs, "ring route");

    // The re-formed ring actually ran under the new leader: in a star
    // rooted at rank 1, ranks 2 and 3 never speak to each other.
    let recs: Vec<&RunRecord> = outcomes[1..].iter().map(|o| o.as_ref().unwrap()).collect();
    assert!(
        summary(recs[1]).links[3].payload_bits_sent > 0,
        "ring neighbors 2 and 3 must have exchanged chunks after the handover"
    );
}

#[test]
fn two_joiners_are_admitted_at_one_boundary_and_track_the_survivors() {
    // Ranks 2 and 3 die at the same step; their restarts park at the
    // rendezvous while the survivors finish the epoch.  The step-32
    // boundary evicts both (evict and admit stay disjoint per transition);
    // the next short-handed boundary sweeps the parked queue and admits
    // *both* under a single epoch frame, in rank order.  Every rank must
    // report the same lone batch-admission event, and both joiners' curves
    // must equal the survivors' bit-for-bit on the overlap.
    let n = 4;
    let epochs = 8;
    let addr = free_loopback_addr().expect("loopback port");
    let mk: Box<MkOpt> =
        Box::new(|init, n| Box::new(ErrorResetEngine::new(init, n, 0.9, ps_plan())));
    let mut cfg = quick_cfg(epochs);
    // Same-step deaths; rank 1 is slowed so the survivors' march to the
    // admission boundary leaves the restarts a wide parking margin.
    cfg.chaos = Some(ChaosSpec::parse("kill:2@17,kill:3@17,slow:1:10").expect("chaos spec"));

    fn run_rank(rank: usize, n: usize, mut cfg: TrainCfg, addr: String, mk: &MkOpt) -> RunRecord {
        let (tr, te, model) = workload();
        let init = model.init(cfg.seed);
        cfg.backend = Backend::Tcp { bind: addr, peers: n, rank };
        let mut opt = mk(&init, 1);
        train_classifier(&model, &tr, &te, opt.as_mut(), &cfg)
    }

    let (rec0, rec1, recj2, recj3) = std::thread::scope(|s| {
        let h0 = {
            let (cfg, addr, mk) = (cfg.clone(), addr.clone(), &mk);
            s.spawn(move || run_rank(0, n, cfg, addr, mk))
        };
        let h1 = {
            let (cfg, addr, mk) = (cfg.clone(), addr.clone(), &mk);
            s.spawn(move || run_rank(1, n, cfg, addr, mk))
        };
        let h2 = {
            let (cfg, addr, mk) = (cfg.clone(), addr.clone(), &mk);
            s.spawn(move || run_rank(2, n, cfg, addr, mk))
        };
        let h3 = {
            let (cfg, addr, mk) = (cfg.clone(), addr.clone(), &mk);
            s.spawn(move || run_rank(3, n, cfg, addr, mk))
        };
        assert!(h2.join().is_err(), "rank 2 was chaos-killed and must have panicked");
        assert!(h3.join().is_err(), "rank 3 was chaos-killed and must have panicked");
        // Both deaths observed: restart both ranks as joiners.  They park
        // together and must be granted together.
        let hj2 = {
            let mut jcfg = quick_cfg(epochs);
            jcfg.join = true;
            let (addr, mk) = (addr.clone(), &mk);
            s.spawn(move || run_rank(2, n, jcfg, addr, mk))
        };
        let hj3 = {
            let mut jcfg = quick_cfg(epochs);
            jcfg.join = true;
            let (addr, mk) = (addr.clone(), &mk);
            s.spawn(move || run_rank(3, n, jcfg, addr, mk))
        };
        (
            h0.join().expect("rank 0 panicked"),
            h1.join().expect("rank 1 panicked"),
            hj2.join().expect("joiner 2 panicked"),
            hj3.join().expect("joiner 3 panicked"),
        )
    });

    for (name, rec) in
        [("rank 0", &rec0), ("rank 1", &rec1), ("joiner 2", &recj2), ("joiner 3", &recj3)]
    {
        assert!(!rec.diverged, "{name} diverged");
        let s = summary(rec);
        assert_eq!(s.live_mask, 0b1111, "{name}: the final view must be whole again");
        assert_eq!(s.joins, 2, "{name}: both admissions must be on record");
        // The batch admission: exactly one event carries a joiner mask,
        // and it names both ranks under one epoch.
        let admissions: Vec<&EpochEvent> = s.events.iter().filter(|e| e.joined != 0).collect();
        assert_eq!(admissions.len(), 1, "{name}: admissions must not split across boundaries");
        assert_eq!(admissions[0].joined, 0b1100, "{name}: one frame admits both ranks");
        assert_eq!(admissions[0].evicted, 0, "{name}: evict and admit stay disjoint");
    }

    let (s0, s1, sj2, sj3) = (summary(&rec0), summary(&rec1), summary(&recj2), summary(&recj3));
    assert_eq!(s0.evictions, 2, "rank 0 observed both evictions");
    assert_eq!(s1.evictions, 2, "rank 1 observed both evictions");
    assert_eq!((sj2.evictions, sj3.evictions), (0, 0), "joiners entered after the evictions");
    assert_eq!(s0.final_epoch, s1.final_epoch, "survivors must agree on the final view");
    assert_eq!(s0.final_epoch, sj2.final_epoch, "joiner 2 must land on the survivors' view");
    assert_eq!(s0.final_epoch, sj3.final_epoch, "joiner 3 must land on the survivors' view");
    assert!(s0.final_epoch >= 2, "one evicting transition, then one admitting transition");

    assert_eq!(rec0.points.len(), epochs, "rank 0 must run the full schedule");
    for (name, recj) in [("joiner 2", &recj2), ("joiner 3", &recj3)] {
        assert!(!recj.points.is_empty(), "{name} must train at least one epoch");
        let first = recj.points[0].epoch;
        assert!(
            (2..=6).contains(&first),
            "{name} resumed at epoch {first}, expected a boundary shortly after the kills"
        );
        assert_eq!(recj.points.last().unwrap().epoch, epochs - 1, "{name} finishes the schedule");
        for p in &recj.points {
            let q = &rec0.points[p.epoch];
            assert_eq!(
                p.train_loss.to_bits(),
                q.train_loss.to_bits(),
                "{name}: epoch {} loss differs from rank 0",
                p.epoch
            );
            assert_eq!(
                p.test_acc.to_bits(),
                q.test_acc.to_bits(),
                "{name}: epoch {} accuracy differs from rank 0",
                p.epoch
            );
        }
    }
}
