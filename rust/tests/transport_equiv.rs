//! Transport-backend equivalence: the threaded wire layer must reproduce the
//! in-process reference end-to-end through the optimizers.
//!
//! * Parameter-server-path compressors (per-worker supports, dense
//!   quantizers) are **bit-identical**: messages decode to the exact
//!   `C(q_i)` bits and the server accumulates in worker order.
//! * Ring-path compressors (GRBS) agree up to f32 reduction-order error;
//!   the trajectory tolerance below (1e-4 relative per coordinate on a
//!   quadratic workload) is the documented bound.
//! * CSER's Lemma 1 (`x_i − e_i` identical across workers) must hold under
//!   the threaded backend exactly as it does in process.

use cser::compressor::{Compressor, Grbs, Qsgd, RandK, SignSgd, TopK};
use cser::optimizer::{Cser, DistOptimizer};
use cser::transport::Backend;
use cser::util::prop::slices_close;
use cser::util::rng::Rng;

/// Run CSER on the quadratic f(x) = ½‖x − c‖² with per-worker gradient
/// noise; returns every worker's final model.
fn quadratic_trajectory(
    backend: Backend,
    c1: Box<dyn Compressor>,
    c2: Box<dyn Compressor>,
    h: u64,
    steps: usize,
) -> Vec<Vec<f32>> {
    let d = 96;
    let n = 4;
    let target = vec![1.0f32; d];
    let mut opt = Cser::new(&vec![0.0; d], n, 0.9, c1, c2, h);
    opt.set_collective(backend.collective());
    let mut rng = Rng::new(0xE0);
    let mut noise = vec![0.0f32; d];
    for _ in 0..steps {
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                rng.fill_normal(&mut noise, 0.05);
                opt.worker_model(i)
                    .iter()
                    .zip(&target)
                    .zip(&noise)
                    .map(|((x, t), z)| x - t + z)
                    .collect()
            })
            .collect();
        opt.step(&grads, 0.05);
    }
    (0..n).map(|i| opt.worker_model(i).to_vec()).collect()
}

#[test]
fn ring_path_matches_in_process_within_reduction_tolerance() {
    let mk = || {
        (
            Box::new(Grbs::new(2.0, 12, 7)) as Box<dyn Compressor>,
            Box::new(Grbs::new(4.0, 12, 11)) as Box<dyn Compressor>,
        )
    };
    let (c1, c2) = mk();
    let a = quadratic_trajectory(Backend::InProcess, c1, c2, 3, 60);
    let (c1, c2) = mk();
    let b = quadratic_trajectory(Backend::Threaded, c1, c2, 3, 60);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        slices_close(x, y, 1e-4).unwrap_or_else(|e| panic!("worker {i}: {e}"));
    }
}

#[test]
fn ps_path_matches_in_process_bit_for_bit() {
    for (name, mk) in [
        (
            "topk/randk",
            (|| {
                (
                    Box::new(TopK::new(4.0)) as Box<dyn Compressor>,
                    Box::new(RandK::new(8.0)) as Box<dyn Compressor>,
                )
            }) as fn() -> (Box<dyn Compressor>, Box<dyn Compressor>),
        ),
        ("signsgd/qsgd", || {
            (
                Box::new(SignSgd) as Box<dyn Compressor>,
                Box::new(Qsgd::new(4)) as Box<dyn Compressor>,
            )
        }),
    ] {
        let (c1, c2) = mk();
        let a = quadratic_trajectory(Backend::InProcess, c1, c2, 3, 40);
        let (c1, c2) = mk();
        let b = quadratic_trajectory(Backend::Threaded, c1, c2, 3, 40);
        assert_eq!(a, b, "{name}: PS path must be bit-identical");
    }
}

#[test]
fn lemma1_holds_under_threaded_backend() {
    // x_{i,t} − e_{i,t} identical across workers with real wire collectives,
    // mixed ring (C2 = GRBS) and PS (C1 = TopK) paths in the same optimizer.
    let d = 64;
    let n = 4;
    let mut opt = Cser::new(
        &vec![0.1; d],
        n,
        0.9,
        Box::new(TopK::new(4.0)),
        Box::new(Grbs::new(4.0, 8, 5)),
        2,
    );
    opt.set_collective(Backend::Threaded.collective());
    let mut rng = Rng::new(3);
    let mut g = vec![0.0f32; d];
    for _ in 0..9 {
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                rng.fill_normal(&mut g, 1.0);
                g.clone()
            })
            .collect();
        opt.step(&grads, 0.05);
        let base: Vec<f32> = opt
            .worker_model(0)
            .iter()
            .zip(opt.local_error(0).unwrap())
            .map(|(x, e)| x - e)
            .collect();
        for i in 1..n {
            let xi: Vec<f32> = opt
                .worker_model(i)
                .iter()
                .zip(opt.local_error(i).unwrap())
                .map(|(x, e)| x - e)
                .collect();
            slices_close(&base, &xi, 1e-4).unwrap_or_else(|e| panic!("worker {i}: {e}"));
        }
    }
}

#[test]
fn worker_resident_matches_central_trajectories() {
    // The worker-resident mode drives the peer-owned mesh collectives from
    // persistent worker threads (serialized wire frames, no per-call
    // spawns).  Ring-path compressors (GRBS) must stay within the
    // documented f32 reduction tolerance of the central in-process
    // reference; the protocol itself is the one the rest of this suite
    // pins.
    use cser::engine::{CommPlan, ErrorResetEngine};
    let d = 96;
    let n = 4;
    let steps = 60;
    let target = vec![1.0f32; d];
    let mk = || {
        CommPlan::cser(
            Box::new(Grbs::new(2.0, 12, 7)) as Box<dyn Compressor>,
            Box::new(Grbs::new(4.0, 12, 11)),
            3,
        )
    };
    // deterministic per-worker gradient of ½‖x − 1‖² with a worker bias
    let gf = cser::engine::as_grad(move |w: usize, x: &[f32], out: &mut [f32]| -> f32 {
        for (j, (o, (xi, ti))) in out.iter_mut().zip(x.iter().zip(&target)).enumerate() {
            *o = xi - ti + 0.02 * ((w * 13 + j) % 5) as f32;
        }
        0.0
    });

    let mut central = ErrorResetEngine::new(&vec![0.0; d], n, 0.9, mk());
    let mut grads = vec![vec![0.0f32; d]; n];
    for _ in 0..steps {
        for w in 0..n {
            gf(w, central.worker_model(w), &mut grads[w]);
        }
        central.step(&grads, 0.05);
    }

    let mut res = ErrorResetEngine::new(&vec![0.0; d], n, 0.9, mk());
    let reports = res.run_resident(steps, 0.05, f64::INFINITY, &gf);
    assert_eq!(reports.len(), steps);

    for i in 0..n {
        slices_close(central.worker_model(i), res.worker_model(i), 1e-4)
            .unwrap_or_else(|e| panic!("worker {i}: {e}"));
    }
}

#[test]
fn threaded_psync_mean_preservation_at_scale() {
    // The integration-scale analogue of the in-process test: n = 8 workers,
    // d = 64k, GRBS R = 64 over the threaded ring.
    use cser::transport::{Collective, Threaded};
    let d = 1 << 16;
    let n = 8;
    let mut rng = Rng::new(9);
    let mut vs: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let probes: Vec<usize> = (0..16).map(|j| j * (d / 16)).collect();
    let before: Vec<f64> = probes
        .iter()
        .map(|&j| vs.iter().map(|v| v[j] as f64).sum::<f64>() / n as f64)
        .collect();
    let c: std::sync::Arc<dyn Compressor> = std::sync::Arc::new(Grbs::new(64.0, d / 256, 13));
    let round = Threaded::new().psync(&mut vs, None, &c, 21);
    assert!(round.allreduce_compatible);
    let wire = round.wire.expect("threaded measures traffic");
    assert!(wire.total_bits() > 0);
    for (&j, &b) in probes.iter().zip(&before) {
        let after = vs.iter().map(|v| v[j] as f64).sum::<f64>() / n as f64;
        assert!((after - b).abs() < 1e-5, "probe {j}: {after} vs {b}");
    }
}
