//! Multi-process equivalence: N ranks over real loopback TCP sockets must
//! reproduce the in-process trainer.
//!
//! Each "process" here is a thread running the **exact** code path of a
//! `cser worker` process — `train_classifier` with `Backend::Tcp`, a
//! single-worker engine, a real `TcpTransport` built through the rank-0
//! rendezvous — so everything but the PID boundary is exercised (the PID
//! boundary itself is the CI `cser launch` smoke job).
//!
//! Contracts pinned here (the acceptance criteria for the TCP backend):
//!
//! * **PS path bit-identical**: a CSER plan with per-worker compressors
//!   (rand-k/top-k ride the parameter server) produces the *identical*
//!   `RunRecord` — every loss, accuracy, bit and second — and identical
//!   worker models, across 4 processes vs the central in-process loop.
//! * **Ring path within f32 tolerance**: the GRBS CSER plan's final metrics
//!   match the central run within the documented reduction-order band,
//!   while the *accounting* (cum_bits/cum_seconds) stays exactly equal —
//!   the α-β pricing is transport-invariant.
//! * **Measured wire ≡ accounted bits**: the payload bits counted at the
//!   sockets equal the `payload_bits_wire` accounting (also asserted
//!   in-module in `transport::tcp` and in `benches/transport.rs`).

use cser::config::OptSpec;
use cser::coordinator::sim_trainer::{train_classifier, TrainCfg};
use cser::coordinator::RunRecord;
use cser::data::ClassDataset;
use cser::engine::{CommPlan, ErrorResetEngine};
use cser::models::{GradModel, Mlp};
use cser::optimizer::DistOptimizer;
use cser::transport::rendezvous::free_loopback_addr;
use cser::transport::{Backend, TcpTransport};

fn workload() -> (ClassDataset, ClassDataset, Mlp) {
    let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 1024, 256, 1.2, 0.8, 0.0, 7);
    (tr, te, Mlp::new(16, 32, 10))
}

fn quick_cfg(epochs: usize) -> TrainCfg {
    let mut c = TrainCfg::new(epochs, 16, 0.1, 7);
    c.schedule = cser::config::LrSchedule::StepDecay { milestones: vec![0.5], factor: 0.2 };
    c.paper_d = 1_000_000;
    c.threads = 4;
    c
}

/// Record identity modulo `wall_ms`: the measured wall clock legitimately
/// differs across ranks and runs, so equality checks compare records with
/// it zeroed out (everything else — losses, accuracies, bits, simulated
/// seconds — must still match to the bit).
fn json_sans_wall(rec: &RunRecord) -> String {
    let mut r = rec.clone();
    for p in &mut r.points {
        p.wall_ms = 0;
    }
    r.to_json()
}

/// Plan builders shared by the central and per-rank runs (`n` differs).
type MkOpt = dyn Fn(&[f32], usize) -> Box<dyn DistOptimizer> + Sync;

fn run_central(mk: &MkOpt, n: usize, cfg: &TrainCfg) -> (RunRecord, Vec<Vec<f32>>) {
    let (tr, te, model) = workload();
    let init = model.init(cfg.seed);
    let mut opt = mk(&init, n);
    let rec = train_classifier(&model, &tr, &te, opt.as_mut(), cfg);
    let models = (0..n).map(|i| opt.worker_model(i).to_vec()).collect();
    (rec, models)
}

/// One thread per rank, each running the full `Backend::Tcp` trainer over a
/// fresh loopback rendezvous.  Returns (record, final model) per rank.
fn run_tcp(mk: &MkOpt, n: usize, cfg: &TrainCfg) -> Vec<(RunRecord, Vec<f32>)> {
    let addr = free_loopback_addr().expect("loopback port");
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let addr = addr.clone();
                let mut cfg = cfg.clone();
                s.spawn(move || {
                    let (tr, te, model) = workload();
                    let init = model.init(cfg.seed);
                    cfg.backend = Backend::Tcp { bind: addr, peers: n, rank };
                    let mut opt = mk(&init, 1);
                    let rec = train_classifier(&model, &tr, &te, opt.as_mut(), &cfg);
                    (rec, opt.worker_model(0).to_vec())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
    })
}

#[test]
fn four_process_ps_path_matches_central_bit_for_bit() {
    // Per-worker compressors → every collective is a parameter-server round
    // → the 4-process job must equal the central in-process trainer exactly:
    // identical records (losses, accuracies, bits, seconds) and identical
    // models, and every rank must agree with every other.
    let n = 4;
    let cfg = quick_cfg(3);
    let mk: Box<MkOpt> = Box::new(|init, n| {
        Box::new(ErrorResetEngine::new(
            init,
            n,
            0.9,
            CommPlan::cser(
                Box::new(cser::compressor::RandK::new(4.0)),
                Box::new(cser::compressor::TopK::new(4.0)),
                2,
            ),
        ))
    });
    let (central_rec, central_models) = run_central(&mk, n, &cfg);
    assert!(!central_rec.diverged);
    let ranks = run_tcp(&mk, n, &cfg);
    for (rank, (rec, model)) in ranks.iter().enumerate() {
        assert_eq!(
            json_sans_wall(rec),
            json_sans_wall(&central_rec),
            "rank {rank}: RunRecord differs from the central trainer"
        );
        assert_eq!(
            model.as_slice(),
            central_models[rank].as_slice(),
            "rank {rank}: final model differs bit-for-bit"
        );
    }
}

#[test]
fn four_process_cser_grbs_matches_central_within_ring_tolerance() {
    // The headline CSER plan (GRBS both paths) rides the ring: metrics agree
    // within the documented f32 reduction-order band, the communication
    // accounting agrees exactly, and all ranks emit the identical record.
    let n = 4;
    let cfg = quick_cfg(3);
    let spec = OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 };
    let mk: Box<MkOpt> = {
        let spec = spec.clone();
        Box::new(move |init, n| spec.build(init, n, 0.9, 7))
    };
    let (central_rec, _) = run_central(&mk, n, &cfg);
    assert!(!central_rec.diverged);
    let ranks = run_tcp(&mk, n, &cfg);

    let rec0 = &ranks[0].0;
    for (rank, (rec, _)) in ranks.iter().enumerate().skip(1) {
        assert_eq!(
            json_sans_wall(rec),
            json_sans_wall(rec0),
            "rank {rank}: CSER syncs every step, so all ranks must agree exactly"
        );
    }
    assert!(!rec0.diverged);
    assert_eq!(rec0.points.len(), central_rec.points.len());
    for (tcp, central) in rec0.points.iter().zip(&central_rec.points) {
        assert!(
            (tcp.test_acc - central.test_acc).abs() < 0.05,
            "epoch {}: acc {} vs central {}",
            tcp.epoch,
            tcp.test_acc,
            central.test_acc
        );
        assert!(
            (tcp.train_loss - central.train_loss).abs() < 0.05 * central.train_loss.abs().max(1.0),
            "epoch {}: loss {} vs central {}",
            tcp.epoch,
            tcp.train_loss,
            central.train_loss
        );
        // Accounting is transport-invariant even where f32 sums are not:
        // accounted upload bits and α-β pricing must match to the bit.
        assert_eq!(tcp.cum_bits, central.cum_bits, "epoch {}: cum_bits drifted", tcp.epoch);
        assert_eq!(
            tcp.cum_seconds, central.cum_seconds,
            "epoch {}: cum_seconds drifted",
            tcp.epoch
        );
    }
}

#[test]
fn four_process_bucketed_ps_path_matches_central_bit_for_bit() {
    // The bucketed pipeline over real sockets: with `cfg.buckets` set the
    // trainer derives layer-aware bucket bounds from the MLP's
    // `param_layout()` on every rank, each rank overlaps bucket
    // compression with the exchange, and — per-worker compressors, so
    // every bucket is a PS round — the 4-process job must equal the
    // central sequential-bucketed trainer exactly: identical records and
    // identical models.
    let n = 4;
    let mut cfg = quick_cfg(2);
    cfg.buckets = 3;
    let mk: Box<MkOpt> = Box::new(|init, n| {
        Box::new(ErrorResetEngine::new(
            init,
            n,
            0.9,
            CommPlan::cser(
                Box::new(cser::compressor::RandK::new(4.0)),
                Box::new(cser::compressor::TopK::new(4.0)),
                2,
            ),
        ))
    });
    let (central_rec, central_models) = run_central(&mk, n, &cfg);
    assert!(!central_rec.diverged);
    let ranks = run_tcp(&mk, n, &cfg);
    for (rank, (rec, model)) in ranks.iter().enumerate() {
        assert_eq!(
            json_sans_wall(rec),
            json_sans_wall(&central_rec),
            "rank {rank}: bucketed RunRecord differs from the central trainer"
        );
        assert_eq!(
            model.as_slice(),
            central_models[rank].as_slice(),
            "rank {rank}: bucketed final model differs bit-for-bit"
        );
    }
}

#[test]
fn killed_tcp_worker_errors_peers_out_of_pipelined_round() {
    // Rank 2 dies partway through a bucketed multi-process run (its
    // gradient oracle panics, unwinding drops its transport and its
    // prepare thread).  The survivors' next collective must surface a
    // TransportError — run_distributed returns Err — instead of wedging
    // in a half-finished pipelined round.
    use cser::engine::SyncBuckets;
    let (n, d, steps) = (3usize, 24usize, 6usize);
    let init = vec![0.3f32; d];
    let buckets = SyncBuckets::from_bounds(vec![0, 7, 24]);
    let addr = free_loopback_addr().unwrap();
    let mut outcomes = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let addr = addr.clone();
                let buckets = buckets.clone();
                let init = init.clone();
                s.spawn(move || -> Result<(), String> {
                    let calls = std::sync::atomic::AtomicUsize::new(0);
                    let gf = cser::engine::as_grad(
                        move |_w: usize, x: &[f32], out: &mut [f32]| -> f32 {
                            let k = calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                            if rank == 2 && k >= 3 {
                                panic!("rank 2 killed mid-run (test)");
                            }
                            for (o, xi) in out.iter_mut().zip(x) {
                                *o = 0.1 * *xi + 0.01;
                            }
                            0.5
                        },
                    );
                    let mut tp =
                        TcpTransport::connect(&addr, rank, n).map_err(|e| e.to_string())?;
                    let mut eng = ErrorResetEngine::new(
                        &init,
                        1,
                        0.9,
                        CommPlan::cser(
                            Box::new(cser::compressor::RandK::new(4.0)),
                            Box::new(cser::compressor::TopK::new(4.0)),
                            2,
                        ),
                    );
                    eng.set_bucketing(Some(buckets));
                    eng.run_distributed(&mut tp, steps, 0.05, f64::INFINITY, &gf)
                        .map(|_| ())
                        .map_err(|e| e.to_string())
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            outcomes.push((rank, h.join().map_err(|_| "panicked".to_string())));
        }
    });
    for (rank, outcome) in &outcomes {
        if *rank == 2 {
            assert!(outcome.is_err(), "rank 2 was killed and must have panicked");
        } else {
            let inner = outcome
                .as_ref()
                .unwrap_or_else(|_| panic!("rank {rank} panicked instead of erroring"));
            let err = inner
                .as_ref()
                .expect_err("surviving rank must surface a TransportError, not finish");
            assert!(
                err.contains("transport error") || err.contains("peer"),
                "rank {rank}: unexpected error: {err}"
            );
        }
    }
}

#[test]
fn two_process_sgd_matches_central_and_killed_fleet_resumes() {
    // Dense SGD rides the gather/mean/broadcast path: the uninterrupted
    // 2-process run must be bit-identical to the central trainer.  Then the
    // kill/resume contract: a fleet that checkpoints, dies, and restarts
    // picks up at the saved epoch and finishes sanely.  (The optimizer
    // state itself resumes bit-identically — pinned by the
    // `coordinator::checkpoint` tests; the data shards draw fresh batches
    // after a restart, so the post-resume trajectory is a new sample of the
    // same run, not a replay.)
    let n = 2;
    let mk: Box<MkOpt> = Box::new(|init, n| OptSpec::Sgd.build(init, n, 0.9, 7));

    let cfg3 = quick_cfg(3);
    let (central_rec, central_models) = run_central(&mk, n, &cfg3);
    assert!(!central_rec.diverged);
    let ranks = run_tcp(&mk, n, &cfg3);
    for (rank, (rec, model)) in ranks.iter().enumerate() {
        assert_eq!(json_sans_wall(rec), json_sans_wall(&central_rec), "rank {rank}: SGD record");
        assert_eq!(model.as_slice(), central_models[rank].as_slice(), "rank {rank}: SGD model");
    }

    let dir = std::env::temp_dir().join(format!("cser_tcp_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck_cfg = |epochs: usize, rank: usize| {
        let mut c = quick_cfg(epochs);
        c.ckpt = Some(dir.join(format!("rank_{rank}.ckpt")));
        c
    };
    // Phase 1: epochs 0-1, checkpoint at each epoch boundary.
    {
        let addr = free_loopback_addr().unwrap();
        std::thread::scope(|s| {
            for rank in 0..n {
                let addr = addr.clone();
                let mk = &mk;
                let mut cfg = ck_cfg(2, rank);
                s.spawn(move || {
                    let (tr, te, model) = workload();
                    let init = model.init(cfg.seed);
                    cfg.backend = Backend::Tcp { bind: addr, peers: n, rank };
                    let mut opt = mk(&init, 1);
                    train_classifier(&model, &tr, &te, opt.as_mut(), &cfg);
                });
            }
        });
    }
    // Phase 2: a fresh fleet resumes from the checkpoints and finishes
    // the 3-epoch schedule.
    let resumed: Vec<(RunRecord, Vec<f32>)> = {
        let addr = free_loopback_addr().unwrap();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let addr = addr.clone();
                    let mk = &mk;
                    let mut cfg = ck_cfg(3, rank);
                    s.spawn(move || {
                        let (tr, te, model) = workload();
                        let init = model.init(cfg.seed);
                        cfg.backend = Backend::Tcp { bind: addr, peers: n, rank };
                        let mut opt = mk(&init, 1);
                        let rec = train_classifier(&model, &tr, &te, opt.as_mut(), &cfg);
                        (rec, opt.worker_model(0).to_vec())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
        })
    };
    let (rec0, model0) = &resumed[0];
    assert_eq!(rec0.points.len(), 1, "resumed run must cover only the final epoch");
    assert_eq!(rec0.points[0].epoch, 2, "resume must restart at the checkpointed epoch");
    assert!(!rec0.diverged);
    assert!(
        rec0.points[0].test_acc > 0.35, // 10 classes — chance is 0.1
        "resumed fleet should keep training sanely (acc {})",
        rec0.points[0].test_acc
    );
    for (rank, (rec, model)) in resumed.iter().enumerate().skip(1) {
        assert_eq!(json_sans_wall(rec), json_sans_wall(rec0), "rank {rank}: records must agree");
        assert_eq!(
            model.as_slice(),
            model0.as_slice(),
            "rank {rank}: SGD replicas must stay bit-identical across a restart"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
