//! Engine-vs-seed parity: the generic `ErrorResetEngine` + `CommPlan` must
//! reproduce the seed algorithm implementations **bit for bit** on the
//! in-process (and therefore parameter-server) collective path.
//!
//! The `seed` module below is a compact port of the original per-algorithm
//! structs exactly as they shipped (same arithmetic, same order, directly
//! over `collective::{psync, exchange_mean}` — which is what the seed's
//! default `InProcess` backend delegated to).  Keeping them here pins the
//! engine to the seed numerics even though the production structs are now
//! thin wrappers over the engine.
//!
//! The second half is the Lemma-1 / consensus-invariant suite across every
//! `CommPlan` family × both transport backends × both execution modes
//! (central step loop and worker-resident threads).

use cser::collective::{exchange_mean, psync};
use cser::compressor::{Compressor, Ctx, Grbs, Identity, RandK, TopK, Zero};
use cser::engine::{CommPlan, ErrorResetEngine};
use cser::optimizer::{DistOptimizer, Momentum};
use cser::transport::Backend;
use cser::util::math;
use cser::util::prop::{slices_close, Gen};

// ---------------------------------------------------------------------------
// Seed reference implementations (ports of the pre-engine structs).
// ---------------------------------------------------------------------------
mod seed {
    use super::*;

    pub struct RefFullSgd {
        n: usize,
        pub x: Vec<f32>,
        momentum: Momentum,
        gbar: Vec<f32>,
        p: Vec<f32>,
    }

    impl RefFullSgd {
        pub fn new(init: &[f32], n: usize, beta: f32) -> Self {
            RefFullSgd {
                n,
                x: init.to_vec(),
                momentum: Momentum::new(beta, 1, init.len()),
                gbar: vec![0.0; init.len()],
                p: vec![0.0; init.len()],
            }
        }
        pub fn step(&mut self, grads: &[Vec<f32>], eta: f32) {
            assert_eq!(grads.len(), self.n);
            let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            math::mean_rows(&refs, &mut self.gbar);
            self.momentum.descent(0, &self.gbar, eta, &mut self.p);
            math::axpy(-1.0, &self.p, &mut self.x);
        }
    }

    pub struct RefEfSgd {
        n: usize,
        pub x: Vec<f32>,
        pub e: Vec<Vec<f32>>,
        momentum: Momentum,
        c1: Box<dyn Compressor>,
        t: u64,
        q: Vec<Vec<f32>>,
    }

    impl RefEfSgd {
        pub fn new(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>) -> Self {
            let d = init.len();
            RefEfSgd {
                n,
                x: init.to_vec(),
                e: vec![vec![0.0; d]; n],
                momentum: Momentum::new(beta, n, d),
                c1,
                t: 0,
                q: vec![vec![0.0; d]; n],
            }
        }
        pub fn step(&mut self, grads: &[Vec<f32>], eta: f32) {
            self.t += 1;
            for i in 0..self.n {
                self.momentum.descent(i, &grads[i], eta, &mut self.q[i]);
                math::axpy(1.0, &self.e[i], &mut self.q[i]);
            }
            exchange_mean(&mut self.q, Some(&mut self.e), self.c1.as_ref(), self.t);
            math::axpy(-1.0, &self.q[0], &mut self.x);
        }
    }

    pub struct RefQsparse {
        n: usize,
        h: u64,
        pub x: Vec<Vec<f32>>,
        xhat: Vec<f32>,
        pub e: Vec<Vec<f32>>,
        momentum: Momentum,
        c1: Box<dyn Compressor>,
        t: u64,
        p: Vec<f32>,
        q: Vec<Vec<f32>>,
    }

    impl RefQsparse {
        pub fn new(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>, h: u64) -> Self {
            let d = init.len();
            RefQsparse {
                n,
                h,
                x: vec![init.to_vec(); n],
                xhat: init.to_vec(),
                e: vec![vec![0.0; d]; n],
                momentum: Momentum::new(beta, n, d),
                c1,
                t: 0,
                p: vec![0.0; d],
                q: vec![vec![0.0; d]; n],
            }
        }
        pub fn step(&mut self, grads: &[Vec<f32>], eta: f32) {
            self.t += 1;
            for i in 0..self.n {
                self.momentum.descent(i, &grads[i], eta, &mut self.p);
                math::axpy(-1.0, &self.p, &mut self.x[i]);
            }
            if self.t % self.h != 0 {
                return;
            }
            for i in 0..self.n {
                for ((qj, ej), (xj, hj)) in self.q[i]
                    .iter_mut()
                    .zip(&self.e[i])
                    .zip(self.x[i].iter().zip(&self.xhat))
                {
                    *qj = ej + xj - hj;
                }
            }
            exchange_mean(&mut self.q, Some(&mut self.e), self.c1.as_ref(), self.t);
            math::axpy(1.0, &self.q[0], &mut self.xhat);
            for i in 0..self.n {
                self.x[i].copy_from_slice(&self.xhat);
            }
        }
    }

    pub struct RefCser {
        n: usize,
        h: u64,
        pub x: Vec<Vec<f32>>,
        pub e: Vec<Vec<f32>>,
        momentum: Momentum,
        c1: Box<dyn Compressor>,
        c2: Box<dyn Compressor>,
        t: u64,
        p: Vec<Vec<f32>>,
        r: Vec<Vec<f32>>,
        e_half: Vec<Vec<f32>>,
    }

    impl RefCser {
        pub fn new(
            init: &[f32],
            n: usize,
            beta: f32,
            c1: Box<dyn Compressor>,
            c2: Box<dyn Compressor>,
            h: u64,
        ) -> Self {
            let d = init.len();
            let needs_r = !c1.globally_synchronized() || !c2.globally_synchronized();
            let needs_ehalf = !c1.globally_synchronized();
            RefCser {
                n,
                h,
                x: vec![init.to_vec(); n],
                e: vec![vec![0.0; d]; n],
                momentum: Momentum::new(beta, n, d),
                c1,
                c2,
                t: 0,
                p: vec![vec![0.0; d]; n],
                r: if needs_r { vec![vec![0.0; d]; n] } else { vec![] },
                e_half: if needs_ehalf { vec![vec![0.0; d]; n] } else { vec![] },
            }
        }
        pub fn step(&mut self, grads: &[Vec<f32>], eta: f32) {
            self.t += 1;
            for i in 0..self.n {
                self.momentum.descent(i, &grads[i], eta, &mut self.p[i]);
            }
            let global = self.c2.globally_synchronized();
            let round = if global {
                psync(&mut self.p, None, self.c2.as_ref(), self.t)
            } else {
                psync(&mut self.p, Some(&mut self.r), self.c2.as_ref(), self.t)
            };
            for i in 0..self.n {
                math::axpy(-1.0, &self.p[i], &mut self.x[i]);
                if global {
                    let (p_i, e_i) = (&self.p[i], &mut self.e[i]);
                    round.for_each_unselected(i, p_i.len(), |s, t| {
                        math::axpy(-1.0, &p_i[s..t], &mut e_i[s..t]);
                    });
                } else {
                    math::axpy(-1.0, &self.r[i], &mut self.e[i]);
                }
            }
            if self.t % self.h == 0 {
                if self.c1.globally_synchronized() {
                    let sel =
                        self.c1.select(Ctx { round: self.t, worker: 0 }, &self.e[0]);
                    let d = self.x[0].len();
                    for i in 0..self.n {
                        let (x_i, e_i) = (&mut self.x[i], &self.e[i]);
                        sel.for_each_range(d, |s, t| {
                            math::axpy(-1.0, &e_i[s..t], &mut x_i[s..t]);
                        });
                    }
                    psync(&mut self.e, None, self.c1.as_ref(), self.t);
                    for i in 0..self.n {
                        let (x_i, e_i) = (&mut self.x[i], &mut self.e[i]);
                        sel.for_each_range(d, |s, t| {
                            math::axpy(1.0, &e_i[s..t], &mut x_i[s..t]);
                            math::fill(&mut e_i[s..t], 0.0);
                        });
                    }
                } else {
                    for i in 0..self.n {
                        self.e_half[i].copy_from_slice(&self.e[i]);
                    }
                    psync(&mut self.e, Some(&mut self.r), self.c1.as_ref(), self.t);
                    for i in 0..self.n {
                        math::axpy(1.0, &self.e[i], &mut self.x[i]);
                        math::axpy(-1.0, &self.e_half[i], &mut self.x[i]);
                        std::mem::swap(&mut self.e[i], &mut self.r[i]);
                    }
                }
            }
        }
    }

    pub struct RefCserImpl2 {
        n: usize,
        h: u64,
        pub x: Vec<Vec<f32>>,
        momentum: Momentum,
        c1: Box<dyn Compressor>,
        c2: Box<dyn Compressor>,
        t: u64,
        p: Vec<Vec<f32>>,
    }

    impl RefCserImpl2 {
        pub fn new(
            init: &[f32],
            n: usize,
            beta: f32,
            c1: Box<dyn Compressor>,
            c2: Box<dyn Compressor>,
            h: u64,
        ) -> Self {
            let d = init.len();
            RefCserImpl2 {
                n,
                h,
                x: vec![init.to_vec(); n],
                momentum: Momentum::new(beta, n, d),
                c1,
                c2,
                t: 0,
                p: vec![vec![0.0; d]; n],
            }
        }
        pub fn step(&mut self, grads: &[Vec<f32>], eta: f32) {
            self.t += 1;
            for i in 0..self.n {
                self.momentum.descent(i, &grads[i], eta, &mut self.p[i]);
            }
            psync(&mut self.p, None, self.c2.as_ref(), self.t);
            for i in 0..self.n {
                math::axpy(-1.0, &self.p[i], &mut self.x[i]);
            }
            if self.t % self.h == 0 {
                psync(&mut self.x, None, self.c1.as_ref(), self.t);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Bit-identical parity: engine == seed reference, in-process path.
// ---------------------------------------------------------------------------

fn shared_grads(g: &mut Gen, n: usize, d: usize, steps: usize) -> Vec<Vec<Vec<f32>>> {
    (0..steps).map(|_| g.worker_vecs_smooth(n, d)).collect()
}

const N: usize = 4;
const D: usize = 40;
const STEPS: usize = 9;
const ETA: f32 = 0.07;
const BETA: f32 = 0.9;

#[test]
fn parity_full_sgd() {
    let mut g = Gen::replay(0xF00D, 0);
    let init = g.vec(D);
    let grads = shared_grads(&mut g, N, D, STEPS);
    let mut r = seed::RefFullSgd::new(&init, N, BETA);
    let mut e = ErrorResetEngine::new(&init, N, BETA, CommPlan::full_sgd());
    for gs in &grads {
        r.step(gs, ETA);
        e.step(gs, ETA);
    }
    for i in 0..N {
        assert_eq!(e.worker_model(i), r.x.as_slice(), "worker {i}");
    }
}

#[test]
fn parity_ef_sgd() {
    let cases: [(&str, fn() -> Box<dyn Compressor>); 2] = [
        ("grbs", || Box::new(Grbs::new(4.0, 8, 3))),
        ("topk", || Box::new(TopK::new(4.0))),
    ];
    for (label, mk) in cases {
        let mut g = Gen::replay(0xEF, 0);
        let init = g.vec(D);
        let grads = shared_grads(&mut g, N, D, STEPS);
        let mut r = seed::RefEfSgd::new(&init, N, BETA, mk());
        let mut e = ErrorResetEngine::new(&init, N, BETA, CommPlan::ef_sgd(mk()));
        for gs in &grads {
            r.step(gs, ETA);
            e.step(gs, ETA);
        }
        for i in 0..N {
            assert_eq!(e.worker_model(i), r.x.as_slice(), "{label} worker {i}");
            assert_eq!(e.local_error(i).unwrap(), r.e[i].as_slice(), "{label} e{i}");
        }
    }
}

#[test]
fn parity_local_sgd_and_qsparse() {
    let cases: [(&str, fn() -> Box<dyn Compressor>); 3] = [
        ("local-sgd", || Box::new(Identity)),
        ("qsparse-grbs", || Box::new(Grbs::new(2.0, 8, 5))),
        ("qsparse-topk", || Box::new(TopK::new(4.0))),
    ];
    for (label, mk) in cases {
        let mut g = Gen::replay(0x05A, 1);
        let init = g.vec(D);
        let grads = shared_grads(&mut g, N, D, STEPS);
        let mut r = seed::RefQsparse::new(&init, N, BETA, mk(), 3);
        let mut e = ErrorResetEngine::new(&init, N, BETA, CommPlan::qsparse(mk(), 3));
        for gs in &grads {
            r.step(gs, ETA);
            e.step(gs, ETA);
        }
        for i in 0..N {
            assert_eq!(e.worker_model(i), r.x[i].as_slice(), "{label} worker {i}");
            assert_eq!(e.local_error(i).unwrap(), r.e[i].as_slice(), "{label} e{i}");
        }
    }
}

#[test]
fn parity_cser_family() {
    type MkPair = fn() -> (Box<dyn Compressor>, Box<dyn Compressor>);
    let cases: [(&str, u64, MkPair); 5] = [
        ("cser-grbs", 2, || {
            (Box::new(Grbs::new(2.0, 8, 7)), Box::new(Grbs::new(4.0, 10, 9)))
        }),
        ("cser-perworker", 3, || {
            (Box::new(RandK::new(4.0)), Box::new(TopK::new(4.0)))
        }),
        ("cser-mixed", 2, || {
            (Box::new(TopK::new(4.0)), Box::new(Grbs::new(4.0, 10, 9)))
        }),
        ("csea", 1, || (Box::new(Grbs::new(2.0, 8, 11)), Box::new(Zero))),
        ("cser-pl", 4, || (Box::new(Grbs::new(2.0, 8, 13)), Box::new(Zero))),
    ];
    for (label, h, mk) in cases {
        let mut g = Gen::replay(0xC5E0, 2);
        let init = g.vec(D);
        let grads = shared_grads(&mut g, N, D, 3 * h as usize + 2);
        let (rc1, rc2) = mk();
        let mut r = seed::RefCser::new(&init, N, BETA, rc1, rc2, h);
        let (ec1, ec2) = mk();
        let mut e = ErrorResetEngine::new(&init, N, BETA, CommPlan::cser(ec1, ec2, h));
        for gs in &grads {
            r.step(gs, ETA);
            e.step(gs, ETA);
        }
        for i in 0..N {
            assert_eq!(e.worker_model(i), r.x[i].as_slice(), "{label} worker {i}");
            assert_eq!(e.local_error(i).unwrap(), r.e[i].as_slice(), "{label} e{i}");
        }
    }
}

#[test]
fn parity_cser_impl2() {
    let mut g = Gen::replay(0xC5E2, 3);
    let init = g.vec(D);
    let grads = shared_grads(&mut g, N, D, STEPS);
    let mk = || {
        (
            Box::new(Grbs::new(2.0, 8, 7)) as Box<dyn Compressor>,
            Box::new(Grbs::new(4.0, 10, 9)) as Box<dyn Compressor>,
        )
    };
    let (rc1, rc2) = mk();
    let mut r = seed::RefCserImpl2::new(&init, N, BETA, rc1, rc2, 2);
    let (ec1, ec2) = mk();
    let mut e = ErrorResetEngine::new(&init, N, BETA, CommPlan::cser_impl2(ec1, ec2, 2));
    for gs in &grads {
        r.step(gs, ETA);
        e.step(gs, ETA);
    }
    for i in 0..N {
        assert_eq!(e.worker_model(i), r.x[i].as_slice(), "worker {i}");
        assert!(e.local_error(i).is_none());
    }
}

// ---------------------------------------------------------------------------
// Lemma-1 / consensus invariants: every plan × both backends × both
// execution modes through the engine.
// ---------------------------------------------------------------------------

/// Per-plan consensus invariant checked after every step (or at sync rounds
/// for the local-descent family, whose e is deliberately stale in between).
enum Invariant {
    /// x_i − e_i identical across workers at every t (Lemma 1 proper).
    Bifurcated,
    /// x_i identical across workers at every t (replicated plans).
    Replicated,
    /// x_i identical across workers whenever t % H == 0.
    SyncedEveryH(u64),
}

fn check_invariant(o: &ErrorResetEngine, inv: &Invariant, t: u64, tol: f32, label: &str) {
    let n = o.n();
    match inv {
        Invariant::Bifurcated => {
            let view = |i: usize| -> Vec<f32> {
                o.worker_model(i)
                    .iter()
                    .zip(o.local_error(i).unwrap())
                    .map(|(x, e)| x - e)
                    .collect()
            };
            let base = view(0);
            for i in 1..n {
                slices_close(&base, &view(i), tol)
                    .unwrap_or_else(|e| panic!("{label} t={t} worker {i}: {e}"));
            }
        }
        Invariant::Replicated => {
            for i in 1..n {
                slices_close(o.worker_model(0), o.worker_model(i), tol)
                    .unwrap_or_else(|e| panic!("{label} t={t} worker {i}: {e}"));
            }
        }
        Invariant::SyncedEveryH(h) => {
            if t % h == 0 {
                for i in 1..n {
                    slices_close(o.worker_model(0), o.worker_model(i), tol)
                        .unwrap_or_else(|e| panic!("{label} t={t} worker {i}: {e}"));
                }
            }
        }
    }
}

type PlanCase = (&'static str, Box<dyn Fn() -> CommPlan>, Invariant);

fn invariant_plans() -> Vec<PlanCase> {
    fn grbs(r: f64, nb: usize, seed: u64) -> Box<dyn Compressor> {
        Box::new(Grbs::new(r, nb, seed))
    }
    vec![
        ("sgd", Box::new(CommPlan::full_sgd), Invariant::Replicated),
        ("ef-grbs", Box::new(|| CommPlan::ef_sgd(grbs(4.0, 8, 3))), Invariant::Replicated),
        (
            "ef-topk",
            Box::new(|| CommPlan::ef_sgd(Box::new(TopK::new(4.0)))),
            Invariant::Replicated,
        ),
        ("local-sgd", Box::new(|| CommPlan::local_sgd(2)), Invariant::SyncedEveryH(2)),
        (
            "qsparse",
            Box::new(|| CommPlan::qsparse(grbs(2.0, 8, 5), 3)),
            Invariant::SyncedEveryH(3),
        ),
        (
            "cser",
            Box::new(|| CommPlan::cser(grbs(2.0, 8, 7), grbs(4.0, 10, 9), 2)),
            Invariant::Bifurcated,
        ),
        (
            "cser-perworker",
            Box::new(|| CommPlan::cser(Box::new(RandK::new(4.0)), Box::new(TopK::new(4.0)), 2)),
            Invariant::Bifurcated,
        ),
        ("csea", Box::new(|| CommPlan::csea(grbs(2.0, 8, 11))), Invariant::Bifurcated),
        (
            "cser-pl",
            Box::new(|| CommPlan::cser_pl(grbs(2.0, 8, 13), 3)),
            Invariant::Bifurcated,
        ),
    ]
}

fn grad_oracle(d: usize) -> impl Fn(usize, &[f32], &mut [f32]) -> f32 + Sync {
    move |w: usize, x: &[f32], out: &mut [f32]| -> f32 {
        let mut loss = 0.0f32;
        for (j, (o, xi)) in out.iter_mut().zip(x).enumerate() {
            *o = xi - 1.0 + 0.03 * ((w * 17 + 3 * j) % 11) as f32;
            loss += *o * *o;
        }
        loss / d as f32
    }
}

#[test]
fn consensus_invariants_all_plans_both_backends_central() {
    let (n, d, steps) = (4, 36, 12);
    let init: Vec<f32> = (0..d).map(|j| (j as f32 * 0.31).cos() * 0.2).collect();
    let gf = grad_oracle(d);
    for backend in [Backend::InProcess, Backend::Threaded] {
        for (label, mk, inv) in invariant_plans() {
            let mut o = ErrorResetEngine::new(&init, n, 0.9, mk());
            o.set_collective(backend.collective());
            let mut grads = vec![vec![0.0f32; d]; n];
            for t in 1..=steps {
                for w in 0..n {
                    gf(w, o.worker_model(w), &mut grads[w]);
                }
                o.step(&grads, 0.05);
                check_invariant(&o, &inv, t as u64, 1e-4, label);
            }
        }
    }
}

#[test]
fn consensus_invariants_all_plans_resident() {
    // Worker-resident execution always runs the peer-owned mesh collectives
    // (serialized wire frames; the installed central backend is not
    // consulted), so there is a single resident path to pin here.
    let (n, d, steps) = (4, 36, 6);
    let init: Vec<f32> = (0..d).map(|j| (j as f32 * 0.31).cos() * 0.2).collect();
    let gf = grad_oracle(d);
    for (label, mk, inv) in invariant_plans() {
        let mut o = ErrorResetEngine::new(&init, n, 0.9, mk());
        // run in short bursts so the invariant is observed at several t
        for burst in 0..3u64 {
            let reports = o.run_resident(steps, 0.05, f64::INFINITY, &gf);
            assert_eq!(reports.len(), steps, "{label}");
            let t = (burst + 1) * steps as u64;
            // burst boundaries land on multiples of every H used above
            check_invariant(&o, &inv, t, 1e-4, label);
        }
    }
}

#[test]
fn resident_ps_path_matches_central_in_process_bitwise() {
    // TopK/RandK ride the parameter-server path, which the peer-owned
    // mesh collectives keep bit-identical to the in-process reference — so
    // worker-resident execution over real serialized wire frames must equal
    // the central in-process loop exactly.
    let (n, d, steps) = (4, 32, 8);
    let init = vec![0.1f32; d];
    let gf = grad_oracle(d);
    let mk = || CommPlan::cser(Box::new(TopK::new(4.0)), Box::new(RandK::new(4.0)), 2);

    let mut central = ErrorResetEngine::new(&init, n, 0.9, mk());
    let mut grads = vec![vec![0.0f32; d]; n];
    for _ in 0..steps {
        for w in 0..n {
            gf(w, central.worker_model(w), &mut grads[w]);
        }
        central.step(&grads, 0.05);
    }

    let mut res = ErrorResetEngine::new(&init, n, 0.9, mk());
    res.run_resident(steps, 0.05, f64::INFINITY, &gf);

    for i in 0..n {
        assert_eq!(central.worker_model(i), res.worker_model(i), "worker {i}");
        assert_eq!(
            central.local_error(i).unwrap(),
            res.local_error(i).unwrap(),
            "error {i}"
        );
    }
}

#[test]
fn legacy_wrappers_are_engine_backed() {
    // the wrappers must expose the engine for Backend::Resident routing
    let init = vec![0.0f32; 8];
    let mut opt = cser::optimizer::Cser::new(
        &init,
        2,
        0.0,
        Box::new(Grbs::new(2.0, 2, 1)),
        Box::new(Zero),
        2,
    );
    assert!(opt.as_engine().is_some());
    assert!(opt.as_engine().unwrap().comm_plan().tracks_error());
}
