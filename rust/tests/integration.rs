//! Cross-module integration tests: the full stack wired together.
//!
//! Heavier paper-shape checks live in the bench harnesses (they take
//! minutes); these tests keep `cargo test` under a couple of minutes while
//! still exercising every seam: data -> model -> optimizer -> trainer ->
//! metrics, and artifacts -> PJRT -> optimizer.

use cser::collective::psync;
use cser::compressor::{Compressor, Ctx, Grbs};
use cser::config::{table3, table3_for, OptSpec, Suite};
use cser::coordinator::metrics::write_results;
use cser::coordinator::{train_classifier, TrainCfg};
use cser::data::ClassDataset;
use cser::models::{GradModel, Mlp};
use cser::util::json::Json;

fn quick_cfg(suite: &Suite, lr: f64, seed: u64, epochs: usize) -> TrainCfg {
    let mut cfg = TrainCfg::new(epochs, suite.batch_per_worker, lr, seed);
    cfg.schedule = suite.schedule.clone();
    cfg.paper_d = suite.paper_d;
    cfg.cost = suite.cost_model();
    cfg.threads = 4;
    cfg
}

/// Paper Table 2 shape, miniature: at a moderate ratio CSER tracks SGD;
/// at an extreme ratio CSER still trains while QSparse collapses.
#[test]
fn paper_shape_cser_beats_qsparse_at_high_compression() {
    let suite = Suite::cifar();
    let model = suite.model();
    let (train, test) = suite.data(1);
    let init = model.init(5);
    let epochs = 10;

    let acc_of = |spec: &OptSpec, lr: f64| -> f64 {
        let mut opt = spec.build(&init, suite.workers, suite.beta, 9);
        train_classifier(&model, &train, &test, opt.as_mut(), &quick_cfg(&suite, lr, 1, epochs))
            .final_acc()
    };

    // lr per the suite grid: SGD tolerates 0.1; at R_C=1024 the tuned lr is
    // smaller (the harness greedily tunes; here we fix the known-good one).
    let sgd = acc_of(&OptSpec::Sgd, 0.1);
    let cser_1024 = acc_of(&table3_for("CSER", 1024).unwrap(), 0.05);
    let qsparse_1024 = acc_of(&table3_for("QSparse", 1024).unwrap(), 0.05);
    assert!(sgd > 0.3, "baseline too weak: {sgd}");
    assert!(
        cser_1024 > qsparse_1024.max(0.05) || qsparse_1024.is_nan(),
        "CSER@1024 ({cser_1024}) should beat QSparse@1024 ({qsparse_1024})"
    );
    assert!(cser_1024 > sgd * 0.5, "CSER@1024 collapsed: {cser_1024} vs sgd {sgd}");
}

/// Overall-R_C bit accounting across algorithm families on a real run.
#[test]
fn measured_compression_matches_advertised_rc() {
    let suite = Suite::cifar();
    let model = suite.model();
    let (train, test) = suite.data(2);
    let init = model.init(6);
    let d = model.dim() as f64;

    for rc in [16usize, 256] {
        let spec = table3_for("CSER", rc).unwrap();
        let mut opt = spec.build(&init, suite.workers, suite.beta, 3);
        let mut cfg = quick_cfg(&suite, 0.05, 2, 2);
        cfg.paper_d = model.dim(); // account at native scale for this check
        let rec = train_classifier(&model, &train, &test, opt.as_mut(), &cfg);
        let steps = 2.0 * (train.len() / (suite.batch_per_worker * suite.workers)) as f64;
        let dense_ring = d * 32.0 * steps * 2.0 * (suite.workers as f64 - 1.0)
            / suite.workers as f64;
        let measured = rec.points.last().unwrap().cum_bits;
        let measured_rc = dense_ring / measured;
        assert!(
            measured_rc > rc as f64 * 0.6 && measured_rc < rc as f64 * 1.7,
            "advertised R_C={rc}, measured {measured_rc:.1}"
        );
    }
}

/// Lemma 1 through the *trainer* (not just the optimizer unit test):
/// bifurcated models stay consistent while real gradients flow.
#[test]
fn lemma1_holds_during_real_training() {
    let suite = Suite::cifar();
    let model = suite.model();
    let (train, _test) = suite.data(3);
    let init = model.init(7);
    let spec = table3_for("CSER", 64).unwrap();
    let mut opt = spec.build(&init, 4, suite.beta, 11);

    let mut shards = cser::data::Shard::split(train.len(), 4, 1);
    let mut grads = vec![vec![0.0f32; model.dim()]; 4];
    let mut batch = Vec::new();
    for _ in 0..20 {
        for w in 0..4 {
            shards[w].sample_batch(8, &mut batch);
            model.loss_grad(opt.worker_model(w), &train, &batch, &mut grads[w]);
        }
        opt.step(&grads, 0.05);
        let e0 = opt.local_error(0).expect("cser tracks errors");
        let x0 = opt.worker_model(0);
        let base: Vec<f32> = x0.iter().zip(e0).map(|(x, e)| x - e).collect();
        for i in 1..4 {
            let xi = opt.worker_model(i);
            let ei = opt.local_error(i).unwrap();
            for (j, (x, e)) in xi.iter().zip(ei).enumerate() {
                assert!(
                    ((x - e) - base[j]).abs() < 1e-3,
                    "Lemma 1 violated at worker {i} coord {j}"
                );
            }
        }
    }
}

/// results-file round trip: write JSON records, parse them back.
#[test]
fn results_files_roundtrip() {
    let suite = Suite::cifar().smoke();
    let model = suite.model();
    let (train, test) = suite.data(4);
    let init = model.init(8);
    let mut opt = OptSpec::Sgd.build(&init, 2, 0.9, 1);
    let rec = train_classifier(&model, &train, &test, opt.as_mut(), &quick_cfg(&suite, 0.1, 4, 3));
    let dir = std::env::temp_dir().join("cser_test_results");
    let path = write_results(dir.to_str().unwrap(), "roundtrip", &[rec.clone()]).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).unwrap();
    let arr = j.as_arr().unwrap();
    assert_eq!(arr.len(), 1);
    assert_eq!(
        arr[0].get("test_acc").unwrap().as_arr().unwrap().len(),
        rec.points.len()
    );
}

/// Every Table 3 row must instantiate and survive a few steps on real
/// gradients without NaNs (catches block-count/ratio rounding issues).
#[test]
fn all_table3_rows_instantiate_and_step() {
    let (train, _) = ClassDataset::gaussian_mixture(10, 16, 256, 64, 1.0, 1.0, 0.0, 5);
    let model = Mlp::new(16, 8, 10);
    let init = model.init(9);
    let mut grads = vec![vec![0.0f32; model.dim()]; 2];
    let idxs: Vec<u32> = (0..16).collect();
    for row in table3() {
        let mut opt = row.spec.build(&init, 2, 0.9, 1);
        for _ in 0..4 {
            for w in 0..2 {
                model.loss_grad(opt.worker_model(w), &train, &idxs, &mut grads[w]);
            }
            opt.step(&grads, 0.05);
        }
        let mut xbar = vec![0.0f32; model.dim()];
        opt.mean_model(&mut xbar);
        assert!(
            xbar.iter().all(|v| v.is_finite()),
            "{:?} produced non-finite params",
            row.spec
        );
    }
}

/// PSync at scale (n=8, d=1M) preserves means exactly enough for training.
#[test]
fn psync_scale_mean_preservation() {
    let d = 1 << 20;
    let n = 8;
    let mut rng = cser::util::rng::Rng::new(4);
    let mut vs: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut before = vec![0.0f64; 16];
    for (j, b) in before.iter_mut().enumerate() {
        *b = vs.iter().map(|v| v[j * 1000] as f64).sum::<f64>() / n as f64;
    }
    let c = Grbs::new(256.0, d / 1024, 9);
    let round = psync(&mut vs, None, &c, 17);
    assert!(round.allreduce_compatible);
    for (j, b) in before.iter().enumerate() {
        let after = vs.iter().map(|v| v[j * 1000] as f64).sum::<f64>() / n as f64;
        assert!((after - b).abs() < 1e-5, "{after} vs {b}");
    }
    // selected fraction ~ 1/256
    let sel = c.select(Ctx { round: 17, worker: 0 }, &vs[0]);
    let frac = sel.count(d) as f64 / d as f64;
    assert!((frac - 1.0 / 256.0).abs() < 1.0 / 512.0, "frac={frac}");
}

/// Failure injection: corrupted artifacts must produce clean errors, not
/// panics or silent garbage.
#[test]
fn corrupted_artifacts_fail_cleanly() {
    use cser::runtime::Manifest;
    let dir = std::env::temp_dir().join("cser_bad_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // malformed JSON
    std::fs::write(dir.join("manifest.json"), b"{ not json").unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("parse"), "unexpected error: {err}");

    // valid manifest, truncated init.bin
    std::fs::write(
        dir.join("manifest.json"),
        br#"{"models": {"t": {"params": 100, "batch": 1, "seq_len": 4,
            "vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 1,
            "use_pallas": false, "train_step": "ts.hlo.txt",
            "eval_loss": "ev.hlo.txt", "init": "init.bin",
            "param_table": []}}, "kernels": {}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("init.bin"), vec![0u8; 17]).unwrap(); // not 400 bytes
    let m = Manifest::load(&dir).unwrap();
    let info = m.model("t").unwrap();
    let err = m.load_init(info).unwrap_err().to_string();
    assert!(err.contains("size mismatch"), "unexpected error: {err}");

    // missing manifest entirely
    let err = Manifest::load(dir.join("nope")).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "unexpected error: {err}");
}

/// M-CSER with identity compressors on a single worker must reproduce
/// single-node Nesterov SGD (Sutskever form, paper §3.2) exactly.
#[test]
fn mcser_single_worker_identity_is_nesterov_sgd() {
    use cser::compressor::Identity;
    use cser::optimizer::{Cser, DistOptimizer};
    let d = 5;
    let (beta, eta) = (0.9f32, 0.1f32);
    let init = vec![0.2f32; d];
    let mut opt = Cser::new(&init, 1, beta, Box::new(Identity), Box::new(Identity), 2);
    // hand-rolled reference
    let mut x = init.clone();
    let mut m = vec![0.0f32; d];
    for t in 0..7 {
        let g: Vec<f32> = (0..d).map(|j| ((t + j) as f32 * 0.3).sin()).collect();
        opt.step(&[g.clone()], eta);
        for j in 0..d {
            m[j] = beta * m[j] + g[j];
            x[j] -= eta * (beta * m[j] + g[j]);
        }
        for j in 0..d {
            assert!(
                (opt.worker_model(0)[j] - x[j]).abs() < 1e-5,
                "t={t} j={j}: {} vs {}",
                opt.worker_model(0)[j],
                x[j]
            );
        }
    }
}

/// With a single worker (n=1) CSER's compression error vanishes entirely
/// (Remark 2: the error-reset bound comes from inter-worker variance) —
/// CSER(n=1) must follow plain momentum SGD no matter the compressors.
#[test]
fn remark2_single_worker_cser_equals_sgd_regardless_of_compression() {
    use cser::config::OptSpec;
    use cser::optimizer::DistOptimizer;
    let d = 64;
    let init = vec![0.5f32; d];
    let mut cser = OptSpec::Cser { rc1: 8.0, rc2: 64.0, h: 4 }.build(&init, 1, 0.9, 3);
    let mut sgd = OptSpec::Sgd.build(&init, 1, 0.9, 3);
    for t in 0..16 {
        let g: Vec<f32> = (0..d).map(|j| ((t * d + j) as f32 * 0.01).cos()).collect();
        cser.step(&[g.clone()], 0.05);
        sgd.step(&[g], 0.05);
    }
    for j in 0..d {
        assert!(
            (cser.worker_model(0)[j] - sgd.worker_model(0)[j]).abs() < 1e-4,
            "j={j}"
        );
    }
}
