//! Tier-1 cross-check: the bits the TCP transport counts at its sockets —
//! in aggregate and per peer — are exactly the bits the collectives
//! account and the α-β cost model charges.
//!
//! Lifted from the assertion sections of `benches/transport.rs` so the
//! invariant runs on every test pass rather than only when someone runs
//! the bench, and with tracing ENABLED so the gated blocked-send timing
//! path is exercised too.  One `#[test]` only: the trace recorder's
//! enable flag is process-global.

use cser::collective::{ring_allreduce_cost, SyncBuckets};
use cser::compressor::{Compressor, Ctx, Grbs};
use cser::transport::rendezvous::free_loopback_addr;
use cser::transport::{peer, pipelined_sync, BucketPipeline, TcpTransport};
use cser::util::rng::Rng;
use std::sync::Arc;

fn worker_vecs(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect()
}

#[test]
fn traced_tcp_wire_bits_equal_accounted_bits() {
    let n = 4usize;
    let d = 1usize << 12;
    let base = worker_vecs(n, d, 2);
    cser::obs::set_enabled(true);
    cser::obs::register_thread("main");

    // ---- whole-vector GRBS ring: socket bits == formula == accounting ----
    {
        let addr = free_loopback_addr().expect("loopback port");
        let round = 7u64;
        let outs: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let addr = addr.clone();
                    let mut v = base[rank].clone();
                    s.spawn(move || {
                        let c = Grbs::new(16.0, 64, 5);
                        let mut tp = TcpTransport::connect(&addr, rank, n).expect("tcp join");
                        let info =
                            peer::psync(&mut tp, &mut v, None, &c, round).expect("tcp psync");
                        (info, tp.payload_bits_sent, tp.payload_bits_received, tp.per_peer.clone())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("tcp worker")).collect()
        });
        let c = Grbs::new(16.0, 64, 5);
        let m = c.select(Ctx { round, worker: 0 }, &base[0]).count(d) as u64;
        assert_eq!(m % n as u64, 0, "test setup: ring chunks must divide evenly");
        let expect = ring_allreduce_cost(m * 32, n);
        for (rank, (info, sent, received, per_peer)) in outs.iter().enumerate() {
            assert_eq!(info.upload_bits_per_worker, m * 32, "rank {rank}: accounted bits");
            let wc = info.wire.expect("tcp measures traffic");
            assert_eq!(
                (wc.up_bits, wc.down_bits),
                (expect.up_bits, expect.down_bits),
                "rank {rank}: socket bits != ring formula"
            );
            // Aggregate socket counters see both ring phases as sends.
            assert_eq!(*sent, expect.up_bits + expect.down_bits, "rank {rank}: bits sent");
            assert_eq!(*received, expect.up_bits + expect.down_bits, "rank {rank}: bits received");
            // Per-peer counters decompose the aggregates exactly, and a
            // ring only ever sends to its successor.
            assert_eq!(
                per_peer.iter().map(|p| p.payload_bits_sent).sum::<u64>(),
                *sent,
                "rank {rank}: per-peer sent bits don't sum to the aggregate"
            );
            assert_eq!(
                per_peer.iter().map(|p| p.payload_bits_received).sum::<u64>(),
                *received,
                "rank {rank}: per-peer received bits don't sum to the aggregate"
            );
            for (j, p) in per_peer.iter().enumerate() {
                if j == (rank + 1) % n {
                    assert_eq!(p.payload_bits_sent, *sent, "rank {rank}: ring sends to successor");
                } else {
                    assert_eq!(
                        p.payload_bits_sent, 0,
                        "rank {rank} sent payload to non-successor {j}"
                    );
                }
            }
        }
        // Fleet-wide conservation: every sent bit is received somewhere.
        let total_sent: u64 = outs.iter().map(|o| o.1).sum();
        let total_received: u64 = outs.iter().map(|o| o.2).sum();
        assert_eq!(total_sent, total_received, "bits lost between sockets");
    }

    // ---- bucketed pipelined sync: per-bucket accounting sums to the
    //      socket aggregate, per peer and in total ----
    {
        let kb = 8usize;
        let buckets = SyncBuckets::even(d, kb);
        let addr = free_loopback_addr().expect("loopback port");
        let outs: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let addr = addr.clone();
                    let buckets = buckets.clone();
                    let v0 = base[rank].clone();
                    s.spawn(move || {
                        let c: Arc<dyn Compressor> = Arc::new(Grbs::new(16.0, 64 / kb, 5));
                        let mut tp = TcpTransport::connect(&addr, rank, n).expect("tcp join");
                        let mut pipe = BucketPipeline::new();
                        let mut v = v0;
                        let info = pipelined_sync(
                            &mut pipe,
                            &mut tp,
                            peer::Mode::Psync,
                            &mut v,
                            None,
                            &c,
                            9,
                            &buckets,
                        )
                        .expect("pipelined tcp psync");
                        let wire_total: u64 = info
                            .parts()
                            .iter()
                            .map(|p| {
                                let w = p.2.wire.expect("tcp measures traffic");
                                w.up_bits + w.down_bits
                            })
                            .sum();
                        (wire_total, tp.payload_bits_sent, tp.per_peer.clone())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pipelined tcp worker")).collect()
        });
        for (rank, (wire_total, sent, per_peer)) in outs.iter().enumerate() {
            assert_eq!(
                wire_total, sent,
                "rank {rank}: per-bucket wire sums != socket payload bits"
            );
            assert_eq!(
                per_peer.iter().map(|p| p.payload_bits_sent).sum::<u64>(),
                *sent,
                "rank {rank}: per-peer sent bits don't sum to the aggregate"
            );
        }
    }

    cser::obs::set_enabled(false);
    cser::obs::reset();
}
