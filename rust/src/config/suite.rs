//! Workload suites: the CIFAR-100 and ImageNet substitutes with the paper's
//! §5.1 protocol (lr grids, schedules, momentum, 8 workers) scaled to the
//! synthetic models, plus the paper-scale constants used by the simulated
//! timeline (Figures 4/5/8/9).

use crate::data::ClassDataset;
use crate::models::Mlp;
use crate::network::CostModel;

/// Learning-rate schedule.
#[derive(Clone, Debug)]
pub enum LrSchedule {
    /// Multiply by `factor` at each fraction-of-training milestone
    /// (paper CIFAR-100: ×0.2 at epochs 60/120/160 of 200).
    StepDecay { milestones: Vec<f64>, factor: f64 },
    /// Linear warmup over `warmup` fraction then cosine to zero
    /// (paper ImageNet: 5 warmup epochs + cosine over 120).
    WarmupCosine { warmup: f64 },
}

impl LrSchedule {
    /// lr multiplier at training progress `frac` in [0, 1].
    pub fn multiplier(&self, frac: f64) -> f64 {
        match self {
            LrSchedule::StepDecay { milestones, factor } => {
                let hits = milestones.iter().filter(|&&m| frac >= m).count();
                factor.powi(hits as i32)
            }
            LrSchedule::WarmupCosine { warmup } => {
                if frac < *warmup {
                    (frac / warmup).max(1e-3)
                } else {
                    let t = (frac - warmup) / (1.0 - warmup);
                    0.5 * (1.0 + (std::f64::consts::PI * t).cos())
                }
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct Suite {
    pub name: &'static str,
    /// Synthetic substitute model dims.
    pub input: usize,
    pub hidden: usize,
    pub classes: usize,
    pub epochs: usize,
    pub batch_per_worker: usize,
    pub workers: usize,
    pub beta: f32,
    pub lr_grid: Vec<f64>,
    pub schedule: LrSchedule,
    /// Paper-scale parameter count for the timeline/bits axes
    /// (WRN-40-8 ≈ 35.7M; ResNet-50 ≈ 25.6M).
    pub paper_d: usize,
    /// Paper-scale per-step compute seconds (V100, from the paper's epoch
    /// times; see EXPERIMENTS.md).
    pub paper_compute_step: f64,
    /// Paper's reported best-config time-to-accuracy speedup (for the
    /// headline comparison printout).
    pub paper_speedup: f64,
}

impl Suite {
    pub fn cifar() -> Self {
        Suite {
            name: "cifar100",
            input: 64,
            hidden: 64,
            classes: 100,
            epochs: 20,
            batch_per_worker: 16,
            workers: 8,
            beta: 0.9,
            lr_grid: vec![0.05, 0.1, 0.5, 1.0],
            schedule: LrSchedule::StepDecay { milestones: vec![0.3, 0.6, 0.8], factor: 0.2 },
            paper_d: 35_700_000,
            paper_compute_step: 0.11,
            paper_speedup: 10.0,
        }
    }

    pub fn imagenet() -> Self {
        Suite {
            name: "imagenet",
            input: 128,
            hidden: 96,
            classes: 1000,
            epochs: 16,
            batch_per_worker: 32,
            workers: 8,
            beta: 0.9,
            lr_grid: vec![0.025, 0.05, 0.1, 0.5],
            schedule: LrSchedule::WarmupCosine { warmup: 5.0 / 120.0 },
            paper_d: 25_600_000,
            paper_compute_step: 0.30,
            paper_speedup: 4.5,
        }
    }

    /// Reduced variants for smoke tests and quick examples.
    pub fn smoke(mut self) -> Self {
        self.epochs = 4;
        self.lr_grid = vec![0.1];
        self
    }

    pub fn model(&self) -> Mlp {
        Mlp::new(self.input, self.hidden, self.classes)
    }

    pub fn data(&self, seed: u64) -> (ClassDataset, ClassDataset) {
        match self.name {
            "cifar100" => ClassDataset::cifar100_like(seed),
            "imagenet" => ClassDataset::imagenet_like(seed),
            _ => ClassDataset::gaussian_mixture(
                self.classes, self.input, 4096, 1024, 1.0, 2.0, 0.02, seed,
            ),
        }
    }

    pub fn cost_model(&self) -> CostModel {
        CostModel { n: self.workers, compute_step: self.paper_compute_step, ..Default::default() }
    }

    pub fn by_name(name: &str) -> Option<Suite> {
        match name {
            "cifar100" | "cifar" => Some(Suite::cifar()),
            "imagenet" => Some(Suite::imagenet()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay_multipliers() {
        let s = LrSchedule::StepDecay { milestones: vec![0.3, 0.6, 0.8], factor: 0.2 };
        assert_eq!(s.multiplier(0.0), 1.0);
        assert!((s.multiplier(0.35) - 0.2).abs() < 1e-12);
        assert!((s.multiplier(0.7) - 0.04).abs() < 1e-12);
        assert!((s.multiplier(0.9) - 0.008).abs() < 1e-12);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine { warmup: 0.1 };
        assert!(s.multiplier(0.01) < 0.2);
        assert!((s.multiplier(0.1) - 1.0).abs() < 1e-9);
        assert!(s.multiplier(0.55) < 1.0);
        assert!(s.multiplier(0.999) < 0.01);
    }

    #[test]
    fn suites_resolve() {
        assert_eq!(Suite::by_name("cifar").unwrap().classes, 100);
        assert_eq!(Suite::by_name("imagenet").unwrap().classes, 1000);
        assert!(Suite::by_name("nope").is_none());
    }
}
