//! The paper's Table 3: best compressor configurations (H, R_C1, R_C2) per
//! optimizer and overall compression ratio, transcribed verbatim.
//!
//! These are the exact hyper-parameters behind Table 2 / Table 4 and all
//! figures; our sweeps use them unchanged (only the learning rate is
//! re-tuned per workload, mirroring §5.1's lr grid).

use super::OptSpec;

#[derive(Clone, Debug)]
pub struct Table3Row {
    pub overall_rc: usize,
    pub spec: OptSpec,
}

/// The full table, in the paper's order.
pub fn table3() -> Vec<Table3Row> {
    let mut rows = Vec::new();
    let mut push = |rc: usize, spec: OptSpec| rows.push(Table3Row { overall_rc: rc, spec });

    // R_C = 2
    push(2, OptSpec::EfSgd { rc1: 2.0 });
    push(2, OptSpec::Qsparse { rc1: 1.0, h: 2 });
    push(2, OptSpec::Csea { rc1: 2.0 });
    push(2, OptSpec::Cser { rc2: 4.0, rc1: 2.0, h: 2 });
    // R_C = 4
    push(4, OptSpec::EfSgd { rc1: 4.0 });
    push(4, OptSpec::Qsparse { rc1: 1.0, h: 4 });
    push(4, OptSpec::Csea { rc1: 4.0 });
    push(4, OptSpec::Cser { rc2: 8.0, rc1: 2.0, h: 4 });
    push(4, OptSpec::CserPl { rc1: 2.0, h: 2 });
    // R_C = 8
    push(8, OptSpec::EfSgd { rc1: 8.0 });
    push(8, OptSpec::Qsparse { rc1: 1.0, h: 8 });
    push(8, OptSpec::Csea { rc1: 8.0 });
    push(8, OptSpec::Cser { rc2: 16.0, rc1: 2.0, h: 8 });
    push(8, OptSpec::CserPl { rc1: 2.0, h: 4 });
    // R_C = 16
    push(16, OptSpec::EfSgd { rc1: 16.0 });
    push(16, OptSpec::Qsparse { rc1: 4.0, h: 4 });
    push(16, OptSpec::Csea { rc1: 16.0 });
    push(16, OptSpec::Cser { rc2: 32.0, rc1: 8.0, h: 4 });
    push(16, OptSpec::CserPl { rc1: 4.0, h: 4 });
    // R_C = 32
    push(32, OptSpec::EfSgd { rc1: 32.0 });
    push(32, OptSpec::Qsparse { rc1: 4.0, h: 8 });
    push(32, OptSpec::Csea { rc1: 32.0 });
    push(32, OptSpec::Cser { rc2: 64.0, rc1: 8.0, h: 8 });
    push(32, OptSpec::CserPl { rc1: 8.0, h: 4 });
    // R_C = 64
    push(64, OptSpec::EfSgd { rc1: 64.0 });
    push(64, OptSpec::Qsparse { rc1: 16.0, h: 4 });
    push(64, OptSpec::Csea { rc1: 64.0 });
    push(64, OptSpec::Cser { rc2: 128.0, rc1: 8.0, h: 16 });
    push(64, OptSpec::CserPl { rc1: 8.0, h: 8 });
    // R_C = 128
    push(128, OptSpec::EfSgd { rc1: 128.0 });
    push(128, OptSpec::Qsparse { rc1: 16.0, h: 8 });
    push(128, OptSpec::Csea { rc1: 128.0 });
    push(128, OptSpec::Cser { rc2: 256.0, rc1: 4.0, h: 64 });
    push(128, OptSpec::CserPl { rc1: 8.0, h: 16 });
    // R_C = 256
    push(256, OptSpec::EfSgd { rc1: 256.0 });
    push(256, OptSpec::Qsparse { rc1: 128.0, h: 2 });
    push(256, OptSpec::Csea { rc1: 256.0 });
    push(256, OptSpec::Cser { rc2: 512.0, rc1: 16.0, h: 32 });
    push(256, OptSpec::CserPl { rc1: 16.0, h: 16 });
    // R_C = 512
    push(512, OptSpec::EfSgd { rc1: 512.0 });
    push(512, OptSpec::Qsparse { rc1: 128.0, h: 4 });
    push(512, OptSpec::Csea { rc1: 512.0 });
    push(512, OptSpec::Cser { rc2: 1024.0, rc1: 8.0, h: 128 });
    push(512, OptSpec::CserPl { rc1: 16.0, h: 32 });
    // R_C = 1024
    push(1024, OptSpec::EfSgd { rc1: 1024.0 });
    push(1024, OptSpec::Qsparse { rc1: 128.0, h: 8 });
    push(1024, OptSpec::Csea { rc1: 1024.0 });
    push(1024, OptSpec::Cser { rc2: 2048.0, rc1: 32.0, h: 64 });
    push(1024, OptSpec::CserPl { rc1: 32.0, h: 32 });
    rows
}

/// Rows for one optimizer family at one overall ratio.
pub fn table3_for(family: &str, overall_rc: usize) -> Option<OptSpec> {
    table3()
        .into_iter()
        .find(|r| r.overall_rc == overall_rc && r.spec.family() == family)
        .map(|r| r.spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_row_satisfies_the_budget_identity() {
        // paper §5.1: the advertised overall R_C must match the formula for
        // each configuration (QSparse: R_C1*H; CSER: harmonic combination).
        for row in table3() {
            let rc = row.spec.overall_rc();
            assert!(
                (rc - row.overall_rc as f64).abs() < 1e-9,
                "{:?}: formula gives {rc}, table says {}",
                row.spec,
                row.overall_rc
            );
        }
    }

    #[test]
    fn hyperparams_come_from_the_paper_grid() {
        // H >= 2, R_C1 >= 1, R_C2 >= 4, all powers of two (paper Appendix C).
        for row in table3() {
            match row.spec {
                OptSpec::Cser { rc1, rc2, h } => {
                    assert!(h >= 2 && (h as f64).log2().fract() == 0.0);
                    assert!(rc1 >= 1.0 && rc1.log2().fract() == 0.0);
                    assert!(rc2 >= 4.0 && rc2.log2().fract() == 0.0);
                }
                OptSpec::Qsparse { rc1, h } | OptSpec::CserPl { rc1, h } => {
                    assert!(h >= 2 && (h as f64).log2().fract() == 0.0);
                    assert!(rc1 >= 1.0 && rc1.log2().fract() == 0.0);
                }
                OptSpec::EfSgd { rc1 } | OptSpec::Csea { rc1 } => {
                    assert!(rc1 >= 2.0 && rc1.log2().fract() == 0.0);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn lookup_by_family() {
        let s = table3_for("CSER", 256).unwrap();
        assert_eq!(s, OptSpec::Cser { rc1: 16.0, rc2: 512.0, h: 32 });
        assert!(table3_for("CSER-PL", 2).is_none()); // paper: PL undefined at R_C=2
    }

    #[test]
    fn families_present_per_ratio() {
        let t = table3();
        for rc in [16, 32, 64, 128, 256, 512, 1024] {
            for fam in ["EF-SGD", "QSparse", "CSEA", "CSER", "CSER-PL"] {
                assert!(
                    t.iter().any(|r| r.overall_rc == rc && r.spec.family() == fam),
                    "missing {fam} at R_C={rc}"
                );
            }
        }
    }
}
