//! Experiment configuration: optimizer specs, the paper's Table 3 compressor
//! configurations, and the two workload suites (CIFAR-100-like and
//! ImageNet-like substitutes, DESIGN.md §3).

pub mod suite;
pub mod table3;

pub use suite::{LrSchedule, Suite};
pub use table3::{table3, table3_for, Table3Row};

use crate::compressor::{Compressor, Grbs, Identity, Zero};
use crate::engine::{CommPlan, ErrorResetEngine};
use crate::optimizer::DistOptimizer;

/// Target length for GRBS blocks, in elements.  The paper uses blockwise
/// sparsification so messages stay contiguous; we fix the block length and
/// derive the block count per model size.
pub const GRBS_BLOCK_LEN: usize = 64;

/// A fully-specified distributed optimizer (algorithm + compressor config).
#[derive(Clone, Debug, PartialEq)]
pub enum OptSpec {
    Sgd,
    EfSgd { rc1: f64 },
    Qsparse { rc1: f64, h: u64 },
    LocalSgd { h: u64 },
    Csea { rc1: f64 },
    CserPl { rc1: f64, h: u64 },
    Cser { rc1: f64, rc2: f64, h: u64 },
    /// CSER implementation II (Appendix A.4): same config as `Cser`,
    /// memory-light GRBS-only implementation.
    Cser2 { rc1: f64, rc2: f64, h: u64 },
}

impl OptSpec {
    /// Overall compression ratio R_C (paper §5.1):
    ///   CSER: 1 / (1/R_C2 + 1/(R_C1 · H));   QSparse/PL: R_C1 · H;
    ///   EF-SGD/CSEA: R_C1;   SGD: 1.
    pub fn overall_rc(&self) -> f64 {
        match *self {
            OptSpec::Sgd => 1.0,
            OptSpec::EfSgd { rc1 } | OptSpec::Csea { rc1 } => rc1,
            OptSpec::Qsparse { rc1, h } | OptSpec::CserPl { rc1, h } => rc1 * h as f64,
            OptSpec::LocalSgd { h } => h as f64,
            OptSpec::Cser { rc1, rc2, h } | OptSpec::Cser2 { rc1, rc2, h } => {
                1.0 / (1.0 / rc2 + 1.0 / (rc1 * h as f64))
            }
        }
    }

    /// Family name as used in the paper's tables.
    pub fn family(&self) -> &'static str {
        match self {
            OptSpec::Sgd => "SGD",
            OptSpec::EfSgd { .. } => "EF-SGD",
            OptSpec::Qsparse { .. } => "QSparse",
            OptSpec::LocalSgd { .. } => "local-SGD",
            OptSpec::Csea { .. } => "CSEA",
            OptSpec::CserPl { .. } => "CSER-PL",
            OptSpec::Cser { .. } => "CSER",
            OptSpec::Cser2 { .. } => "CSER(II)",
        }
    }

    /// Lower this spec to a declarative [`CommPlan`] for a d-dimensional
    /// model.  `seed` decorrelates the GRBS streams of C1 and C2.  This is
    /// the single config → engine lowering every harness and trainer goes
    /// through; [`OptSpec::build`] wraps it in an [`ErrorResetEngine`].
    pub fn plan(&self, d: usize, seed: u64) -> CommPlan {
        let grbs = |r: f64, salt: u64| -> Box<dyn Compressor> {
            Box::new(Grbs::with_block_len(r, d, GRBS_BLOCK_LEN, seed ^ salt))
        };
        match *self {
            OptSpec::Sgd => CommPlan::full_sgd(),
            OptSpec::EfSgd { rc1 } => CommPlan::ef_sgd(grbs(rc1, 0x1)),
            OptSpec::Qsparse { rc1, h } => {
                if rc1 <= 1.0 {
                    CommPlan::qsparse(Box::new(Identity), h)
                } else {
                    CommPlan::qsparse(grbs(rc1, 0x2), h)
                }
            }
            OptSpec::LocalSgd { h } => CommPlan::local_sgd(h),
            OptSpec::Csea { rc1 } => CommPlan::csea(grbs(rc1, 0x3)),
            OptSpec::CserPl { rc1, h } => CommPlan::cser_pl(grbs(rc1, 0x4), h),
            OptSpec::Cser { rc1, rc2, h } => CommPlan::cser(grbs(rc1, 0x5), grbs(rc2, 0x6), h),
            OptSpec::Cser2 { rc1, rc2, h } => {
                let c2: Box<dyn Compressor> =
                    if rc2.is_infinite() { Box::new(Zero) } else { grbs(rc2, 0x6) };
                CommPlan::cser_impl2(grbs(rc1, 0x5), c2, h)
            }
        }
    }

    /// Instantiate for a d-dimensional model, n workers, momentum beta.
    pub fn build(&self, init: &[f32], n: usize, beta: f32, seed: u64) -> Box<dyn DistOptimizer> {
        Box::new(ErrorResetEngine::new(init, n, beta, self.plan(init.len(), seed)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overall_rc_formulas() {
        assert_eq!(OptSpec::Sgd.overall_rc(), 1.0);
        assert_eq!(OptSpec::EfSgd { rc1: 64.0 }.overall_rc(), 64.0);
        assert_eq!(OptSpec::Qsparse { rc1: 16.0, h: 8 }.overall_rc(), 128.0);
        let c = OptSpec::Cser { rc1: 16.0, rc2: 512.0, h: 32 };
        assert!((c.overall_rc() - 256.0).abs() < 1e-9);
        assert_eq!(OptSpec::CserPl { rc1: 32.0, h: 32 }.overall_rc(), 1024.0);
    }

    #[test]
    fn plan_lowering_keeps_legacy_names() {
        // result files/figures key on the optimizer name — the OptSpec →
        // CommPlan lowering must preserve the seed formats
        assert_eq!(OptSpec::Sgd.plan(64, 1).name(), "sgd");
        assert!(OptSpec::EfSgd { rc1: 4.0 }.plan(64, 1).name().starts_with("ef-sgd["));
        assert!(OptSpec::LocalSgd { h: 2 }.plan(64, 1).name().contains("identity,H=2"));
        assert!(OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 }
            .plan(64, 1)
            .name()
            .starts_with("cser["));
        assert!(OptSpec::Cser2 { rc1: 2.0, rc2: 4.0, h: 2 }
            .plan(64, 1)
            .name()
            .starts_with("cser2["));
    }

    #[test]
    fn build_produces_working_optimizers() {
        let init = vec![0.1f32; 256];
        for spec in [
            OptSpec::Sgd,
            OptSpec::EfSgd { rc1: 4.0 },
            OptSpec::Qsparse { rc1: 2.0, h: 2 },
            OptSpec::LocalSgd { h: 2 },
            OptSpec::Csea { rc1: 4.0 },
            OptSpec::CserPl { rc1: 2.0, h: 2 },
            OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 },
            OptSpec::Cser2 { rc1: 2.0, rc2: 4.0, h: 2 },
        ] {
            let mut o = spec.build(&init, 4, 0.9, 42);
            let grads = vec![vec![0.01f32; 256]; 4];
            for _ in 0..4 {
                o.step(&grads, 0.1);
            }
            let mut xbar = vec![0.0f32; 256];
            o.mean_model(&mut xbar);
            assert!(xbar.iter().all(|v| v.is_finite()), "{}", o.name());
            let mean: f32 = xbar.iter().sum::<f32>() / 256.0;
            assert!(mean < 0.1, "{} did not descend (mean {mean})", o.name());
        }
    }
}
