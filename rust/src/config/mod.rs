//! Experiment configuration: optimizer specs, the paper's Table 3 compressor
//! configurations, and the two workload suites (CIFAR-100-like and
//! ImageNet-like substitutes, DESIGN.md §3).

pub mod suite;
pub mod table3;

pub use suite::{LrSchedule, Suite};
pub use table3::{table3, table3_for, Table3Row};

use crate::compressor::{Grbs, Identity, Zero};
use crate::optimizer::{Cser, CserImpl2, DistOptimizer, EfSgd, FullSgd, QsparseLocalSgd};

/// Target length for GRBS blocks, in elements.  The paper uses blockwise
/// sparsification so messages stay contiguous; we fix the block length and
/// derive the block count per model size.
pub const GRBS_BLOCK_LEN: usize = 64;

/// A fully-specified distributed optimizer (algorithm + compressor config).
#[derive(Clone, Debug, PartialEq)]
pub enum OptSpec {
    Sgd,
    EfSgd { rc1: f64 },
    Qsparse { rc1: f64, h: u64 },
    LocalSgd { h: u64 },
    Csea { rc1: f64 },
    CserPl { rc1: f64, h: u64 },
    Cser { rc1: f64, rc2: f64, h: u64 },
    /// CSER implementation II (Appendix A.4): same config as `Cser`,
    /// memory-light GRBS-only implementation.
    Cser2 { rc1: f64, rc2: f64, h: u64 },
}

impl OptSpec {
    /// Overall compression ratio R_C (paper §5.1):
    ///   CSER: 1 / (1/R_C2 + 1/(R_C1 · H));   QSparse/PL: R_C1 · H;
    ///   EF-SGD/CSEA: R_C1;   SGD: 1.
    pub fn overall_rc(&self) -> f64 {
        match *self {
            OptSpec::Sgd => 1.0,
            OptSpec::EfSgd { rc1 } | OptSpec::Csea { rc1 } => rc1,
            OptSpec::Qsparse { rc1, h } | OptSpec::CserPl { rc1, h } => rc1 * h as f64,
            OptSpec::LocalSgd { h } => h as f64,
            OptSpec::Cser { rc1, rc2, h } | OptSpec::Cser2 { rc1, rc2, h } => {
                1.0 / (1.0 / rc2 + 1.0 / (rc1 * h as f64))
            }
        }
    }

    /// Family name as used in the paper's tables.
    pub fn family(&self) -> &'static str {
        match self {
            OptSpec::Sgd => "SGD",
            OptSpec::EfSgd { .. } => "EF-SGD",
            OptSpec::Qsparse { .. } => "QSparse",
            OptSpec::LocalSgd { .. } => "local-SGD",
            OptSpec::Csea { .. } => "CSEA",
            OptSpec::CserPl { .. } => "CSER-PL",
            OptSpec::Cser { .. } => "CSER",
            OptSpec::Cser2 { .. } => "CSER(II)",
        }
    }

    /// Instantiate for a d-dimensional model, n workers, momentum beta.
    /// `seed` decorrelates the GRBS streams of C1 and C2.
    pub fn build(&self, init: &[f32], n: usize, beta: f32, seed: u64) -> Box<dyn DistOptimizer> {
        let d = init.len();
        let grbs = |r: f64, salt: u64| {
            Box::new(Grbs::with_block_len(r, d, GRBS_BLOCK_LEN, seed ^ salt))
        };
        match *self {
            OptSpec::Sgd => Box::new(FullSgd::new(init, n, beta)),
            OptSpec::EfSgd { rc1 } => Box::new(EfSgd::new(init, n, beta, grbs(rc1, 0x1))),
            OptSpec::Qsparse { rc1, h } => {
                if rc1 <= 1.0 {
                    Box::new(QsparseLocalSgd::new(init, n, beta, Box::new(Identity), h))
                } else {
                    Box::new(QsparseLocalSgd::new(init, n, beta, grbs(rc1, 0x2), h))
                }
            }
            OptSpec::LocalSgd { h } => Box::new(QsparseLocalSgd::local_sgd(init, n, beta, h)),
            OptSpec::Csea { rc1 } => Box::new(Cser::csea(init, n, beta, grbs(rc1, 0x3))),
            OptSpec::CserPl { rc1, h } => {
                Box::new(Cser::cser_pl(init, n, beta, grbs(rc1, 0x4), h))
            }
            OptSpec::Cser { rc1, rc2, h } => {
                Box::new(Cser::new(init, n, beta, grbs(rc1, 0x5), grbs(rc2, 0x6), h))
            }
            OptSpec::Cser2 { rc1, rc2, h } => {
                let c2: Box<dyn crate::compressor::Compressor> =
                    if rc2.is_infinite() { Box::new(Zero) } else { grbs(rc2, 0x6) };
                Box::new(CserImpl2::new(init, n, beta, grbs(rc1, 0x5), c2, h))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overall_rc_formulas() {
        assert_eq!(OptSpec::Sgd.overall_rc(), 1.0);
        assert_eq!(OptSpec::EfSgd { rc1: 64.0 }.overall_rc(), 64.0);
        assert_eq!(OptSpec::Qsparse { rc1: 16.0, h: 8 }.overall_rc(), 128.0);
        let c = OptSpec::Cser { rc1: 16.0, rc2: 512.0, h: 32 };
        assert!((c.overall_rc() - 256.0).abs() < 1e-9);
        assert_eq!(OptSpec::CserPl { rc1: 32.0, h: 32 }.overall_rc(), 1024.0);
    }

    #[test]
    fn build_produces_working_optimizers() {
        let init = vec![0.1f32; 256];
        for spec in [
            OptSpec::Sgd,
            OptSpec::EfSgd { rc1: 4.0 },
            OptSpec::Qsparse { rc1: 2.0, h: 2 },
            OptSpec::LocalSgd { h: 2 },
            OptSpec::Csea { rc1: 4.0 },
            OptSpec::CserPl { rc1: 2.0, h: 2 },
            OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 },
            OptSpec::Cser2 { rc1: 2.0, rc2: 4.0, h: 2 },
        ] {
            let mut o = spec.build(&init, 4, 0.9, 42);
            let grads = vec![vec![0.01f32; 256]; 4];
            for _ in 0..4 {
                o.step(&grads, 0.1);
            }
            let mut xbar = vec![0.0f32; 256];
            o.mean_model(&mut xbar);
            assert!(xbar.iter().all(|v| v.is_finite()), "{}", o.name());
            let mean: f32 = xbar.iter().sum::<f32>() / 256.0;
            assert!(mean < 0.1, "{} did not descend (mean {mean})", o.name());
        }
    }
}
