//! Alpha-beta network cost model, parameterized to the paper's testbed.
//!
//! Round time for a collective with `steps` sequential phases moving
//! `bits` through each NIC:  t = steps * alpha + bits / bandwidth.
//! Defaults: 10 Gb/s links, 25 µs per-hop latency (commodity Ethernet),
//! 8 workers — the paper's §5.1 cluster.

use crate::collective::{param_server_cost, ring_allreduce_cost, WireCost};

#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-step latency, seconds.
    pub alpha: f64,
    /// Link bandwidth, bits/second.
    pub bandwidth: f64,
    /// Workers.
    pub n: usize,
    /// Compute seconds for one local step (fwd+bwd) on one worker.
    pub compute_step: f64,
}

/// Traffic of one synchronization round before timing.
#[derive(Debug, Clone, Copy)]
pub struct RoundTraffic {
    pub wire: WireCost,
    pub seconds: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { alpha: 25e-6, bandwidth: 10e9, n: 8, compute_step: 0.0 }
    }
}

impl CostModel {
    /// Paper testbed: 8 machines, 1 V100 each, 10 Gb/s.  `compute_step` is
    /// workload-specific; harnesses pass measured or paper-derived values.
    pub fn paper_testbed(compute_step: f64) -> Self {
        CostModel { compute_step, ..Default::default() }
    }

    pub fn seconds_for(&self, wire: WireCost) -> f64 {
        wire.steps as f64 * self.alpha + wire.total_bits() as f64 / self.bandwidth
    }

    /// One synchronization round moving `payload_bits` per worker.
    /// `allreduce_compatible` selects ring vs parameter-server aggregation;
    /// for PS, the aggregate message is conservatively `union_factor` times
    /// the per-worker payload (supports of different workers overlap less as
    /// n grows; callers pass min(n, R) based on the compressor).
    pub fn sync_round(&self, payload_bits: u64, allreduce_compatible: bool, union_factor: f64) -> RoundTraffic {
        let wire = if allreduce_compatible {
            ring_allreduce_cost(payload_bits, self.n)
        } else {
            let agg = (payload_bits as f64 * union_factor) as u64;
            param_server_cost(payload_bits, agg, self.n)
        };
        RoundTraffic { wire, seconds: self.seconds_for(wire) }
    }

    /// Full-precision baseline round (dense model/gradient allreduce).
    pub fn dense_round(&self, d: usize) -> RoundTraffic {
        self.sync_round(d as u64 * 32, true, 1.0)
    }

    /// Time for `k` local compute steps.
    pub fn compute(&self, k: u64) -> f64 {
        self.compute_step * k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_round_time_matches_formula() {
        let m = CostModel { alpha: 1e-5, bandwidth: 1e9, n: 4, compute_step: 0.1 };
        // d = 1e6 params -> 32e6 bits; ring: 2*(3/4)*32e6 = 48e6 bits, 6 steps
        let rt = m.dense_round(1_000_000);
        assert_eq!(rt.wire.steps, 6);
        let expect = 6.0 * 1e-5 + 48e6 / 1e9;
        assert!((rt.seconds - expect).abs() < 1e-12, "{} vs {expect}", rt.seconds);
    }

    #[test]
    fn compression_reduces_round_time() {
        let m = CostModel::paper_testbed(0.1);
        let dense = m.dense_round(10_000_000).seconds;
        let sparse = m.sync_round(10_000_000 * 32 / 256, true, 1.0).seconds;
        assert!(sparse < dense / 50.0, "sparse={sparse} dense={dense}");
    }

    #[test]
    fn ps_round_counts_union() {
        let m = CostModel::paper_testbed(0.0);
        let rt = m.sync_round(1000, false, 4.0);
        assert_eq!(rt.wire.up_bits, 1000);
        assert_eq!(rt.wire.down_bits, 4000);
    }
}
