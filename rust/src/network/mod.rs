//! Network substrate: analytic cost model + per-run communication accounting.
//!
//! The paper's time-axis results (Figures 4 and 8) and the headline 10×/4.5×
//! speedups are communication-bound wall-clock numbers from an 8×V100,
//! 10 Gb/s testbed we do not have.  DESIGN.md §3 substitutes a deterministic
//! timeline: measured compute time per local step + the alpha-beta cost of
//! each synchronization round.  Bit counts are *exact* (from the compressor
//! selections), only their translation to seconds is modeled.

pub mod cost_model;

pub use cost_model::{CostModel, RoundTraffic};

/// Running totals for a training run (one worker's perspective; the paper
/// plots per-worker NIC traffic).
#[derive(Debug, Clone, Copy, Default)]
pub struct CommAccount {
    pub up_bits: u64,
    pub down_bits: u64,
    pub sync_rounds: u64,
    pub sim_seconds: f64,
}

impl CommAccount {
    pub fn total_bits(&self) -> u64 {
        self.up_bits + self.down_bits
    }

    pub fn add_round(&mut self, c: crate::collective::WireCost, seconds: f64) {
        self.up_bits += c.up_bits;
        self.down_bits += c.down_bits;
        self.sync_rounds += 1;
        self.sim_seconds += seconds;
    }

    pub fn add_compute(&mut self, seconds: f64) {
        self.sim_seconds += seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::WireCost;

    #[test]
    fn account_accumulates() {
        let mut a = CommAccount::default();
        a.add_round(WireCost { up_bits: 10, down_bits: 20, steps: 2 }, 0.5);
        a.add_compute(1.0);
        a.add_round(WireCost { up_bits: 1, down_bits: 2, steps: 2 }, 0.25);
        assert_eq!(a.total_bits(), 33);
        assert_eq!(a.sync_rounds, 2);
        assert!((a.sim_seconds - 1.75).abs() < 1e-12);
    }
}
