//! Blocked row-major matmul tiles for the batched MLP forward/backprop.
//!
//! The per-sample MLP forward walked weight matrices column-wise
//! (`w[j * n + k]` with `k` in the outer loop — stride-`n` access that
//! thrashes the cache at every hidden width).  These kernels flip the loops:
//! the reduction index `j` is outermost (weight rows stream contiguously),
//! blocked by `jb` so a tile of `w` stays hot across the whole row block of
//! samples.
//!
//! **Order contract:** per output element, the reduction accumulates in
//! ascending `j` — exactly the order of the scalar dot product the
//! per-sample reference computes — and no term is skipped or reassociated,
//! so the batched forward is **bit-identical** to the per-sample forward
//! (pinned by the tests below and by `models::mlp`'s parity test).

/// `out[r, :] = bias` for every row (the accumulator init before
/// [`gemm_acc_rowmajor`] — matches the reference's `z = b[k]` seed).
pub fn init_rows_with_bias(out: &mut [f32], n: usize, bias: &[f32]) {
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len() % n, 0);
    for row in out.chunks_mut(n) {
        row.copy_from_slice(bias);
    }
}

/// `out[r, :] += Σ_j x[r, j] · w[j, :]` — row-major `x` (rows×k), `w` (k×n),
/// `out` (rows×n), with the `j` loop blocked by `jb`.
pub fn gemm_acc_rowmajor(
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    n: usize,
    out: &mut [f32],
    jb: usize,
) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), rows * n);
    let jb = jb.max(1);
    let mut j0 = 0usize;
    while j0 < k {
        let j1 = (j0 + jb).min(k);
        for r in 0..rows {
            let xr = &x[r * k + j0..r * k + j1];
            let or = &mut out[r * n..(r + 1) * n];
            for (j, &xj) in (j0..j1).zip(xr) {
                let wr = &w[j * n..(j + 1) * n];
                for (o, wv) in or.iter_mut().zip(wr) {
                    *o += xj * *wv;
                }
            }
        }
        j0 = j1;
    }
}

/// In-place ReLU over a (rows×n) activation block.
pub fn relu(a: &mut [f32]) {
    for v in a.iter_mut() {
        *v = v.max(0.0);
    }
}

/// A good `jb` for [`gemm_acc_rowmajor`]: as many `w` rows as fit in half of
/// a typical 32 KiB L1d, at least one.
pub fn jb_for(n: usize) -> usize {
    (4096 / n.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The per-sample reference loop (the shape `Mlp::forward` used): output
    /// element (r, col) as a scalar dot accumulated in ascending j.
    fn naive(x: &[f32], rows: usize, k: usize, w: &[f32], n: usize, bias: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * n];
        for r in 0..rows {
            for col in 0..n {
                let mut z = bias[col];
                for j in 0..k {
                    z += w[j * n + col] * x[r * k + j];
                }
                out[r * n + col] = z;
            }
        }
        out
    }

    #[test]
    fn prop_blocked_gemm_bitexact_vs_naive() {
        use crate::util::prop::{forall, Gen};
        forall(40, 0x6E44, |g: &mut Gen| {
            let rows = g.usize_in(1, 9);
            let k = g.usize_in(1, 40);
            let n = g.usize_in(1, 24);
            let x = g.vec(rows * k);
            let w = g.vec(k * n);
            let bias = g.vec(n);
            let expect = naive(&x, rows, k, &w, n, &bias);
            for jb in [1, 2, 7, k, k + 3] {
                let mut out = vec![0.0f32; rows * n];
                init_rows_with_bias(&mut out, n, &bias);
                gemm_acc_rowmajor(&x, rows, k, &w, n, &mut out, jb);
                for (i, (a, b)) in out.iter().zip(&expect).enumerate() {
                    crate::prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "jb={jb} element {i}: {a:?} != {b:?}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut a = [1.0f32, -2.0, 0.0, 3.5];
        relu(&mut a);
        assert_eq!(a, [1.0, 0.0, 0.0, 3.5]);
    }

    #[test]
    fn jb_reasonable() {
        assert!(jb_for(32) >= 1);
        assert_eq!(jb_for(0), 4096);
    }
}
