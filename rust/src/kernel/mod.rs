//! The kernel layer: every O(d) sweep and O(batch·d) matmul the hot paths
//! run, in one place.
//!
//! CSER's wall-clock claim only materializes when local compute is fast
//! enough that communication is the bottleneck being removed (paper §1;
//! Qsparse-local-SGD makes the same compute/communication trade explicit).
//! This module is where that compute lives:
//!
//! * [`dense`] — the elementwise vector kernels (`axpy`, `dot`, softmax, …)
//!   that used to live in `util::math` (which now re-exports them).  Single
//!   slices, in-place, autovectorizing shapes.
//! * [`fused`] — **single-traversal combined ops** replacing the chains of
//!   `axpy`/`axpby` sweeps in the optimizer engine: momentum descent + model
//!   apply, descent + error fold, gradient apply + residual fold, reset
//!   add/sub.  Each fused kernel performs the *identical per-element
//!   operation sequence* as the unfused chain it replaces, so results are
//!   bit-identical (pinned by property tests in `fused`), while touching
//!   each cache line once instead of 2–4 times.
//! * [`gemm`] — blocked row-major matmul tiles for the batched MLP
//!   forward/backprop (`models::mlp`): j-blocked accumulation that keeps the
//!   weight tile in cache across a chunk of samples while preserving the
//!   reference per-element accumulation order (ascending reduction index).
//! * [`scratch`] — the reusable [`Scratch`] handle threaded through
//!   `Compressor::select_with` and the PSync generic path, so top-k's `0..d`
//!   index vector, blockwise mass buffers, and the dense mean/staging
//!   buffers are allocated once and reused across steps.
//!
//! Invariant: nothing in this module allocates in steady state — callers own
//! every buffer (directly or through a [`Scratch`]), and the only growth is
//! a scratch buffer's first use at a new dimension.

pub mod dense;
pub mod fused;
pub mod gemm;
pub mod scratch;

pub use scratch::{with_thread_scratch, Scratch};
