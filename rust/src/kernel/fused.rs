//! Fused single-traversal step kernels.
//!
//! Every optimizer family in the engine is a chain of O(d) sweeps: momentum
//! descent, error fold, model apply, residual fold, reset add/sub.  Run as
//! separate `axpy` calls each sweep streams the full vector through the
//! cache again — at WRN-scale d the step is pure memory traffic, so k
//! traversals cost k× the bandwidth of one.  The kernels here combine the
//! chains the engine actually executes into single passes.
//!
//! **Bit-exactness contract:** each fused kernel performs the *identical
//! per-element operation sequence* as the unfused chain it replaces — same
//! f32 ops, same order, no reassociation, no FMA (Rust never contracts
//! `a * b + c` without explicit `mul_add`).  The property tests below pin
//! every kernel bit-identical to its reference chain, which is what keeps
//! the `engine_parity` and `tcp_equiv` equivalence pins valid across the
//! fusion.

/// The momentum kernel shared by every plan (Sutskever form, paper §3.2):
///   m ← β m + g,   out = η(β m + g);   out = η g at β = 0.
/// `m` may be empty when β = 0 (no momentum state is kept).
pub fn descent_into(beta: f32, m: &mut [f32], g: &[f32], eta: f32, out: &mut [f32]) {
    if beta == 0.0 {
        for (o, gi) in out.iter_mut().zip(g) {
            *o = eta * *gi;
        }
        return;
    }
    for ((o, mi), gi) in out.iter_mut().zip(m.iter_mut()).zip(g) {
        *mi = beta * *mi + *gi;
        *o = eta * (beta * *mi + *gi);
    }
}

/// Fused descent + model apply: `descent_into` immediately followed by
/// `x -= p`, in one traversal.  Replaces the two-sweep chain on the dense
/// SGD and local-descent paths (`p` still holds the step, unchanged — some
/// plans transmit it afterwards).
pub fn descent_apply(beta: f32, m: &mut [f32], g: &[f32], eta: f32, x: &mut [f32], p: &mut [f32]) {
    if beta == 0.0 {
        for ((o, gi), xi) in p.iter_mut().zip(g).zip(x.iter_mut()) {
            *o = eta * *gi;
            *xi -= *o;
        }
        return;
    }
    for (((o, mi), gi), xi) in p.iter_mut().zip(m.iter_mut()).zip(g).zip(x.iter_mut()) {
        *mi = beta * *mi + *gi;
        *o = eta * (beta * *mi + *gi);
        *xi -= *o;
    }
}

/// Fused descent + error fold (EF-SGD, Alg 10): `descent_into` immediately
/// followed by `p += e`, in one traversal.  The message q_i = η(βm+g) + e_i
/// is built without re-streaming `p`.
pub fn descent_plus_error(
    beta: f32,
    m: &mut [f32],
    g: &[f32],
    e: &[f32],
    eta: f32,
    p: &mut [f32],
) {
    if beta == 0.0 {
        for ((o, gi), ei) in p.iter_mut().zip(g).zip(e) {
            *o = eta * *gi;
            *o += *ei;
        }
        return;
    }
    for (((o, mi), gi), ei) in p.iter_mut().zip(m.iter_mut()).zip(g).zip(e) {
        *mi = beta * *mi + *gi;
        *o = eta * (beta * *mi + *gi);
        *o += *ei;
    }
}

/// Fused CSER impl. I apply (general path): `x -= p` and `e -= r` in one
/// traversal — the synced step hits the model while the residual folds into
/// the error, streaming all four vectors once.
pub fn apply_sub_pair(x: &mut [f32], p: &[f32], e: &mut [f32], r: &[f32]) {
    debug_assert_eq!(x.len(), p.len());
    debug_assert_eq!(e.len(), r.len());
    for (((xi, pi), ei), ri) in x.iter_mut().zip(p).zip(e.iter_mut()).zip(r) {
        *xi -= *pi;
        *ei -= *ri;
    }
}

/// Fused reset fold (CSER impl. I general reset, post-PSync):
/// `x += e` then `x -= e_half`, per element, in one traversal.
pub fn add_sub(x: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(x.len(), a.len());
    debug_assert_eq!(x.len(), b.len());
    for ((xi, ai), bi) in x.iter_mut().zip(a).zip(b) {
        *xi += *ai;
        *xi -= *bi;
    }
}

/// Fused QSparse resync apply: advance the anchor by the mean message and
/// reset the model to it — `xhat += p; x = xhat` in one traversal.
pub fn advance_and_copy(xhat: &mut [f32], p: &[f32], x: &mut [f32]) {
    debug_assert_eq!(xhat.len(), p.len());
    debug_assert_eq!(xhat.len(), x.len());
    for ((hi, pi), xi) in xhat.iter_mut().zip(p).zip(x.iter_mut()) {
        *hi += *pi;
        *xi = *hi;
    }
}

/// QSparse sync message (already a single pass; lives here with its family):
/// `p = e + x − xhat`.
pub fn qsparse_message(p: &mut [f32], e: &[f32], x: &[f32], xhat: &[f32]) {
    debug_assert_eq!(p.len(), e.len());
    debug_assert_eq!(p.len(), x.len());
    debug_assert_eq!(p.len(), xhat.len());
    for ((pi, ei), (xi, hi)) in p.iter_mut().zip(e).zip(x.iter().zip(xhat)) {
        *pi = *ei + *xi - *hi;
    }
}

/// `x -= p` — the lone apply where no fusion partner exists.  Identical
/// arithmetic to `axpy(-1.0, p, x)` (IEEE: `x + (−1·p) ≡ x − p`).
#[inline]
pub fn sub_assign(x: &mut [f32], p: &[f32]) {
    debug_assert_eq!(x.len(), p.len());
    for (xi, pi) in x.iter_mut().zip(p) {
        *xi -= *pi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dense::axpy;
    use crate::util::prop::{forall, Gen};

    /// Bit-level equality — tolerance would hide exactly the drift these
    /// kernels must not introduce.
    fn bits_eq(a: &[f32], b: &[f32]) -> Result<(), String> {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("element {i}: {x:?} != {y:?} (bitwise)"));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_descent_apply_bitexact_vs_chain() {
        forall(60, 0xF0_01, |g: &mut Gen| {
            let d = g.usize_in(1, 200);
            let (gr, x0, m0) = (g.vec(d), g.vec(d), g.vec(d));
            let beta = if g.usize_in(0, 2) == 0 { 0.0 } else { 0.9f32 };
            let eta = 0.05f32;
            // reference: unfused chain
            let mut m_ref = if beta > 0.0 { m0.clone() } else { vec![] };
            let mut p_ref = vec![0.0f32; d];
            let mut x_ref = x0.clone();
            descent_into(beta, &mut m_ref, &gr, eta, &mut p_ref);
            axpy(-1.0, &p_ref, &mut x_ref);
            // fused
            let mut m = if beta > 0.0 { m0.clone() } else { vec![] };
            let mut p = vec![0.0f32; d];
            let mut x = x0.clone();
            descent_apply(beta, &mut m, &gr, eta, &mut x, &mut p);
            bits_eq(&x, &x_ref)?;
            bits_eq(&p, &p_ref)?;
            bits_eq(&m, &m_ref)?;
            Ok(())
        });
    }

    #[test]
    fn prop_descent_plus_error_bitexact_vs_chain() {
        forall(60, 0xF0_02, |g: &mut Gen| {
            let d = g.usize_in(1, 200);
            let (gr, e, m0) = (g.vec(d), g.vec(d), g.vec(d));
            let beta = if g.usize_in(0, 2) == 0 { 0.0 } else { 0.9f32 };
            let eta = 0.1f32;
            let mut m_ref = if beta > 0.0 { m0.clone() } else { vec![] };
            let mut p_ref = vec![0.0f32; d];
            descent_into(beta, &mut m_ref, &gr, eta, &mut p_ref);
            axpy(1.0, &e, &mut p_ref);
            let mut m = if beta > 0.0 { m0.clone() } else { vec![] };
            let mut p = vec![0.0f32; d];
            descent_plus_error(beta, &mut m, &gr, &e, eta, &mut p);
            bits_eq(&p, &p_ref)?;
            bits_eq(&m, &m_ref)?;
            Ok(())
        });
    }

    #[test]
    fn prop_apply_sub_pair_bitexact_vs_two_axpys() {
        forall(60, 0xF0_03, |g: &mut Gen| {
            let d = g.usize_in(1, 200);
            let (p, r, x0, e0) = (g.vec(d), g.vec(d), g.vec(d), g.vec(d));
            let mut x_ref = x0.clone();
            let mut e_ref = e0.clone();
            axpy(-1.0, &p, &mut x_ref);
            axpy(-1.0, &r, &mut e_ref);
            let mut x = x0.clone();
            let mut e = e0.clone();
            apply_sub_pair(&mut x, &p, &mut e, &r);
            bits_eq(&x, &x_ref)?;
            bits_eq(&e, &e_ref)?;
            Ok(())
        });
    }

    #[test]
    fn prop_add_sub_bitexact_vs_two_axpys() {
        forall(60, 0xF0_04, |g: &mut Gen| {
            let d = g.usize_in(1, 200);
            let (a, b, x0) = (g.vec(d), g.vec(d), g.vec(d));
            let mut x_ref = x0.clone();
            axpy(1.0, &a, &mut x_ref);
            axpy(-1.0, &b, &mut x_ref);
            let mut x = x0.clone();
            add_sub(&mut x, &a, &b);
            bits_eq(&x, &x_ref)?;
            Ok(())
        });
    }

    #[test]
    fn prop_advance_and_copy_bitexact_vs_chain() {
        forall(60, 0xF0_05, |g: &mut Gen| {
            let d = g.usize_in(1, 200);
            let (p, h0) = (g.vec(d), g.vec(d));
            let mut h_ref = h0.clone();
            axpy(1.0, &p, &mut h_ref);
            let x_ref = h_ref.clone();
            let mut h = h0.clone();
            let mut x = vec![0.0f32; d];
            advance_and_copy(&mut h, &p, &mut x);
            bits_eq(&h, &h_ref)?;
            bits_eq(&x, &x_ref)?;
            Ok(())
        });
    }

    #[test]
    fn prop_sub_assign_bitexact_vs_axpy() {
        forall(40, 0xF0_06, |g: &mut Gen| {
            let d = g.usize_in(1, 200);
            let (p, x0) = (g.vec(d), g.vec(d));
            let mut x_ref = x0.clone();
            axpy(-1.0, &p, &mut x_ref);
            let mut x = x0.clone();
            sub_assign(&mut x, &p);
            bits_eq(&x, &x_ref)?;
            Ok(())
        });
    }

    #[test]
    fn descent_beta_zero_is_plain_direction() {
        let mut m: Vec<f32> = vec![];
        let mut p = vec![0.0f32; 3];
        descent_into(0.0, &mut m, &[1.0, -2.0, 3.0], 0.1, &mut p);
        assert_eq!(p, vec![0.1, -0.2, 0.3]);
    }

    #[test]
    fn descent_matches_sutskever_recursion() {
        let (beta, eta) = (0.9f32, 0.5f32);
        let mut m = vec![0.0f32];
        let mut p = vec![0.0f32];
        descent_into(beta, &mut m, &[2.0], eta, &mut p);
        assert!((p[0] - 1.9).abs() < 1e-6);
        descent_into(beta, &mut m, &[1.0], eta, &mut p);
        assert!((p[0] - 1.76).abs() < 1e-6);
    }

    #[test]
    fn qsparse_message_formula() {
        let e = [1.0f32, 2.0];
        let x = [10.0f32, 20.0];
        let h = [3.0f32, 4.0];
        let mut p = [0.0f32; 2];
        qsparse_message(&mut p, &e, &x, &h);
        assert_eq!(p, [8.0, 18.0]);
    }
}
