//! Dense vector kernels used by every optimizer's O(d) inner loop.
//!
//! All hot-path functions take slices and write in place; callers own the
//! buffers so steady-state training allocates nothing per step.  The forms
//! below autovectorize under `-C opt-level=3` (verified in the §Perf pass).
//! Multi-input single-pass combinations live in [`super::fused`].

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * *xi;
    }
}

/// y = a * x + y_scale * y
#[inline]
pub fn axpby(a: f32, x: &[f32], b: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * *xi + b * *yi;
    }
}

#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    x.iter().map(|a| (*a as f64) * (*a as f64)).sum()
}

#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

#[inline]
pub fn fill(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v = a;
    }
}

/// out = mean of rows (rows all same length as out).
pub fn mean_rows(rows: &[&[f32]], out: &mut [f32]) {
    fill(out, 0.0);
    let inv = 1.0 / rows.len() as f32;
    for r in rows {
        axpy(inv, r, out);
    }
}

/// Numerically-stable softmax in place over `x`.
pub fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut s = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        s += *v;
    }
    let inv = 1.0 / s;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// log(sum(exp(x))) without overflow.
pub fn logsumexp(x: &[f32]) -> f32 {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    m + x.iter().map(|v| (v - m).exp()).sum::<f32>().ln()
}

/// argmax index (first on ties).
pub fn argmax(x: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = [1.0f32, 2.0, 3.0, 4.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[3] > x[2] && x[2] > x[1]);
    }

    #[test]
    fn softmax_large_values_stable() {
        let mut x = [1000.0f32, 1001.0, 999.0];
        softmax_inplace(&mut x);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn logsumexp_matches_naive_small() {
        let x = [0.1f32, 0.2, 0.3];
        let naive = x.iter().map(|v| v.exp()).sum::<f32>().ln();
        assert!((logsumexp(&x) - naive).abs() < 1e-6);
    }

    #[test]
    fn mean_rows_basic() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_rows(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }
}
