//! Reusable hot-path scratch buffers.
//!
//! Compressor selection and the PSync generic path used to rebuild their
//! working buffers on every call: top-k's `0..d` index permutation (4 MB at
//! WRN-scale d), blockwise top-k's per-block mass table, random-k's draw
//! pool, and PSync's dense mean/staging pair.  A [`Scratch`] owns all of
//! them; callers hold one per worker (engine `WorkerState`), per pool
//! thread (`transport::Threaded`), or per calling thread
//! ([`with_thread_scratch`] for `&self` entry points like the `Collective`
//! trait), so steady-state steps allocate nothing — buffers grow on first
//! use at a new dimension and are reused thereafter.

use std::cell::RefCell;

/// The scratch handle threaded through `Compressor::select_with` /
/// `compress_into_with` and the PSync generic path.  All fields are plain
/// buffers; no compressor stores state here between calls (selections stay
/// deterministic in `(ctx, v)` — the scratch only changes *where* the
/// working memory lives).
#[derive(Default)]
pub struct Scratch {
    /// u32 index workspace: top-k's `0..d` permutation, `choose_k`'s draw
    /// pool (random-k / GRBS block draws).
    pub ix: Vec<u32>,
    /// Per-block `(mass, block-id)` ranking workspace (blockwise top-k).
    pub mass: Vec<(f64, u32)>,
    /// Dense f32 workspace A (PSync's mean-of-compressed accumulator).
    pub va: Vec<f32>,
    /// Dense f32 workspace B (PSync's per-worker `C(v)` staging).
    pub vb: Vec<f32>,
    /// Dense f32 workspace C (peer PS server's per-upload decode staging).
    pub vc: Vec<f32>,
    /// Dense f32 workspace D (peer PS path's decoded-aggregate staging —
    /// separate from A so the own-message copy survives the download).
    pub vd: Vec<f32>,
    /// Union-mask workspace (peer PS server's aggregate support).
    pub mask: Vec<bool>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// The `0..d` index vector, rebuilt in place (no allocation once grown).
    pub fn iota(&mut self, d: usize) -> &mut Vec<u32> {
        self.ix.clear();
        self.ix.extend(0..d as u32);
        &mut self.ix
    }

    /// Move the dense workspace pair out, both zero-filled at length `d`
    /// (A is an accumulator and needs the zeros; B is fully overwritten by
    /// its users, but is cleared the same way — an O(d) memset is noise
    /// next to the O(n·d) round it serves, and a uniform contract is harder
    /// to misuse).  Return with [`Scratch::put_dense_pair`] so the capacity
    /// is reused.
    pub fn take_dense_pair(&mut self, d: usize) -> (Vec<f32>, Vec<f32>) {
        let mut a = std::mem::take(&mut self.va);
        let mut b = std::mem::take(&mut self.vb);
        a.clear();
        a.resize(d, 0.0);
        b.clear();
        b.resize(d, 0.0);
        (a, b)
    }

    pub fn put_dense_pair(&mut self, a: Vec<f32>, b: Vec<f32>) {
        self.va = a;
        self.vb = b;
    }

    /// Move workspace A out alone, zero-filled at length `d` (for paths that
    /// need a single dense staging buffer); return with
    /// [`Scratch::put_dense`].
    pub fn take_dense(&mut self, d: usize) -> Vec<f32> {
        let mut a = std::mem::take(&mut self.va);
        a.clear();
        a.resize(d, 0.0);
        a
    }

    pub fn put_dense(&mut self, a: Vec<f32>) {
        self.va = a;
    }
}

thread_local! {
    static TL_SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Run `f` with this thread's persistent [`Scratch`] — the reuse vehicle for
/// `&self` entry points that cannot hold one (the `Collective` trait's
/// in-process backend, wire-codec decode).  Must not be re-entered from
/// inside `f` (the engine/peer paths thread explicit scratch handles and
/// never call back into this).
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    TL_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iota_rebuilds_without_shrinking_capacity() {
        let mut s = Scratch::new();
        assert_eq!(s.iota(4).as_slice(), &[0, 1, 2, 3]);
        let cap = s.ix.capacity();
        assert_eq!(s.iota(3).as_slice(), &[0, 1, 2]);
        assert!(s.ix.capacity() >= cap.min(3));
    }

    #[test]
    fn dense_pair_roundtrip_reuses_capacity() {
        let mut s = Scratch::new();
        let (a, b) = s.take_dense_pair(100);
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|&x| x == 0.0));
        let cap = a.capacity();
        s.put_dense_pair(a, b);
        let (a2, _b2) = s.take_dense_pair(50);
        assert_eq!(a2.len(), 50);
        assert!(a2.capacity() >= cap.min(50));
    }

    #[test]
    fn thread_scratch_persists_across_calls() {
        with_thread_scratch(|s| {
            s.iota(128);
        });
        with_thread_scratch(|s| {
            assert!(s.ix.capacity() >= 128);
        });
    }
}
