//! Minimal JSON writer + parser (no serde available offline).
//!
//! The writer covers everything the harness emits (results files, curves);
//! the parser covers everything we consume (artifacts/manifest.json written
//! by python/compile/aot.py).  It is a strict, recursive-descent parser for
//! the JSON subset json.dump produces: objects, arrays, strings (with \u
//! escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Tiny builder for writing results files without serde.
pub struct JsonWriter {
    buf: String,
    stack: Vec<bool>, // per open scope: "has at least one element already"
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    pub fn new() -> Self {
        Self { buf: String::new(), stack: vec![] }
    }
    fn comma(&mut self) {
        if let Some(has) = self.stack.last_mut() {
            if *has {
                self.buf.push(',');
            }
            *has = true;
        }
    }
    pub fn begin_obj(&mut self) -> &mut Self {
        self.comma();
        self.buf.push('{');
        self.stack.push(false);
        self
    }
    pub fn end_obj(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push('}');
        self
    }
    pub fn begin_arr(&mut self) -> &mut Self {
        self.comma();
        self.buf.push('[');
        self.stack.push(false);
        self
    }
    pub fn end_arr(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push(']');
        self
    }
    pub fn key(&mut self, k: &str) -> &mut Self {
        self.comma();
        let _ = write!(self.buf, "\"{}\":", escape(k));
        // the value that follows must not emit a comma
        if let Some(has) = self.stack.last_mut() {
            *has = false;
        }
        self
    }
    pub fn str(&mut self, v: &str) -> &mut Self {
        self.comma();
        let _ = write!(self.buf, "\"{}\"", escape(v));
        self
    }
    pub fn num(&mut self, v: f64) -> &mut Self {
        self.comma();
        if v.is_finite() {
            let _ = write!(self.buf, "{v}");
        } else {
            self.buf.push_str("null");
        }
        self
    }
    pub fn int(&mut self, v: i64) -> &mut Self {
        self.comma();
        let _ = write!(self.buf, "{v}");
        self
    }
    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.comma();
        let _ = write!(self.buf, "{v}");
        self
    }
    pub fn nums(&mut self, vs: &[f64]) -> &mut Self {
        self.begin_arr();
        for &v in vs {
            self.num(v);
        }
        self.end_arr()
    }
    pub fn finish(self) -> String {
        debug_assert!(self.stack.is_empty(), "unbalanced JSON writer");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"models": {"tiny": {"params": 123, "use_pallas": false,
            "files": ["a.txt", "b.bin"]}}, "x": -1.5e3, "ok": true, "n": null}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(
            j.get("models").unwrap().get("tiny").unwrap().get("params").unwrap().as_usize(),
            Some(123)
        );
        assert_eq!(j.get("x").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(
            j.get("models").unwrap().get("tiny").unwrap().get("files").unwrap().as_arr().unwrap()[0]
                .as_str(),
            Some("a.txt")
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nbA\"c""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nbA\"c"));
    }

    #[test]
    fn writer_roundtrip() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("name").str("cser");
        w.key("vals").nums(&[1.0, 2.5]);
        w.key("n").int(42);
        w.key("nested").begin_obj();
        w.key("ok").bool(true);
        w.end_obj();
        w.end_obj();
        let s = w.finish();
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("cser"));
        assert_eq!(j.get("vals").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(j.get("nested").unwrap().get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn reject_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }
}
