//! Deterministic pseudo-random generators.
//!
//! The build environment is offline (no `rand` crate), and more importantly
//! GRBS *requires* a deterministic generator with an explicit seed schedule:
//! every worker must draw the identical block permutation in round `t`
//! (paper §3.3 — "synchronized random seed").  We use SplitMix64 for seeding
//! and xoshiro256++ for the stream; both are tiny, fast and well studied.

/// SplitMix64 — used to expand a (seed, stream) pair into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Independent stream derived from (seed, stream id) — the GRBS schedule
    /// uses `Rng::stream(global_seed, round)` so that selection depends only
    /// on quantities all workers share.
    pub fn stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xD2B74407B1CE6E93);
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).  Uses Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped: simpler,
    /// and gradient noise does not need the extra throughput).
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher–Yates partial shuffle: returns the first `k` entries of a
    /// random permutation of 0..n (the GRBS block draw).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<u32> {
        let mut pool = Vec::new();
        self.choose_k_with(n, k, &mut pool)
    }

    /// [`Rng::choose_k`] with a caller-owned draw pool: the dense `0..n`
    /// index vector is rebuilt in `pool` (no allocation once grown) instead
    /// of being freshly allocated per draw.  Identical RNG consumption and
    /// results to `choose_k` — only the working memory moves.
    pub fn choose_k_with(&mut self, n: usize, k: usize, pool: &mut Vec<u32>) -> Vec<u32> {
        debug_assert!(k <= n);
        pool.clear();
        pool.extend(0..n as u32);
        for i in 0..k {
            let j = i + self.below(n - i);
            pool.swap(i, j);
        }
        pool[..k].to_vec()
    }

    /// Sample from a categorical distribution given cumulative weights.
    pub fn categorical(&mut self, cdf: &[f32]) -> usize {
        let u = self.f32() * cdf[cdf.len() - 1];
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::stream(42, 0);
        let mut b = Rng::stream(42, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(13);
            assert!(n < 13);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn choose_k_is_a_k_subset() {
        let mut r = Rng::new(9);
        let k = r.choose_k(100, 17);
        assert_eq!(k.len(), 17);
        let mut s = k.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 17);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn choose_k_with_matches_choose_k() {
        // Same RNG consumption, same subset — only the pool's home differs.
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut pool = Vec::new();
        for t in 0usize..50 {
            let n = 3 + (t % 97);
            let k = 1 + (t % n.min(7));
            assert_eq!(a.choose_k(n, k), b.choose_k_with(n, k, &mut pool));
        }
    }

    #[test]
    fn choose_k_uniformity() {
        // each of 10 blocks selected ~ k/n of the time
        let mut counts = [0u32; 10];
        for round in 0..5000 {
            let mut r = Rng::stream(1, round);
            for b in r.choose_k(10, 3) {
                counts[b as usize] += 1;
            }
        }
        for &c in &counts {
            let p = c as f64 / 5000.0;
            assert!((p - 0.3).abs() < 0.04, "p={p}");
        }
    }
}
