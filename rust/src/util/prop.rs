//! Property-based testing mini-framework (no `proptest` offline).
//!
//! `forall(cases, seed, |g| ...)` runs a closure over `cases` independently
//! seeded generator instances; on failure it reports the failing case seed so
//! the case can be replayed deterministically:
//!
//! ```text
//! property failed at case 17 (replay with Gen::replay(BASE_SEED, 17)): ...
//! ```
//!
//! `Gen` wraps [`crate::util::rng::Rng`] with convenience draws shaped for
//! this codebase (vectors, worker counts, compressor ratios...).

use super::rng::Rng;

pub struct Gen {
    pub rng: Rng,
    pub case: u64,
}

impl Gen {
    pub fn replay(base_seed: u64, case: u64) -> Self {
        Gen { rng: Rng::stream(base_seed, case), case }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Random normal vector of length `d` with occasional adversarial
    /// entries (zeros, huge magnitudes) to poke edge cases.
    pub fn vec(&mut self, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        self.rng.fill_normal(&mut v, 1.0);
        // sprinkle edge-case values
        for _ in 0..(d / 16).max(1) {
            let i = self.rng.below(d);
            v[i] = match self.rng.below(4) {
                0 => 0.0,
                1 => 1e6,
                2 => -1e-6,
                _ => v[i],
            };
        }
        v
    }

    /// Plain normal vector without adversarial magnitudes — for properties
    /// that are exact in real arithmetic but accumulate fp error when fed
    /// 1e6-scale outliers (e.g. the Lemma 1 invariant).
    pub fn vec_smooth(&mut self, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        self.rng.fill_normal(&mut v, 1.0);
        v
    }

    /// `n` vectors of length `d` (one per simulated worker).
    pub fn worker_vecs(&mut self, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n).map(|_| self.vec(d)).collect()
    }

    /// Smooth variant of [`Self::worker_vecs`].
    pub fn worker_vecs_smooth(&mut self, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n).map(|_| self.vec_smooth(d)).collect()
    }

    /// A power of two in [1, max_pow2].
    pub fn pow2(&mut self, max_exp: u32) -> usize {
        1usize << self.rng.below(max_exp as usize + 1)
    }
}

/// Run `f` for `cases` cases. Panics (with replay info) on the first failure.
pub fn forall<F: FnMut(&mut Gen) -> Result<(), String>>(cases: u64, base_seed: u64, mut f: F) {
    for case in 0..cases {
        let mut g = Gen::replay(base_seed, case);
        if let Err(msg) = f(&mut g) {
            panic!("property failed at case {case} (replay with Gen::replay({base_seed}, {case})): {msg}");
        }
    }
}

/// Assert helper returning Result for use inside `forall` closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Approximate equality with context for floating-point properties.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

pub fn slices_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("idx {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, 1, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed at case 3")]
    fn forall_reports_case() {
        forall(10, 1, |g| {
            if g.case == 3 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut a = Gen::replay(9, 4);
        let mut b = Gen::replay(9, 4);
        assert_eq!(a.vec(32), b.vec(32));
    }

    #[test]
    fn slices_close_detects_mismatch() {
        assert!(slices_close(&[1.0, 2.0], &[1.0, 2.5], 1e-3).is_err());
        assert!(slices_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
    }
}
