//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

/// Error type for CLI parsing (implements std::error::Error so `?` works
/// under anyhow in main).
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl From<String> for CliError {
    fn from(s: String) -> Self {
        CliError(s)
    }
}

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name). `known` lists accepted flag
    /// names (without `--`); anything else is rejected.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, known: &[&str]) -> Result<Self, CliError> {
        let mut a = Args { known: known.iter().map(|s| s.to_string()).collect(), ..Default::default() };
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if !a.known.iter().any(|k| k == &key) {
                    return Err(CliError(format!("unknown flag --{key} (known: {})", a.known.join(", "))));
                }
                let val = match val {
                    Some(v) => v,
                    None => {
                        // take the next token as the value unless it looks
                        // like another flag — then treat as boolean.
                        match it.peek() {
                            Some(nxt) if !nxt.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                a.flags.insert(key, val);
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError(format!("--{key}: expected number, got '{v}'"))),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError(format!("--{key}: expected bool, got '{v}'"))),
        }
    }

    /// Comma-separated list of usize, e.g. `--rc 32,256,1024`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| t.trim().parse().map_err(|_| CliError(format!("--{key}: bad entry '{t}'"))))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(argv("train --lr 0.5 --epochs=10 --verbose --rc 32,64"),
                            &["lr", "epochs", "verbose", "rc"]).unwrap();
        assert_eq!(a.positional(), ["train"]);
        assert_eq!(a.f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.usize("epochs", 0).unwrap(), 10);
        assert!(a.bool("verbose", false).unwrap());
        assert_eq!(a.usize_list("rc", &[]).unwrap(), vec![32, 64]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse(argv("--nope 1"), &["yes"]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), &["x"]).unwrap();
        assert_eq!(a.usize("x", 7).unwrap(), 7);
        assert_eq!(a.str("x", "d"), "d");
    }
}
