//! In-tree replacements for the usual crates.io utilities (offline build) +
//! shared numeric kernels.

pub mod bench;
pub mod cli;
pub mod json;
pub mod math;
pub mod pool;
pub mod prop;
pub mod rng;
