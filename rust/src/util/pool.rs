//! Scoped worker thread pool (no `rayon`/`tokio` offline).
//!
//! The coordinator computes per-worker gradients in parallel; the experiment
//! harness runs independent (optimizer, R_C, seed) cells in parallel.  Both
//! only need a fork-join `scope_map` over indices, which `std::thread::scope`
//! provides safely without unsafe code.

/// Run `f(i)` for `i in 0..n` on up to `threads` OS threads; returns results
/// in index order.  `f` must be `Sync` (it is shared by reference).
pub fn scope_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return vec![];
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker finished")).collect()
}

/// Number of hardware threads (bounded to avoid oversubscription in benches).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = scope_map(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(scope_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = scope_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn parallel_side_effects_visible() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        scope_map(64, 8, |_| counter.fetch_add(1, Ordering::SeqCst));
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }
}
