//! Scoped worker thread pool (no `rayon`/`tokio` offline).
//!
//! The coordinator computes per-worker gradients in parallel; the experiment
//! harness runs independent (optimizer, R_C, seed) cells in parallel; the
//! batched MLP backprop fans sample chunks out.  All only need fork-join
//! primitives over indices, which `std::thread::scope` provides safely
//! without unsafe code.
//!
//! Work distribution is **chunked ownership**: the output is pre-split into
//! contiguous chunks (`chunks_mut`, i.e. `split_at_mut` repeatedly) held in
//! a claim queue; a worker takes one short lock to claim a whole chunk, then
//! fills its exclusively-owned slice lock-free.  The earlier design wrapped
//! every output slot in its own `Mutex` — one lock acquisition *per
//! element*; now locking is one acquisition per chunk, and oversubscribing
//! chunks (4× threads) keeps the dynamic load balancing.

use std::sync::Mutex;

/// Run `f(i)` for `i in 0..n` on up to `threads` OS threads; returns results
/// in index order.  `f` must be `Sync` (it is shared by reference).
pub fn scope_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return vec![];
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    // 4× oversubscription: enough chunks for dynamic balancing, few enough
    // that the per-chunk lock is noise.
    let chunk = n.div_ceil(threads * 4).max(1);
    let queue = Mutex::new(
        out.chunks_mut(chunk).enumerate().map(|(ci, s)| (ci * chunk, s)).collect::<Vec<_>>(),
    );
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let claimed = queue.lock().unwrap().pop();
                let (base, slice) = match claimed {
                    Some(c) => c,
                    None => break,
                };
                for (j, slot) in slice.iter_mut().enumerate() {
                    *slot = Some(f(base + j));
                }
            });
        }
    });
    drop(queue); // release the chunk borrows of `out` before consuming it
    out.into_iter().map(|v| v.expect("worker finished")).collect()
}

/// Run `f(i, &mut items[i])` for every item on up to `threads` OS threads.
/// Each invocation exclusively owns its item (claimed whole from the queue —
/// no per-element locking), so items can carry heavy per-task state: grad
/// buffers, scratch arenas, samplers.  Items are heavyweight work units here
/// (one per worker/chunk), so the claim granularity is one item.
pub fn scope_zip<A: Send, F: Fn(usize, &mut A) + Sync>(items: &mut [A], threads: usize, f: F) {
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return;
    }
    if threads == 1 {
        for (i, a) in items.iter_mut().enumerate() {
            f(i, a);
        }
        return;
    }
    let queue = Mutex::new(items.iter_mut().enumerate().collect::<Vec<_>>());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let claimed = queue.lock().unwrap().pop();
                let (i, a) = match claimed {
                    Some(c) => c,
                    None => break,
                };
                f(i, a);
            });
        }
    });
}

/// Number of hardware threads (bounded to avoid oversubscription in benches).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = scope_map(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn maps_in_order_at_awkward_sizes() {
        // n not a multiple of the chunk size, n < threads, n == 1
        for (n, threads) in [(97, 8), (3, 16), (1, 4), (33, 2)] {
            let out = scope_map(n, threads, |i| i + 7);
            assert_eq!(out, (0..n).map(|i| i + 7).collect::<Vec<_>>(), "n={n} t={threads}");
        }
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(scope_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = scope_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn parallel_side_effects_visible() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        scope_map(64, 8, |_| counter.fetch_add(1, Ordering::SeqCst));
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn zip_visits_every_item_exactly_once_with_its_index() {
        let mut items: Vec<(usize, u32)> = (0..37).map(|i| (i, 0u32)).collect();
        scope_zip(&mut items, 8, |i, it| {
            assert_eq!(i, it.0);
            it.1 += 1;
        });
        assert!(items.iter().all(|&(_, c)| c == 1));
    }

    #[test]
    fn zip_serial_and_empty() {
        let mut items = vec![1u64, 2, 3];
        scope_zip(&mut items, 1, |i, it| *it += i as u64);
        assert_eq!(items, vec![1, 3, 5]);
        let mut none: Vec<u8> = vec![];
        scope_zip(&mut none, 4, |_i, _it| {});
    }
}
