//! Criterion-style micro-benchmark harness (no `criterion` offline).
//!
//! Cargo `[[bench]] harness = false` targets call [`Bench::run`] with named
//! closures.  The harness warms up, picks an iteration count targeting a
//! fixed measurement window, reports median / mean / p10 / p90 over samples,
//! and optionally writes a JSON record so EXPERIMENTS.md numbers are
//! regenerable.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn human(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.1} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        }
        format!(
            "{:<44} median {:>12}  mean {:>12}  [p10 {:>12}, p90 {:>12}]  ({} iters x {} samples)",
            self.name,
            fmt(self.median_ns),
            fmt(self.mean_ns),
            fmt(self.p10_ns),
            fmt(self.p90_ns),
            self.iters_per_sample,
            self.samples
        )
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub window: Duration,
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Prevent the optimizer from deleting a computed value (ptr read volatile).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

impl Bench {
    pub fn new() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            window: Duration::from_millis(700),
            samples: 12,
            results: vec![],
        }
    }

    /// Fast profile for long-running "macro" benches (whole training runs).
    pub fn macro_bench() -> Self {
        Bench { warmup: Duration::ZERO, window: Duration::ZERO, samples: 1, results: vec![] }
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) {
        // Warmup + calibration
        let mut iters: u64 = 1;
        if self.window > Duration::ZERO {
            let start = Instant::now();
            let mut n = 0u64;
            while start.elapsed() < self.warmup {
                f();
                n += 1;
            }
            let per = self.warmup.as_nanos() as f64 / n.max(1) as f64;
            iters = ((self.window.as_nanos() as f64 / self.samples as f64) / per).max(1.0) as u64;
        }

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let p10 = times[times.len() / 10];
        let p90 = times[times.len() * 9 / 10];
        let r = BenchResult {
            name: name.to_string(),
            median_ns: median,
            mean_ns: mean,
            p10_ns: p10,
            p90_ns: p90,
            iters_per_sample: iters,
            samples: times.len(),
        };
        println!("{}", r.human());
        self.results.push(r);
    }

    /// Throughput helper: elements processed per second at the median.
    pub fn throughput(&self, name: &str, elems: u64) -> Option<f64> {
        self.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| elems as f64 / (r.median_ns / 1e9))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench { warmup: Duration::from_millis(5), window: Duration::from_millis(20), samples: 4, results: vec![] };
        let mut acc = 0u64;
        b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].median_ns > 0.0);
    }
}
