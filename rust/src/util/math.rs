//! Compatibility shim: the dense vector kernels moved to
//! [`crate::kernel::dense`] (the dedicated kernel layer, which also holds
//! the fused single-pass variants in [`crate::kernel::fused`] and the
//! batched matmul tiles in [`crate::kernel::gemm`]).  Existing `math::`
//! call sites keep working through this re-export; new hot-path code should
//! use `crate::kernel` directly.

pub use crate::kernel::dense::*;
