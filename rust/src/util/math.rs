//! Compatibility shim: the dense vector kernels moved to
//! [`crate::kernel::dense`] (the dedicated kernel layer, which also holds
//! the fused single-pass variants in [`crate::kernel::fused`] and the
//! batched matmul tiles in [`crate::kernel::gemm`]).  Existing `math::`
//! call sites keep working through this re-export; new hot-path code
//! imports `crate::kernel` directly — the bucketed sync pipeline
//! (`transport::pipeline`, `collective::bucket`, `engine::pipeline`) was
//! written against `kernel::dense` and adds no new `util::math` callers.
//! The shim retires once the remaining legacy call sites (benches,
//! harnesses, model zoo) migrate.

pub use crate::kernel::dense::*;
