//! Pure-Rust training substrate: models with hand-written fwd/bwd over a
//! flat parameter vector.
//!
//! These power the table/figure sweeps (hundreds of training runs), where
//! going through the PJRT artifact per gradient would be needlessly slow and
//! would measure XLA rather than the optimizers.  The transformer end-to-end
//! path (examples/lm_e2e.rs) uses the real L2/L1 artifacts instead.
//!
//! All models implement [`GradModel`]: stochastic gradient of the minibatch
//! loss at a given flat parameter vector, plus evaluation metrics.  Gradients
//! are verified against central finite differences in each model's tests.

pub mod layout;
pub mod logistic;
pub mod mlp;
pub mod quadratic;

pub use layout::ParamLayout;
pub use logistic::Logistic;
pub use mlp::Mlp;
pub use quadratic::Quadratic;

use crate::data::ClassDataset;

/// Reusable per-caller gradient-evaluation scratch, opaque to callers.
///
/// The batched MLP backprop owns a per-model arena here (gathered inputs,
/// activation/logit tiles, per-chunk partial gradients) so steady-state
/// training allocates nothing per `loss_grad_scratch` call; models without
/// internal buffers ignore it.  Trainers hold one per worker.
#[derive(Default)]
pub struct ModelScratch {
    pub(crate) mlp: mlp::MlpScratch,
}

impl ModelScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enable intra-gradient chunk parallelism: the MLP fans sample chunks
    /// out over up to `threads` OS threads via `util::pool`.  Serial by
    /// default — the trainers already parallelize across workers, so nested
    /// fan-out only pays off for single-worker callers (benches, eval).
    pub fn parallel(threads: usize) -> Self {
        ModelScratch { mlp: mlp::MlpScratch::with_threads(threads) }
    }
}

/// A model trainable by the distributed optimizers.
pub trait GradModel: Send + Sync {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;

    /// Tensor boundaries of the flat parameter vector (drives layer-aware
    /// gradient bucketing — see [`ParamLayout`]).  Default: one dense
    /// segment; models with named tensors override.
    fn param_layout(&self) -> ParamLayout {
        ParamLayout::dense(self.dim())
    }

    /// Initialize parameters (deterministic in `seed`).
    fn init(&self, seed: u64) -> Vec<f32>;

    /// Minibatch loss + gradient at `params` over `idxs` into `grad`
    /// (overwritten). Returns the minibatch mean loss.
    fn loss_grad(&self, params: &[f32], data: &ClassDataset, idxs: &[u32], grad: &mut [f32]) -> f32;

    /// [`GradModel::loss_grad`] with caller-owned scratch — the hot-path
    /// entry (trainers hold a [`ModelScratch`] per worker and reuse it every
    /// step).  Default: delegates to `loss_grad` for models that keep no
    /// working buffers.
    fn loss_grad_scratch(
        &self,
        params: &[f32],
        data: &ClassDataset,
        idxs: &[u32],
        grad: &mut [f32],
        scratch: &mut ModelScratch,
    ) -> f32 {
        let _ = scratch;
        self.loss_grad(params, data, idxs, grad)
    }

    /// Mean loss over a whole dataset (no gradient).
    fn loss(&self, params: &[f32], data: &ClassDataset) -> f32;

    /// Top-1 accuracy over a dataset.
    fn accuracy(&self, params: &[f32], data: &ClassDataset) -> f32;
}

/// Central finite-difference check used by each model's tests.
#[cfg(test)]
pub(crate) fn fd_check(model: &dyn GradModel, data: &ClassDataset, tol: f32) {
    use crate::util::rng::Rng;
    let mut params = model.init(7);
    let d = model.dim();
    let idxs: Vec<u32> = (0..data.len().min(8) as u32).collect();
    let mut grad = vec![0.0f32; d];
    model.loss_grad(&params, data, &idxs, &mut grad);
    let mut rng = Rng::new(99);
    // check a few random coordinates
    let eps = 1e-3f32;
    let sub = ClassDataset {
        dim: data.dim,
        classes: data.classes,
        x: idxs.iter().flat_map(|&i| data.feat(i as usize).to_vec()).collect(),
        y: idxs.iter().map(|&i| data.y[i as usize]).collect(),
    };
    for _ in 0..20 {
        let j = rng.below(d);
        let orig = params[j];
        params[j] = orig + eps;
        let lp = model.loss(&params, &sub);
        params[j] = orig - eps;
        let lm = model.loss(&params, &sub);
        params[j] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - grad[j]).abs() < tol * (1.0 + fd.abs()),
            "coord {j}: fd={fd} analytic={}",
            grad[j]
        );
    }
}
