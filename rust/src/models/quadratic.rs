//! Distributed least squares: f(x) = 0.5‖Ax − b‖²/m over row shards.
//!
//! The theory-validation model: L-smooth with known L = λ_max(AᵀA)/m, known
//! minimizer, and exactly computable ‖∇F‖ — used by the ablation bench that
//! checks Theorem 1's error-term scaling in η, H, δ1, δ2.
//!
//! Rows of A (and entries of b) are generated per "sample index", so it can
//! reuse the ClassDataset sharding machinery: `data.feat(i)` is row a_i and
//! the target is stored separately via `targets`.

use super::GradModel;
use crate::data::ClassDataset;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Quadratic {
    pub dim: usize,
    /// b_i targets, one per dataset row (same length as data).
    pub targets: Vec<f32>,
}

impl Quadratic {
    /// Build a synthetic least-squares instance on top of `data`'s features:
    /// picks a ground-truth x*, sets b_i = <a_i, x*> + noise.
    pub fn from_features(data: &ClassDataset, noise: f32, seed: u64) -> (Self, Vec<f32>) {
        let mut rng = Rng::stream(seed, 0x4A4);
        let mut xstar = vec![0.0f32; data.dim];
        rng.fill_normal(&mut xstar, 1.0);
        let targets = (0..data.len())
            .map(|i| {
                let dot: f32 = data.feat(i).iter().zip(&xstar).map(|(a, x)| a * x).sum();
                dot + rng.normal() * noise
            })
            .collect();
        (Quadratic { dim: data.dim, targets }, xstar)
    }
}

impl GradModel for Quadratic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::stream(seed, 0x4A5);
        let mut p = vec![0.0f32; self.dim];
        rng.fill_normal(&mut p, 1.0);
        p
    }

    fn loss_grad(&self, params: &[f32], data: &ClassDataset, idxs: &[u32], grad: &mut [f32]) -> f32 {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let inv = 1.0 / idxs.len() as f32;
        let mut loss = 0.0f32;
        for &gi in idxs {
            let a = data.feat(gi as usize);
            let r: f32 = a.iter().zip(params).map(|(ai, xi)| ai * xi).sum::<f32>()
                - self.targets[gi as usize];
            loss += 0.5 * r * r * inv;
            for (gj, aj) in grad.iter_mut().zip(a) {
                *gj += inv * r * aj;
            }
        }
        loss
    }

    fn loss(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let mut loss = 0.0f32;
        for i in 0..data.len() {
            let a = data.feat(i);
            let r: f32 =
                a.iter().zip(params).map(|(ai, xi)| ai * xi).sum::<f32>() - self.targets[i];
            loss += 0.5 * r * r;
        }
        loss / data.len() as f32
    }

    /// "Accuracy" for a regression model: fraction of residuals under 0.5
    /// (keeps the GradModel interface uniform for the harness).
    fn accuracy(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let mut ok = 0usize;
        for i in 0..data.len() {
            let a = data.feat(i);
            let r: f32 =
                a.iter().zip(params).map(|(ai, xi)| ai * xi).sum::<f32>() - self.targets[i];
            if r.abs() < 0.5 {
                ok += 1;
            }
        }
        ok as f32 / data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instance() -> (ClassDataset, Quadratic, Vec<f32>) {
        let (tr, _) = ClassDataset::gaussian_mixture(2, 12, 256, 16, 1.0, 1.0, 0.0, 8);
        let (q, xstar) = Quadratic::from_features(&tr, 0.0, 9);
        (tr, q, xstar)
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (tr, q, _) = instance();
        super::super::fd_check(&q, &tr, 1e-2);
    }

    #[test]
    fn gd_recovers_xstar_noiseless() {
        let (tr, q, xstar) = instance();
        let mut x = q.init(1);
        let mut g = vec![0.0f32; q.dim()];
        let idxs: Vec<u32> = (0..tr.len() as u32).collect();
        for _ in 0..500 {
            q.loss_grad(&x, &tr, &idxs, &mut g);
            for (xj, gj) in x.iter_mut().zip(&g) {
                *xj -= 0.02 * gj;
            }
        }
        let err: f32 = x.iter().zip(&xstar).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(err < 1e-2, "err={err}");
    }
}
