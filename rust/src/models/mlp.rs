//! Two-layer MLP (ReLU, softmax cross-entropy) with batched backprop.
//!
//! The non-convex stand-in for WRN-40-8 / ResNet-50 in the sweeps
//! (DESIGN.md §3): small enough that a full Table-4 sweep (6 optimizers ×
//! 11 ratios × lr grid × seeds) finishes in minutes, non-convex enough that
//! aggressive compression noise visibly hurts/destroys convergence.
//!
//! Flat layout: [W1 (in×h) | b1 (h) | W2 (h×c) | b2 (c)], row-major W.
//!
//! Gradient evaluation is structured as **batched tiles over sample
//! chunks** (`kernel::gemm`): inputs are gathered once per chunk, the
//! forward runs as j-blocked row-major matmuls (weight rows stream
//! contiguously instead of the per-sample column walk), and chunks fan out
//! over `util::pool::scope_zip` when the caller opts in.  All working
//! buffers live in the caller-owned [`MlpScratch`] arena, so steady-state
//! training allocates nothing per call (the seed implementation copied `w2`
//! and allocated three scratch vectors per minibatch).
//!
//! Numerics: within one chunk the per-element accumulation order is
//! *identical* to the per-sample reference ([`Mlp::loss_grad_reference`]) —
//! bit-identical results, pinned by a test below.  Across chunks
//! (`batch > CHUNK`) partial gradients are reduced serially in chunk order,
//! so multi-chunk results differ from the reference only by f32 summation
//! order (finite-difference-checked; tolerance documented in DESIGN.md
//! §Perf) while staying deterministic for any thread count.

use super::{GradModel, ModelScratch};
use crate::data::ClassDataset;
use crate::kernel::dense::{argmax, logsumexp};
use crate::kernel::{dense, gemm};
use crate::util::rng::Rng;

/// Samples per batched tile.  Fixed (not thread-derived) so results are
/// independent of the machine's parallelism.
const CHUNK: usize = 64;

/// Per-chunk working buffers (one set per concurrently-processed chunk).
#[derive(Default)]
struct ChunkBuf {
    /// Gathered inputs, chunk×in.
    x: Vec<f32>,
    /// Hidden activations, chunk×h.
    a: Vec<f32>,
    /// Logits → dlogits, chunk×c.
    dl: Vec<f32>,
    /// Per-sample hidden gradient, h.
    dz: Vec<f32>,
    /// Partial gradient, d (sized only when more than one chunk is live).
    grad: Vec<f32>,
    loss: f32,
}

/// The caller-owned arena for [`Mlp`] gradient evaluation.  Reused across
/// calls; grows on first use at a new batch/model shape and never shrinks.
#[derive(Default)]
pub struct MlpScratch {
    threads: usize,
    chunks: Vec<ChunkBuf>,
}

impl MlpScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fan sample chunks out over up to `threads` OS threads (serial when
    /// 0/1 — the default, since trainers already parallelize over workers).
    pub fn with_threads(threads: usize) -> Self {
        MlpScratch { threads, chunks: Vec::new() }
    }
}

#[derive(Clone, Debug)]
pub struct Mlp {
    pub input: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl Mlp {
    pub fn new(input: usize, hidden: usize, classes: usize) -> Self {
        Mlp { input, hidden, classes }
    }

    fn split<'a>(&self, p: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let w1 = &p[..i * h];
        let b1 = &p[i * h..i * h + h];
        let w2 = &p[i * h + h..i * h + h + h * c];
        let b2 = &p[i * h + h + h * c..];
        (w1, b1, w2, b2)
    }

    /// logits for one sample into `logits`; returns hidden activations in `a`.
    fn forward(&self, p: &[f32], x: &[f32], a: &mut [f32], logits: &mut [f32]) {
        let (w1, b1, w2, b2) = self.split(p);
        let (i, h, c) = (self.input, self.hidden, self.classes);
        for k in 0..h {
            // W1 row-major [in, h]: column k
            let mut z = b1[k];
            for j in 0..i {
                z += w1[j * h + k] * x[j];
            }
            a[k] = z.max(0.0);
        }
        for m in 0..c {
            let mut z = b2[m];
            for k in 0..h {
                z += w2[k * c + m] * a[k];
            }
            logits[m] = z;
        }
    }

    /// The per-sample scalar reference implementation (the seed's
    /// `loss_grad`, kept verbatim): the numerical spec the batched path is
    /// pinned against.  Allocates per call — tests and parity checks only.
    pub fn loss_grad_reference(
        &self,
        params: &[f32],
        data: &ClassDataset,
        idxs: &[u32],
        grad: &mut [f32],
    ) -> f32 {
        debug_assert_eq!(grad.len(), self.dim());
        grad.iter_mut().for_each(|g| *g = 0.0);
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let (w1o, _b1o, w2o, _b2o) = (0, i * h, i * h + h, i * h + h + h * c);
        let b1o = i * h;
        let b2o = i * h + h + h * c;
        let w2 = {
            let (_, _, w2, _) = self.split(params);
            w2.to_vec()
        };
        let mut a = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut dz1 = vec![0.0f32; h];
        let inv = 1.0 / idxs.len() as f32;
        let mut loss = 0.0f32;
        for &gi in idxs {
            let x = data.feat(gi as usize);
            let y = data.y[gi as usize] as usize;
            self.forward(params, x, &mut a, &mut logits);
            let lse = logsumexp(&logits);
            loss += (lse - logits[y]) * inv;
            // dlogits = softmax - onehot
            for m in 0..c {
                logits[m] = (logits[m] - lse).exp();
            }
            logits[y] -= 1.0;
            // W2/b2 grads + backprop into hidden
            for k in 0..h {
                let ak = a[k];
                let mut acc = 0.0f32;
                if ak > 0.0 {
                    for m in 0..c {
                        let dl = logits[m];
                        grad[w2o + k * c + m] += inv * ak * dl;
                        acc += w2[k * c + m] * dl;
                    }
                    dz1[k] = acc;
                } else {
                    for m in 0..c {
                        grad[w2o + k * c + m] += inv * ak * logits[m];
                    }
                    dz1[k] = 0.0;
                }
            }
            for m in 0..c {
                grad[b2o + m] += inv * logits[m];
            }
            // W1/b1 grads
            for j in 0..i {
                let xj = x[j] * inv;
                if xj != 0.0 {
                    let row = &mut grad[w1o + j * h..w1o + j * h + h];
                    for k in 0..h {
                        row[k] += xj * dz1[k];
                    }
                }
            }
            for k in 0..h {
                grad[b1o + k] += inv * dz1[k];
            }
        }
        loss
    }

    /// One chunk's forward + backward, accumulating scaled (by `inv`, the
    /// reciprocal of the *full* batch) gradient contributions into `grad`.
    ///
    /// Forward is tiled (`kernel::gemm`: bias init, j-blocked matmul, ReLU —
    /// per-element accumulation in ascending j, bit-identical to the scalar
    /// forward); backward is the reference per-sample loop verbatim, in
    /// sample order, so the whole pass matches [`Self::loss_grad_reference`]
    /// bit-for-bit over the same index slice.
    #[allow(clippy::too_many_arguments)]
    fn chunk_pass(
        &self,
        params: &[f32],
        data: &ClassDataset,
        idxs: &[u32],
        inv: f32,
        grad: &mut [f32],
        xbuf: &mut Vec<f32>,
        abuf: &mut Vec<f32>,
        dlbuf: &mut Vec<f32>,
        dzbuf: &mut Vec<f32>,
    ) -> f32 {
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let (w1, b1, w2, b2) = self.split(params);
        let (w1o, b1o, w2o, b2o) = (0, i * h, i * h + h, i * h + h + h * c);
        let s = idxs.len();

        // Gather inputs once: chunk×in, contiguous for the matmul tiles.
        xbuf.clear();
        xbuf.reserve(s * i);
        for &gi in idxs {
            xbuf.extend_from_slice(data.feat(gi as usize));
        }

        // Forward hidden: A = relu(X·W1 + b1), j-blocked.  The tiles are
        // shaped with a bare `resize` (a steady-state no-op) — every element
        // is written by the bias init before being read, so no zero-fill.
        abuf.resize(s * h, 0.0);
        gemm::init_rows_with_bias(abuf, h, b1);
        gemm::gemm_acc_rowmajor(xbuf, s, i, w1, h, abuf, gemm::jb_for(h));
        gemm::relu(abuf);

        // Logits: L = A·W2 + b2, k-blocked.
        dlbuf.resize(s * c, 0.0);
        gemm::init_rows_with_bias(dlbuf, c, b2);
        gemm::gemm_acc_rowmajor(abuf, s, h, w2, c, dlbuf, gemm::jb_for(c));

        // Loss + dlogits = softmax − onehot (same expressions as the
        // reference, per sample in order).
        let mut loss = 0.0f32;
        for (r, &gi) in idxs.iter().enumerate() {
            let y = data.y[gi as usize] as usize;
            let logits = &mut dlbuf[r * c..(r + 1) * c];
            let lse = logsumexp(logits);
            loss += (lse - logits[y]) * inv;
            for l in logits.iter_mut() {
                *l = (*l - lse).exp();
            }
            logits[y] -= 1.0;
        }

        // Backward: the reference per-sample loop, sample-major so the
        // accumulation order into `grad` is identical.
        dzbuf.clear();
        dzbuf.resize(h, 0.0);
        for r in 0..s {
            let arow = &abuf[r * h..(r + 1) * h];
            let dl = &dlbuf[r * c..(r + 1) * c];
            let xrow = &xbuf[r * i..(r + 1) * i];
            // W2/b2 grads + backprop into hidden
            for k in 0..h {
                let ak = arow[k];
                let grow = &mut grad[w2o + k * c..w2o + (k + 1) * c];
                let wrow = &w2[k * c..(k + 1) * c];
                if ak > 0.0 {
                    let mut acc = 0.0f32;
                    for m in 0..c {
                        let dlm = dl[m];
                        grow[m] += inv * ak * dlm;
                        acc += wrow[m] * dlm;
                    }
                    dzbuf[k] = acc;
                } else {
                    for m in 0..c {
                        grow[m] += inv * ak * dl[m];
                    }
                    dzbuf[k] = 0.0;
                }
            }
            for m in 0..c {
                grad[b2o + m] += inv * dl[m];
            }
            // W1/b1 grads
            for j in 0..i {
                let xj = xrow[j] * inv;
                if xj != 0.0 {
                    let row = &mut grad[w1o + j * h..w1o + (j + 1) * h];
                    for (rk, dzk) in row.iter_mut().zip(dzbuf.iter()) {
                        *rk += xj * *dzk;
                    }
                }
            }
            for k in 0..h {
                grad[b1o + k] += inv * dzbuf[k];
            }
        }
        loss
    }
}

impl GradModel for Mlp {
    fn dim(&self) -> usize {
        self.input * self.hidden + self.hidden + self.hidden * self.classes + self.classes
    }

    fn param_layout(&self) -> super::ParamLayout {
        let (i, h, c) = (self.input, self.hidden, self.classes);
        super::ParamLayout::from_segments(&[i * h, h, h * c, c])
    }

    fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::stream(seed, 0x317);
        let mut p = vec![0.0f32; self.dim()];
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let s1 = (2.0 / i as f32).sqrt();
        // damp the output layer so initial logits stay near uniform
        // (loss ~ ln(classes) at init, like the usual zero-init head)
        let s2 = (2.0 / h as f32).sqrt() * 0.1;
        for v in &mut p[..i * h] {
            *v = rng.normal() * s1;
        }
        for v in &mut p[i * h + h..i * h + h + h * c] {
            *v = rng.normal() * s2;
        }
        p
    }

    fn loss_grad(&self, params: &[f32], data: &ClassDataset, idxs: &[u32], grad: &mut [f32]) -> f32 {
        self.loss_grad_scratch(params, data, idxs, grad, &mut ModelScratch::new())
    }

    fn loss_grad_scratch(
        &self,
        params: &[f32],
        data: &ClassDataset,
        idxs: &[u32],
        grad: &mut [f32],
        scratch: &mut ModelScratch,
    ) -> f32 {
        debug_assert_eq!(grad.len(), self.dim());
        grad.iter_mut().for_each(|g| *g = 0.0);
        if idxs.is_empty() {
            return 0.0;
        }
        let ms = &mut scratch.mlp;
        let b = idxs.len();
        let d = self.dim();
        let inv = 1.0 / b as f32;
        let n_chunks = b.div_ceil(CHUNK);
        if ms.chunks.len() < n_chunks {
            ms.chunks.resize_with(n_chunks, Default::default);
        }

        if n_chunks == 1 {
            // Single tile: accumulate straight into the caller's grad —
            // bit-identical to the per-sample reference.
            let ChunkBuf { x, a, dl, dz, .. } = &mut ms.chunks[0];
            return self.chunk_pass(params, data, idxs, inv, grad, x, a, dl, dz);
        }

        // Multi-tile: chunks compute partial gradients independently (fanned
        // out over the arena's thread budget), then reduce serially in chunk
        // order — deterministic for any thread count.
        let threads = ms.threads.max(1).min(n_chunks);
        crate::util::pool::scope_zip(&mut ms.chunks[..n_chunks], threads, |ci, ch| {
            let lo = ci * CHUNK;
            let hi = (lo + CHUNK).min(b);
            let ChunkBuf { x, a, dl, dz, grad: cg, loss } = ch;
            cg.clear();
            cg.resize(d, 0.0);
            *loss = self.chunk_pass(params, data, &idxs[lo..hi], inv, cg, x, a, dl, dz);
        });
        let mut loss = 0.0f32;
        for ch in ms.chunks[..n_chunks].iter() {
            loss += ch.loss;
            dense::axpy(1.0, &ch.grad, grad);
        }
        loss
    }

    fn loss(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let (h, c) = (self.hidden, self.classes);
        let mut a = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut loss = 0.0f32;
        for idx in 0..data.len() {
            self.forward(params, data.feat(idx), &mut a, &mut logits);
            let lse = logsumexp(&logits);
            loss += lse - logits[data.y[idx] as usize];
        }
        loss / data.len() as f32
    }

    fn accuracy(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let (h, c) = (self.hidden, self.classes);
        let mut a = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut correct = 0usize;
        for idx in 0..data.len() {
            self.forward(params, data.feat(idx), &mut a, &mut logits);
            if argmax(&logits) == data.y[idx] as usize {
                correct += 1;
            }
        }
        correct as f32 / data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_matches_finite_differences() {
        let (tr, _) = ClassDataset::gaussian_mixture(5, 6, 16, 8, 1.0, 0.5, 0.0, 3);
        let m = Mlp::new(6, 7, 5);
        super::super::fd_check(&m, &tr, 2e-2);
    }

    #[test]
    fn batched_single_chunk_bitexact_vs_reference() {
        // batch <= CHUNK: the tiled pass must reproduce the per-sample
        // reference bit-for-bit (this is what keeps every pinned training
        // trajectory unchanged at trainer batch sizes).
        let (tr, _) = ClassDataset::gaussian_mixture(7, 12, 256, 16, 1.1, 0.6, 0.0, 11);
        let m = Mlp::new(12, 19, 7);
        let p = m.init(5);
        let mut scratch = ModelScratch::new();
        let mut rng = Rng::new(3);
        for trial in 0..10 {
            let bs = 1 + (trial * 7) % CHUNK;
            let idxs: Vec<u32> = (0..bs).map(|_| rng.below(tr.len()) as u32).collect();
            let mut g_ref = vec![0.0f32; m.dim()];
            let l_ref = m.loss_grad_reference(&p, &tr, &idxs, &mut g_ref);
            let mut g = vec![0.0f32; m.dim()];
            let l = m.loss_grad_scratch(&p, &tr, &idxs, &mut g, &mut scratch);
            assert_eq!(l.to_bits(), l_ref.to_bits(), "trial {trial}: loss differs");
            for (j, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "trial {trial} coord {j}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batched_multichunk_matches_reference_and_is_thread_invariant() {
        // batch > CHUNK: cross-chunk reduction reorders f32 sums, so the
        // comparison is tolerance-based (DESIGN.md §Perf documents 1e-5
        // relative); but serial vs parallel chunking must agree *bitwise*
        // (fixed chunk size + serial reduce ⇒ thread-count invariant).
        let (tr, _) = ClassDataset::gaussian_mixture(6, 10, 512, 16, 1.2, 0.7, 0.0, 13);
        let m = Mlp::new(10, 16, 6);
        let p = m.init(8);
        let mut rng = Rng::new(9);
        let idxs: Vec<u32> = (0..(3 * CHUNK + 17)).map(|_| rng.below(tr.len()) as u32).collect();

        let mut g_ref = vec![0.0f32; m.dim()];
        let l_ref = m.loss_grad_reference(&p, &tr, &idxs, &mut g_ref);

        let mut g1 = vec![0.0f32; m.dim()];
        let l1 = m.loss_grad_scratch(&p, &tr, &idxs, &mut g1, &mut ModelScratch::new());
        crate::util::prop::slices_close(&g1, &g_ref, 1e-5).unwrap();
        assert!((l1 - l_ref).abs() < 1e-5 * (1.0 + l_ref.abs()));

        let mut g4 = vec![0.0f32; m.dim()];
        let l4 = m.loss_grad_scratch(&p, &tr, &idxs, &mut g4, &mut ModelScratch::parallel(4));
        assert_eq!(l1.to_bits(), l4.to_bits());
        for (j, (a, b)) in g1.iter().zip(&g4).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "coord {j}: serial vs 4-thread");
        }
    }

    #[test]
    fn init_loss_near_uniform() {
        let (tr, _) = ClassDataset::gaussian_mixture(10, 8, 64, 8, 1.0, 0.5, 0.0, 4);
        let m = Mlp::new(8, 16, 10);
        let p = m.init(1);
        let l = m.loss(&p, &tr);
        assert!((l - (10f32).ln()).abs() < 0.8, "loss={l}");
    }

    #[test]
    fn sgd_learns_separable_mixture() {
        let (tr, te) = ClassDataset::gaussian_mixture(6, 8, 512, 128, 1.5, 0.3, 0.0, 5);
        let m = Mlp::new(8, 16, 6);
        let mut p = m.init(2);
        let mut g = vec![0.0f32; m.dim()];
        let mut scratch = ModelScratch::new();
        let mut rng = Rng::new(1);
        for _ in 0..800 {
            let idxs: Vec<u32> = (0..16).map(|_| rng.below(tr.len()) as u32).collect();
            m.loss_grad_scratch(&p, &tr, &idxs, &mut g, &mut scratch);
            for (pj, gj) in p.iter_mut().zip(&g) {
                *pj -= 0.2 * gj;
            }
        }
        let acc = m.accuracy(&p, &te);
        assert!(acc > 0.9, "acc={acc}");
    }
}
