//! Two-layer MLP (ReLU, softmax cross-entropy) with manual backprop.
//!
//! The non-convex stand-in for WRN-40-8 / ResNet-50 in the sweeps
//! (DESIGN.md §3): small enough that a full Table-4 sweep (6 optimizers ×
//! 11 ratios × lr grid × seeds) finishes in minutes, non-convex enough that
//! aggressive compression noise visibly hurts/destroys convergence.
//!
//! Flat layout: [W1 (in×h) | b1 (h) | W2 (h×c) | b2 (c)], row-major W.

use super::GradModel;
use crate::data::ClassDataset;
use crate::util::math::{argmax, logsumexp};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Mlp {
    pub input: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl Mlp {
    pub fn new(input: usize, hidden: usize, classes: usize) -> Self {
        Mlp { input, hidden, classes }
    }

    fn split<'a>(&self, p: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let w1 = &p[..i * h];
        let b1 = &p[i * h..i * h + h];
        let w2 = &p[i * h + h..i * h + h + h * c];
        let b2 = &p[i * h + h + h * c..];
        (w1, b1, w2, b2)
    }

    /// logits for one sample into `logits`; returns hidden activations in `a`.
    fn forward(&self, p: &[f32], x: &[f32], a: &mut [f32], logits: &mut [f32]) {
        let (w1, b1, w2, b2) = self.split(p);
        let (i, h, c) = (self.input, self.hidden, self.classes);
        for k in 0..h {
            // W1 row-major [in, h]: column k
            let mut z = b1[k];
            for j in 0..i {
                z += w1[j * h + k] * x[j];
            }
            a[k] = z.max(0.0);
        }
        for m in 0..c {
            let mut z = b2[m];
            for k in 0..h {
                z += w2[k * c + m] * a[k];
            }
            logits[m] = z;
        }
    }
}

impl GradModel for Mlp {
    fn dim(&self) -> usize {
        self.input * self.hidden + self.hidden + self.hidden * self.classes + self.classes
    }

    fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::stream(seed, 0x317);
        let mut p = vec![0.0f32; self.dim()];
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let s1 = (2.0 / i as f32).sqrt();
        // damp the output layer so initial logits stay near uniform
        // (loss ~ ln(classes) at init, like the usual zero-init head)
        let s2 = (2.0 / h as f32).sqrt() * 0.1;
        for v in &mut p[..i * h] {
            *v = rng.normal() * s1;
        }
        for v in &mut p[i * h + h..i * h + h + h * c] {
            *v = rng.normal() * s2;
        }
        p
    }

    fn loss_grad(&self, params: &[f32], data: &ClassDataset, idxs: &[u32], grad: &mut [f32]) -> f32 {
        debug_assert_eq!(grad.len(), self.dim());
        grad.iter_mut().for_each(|g| *g = 0.0);
        let (i, h, c) = (self.input, self.hidden, self.classes);
        let (w1o, _b1o, w2o, _b2o) = (0, i * h, i * h + h, i * h + h + h * c);
        let b1o = i * h;
        let b2o = i * h + h + h * c;
        let w2 = {
            let (_, _, w2, _) = self.split(params);
            w2.to_vec() // copy: avoids borrow conflict with grad writes
        };
        let mut a = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut dz1 = vec![0.0f32; h];
        let inv = 1.0 / idxs.len() as f32;
        let mut loss = 0.0f32;
        for &gi in idxs {
            let x = data.feat(gi as usize);
            let y = data.y[gi as usize] as usize;
            self.forward(params, x, &mut a, &mut logits);
            let lse = logsumexp(&logits);
            loss += (lse - logits[y]) * inv;
            // dlogits = softmax - onehot
            for m in 0..c {
                logits[m] = (logits[m] - lse).exp();
            }
            logits[y] -= 1.0;
            // W2/b2 grads + backprop into hidden
            for k in 0..h {
                let ak = a[k];
                let mut acc = 0.0f32;
                if ak > 0.0 {
                    for m in 0..c {
                        let dl = logits[m];
                        grad[w2o + k * c + m] += inv * ak * dl;
                        acc += w2[k * c + m] * dl;
                    }
                    dz1[k] = acc;
                } else {
                    for m in 0..c {
                        grad[w2o + k * c + m] += inv * ak * logits[m];
                    }
                    dz1[k] = 0.0;
                }
            }
            for m in 0..c {
                grad[b2o + m] += inv * logits[m];
            }
            // W1/b1 grads
            for j in 0..i {
                let xj = x[j] * inv;
                if xj != 0.0 {
                    let row = &mut grad[w1o + j * h..w1o + j * h + h];
                    for k in 0..h {
                        row[k] += xj * dz1[k];
                    }
                }
            }
            for k in 0..h {
                grad[b1o + k] += inv * dz1[k];
            }
        }
        loss
    }

    fn loss(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let (h, c) = (self.hidden, self.classes);
        let mut a = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut loss = 0.0f32;
        for idx in 0..data.len() {
            self.forward(params, data.feat(idx), &mut a, &mut logits);
            let lse = logsumexp(&logits);
            loss += lse - logits[data.y[idx] as usize];
        }
        loss / data.len() as f32
    }

    fn accuracy(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let (h, c) = (self.hidden, self.classes);
        let mut a = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut correct = 0usize;
        for idx in 0..data.len() {
            self.forward(params, data.feat(idx), &mut a, &mut logits);
            if argmax(&logits) == data.y[idx] as usize {
                correct += 1;
            }
        }
        correct as f32 / data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_matches_finite_differences() {
        let (tr, _) = ClassDataset::gaussian_mixture(5, 6, 16, 8, 1.0, 0.5, 0.0, 3);
        let m = Mlp::new(6, 7, 5);
        super::super::fd_check(&m, &tr, 2e-2);
    }

    #[test]
    fn init_loss_near_uniform() {
        let (tr, _) = ClassDataset::gaussian_mixture(10, 8, 64, 8, 1.0, 0.5, 0.0, 4);
        let m = Mlp::new(8, 16, 10);
        let p = m.init(1);
        let l = m.loss(&p, &tr);
        assert!((l - (10f32).ln()).abs() < 0.8, "loss={l}");
    }

    #[test]
    fn sgd_learns_separable_mixture() {
        let (tr, te) = ClassDataset::gaussian_mixture(6, 8, 512, 128, 1.5, 0.3, 0.0, 5);
        let m = Mlp::new(8, 16, 6);
        let mut p = m.init(2);
        let mut g = vec![0.0f32; m.dim()];
        let mut rng = Rng::new(1);
        for _ in 0..800 {
            let idxs: Vec<u32> = (0..16).map(|_| rng.below(tr.len()) as u32).collect();
            m.loss_grad(&p, &tr, &idxs, &mut g);
            for (pj, gj) in p.iter_mut().zip(&g) {
                *pj -= 0.2 * gj;
            }
        }
        let acc = m.accuracy(&p, &te);
        assert!(acc > 0.9, "acc={acc}");
    }
}
