//! Parameter-layout metadata: where the layer boundaries of a flattened
//! model live.
//!
//! Every model in this repo trains over one flat `Vec<f32>`; the optimizer
//! and transport layers never needed to know that the vector is really
//! `[W1 | b1 | W2 | b2]`.  The bucketed synchronization pipeline does: a
//! gradient bucket that straddles a layer boundary mixes tensors with very
//! different magnitudes under one top-k/GRBS draw, and (more practically)
//! bucket boundaries aligned to tensor boundaries keep per-bucket selections
//! meaningful per layer — the blockwise error-feedback framing of
//! dist-EF-SGDM (PAPERS.md).
//!
//! [`ParamLayout`] records the segment (tensor) boundaries and computes a
//! bucket partition: `bucket_bounds(k)` cuts the vector into at most `k`
//! contiguous buckets whose boundaries snap to segment boundaries when a
//! segment boundary lies close to the ideal even cut, and fall back to the
//! ideal cut when a single tensor is larger than a bucket (a huge embedding
//! matrix must still be splittable).  Models report their layout through
//! [`super::GradModel::param_layout`]; the default is one dense segment.

/// Segment (tensor) boundaries of a flat parameter vector: `bounds` is
/// strictly increasing, starts at 0, ends at `dim()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamLayout {
    bounds: Vec<usize>,
}

impl ParamLayout {
    /// Layout from per-segment lengths (all non-zero).
    pub fn from_segments(lens: &[usize]) -> Self {
        assert!(!lens.is_empty(), "a layout needs at least one segment");
        let mut bounds = Vec::with_capacity(lens.len() + 1);
        bounds.push(0usize);
        let mut acc = 0usize;
        for &l in lens {
            assert!(l > 0, "zero-length parameter segment");
            acc += l;
            bounds.push(acc);
        }
        ParamLayout { bounds }
    }

    /// Single dense segment (models that don't describe their tensors).
    pub fn dense(d: usize) -> Self {
        assert!(d > 0);
        ParamLayout { bounds: vec![0, d] }
    }

    /// Flat parameter dimension.
    pub fn dim(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Number of segments (tensors).
    pub fn num_segments(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Segment `i` as `(start, end)`.
    pub fn segment(&self, i: usize) -> (usize, usize) {
        (self.bounds[i], self.bounds[i + 1])
    }

    /// Partition `[0, dim)` into at most `k` contiguous buckets.
    ///
    /// Each interior cut starts at the ideal even position `i·d/k` and snaps
    /// to the nearest segment boundary when one lies within half a bucket of
    /// it (layer-boundary-aware); otherwise the ideal cut stands (segments
    /// larger than a bucket are split mid-tensor).  Cuts that would collapse
    /// a bucket to zero length are dropped, so the result can have fewer
    /// than `k` buckets but never an empty one.  Returned bounds are
    /// strictly increasing, `0 ..= d`.
    pub fn bucket_bounds(&self, k: usize) -> Vec<usize> {
        let d = self.dim();
        let k = k.max(1).min(d);
        let target = d.div_ceil(k);
        let mut out = Vec::with_capacity(k + 1);
        out.push(0usize);
        for i in 1..k {
            let ideal = i * d / k;
            // nearest segment boundary to `ideal`
            let snapped = match self.bounds.binary_search(&ideal) {
                Ok(_) => ideal,
                Err(pos) => {
                    let hi = self.bounds[pos.min(self.bounds.len() - 1)];
                    let lo = self.bounds[pos.saturating_sub(1)];
                    if ideal - lo <= hi - ideal {
                        lo
                    } else {
                        hi
                    }
                }
            };
            let cut = if snapped.abs_diff(ideal) * 2 <= target { snapped } else { ideal };
            if cut > *out.last().unwrap() && cut < d {
                out.push(cut);
            }
        }
        out.push(d);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn segments_roundtrip() {
        let l = ParamLayout::from_segments(&[12, 3, 6, 2]);
        assert_eq!(l.dim(), 23);
        assert_eq!(l.num_segments(), 4);
        assert_eq!(l.segment(0), (0, 12));
        assert_eq!(l.segment(3), (21, 23));
        assert_eq!(ParamLayout::dense(7).segment(0), (0, 7));
    }

    #[test]
    fn buckets_snap_to_layer_boundaries() {
        // MLP-ish layout: a big W1, small b1, medium W2, small b2.  Asking
        // for 2 buckets should cut at a tensor boundary near the middle,
        // not through the middle of a tensor.
        let l = ParamLayout::from_segments(&[512, 32, 320, 10]);
        let b = l.bucket_bounds(2);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&l.dim()));
        for cut in &b[1..b.len() - 1] {
            assert!(
                l.bounds.contains(cut),
                "cut {cut} is not a segment boundary of {:?}",
                l.bounds
            );
        }
    }

    #[test]
    fn oversized_segments_are_split() {
        // One giant tensor: no boundary to snap to, so the even cuts stand.
        let l = ParamLayout::from_segments(&[1000]);
        let b = l.bucket_bounds(4);
        assert_eq!(b, vec![0, 250, 500, 750, 1000]);
    }

    #[test]
    fn prop_bucket_bounds_partition_the_vector() {
        forall(60, 0x1A70, |g: &mut Gen| {
            let nseg = g.usize_in(1, 8);
            let lens: Vec<usize> = (0..nseg).map(|_| g.usize_in(1, 300)).collect();
            let l = ParamLayout::from_segments(&lens);
            let k = g.usize_in(1, 12);
            let b = l.bucket_bounds(k);
            crate::prop_assert!(b[0] == 0, "first bound {} != 0", b[0]);
            crate::prop_assert!(*b.last().unwrap() == l.dim(), "last bound misses dim");
            crate::prop_assert!(b.len() <= k + 1, "{} buckets for k = {k}", b.len() - 1);
            for w in b.windows(2) {
                crate::prop_assert!(w[0] < w[1], "bounds not strictly increasing: {b:?}");
            }
            Ok(())
        });
    }
}
