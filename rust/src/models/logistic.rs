//! Multinomial logistic regression (softmax linear model).
//!
//! The convex member of the model zoo: used in ablations where we want the
//! optimization landscape to be benign so that *only* the compression noise
//! differentiates the optimizers, and in fast smoke tests.
//!
//! Flat layout: [W (in×c) | b (c)], row-major.

use super::GradModel;
use crate::data::ClassDataset;
use crate::util::math::{argmax, logsumexp};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Logistic {
    pub input: usize,
    pub classes: usize,
}

impl Logistic {
    pub fn new(input: usize, classes: usize) -> Self {
        Logistic { input, classes }
    }

    fn logits(&self, p: &[f32], x: &[f32], out: &mut [f32]) {
        let (i, c) = (self.input, self.classes);
        out.copy_from_slice(&p[i * c..]);
        for j in 0..i {
            let xj = x[j];
            if xj != 0.0 {
                let row = &p[j * c..(j + 1) * c];
                for m in 0..c {
                    out[m] += xj * row[m];
                }
            }
        }
    }
}

impl GradModel for Logistic {
    fn dim(&self) -> usize {
        self.input * self.classes + self.classes
    }

    fn param_layout(&self) -> super::ParamLayout {
        super::ParamLayout::from_segments(&[self.input * self.classes, self.classes])
    }

    fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::stream(seed, 0x109);
        let mut p = vec![0.0f32; self.dim()];
        let s = (1.0 / self.input as f32).sqrt();
        for v in &mut p[..self.input * self.classes] {
            *v = rng.normal() * s * 0.1;
        }
        p
    }

    fn loss_grad(&self, params: &[f32], data: &ClassDataset, idxs: &[u32], grad: &mut [f32]) -> f32 {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let (i, c) = (self.input, self.classes);
        let mut logits = vec![0.0f32; c];
        let inv = 1.0 / idxs.len() as f32;
        let mut loss = 0.0f32;
        for &gi in idxs {
            let x = data.feat(gi as usize);
            let y = data.y[gi as usize] as usize;
            self.logits(params, x, &mut logits);
            let lse = logsumexp(&logits);
            loss += (lse - logits[y]) * inv;
            for m in 0..c {
                logits[m] = (logits[m] - lse).exp();
            }
            logits[y] -= 1.0;
            for j in 0..i {
                let xj = x[j] * inv;
                if xj != 0.0 {
                    let row = &mut grad[j * c..(j + 1) * c];
                    for m in 0..c {
                        row[m] += xj * logits[m];
                    }
                }
            }
            let brow = &mut grad[i * c..];
            for m in 0..c {
                brow[m] += inv * logits[m];
            }
        }
        loss
    }

    fn loss(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let mut logits = vec![0.0f32; self.classes];
        let mut loss = 0.0f32;
        for idx in 0..data.len() {
            self.logits(params, data.feat(idx), &mut logits);
            loss += logsumexp(&logits) - logits[data.y[idx] as usize];
        }
        loss / data.len() as f32
    }

    fn accuracy(&self, params: &[f32], data: &ClassDataset) -> f32 {
        let mut logits = vec![0.0f32; self.classes];
        let mut correct = 0usize;
        for idx in 0..data.len() {
            self.logits(params, data.feat(idx), &mut logits);
            if argmax(&logits) == data.y[idx] as usize {
                correct += 1;
            }
        }
        correct as f32 / data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_matches_finite_differences() {
        let (tr, _) = ClassDataset::gaussian_mixture(4, 5, 12, 8, 1.0, 0.5, 0.0, 2);
        let m = Logistic::new(5, 4);
        super::super::fd_check(&m, &tr, 1e-2);
    }

    #[test]
    fn learns_linear_problem() {
        let (tr, te) = ClassDataset::gaussian_mixture(5, 10, 600, 150, 2.0, 0.4, 0.0, 6);
        let m = Logistic::new(10, 5);
        let mut p = m.init(1);
        let mut g = vec![0.0f32; m.dim()];
        let mut rng = Rng::new(2);
        for _ in 0..600 {
            let idxs: Vec<u32> = (0..16).map(|_| rng.below(tr.len()) as u32).collect();
            m.loss_grad(&p, &tr, &idxs, &mut g);
            for (pj, gj) in p.iter_mut().zip(&g) {
                *pj -= 0.5 * gj;
            }
        }
        assert!(m.accuracy(&p, &te) > 0.95);
    }
}
