//! # cser — Communication-efficient SGD with Error Reset
//!
//! Full-system reproduction of *CSER: Communication-efficient SGD with Error
//! Reset* (Xie, Zheng, Koyejo, Gupta, Li, Lin — NeurIPS 2020) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator: the
//!   worker-centric optimizer engine ([`engine`]: per-worker
//!   `WorkerState` + declarative `CommPlan` sync schedules executed by one
//!   generic `ErrorResetEngine`, centrally or as worker-resident threads
//!   that meet only at the collective), the compute kernel layer
//!   ([`kernel`]: fused single-pass step sweeps pinned bit-identical to
//!   their unfused chains, blocked matmul tiles for the batched MLP
//!   backprop, and the reusable `Scratch` that keeps steady-state steps
//!   allocation-free), the paper's algorithm families as
//!   plan constructors with deprecated legacy wrappers ([`optimizer`]), the
//!   GRBS compressor family ([`compressor`]), partial synchronization
//!   ([`collective`]), the wire layer ([`transport`]: bit-packed codecs for
//!   every compressor payload — encoded bits ≡ accounted bits, hardened
//!   against untrusted frames — plus the peer-owned ring/parameter-server
//!   protocol each worker executes over its own links: mpsc mesh endpoints
//!   for resident threads and the persistent `Threaded` pool, or real TCP
//!   sockets for `cser launch`-style multi-process jobs), the
//!   observability layer ([`obs`]: zero-alloc per-thread phase tracing
//!   with Chrome-trace export and per-peer wire counters, plus the
//!   run-wide metrics plane — a static lock-free counter/gauge/histogram
//!   registry whose per-rank delta snapshots ride the epoch boundary to
//!   the leader for Prometheus/JSON exposition and the live `cser top`
//!   view; both off by default, costing one flag check per site when
//!   disabled), the elastic membership control plane ([`membership`]:
//!   epoch-based eviction/rejoin, the censoring-rule threshold
//!   derivations including the metrics-fed `--adaptive-tau` loop, and
//!   `--failover` leader succession — generation-fenced epoch frames,
//!   per-boundary control-state replication to the lowest live non-zero
//!   rank, and takeover of every leader role on its death), the
//!   network
//!   cost/accounting substrate ([`network`]), data sharding ([`data`]), a
//!   fast pure-Rust model zoo for the paper's sweeps ([`models`]), the PJRT
//!   runtime that executes AOT-compiled JAX/Pallas artifacts ([`runtime`]),
//!   the training loop ([`coordinator`]) and one harness per paper
//!   table/figure ([`harness`]).
//! * **Layer 2** — `python/compile/model.py`: transformer LM fwd/bwd over a
//!   flat parameter vector, AOT-lowered to HLO text (build-time only).
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels (GRBS block
//!   masking, fused CSER update, flash attention fwd+bwd).
//!
//! See DESIGN.md for the system inventory and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod collective;
pub mod compressor;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod data;
pub mod harness;
pub mod kernel;
pub mod membership;
pub mod models;
pub mod network;
pub mod obs;
pub mod optimizer;
pub mod runtime;
pub mod transport;
pub mod util;
