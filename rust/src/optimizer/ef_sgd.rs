//! EF-SGD — error-feedback SGD (paper Algorithm 10; Karimireddy et al. 2019,
//! with the momentum treatment of Zheng et al. 2019).
//!
//! Per worker:  q_i = e_i + p_i  (p_i = η(β m_i + g_i));  q'_i = C1(q_i);
//! e_i ← q_i − q'_i;  x ← x + mean_j q'_j applied as descent (all local
//! models stay identical — the residual is fed back with one step of delay,
//! never applied to the model directly; contrast with CSEA's error reset).
//!
//! Deprecated thin wrapper over [`crate::engine::ErrorResetEngine`] with
//! [`CommPlan::ef_sgd`]; prefer building the plan directly.

use crate::compressor::Compressor;
use crate::engine::{CommPlan, ErrorResetEngine};

pub struct EfSgd(ErrorResetEngine);

impl EfSgd {
    pub fn new(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>) -> Self {
        EfSgd(ErrorResetEngine::new(init, n, beta, CommPlan::ef_sgd(c1)))
    }
}

super::delegate_to_engine!(EfSgd);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Grbs, Identity};
    use crate::optimizer::DistOptimizer;

    #[test]
    fn identity_compressor_reduces_to_sgd() {
        let init = [1.0f32, -1.0, 0.5, 2.0];
        let mut ef = EfSgd::new(&init, 2, 0.9, Box::new(Identity));
        let mut sgd = super::super::FullSgd::new(&init, 2, 0.9);
        for t in 0..20 {
            let g: Vec<Vec<f32>> =
                (0..2).map(|i| vec![0.1 * t as f32 + i as f32; 4]).collect();
            ef.step(&g, 0.05);
            sgd.step(&g, 0.05);
        }
        for (a, b) in ef.worker_model(0).iter().zip(sgd.worker_model(0)) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn error_feedback_preserves_total_update_mass() {
        // Over time, x + mean(e) should track where plain SGD would be:
        // x_t + mean_i e_{i,t} == x^{sgd}_t for constant gradients.
        let d = 32;
        let init = vec![0.0f32; d];
        let mut ef = EfSgd::new(&init, 4, 0.0, Box::new(Grbs::new(4.0, 8, 3)));
        let g = vec![vec![1.0f32; d]; 4];
        let steps = 50;
        for _ in 0..steps {
            ef.step(&g, 0.1);
        }
        let mut drift = ef.worker_model(0).to_vec();
        for i in 0..4 {
            let e = ef.local_error(i).unwrap();
            for (dj, ej) in drift.iter_mut().zip(e) {
                *dj -= *ej / 4.0;
            }
        }
        // plain SGD endpoint: x = -eta * g * steps = -5.0
        for v in &drift {
            assert!((v + 5.0).abs() < 1e-4, "{v}");
        }
    }

    #[test]
    fn quadratic_converges_under_heavy_compression() {
        let d = 64;
        let c = vec![1.0f32; d];
        let mut ef = EfSgd::new(&vec![0.0; d], 4, 0.0, Box::new(Grbs::new(16.0, 16, 9)));
        for _ in 0..3000 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|_| ef.worker_model(0).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            ef.step(&g, 0.1);
        }
        let err: f64 = ef
            .worker_model(0)
            .iter()
            .zip(&c)
            .map(|(x, ci)| ((x - ci) as f64).powi(2))
            .sum();
        assert!(err < 1e-3, "err={err}");
    }
}
