//! EF-SGD — error-feedback SGD (paper Algorithm 10; Karimireddy et al. 2019,
//! with the momentum treatment of Zheng et al. 2019).
//!
//! Per worker:  q_i = e_i + p_i  (p_i = η(β m_i + g_i));  q'_i = C1(q_i);
//! e_i ← q_i − q'_i;  x ← x + mean_j q'_j applied as descent (all local
//! models stay identical — the residual is fed back with one step of delay,
//! never applied to the model directly; contrast with CSEA's error reset).

use super::{DistOptimizer, Momentum, RoundStats};
use crate::compressor::{payload_bits, Compressor, Ctx};
use crate::util::math;

pub struct EfSgd {
    n: usize,
    x: Vec<f32>,
    e: Vec<Vec<f32>>,
    momentum: Momentum,
    c1: Box<dyn Compressor>,
    t: u64,
    // scratch
    q: Vec<f32>,
    qbar: Vec<f32>,
    kept: Vec<f32>,
}

impl EfSgd {
    pub fn new(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>) -> Self {
        let d = init.len();
        EfSgd {
            n,
            x: init.to_vec(),
            e: vec![vec![0.0; d]; n],
            momentum: Momentum::new(beta, n, d),
            c1,
            t: 0,
            q: vec![0.0; d],
            qbar: vec![0.0; d],
            kept: vec![0.0; d],
        }
    }
}

impl DistOptimizer for EfSgd {
    fn step(&mut self, grads: &[Vec<f32>], eta: f32) -> RoundStats {
        debug_assert_eq!(grads.len(), self.n);
        let d = self.x.len();
        self.t += 1;
        math::fill(&mut self.qbar, 0.0);
        let inv = 1.0 / self.n as f32;
        let mut bits = 0u64;
        for i in 0..self.n {
            // q_i = e_i + p_i
            self.momentum.descent(i, &grads[i], eta, &mut self.q);
            for (qj, ej) in self.q.iter_mut().zip(&self.e[i]) {
                *qj += *ej;
            }
            let ctx = Ctx { round: self.t, worker: i as u32 };
            if self.c1.is_dense() {
                // value quantizers (QSGD/sign-SGD): C(q) is dense
                bits += self.c1.compress_into(ctx, &self.q, &mut self.kept);
                math::axpy(inv, &self.kept, &mut self.qbar);
                for ((ej, qj), kj) in self.e[i].iter_mut().zip(&self.q).zip(&self.kept) {
                    *ej = qj - kj;
                }
            } else {
                let sel = self.c1.select(ctx, &self.q);
                bits += payload_bits(&sel, d);
                // e_i = q_i - C1(q_i); qbar += C1(q_i)/n — range-wise (§Perf:
                // no per-step d-sized mask allocation)
                self.e[i].copy_from_slice(&self.q);
                let (q, qbar, e) = (&self.q, &mut self.qbar, &mut self.e[i]);
                sel.for_each_range(d, |s, t| {
                    math::axpy(inv, &q[s..t], &mut qbar[s..t]);
                    math::fill(&mut e[s..t], 0.0);
                });
            }
        }
        math::axpy(-1.0, &self.qbar, &mut self.x);
        RoundStats {
            grad_bits: bits / self.n as u64,
            model_bits: 0,
            grad_allreduce: self.c1.globally_synchronized(),
            model_allreduce: true,
            synced: true,
        }
    }

    fn n(&self) -> usize {
        self.n
    }
    fn dim(&self) -> usize {
        self.x.len()
    }
    fn worker_model(&self, _i: usize) -> &[f32] {
        &self.x
    }
    fn mean_model(&self, out: &mut [f32]) {
        out.copy_from_slice(&self.x);
    }
    fn local_error(&self, i: usize) -> Option<&[f32]> {
        Some(&self.e[i])
    }
    fn name(&self) -> String {
        format!("ef-sgd[{}]", self.c1.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Grbs, Identity};

    #[test]
    fn identity_compressor_reduces_to_sgd() {
        let init = [1.0f32, -1.0, 0.5, 2.0];
        let mut ef = EfSgd::new(&init, 2, 0.9, Box::new(Identity));
        let mut sgd = super::super::FullSgd::new(&init, 2, 0.9);
        for t in 0..20 {
            let g: Vec<Vec<f32>> =
                (0..2).map(|i| vec![0.1 * t as f32 + i as f32; 4]).collect();
            ef.step(&g, 0.05);
            sgd.step(&g, 0.05);
        }
        for (a, b) in ef.worker_model(0).iter().zip(sgd.worker_model(0)) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn error_feedback_preserves_total_update_mass() {
        // Over time, x + mean(e) should track where plain SGD would be:
        // x_t + mean_i e_{i,t} == x^{sgd}_t for constant gradients.
        let d = 32;
        let init = vec![0.0f32; d];
        let mut ef = EfSgd::new(&init, 4, 0.0, Box::new(Grbs::new(4.0, 8, 3)));
        let g = vec![vec![1.0f32; d]; 4];
        let steps = 50;
        for _ in 0..steps {
            ef.step(&g, 0.1);
        }
        let mut drift = ef.worker_model(0).to_vec();
        for i in 0..4 {
            let e = ef.local_error(i).unwrap();
            for (dj, ej) in drift.iter_mut().zip(e) {
                *dj -= *ej / 4.0;
            }
        }
        // plain SGD endpoint: x = -eta * g * steps = -5.0
        for v in &drift {
            assert!((v + 5.0).abs() < 1e-4, "{v}");
        }
    }

    #[test]
    fn quadratic_converges_under_heavy_compression() {
        let d = 64;
        let c = vec![1.0f32; d];
        let mut ef = EfSgd::new(&vec![0.0; d], 4, 0.0, Box::new(Grbs::new(16.0, 16, 9)));
        for _ in 0..3000 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|_| ef.worker_model(0).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            ef.step(&g, 0.1);
        }
        let err: f64 = ef
            .worker_model(0)
            .iter()
            .zip(&c)
            .map(|(x, ci)| ((x - ci) as f64).powi(2))
            .sum();
        assert!(err < 1e-3, "err={err}");
    }
}
