//! QSparse-local-SGD (paper Algorithm 1 / Algorithm 12; Basu et al. 2019).
//!
//! Local models evolve independently for H steps.  On sync rounds each
//! worker compresses (stale error + accumulated local progress):
//!
//!   q_i  = e_i + (x_{i,t-1/2} − x̂_{t-1})
//!   q'_i = C1(q_i);   e_i ← q_i − q'_i
//!   x̂_t  = x̂_{t-1} + mean_j q'_j;   x_i ← x̂_t      (full resync)
//!
//! The residual e_i is *set aside* between syncs — it enters neither the
//! local model nor gradient computation for H steps.  That H-step staleness
//! is exactly what CSER's error reset removes, and why QSparse degrades and
//! then diverges as R_C = R_C1 × H grows (paper Table 2).
//!
//! `local_sgd` (C1 = identity) is the paper's local-SGD row.
//!
//! Deprecated thin wrapper over [`crate::engine::ErrorResetEngine`] with
//! [`CommPlan::qsparse`] / [`CommPlan::local_sgd`]; prefer building the plan
//! directly.

use crate::compressor::Compressor;
use crate::engine::{CommPlan, ErrorResetEngine};

pub struct QsparseLocalSgd(ErrorResetEngine);

impl QsparseLocalSgd {
    pub fn new(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>, h: u64) -> Self {
        QsparseLocalSgd(ErrorResetEngine::new(init, n, beta, CommPlan::qsparse(c1, h)))
    }

    /// Paper's local SGD row: identity compressor, sync every H steps.
    pub fn local_sgd(init: &[f32], n: usize, beta: f32, h: u64) -> Self {
        QsparseLocalSgd(ErrorResetEngine::new(init, n, beta, CommPlan::local_sgd(h)))
    }
}

super::delegate_to_engine!(QsparseLocalSgd);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Grbs, Identity};
    use crate::optimizer::DistOptimizer;

    #[test]
    fn h1_identity_reduces_to_sgd() {
        let init = [0.5f32, -0.5, 1.0];
        let mut q = QsparseLocalSgd::new(&init, 3, 0.9, Box::new(Identity), 1);
        let mut s = super::super::FullSgd::new(&init, 3, 0.9);
        for t in 0..15 {
            let g: Vec<Vec<f32>> =
                (0..3).map(|i| vec![(t as f32 - i as f32) * 0.1; 3]).collect();
            q.step(&g, 0.1);
            s.step(&g, 0.1);
        }
        for (a, b) in q.worker_model(0).iter().zip(s.worker_model(0)) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn local_sgd_averages_on_sync() {
        let mut q = QsparseLocalSgd::local_sgd(&[0.0, 0.0], 2, 0.0, 2);
        // step 1 (no sync): workers diverge
        q.step(&[vec![1.0, 0.0], vec![0.0, 1.0]], 1.0);
        assert_ne!(q.worker_model(0), q.worker_model(1));
        // step 2 (sync): full model averaging
        q.step(&[vec![1.0, 0.0], vec![0.0, 1.0]], 1.0);
        assert_eq!(q.worker_model(0), q.worker_model(1));
        assert_eq!(q.worker_model(0), &[-1.0, -1.0]);
    }

    #[test]
    fn models_fully_resynced_after_compressed_round() {
        let d = 40;
        let mut q = QsparseLocalSgd::new(
            &vec![0.0; d],
            4,
            0.0,
            Box::new(Grbs::new(4.0, 10, 5)),
            4,
        );
        for t in 1..=8 {
            let g: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32 * 0.1 + t as f32 * 0.01; d]).collect();
            q.step(&g, 0.1);
            if t % 4 == 0 {
                for i in 1..4 {
                    assert_eq!(q.worker_model(0), q.worker_model(i), "t={t}");
                }
            }
        }
    }

    #[test]
    fn no_communication_between_syncs() {
        let mut q =
            QsparseLocalSgd::new(&[0.0; 8], 2, 0.0, Box::new(Grbs::new(2.0, 4, 1)), 4);
        for t in 1..=8u64 {
            let stats = q.step(&[vec![1.0; 8], vec![2.0; 8]], 0.1);
            assert_eq!(stats.synced, t % 4 == 0);
            if !stats.synced {
                assert_eq!(stats.upload_bits(), 0);
            } else {
                assert!(stats.model_bits > 0);
            }
        }
    }

    #[test]
    fn quadratic_converges_moderate_compression() {
        let d = 64;
        let c = vec![1.0f32; d];
        let mut q =
            QsparseLocalSgd::new(&vec![0.0; d], 4, 0.0, Box::new(Grbs::new(4.0, 16, 9)), 4);
        for _ in 0..4000 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|i| q.worker_model(i).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            q.step(&g, 0.05);
        }
        let mut xbar = vec![0.0f32; d];
        q.mean_model(&mut xbar);
        let err: f64 = xbar.iter().zip(&c).map(|(x, ci)| ((x - ci) as f64).powi(2)).sum();
        assert!(err < 1e-2, "err={err}");
    }
}
