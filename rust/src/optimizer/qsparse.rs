//! QSparse-local-SGD (paper Algorithm 1 / Algorithm 12; Basu et al. 2019).
//!
//! Local models evolve independently for H steps.  On sync rounds each
//! worker compresses (stale error + accumulated local progress):
//!
//!   q_i  = e_i + (x_{i,t-1/2} − x̂_{t-1})
//!   q'_i = C1(q_i);   e_i ← q_i − q'_i
//!   x̂_t  = x̂_{t-1} + mean_j q'_j;   x_i ← x̂_t      (full resync)
//!
//! The residual e_i is *set aside* between syncs — it enters neither the
//! local model nor gradient computation for H steps.  That H-step staleness
//! is exactly what CSER's error reset removes, and why QSparse degrades and
//! then diverges as R_C = R_C1 × H grows (paper Table 2).
//!
//! `local_sgd` (C1 = identity) is the paper's local-SGD row.

use super::{DistOptimizer, Momentum, RoundStats};
use crate::compressor::{payload_bits, Compressor, Ctx, Identity};
use crate::util::math;

pub struct QsparseLocalSgd {
    n: usize,
    h: u64,
    x: Vec<Vec<f32>>,
    xhat: Vec<f32>,
    e: Vec<Vec<f32>>,
    momentum: Momentum,
    c1: Box<dyn Compressor>,
    t: u64,
    // scratch
    p: Vec<f32>,
    q: Vec<f32>,
    qbar: Vec<f32>,
    kept: Vec<f32>,
}

impl QsparseLocalSgd {
    pub fn new(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>, h: u64) -> Self {
        assert!(h >= 1);
        let d = init.len();
        QsparseLocalSgd {
            n,
            h,
            x: vec![init.to_vec(); n],
            xhat: init.to_vec(),
            e: vec![vec![0.0; d]; n],
            momentum: Momentum::new(beta, n, d),
            c1,
            t: 0,
            p: vec![0.0; d],
            q: vec![0.0; d],
            qbar: vec![0.0; d],
            kept: vec![0.0; d],
        }
    }

    /// Paper's local SGD row: identity compressor, sync every H steps.
    pub fn local_sgd(init: &[f32], n: usize, beta: f32, h: u64) -> Self {
        Self::new(init, n, beta, Box::new(Identity), h)
    }
}

impl DistOptimizer for QsparseLocalSgd {
    fn step(&mut self, grads: &[Vec<f32>], eta: f32) -> RoundStats {
        debug_assert_eq!(grads.len(), self.n);
        let d = self.xhat.len();
        self.t += 1;
        // local half-step on every worker
        for i in 0..self.n {
            self.momentum.descent(i, &grads[i], eta, &mut self.p);
            math::axpy(-1.0, &self.p, &mut self.x[i]);
        }
        if self.t % self.h != 0 {
            return RoundStats::default();
        }
        // synchronization round
        math::fill(&mut self.qbar, 0.0);
        let inv = 1.0 / self.n as f32;
        let mut bits = 0u64;
        for i in 0..self.n {
            for j in 0..d {
                self.q[j] = self.e[i][j] + self.x[i][j] - self.xhat[j];
            }
            let ctx = Ctx { round: self.t, worker: i as u32 };
            if self.c1.is_dense() {
                bits += self.c1.compress_into(ctx, &self.q, &mut self.kept);
                math::axpy(inv, &self.kept, &mut self.qbar);
                for ((ej, qj), kj) in self.e[i].iter_mut().zip(&self.q).zip(&self.kept) {
                    *ej = qj - kj;
                }
            } else {
                let sel = self.c1.select(ctx, &self.q);
                bits += payload_bits(&sel, d);
                // e_i = q_i off support; qbar accumulates the compressed part —
                // range-wise (§Perf: no per-step d-sized mask allocation)
                self.e[i].copy_from_slice(&self.q);
                let (q, qbar, e) = (&self.q, &mut self.qbar, &mut self.e[i]);
                sel.for_each_range(d, |s, t| {
                    math::axpy(inv, &q[s..t], &mut qbar[s..t]);
                    math::fill(&mut e[s..t], 0.0);
                });
            }
        }
        math::axpy(1.0, &self.qbar, &mut self.xhat);
        for i in 0..self.n {
            self.x[i].copy_from_slice(&self.xhat);
        }
        RoundStats {
            grad_bits: 0,
            model_bits: bits / self.n as u64,
            grad_allreduce: true,
            model_allreduce: self.c1.globally_synchronized(),
            synced: true,
        }
    }

    fn n(&self) -> usize {
        self.n
    }
    fn dim(&self) -> usize {
        self.xhat.len()
    }
    fn worker_model(&self, i: usize) -> &[f32] {
        &self.x[i]
    }
    fn local_error(&self, i: usize) -> Option<&[f32]> {
        Some(&self.e[i])
    }
    fn name(&self) -> String {
        format!("qsparse[{},H={}]", self.c1.name(), self.h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::Grbs;

    #[test]
    fn h1_identity_reduces_to_sgd() {
        let init = [0.5f32, -0.5, 1.0];
        let mut q = QsparseLocalSgd::new(&init, 3, 0.9, Box::new(Identity), 1);
        let mut s = super::super::FullSgd::new(&init, 3, 0.9);
        for t in 0..15 {
            let g: Vec<Vec<f32>> =
                (0..3).map(|i| vec![(t as f32 - i as f32) * 0.1; 3]).collect();
            q.step(&g, 0.1);
            s.step(&g, 0.1);
        }
        for (a, b) in q.worker_model(0).iter().zip(s.worker_model(0)) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn local_sgd_averages_on_sync() {
        let mut q = QsparseLocalSgd::local_sgd(&[0.0, 0.0], 2, 0.0, 2);
        // step 1 (no sync): workers diverge
        q.step(&[vec![1.0, 0.0], vec![0.0, 1.0]], 1.0);
        assert_ne!(q.worker_model(0), q.worker_model(1));
        // step 2 (sync): full model averaging
        q.step(&[vec![1.0, 0.0], vec![0.0, 1.0]], 1.0);
        assert_eq!(q.worker_model(0), q.worker_model(1));
        assert_eq!(q.worker_model(0), &[-1.0, -1.0]);
    }

    #[test]
    fn models_fully_resynced_after_compressed_round() {
        let d = 40;
        let mut q = QsparseLocalSgd::new(
            &vec![0.0; d],
            4,
            0.0,
            Box::new(Grbs::new(4.0, 10, 5)),
            4,
        );
        for t in 1..=8 {
            let g: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32 * 0.1 + t as f32 * 0.01; d]).collect();
            q.step(&g, 0.1);
            if t % 4 == 0 {
                for i in 1..4 {
                    assert_eq!(q.worker_model(0), q.worker_model(i), "t={t}");
                }
            }
        }
    }

    #[test]
    fn no_communication_between_syncs() {
        let mut q =
            QsparseLocalSgd::new(&[0.0; 8], 2, 0.0, Box::new(Grbs::new(2.0, 4, 1)), 4);
        for t in 1..=8u64 {
            let stats = q.step(&[vec![1.0; 8], vec![2.0; 8]], 0.1);
            assert_eq!(stats.synced, t % 4 == 0);
            if !stats.synced {
                assert_eq!(stats.upload_bits(), 0);
            } else {
                assert!(stats.model_bits > 0);
            }
        }
    }

    #[test]
    fn quadratic_converges_moderate_compression() {
        let d = 64;
        let c = vec![1.0f32; d];
        let mut q =
            QsparseLocalSgd::new(&vec![0.0; d], 4, 0.0, Box::new(Grbs::new(4.0, 16, 9)), 4);
        for _ in 0..4000 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|i| q.worker_model(i).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            q.step(&g, 0.05);
        }
        let mut xbar = vec![0.0f32; d];
        q.mean_model(&mut xbar);
        let err: f64 = xbar.iter().zip(&c).map(|(x, ci)| ((x - ci) as f64).powi(2)).sum();
        assert!(err < 1e-2, "err={err}");
    }
}
