//! CSER "implementation II" (paper Algorithm 13; Appendix A.4).
//!
//! With randomized *sparsifiers* (GRBS), the residual bookkeeping of
//! implementation I is redundant: for any block, its local residual either
//! was already assimilated into the local model (unselected blocks) or was
//! just reset to zero (selected blocks).  So PSync can run **directly on the
//! local models** and the e_i vectors disappear:
//!
//!   p_i = η(β m_i + g_i)
//!   p'_i ← PSync(p_i, C2);   x_i ← x_i − p'_i
//!   every H steps:  x_i ← PSync(x_i, C1)
//!
//! Memory: 1×d state per worker instead of implementation I's 2×d (+2×d
//! scratch) — the paper's "less memory footprint" claim for GRBS.  The
//! equivalence with implementation I under globally-synchronized sparsifiers
//! is verified by a property test below; it does NOT hold for per-worker
//! compressors (rand-k/top-k), which is why the plan constructor asserts
//! `globally_synchronized()`.
//!
//! Deprecated thin wrapper over [`crate::engine::ErrorResetEngine`] with
//! [`CommPlan::cser_impl2`]; prefer building the plan directly.

use crate::compressor::Compressor;
use crate::engine::{CommPlan, ErrorResetEngine};

pub struct CserImpl2(ErrorResetEngine);

impl CserImpl2 {
    pub fn new(
        init: &[f32],
        n: usize,
        beta: f32,
        c1: Box<dyn Compressor>,
        c2: Box<dyn Compressor>,
        h: u64,
    ) -> Self {
        CserImpl2(ErrorResetEngine::new(init, n, beta, CommPlan::cser_impl2(c1, c2, h)))
    }
}

super::delegate_to_engine!(CserImpl2);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Grbs, Zero};
    use crate::optimizer::{Cser, DistOptimizer};
    use crate::util::prop::{forall, slices_close, Gen};

    #[test]
    fn prop_impl2_equals_impl1_under_grbs() {
        // Appendix A.4: with GRBS, implementation II (no e vectors) produces
        // the same local models as implementation I at every step.
        forall(20, 0x1317, |g: &mut Gen| {
            let n = g.usize_in(2, 5);
            let d = 8 * g.usize_in(2, 12);
            let h = g.usize_in(1, 4) as u64;
            let beta = if g.bool() { 0.9 } else { 0.0 };
            let seed1 = g.rng.next_u64();
            let seed2 = g.rng.next_u64();
            let nb1 = (d / 4).max(2);
            let nb2 = (d / 8).max(2);
            let init = g.vec(d);
            let mut a = Cser::new(
                &init,
                n,
                beta,
                Box::new(Grbs::new(2.0, nb1, seed1)),
                Box::new(Grbs::new(4.0, nb2, seed2)),
                h,
            );
            let mut b = CserImpl2::new(
                &init,
                n,
                beta,
                Box::new(Grbs::new(2.0, nb1, seed1)),
                Box::new(Grbs::new(4.0, nb2, seed2)),
                h,
            );
            for t in 0..(2 * h + 3) {
                let grads = g.worker_vecs(n, d);
                a.step(&grads, 0.1);
                b.step(&grads, 0.1);
                for i in 0..n {
                    slices_close(a.worker_model(i), b.worker_model(i), 1e-4)
                        .map_err(|e| format!("t={t} worker={i}: {e}"))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn impl2_equals_impl1_for_cser_pl() {
        // C2 = Zero is also globally synchronized; PL special case must agree.
        let d = 32;
        let init = vec![0.1f32; d];
        let mut a = Cser::cser_pl(&init, 3, 0.9, Box::new(Grbs::new(4.0, 8, 2)), 3);
        let mut b = CserImpl2::new(
            &init,
            3,
            0.9,
            Box::new(Grbs::new(4.0, 8, 2)),
            Box::new(Zero),
            3,
        );
        let mut g = Gen::replay(5, 0);
        for _ in 0..9 {
            let grads = g.worker_vecs(3, d);
            a.step(&grads, 0.05);
            b.step(&grads, 0.05);
        }
        for i in 0..3 {
            slices_close(a.worker_model(i), b.worker_model(i), 1e-4).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "globally-synchronized")]
    fn rejects_per_worker_compressors() {
        let _ = CserImpl2::new(
            &[0.0; 8],
            2,
            0.0,
            Box::new(crate::compressor::RandK::new(2.0)),
            Box::new(Zero),
            2,
        );
    }

    #[test]
    fn memory_footprint_is_model_only() {
        // structural check: impl2 owns n model vecs + n scratch, no e/e_half.
        let d = 16;
        let o = CserImpl2::new(&vec![0.0; d], 4, 0.0,
            Box::new(Grbs::new(2.0, 4, 1)), Box::new(Zero), 2);
        assert!(o.local_error(0).is_none());
    }
}
