//! CSER — Communication-efficient SGD with Error Reset (paper Algorithm 2,
//! momentum variant Algorithm 4 "implementation I").
//!
//! Each iteration (worker i):
//!
//!   p_i = η(β m_i + g_i)                       (momentum; η g_i at β=0)
//!   p'_i, r_i = PSync(p_i, C2)                 (partial GRADIENT sync)
//!   x_i ← x_i − p'_i        e_i ← e_i − r_i    (residual applied to the
//!                                               model IMMEDIATELY — the
//!                                               "error reset" bifurcation)
//!   every H steps:
//!     e'_i, e_i ← PSync(e_half_i, C1)          (partial ERROR/model sync)
//!     x_i ← x_half_i − e_half_i + e'_i
//!
//! Lemma 1 (tested as a property): x_{i,t} − e_{i,t} is identical across
//! workers at every t — e_i is exactly each worker's private divergence from
//! the consensus trajectory, and the C1 round (partially) resets it.
//!
//! Special cases (paper Appendix A):
//!   * `Cser::csea`    — H = 1, C2 = 0  (Algorithm 7: "error assimilation")
//!   * `Cser::cser_pl` — C2 = 0         (Algorithm 8: partial-local SGD)
//!   * C1 = identity, C2 = 0            — local SGD (model averaging)
//!   * C1 = C2 = identity               — fully-synchronous SGD
//!
//! Deprecated thin wrapper over [`crate::engine::ErrorResetEngine`] with
//! [`CommPlan::cser`] / [`CommPlan::csea`] / [`CommPlan::cser_pl`]; prefer
//! building the plan directly.

use crate::compressor::Compressor;
use crate::engine::{CommPlan, ErrorResetEngine};

pub struct Cser(ErrorResetEngine);

impl Cser {
    /// Full CSER/M-CSER: gradient compressor `c2` every step, error-reset
    /// compressor `c1` every `h` steps, momentum `beta` (0 disables).
    pub fn new(
        init: &[f32],
        n: usize,
        beta: f32,
        c1: Box<dyn Compressor>,
        c2: Box<dyn Compressor>,
        h: u64,
    ) -> Self {
        Cser(ErrorResetEngine::new(init, n, beta, CommPlan::cser(c1, c2, h)))
    }

    /// CSEA (Algorithm 7): error assimilation — H=1, no gradient sync path.
    pub fn csea(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>) -> Self {
        Cser(ErrorResetEngine::new(init, n, beta, CommPlan::csea(c1)))
    }

    /// CSER-PL (Algorithm 8): partial-local SGD — no gradient sync path.
    pub fn cser_pl(init: &[f32], n: usize, beta: f32, c1: Box<dyn Compressor>, h: u64) -> Self {
        Cser(ErrorResetEngine::new(init, n, beta, CommPlan::cser_pl(c1, h)))
    }
}

super::delegate_to_engine!(Cser);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Grbs, Identity, RandK, TopK, Zero};
    use crate::optimizer::{DistOptimizer, FullSgd, QsparseLocalSgd};
    use crate::util::prop::{forall, slices_close, Gen};

    fn random_grads(g: &mut Gen, n: usize, d: usize) -> Vec<Vec<f32>> {
        // smooth vectors: the Lemma 1 identity is exact in real arithmetic;
        // 1e6-scale outliers would only probe f32 cancellation noise.
        g.worker_vecs_smooth(n, d)
    }

    #[test]
    fn prop_lemma1_bifurcated_models() {
        // x_{i,t} - e_{i,t} identical across workers, any compressors/H/beta.
        forall(25, 0xCE5E, |g: &mut Gen| {
            let n = g.usize_in(2, 6);
            let d = g.usize_in(8, 64);
            let h = g.usize_in(1, 5) as u64;
            let beta = if g.bool() { 0.9 } else { 0.0 };
            let c1: Box<dyn Compressor> = match g.usize_in(0, 3) {
                0 => Box::new(Grbs::new(2.0, (d / 4).max(2), 7)),
                1 => Box::new(RandK::new(4.0)),
                _ => Box::new(TopK::new(4.0)),
            };
            let c2: Box<dyn Compressor> = match g.usize_in(0, 3) {
                0 => Box::new(Zero),
                1 => Box::new(Grbs::new(4.0, (d / 4).max(2), 11)),
                _ => Box::new(RandK::new(8.0)),
            };
            let init = g.vec(d);
            let mut o = Cser::new(&init, n, beta, c1, c2, h);
            for _ in 0..(3 * h + 2) {
                o.step(&random_grads(g, n, d), 0.05);
                let base: Vec<f32> = o
                    .worker_model(0)
                    .iter()
                    .zip(o.local_error(0).unwrap())
                    .map(|(x, e)| x - e)
                    .collect();
                for i in 1..n {
                    let xi: Vec<f32> = o
                        .worker_model(i)
                        .iter()
                        .zip(o.local_error(i).unwrap())
                        .map(|(x, e)| x - e)
                        .collect();
                    slices_close(&base, &xi, 1e-4)
                        .map_err(|e| format!("worker {i}: {e}"))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identity_everything_reduces_to_sgd() {
        let init = [0.3f32, -0.7, 0.1, 0.9];
        let mut cs = Cser::new(&init, 3, 0.9, Box::new(Identity), Box::new(Identity), 2);
        let mut s = FullSgd::new(&init, 3, 0.9);
        for t in 0..12 {
            let g: Vec<Vec<f32>> =
                (0..3).map(|i| vec![0.1 * (t + i) as f32, -0.2, 0.05, 0.3]).collect();
            cs.step(&g, 0.1);
            s.step(&g, 0.1);
            for i in 0..3 {
                for (a, b) in cs.worker_model(i).iter().zip(s.worker_model(0)) {
                    assert!((a - b).abs() < 1e-5, "t={t} {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn c1_identity_c2_zero_is_local_sgd() {
        // CSER(δ1=1, C2=0, H) must match QsparseLocalSgd with identity C1
        // (i.e. local SGD with model averaging every H).
        let init = [0.0f32; 6];
        let h = 3;
        let mut cs = Cser::new(&init, 2, 0.9, Box::new(Identity), Box::new(Zero), h);
        let mut ls = QsparseLocalSgd::local_sgd(&init, 2, 0.9, h);
        let mut g = Gen::replay(42, 0);
        for t in 0..12 {
            let grads = vec![g.vec(6), g.vec(6)];
            cs.step(&grads, 0.1);
            ls.step(&grads, 0.1);
            for i in 0..2 {
                slices_close(cs.worker_model(i), ls.worker_model(i), 1e-4)
                    .unwrap_or_else(|e| panic!("t={t} worker={i}: {e}"));
            }
        }
    }

    #[test]
    fn csea_matches_cser_h1() {
        let init = [0.1f32; 8];
        let c = || Box::new(Grbs::new(2.0, 4, 5));
        let mut a = Cser::csea(&init, 2, 0.9, c());
        let mut b = Cser::new(&init, 2, 0.9, c(), Box::new(Zero), 1);
        let mut g = Gen::replay(7, 0);
        for _ in 0..10 {
            let grads = vec![g.vec(8), g.vec(8)];
            a.step(&grads, 0.2);
            b.step(&grads, 0.2);
        }
        assert_eq!(a.worker_model(0), b.worker_model(0));
        assert_eq!(a.worker_model(1), b.worker_model(1));
    }

    #[test]
    fn reset_round_reduces_error_mass() {
        // after a C1 round with ratio R, E||e||^2 shrinks by ~(1-1/R)
        let d = 4096;
        let n = 4;
        let mut o = Cser::new(
            &vec![0.0; d],
            n,
            0.0,
            Box::new(Grbs::new(2.0, 64, 3)),
            Box::new(Zero),
            4,
        );
        let mut g = Gen::replay(11, 1);
        let mut before = 0.0;
        for t in 1..=4 {
            let grads: Vec<Vec<f32>> = (0..n).map(|_| g.vec(d)).collect();
            if t == 4 {
                // measure error mass entering the reset
                before = (0..n)
                    .map(|i| crate::util::math::norm2(o.local_error(i).unwrap()))
                    .sum::<f64>();
                assert!(before > 0.0);
            }
            o.step(&grads, 0.1);
        }
        let after: f64 = (0..n)
            .map(|i| crate::util::math::norm2(o.local_error(i).unwrap()))
            .sum();
        // The reset round first accumulates one more gradient residual, then
        // halves (R=2) in expectation; just require a strict decrease vs the
        // pre-reset mass grown by one more step.
        assert!(after < before * 1.5, "before={before} after={after}");
        // errors on the synced blocks are exactly zero
        let sel_zeroed = o.local_error(0).unwrap().iter().filter(|&&x| x == 0.0).count();
        assert!(sel_zeroed >= d / 4, "zeroed={sel_zeroed}");
    }

    #[test]
    fn quadratic_converges_aggressive_compression() {
        // R_C = 256-ish: C2 ratio 512, C1 ratio 16, H 32
        let d = 512;
        let c = vec![1.0f32; d];
        let mut o = Cser::new(
            &vec![0.0; d],
            4,
            0.0,
            Box::new(Grbs::new(16.0, 64, 3)),
            Box::new(Grbs::new(512.0, 512, 5)),
            32,
        );
        for _ in 0..6000 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|i| o.worker_model(i).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            o.step(&g, 0.05);
        }
        let mut xbar = vec![0.0f32; d];
        o.mean_model(&mut xbar);
        let err: f64 =
            xbar.iter().zip(&c).map(|(x, ci)| ((x - ci) as f64).powi(2)).sum::<f64>() / d as f64;
        assert!(err < 1e-3, "err={err}");
    }

    #[test]
    fn comm_bits_respect_budget_formula() {
        // overall R_C = 1 / (1/R_C2 + 1/(R_C1 * H)): measured bits per step
        // should equal d*32 / R_C within block-rounding slack.
        let d = 1 << 14;
        let (r1, r2, h) = (8.0, 64.0, 8u64);
        let mut o = Cser::new(
            &vec![0.0; d],
            4,
            0.0,
            Box::new(Grbs::new(r1, 512, 3)),
            Box::new(Grbs::new(r2, 1024, 5)),
            h,
        );
        let mut g = Gen::replay(3, 0);
        let steps = 64u64;
        let mut bits = 0u64;
        for _ in 0..steps {
            let grads = vec![g.vec(d), g.vec(d), g.vec(d), g.vec(d)];
            bits += o.step(&grads, 0.01).upload_bits();
        }
        let per_step = bits as f64 / steps as f64;
        let rc = 1.0 / (1.0 / r2 + 1.0 / (r1 * h as f64));
        let expect = d as f64 * 32.0 / rc;
        assert!(
            (per_step - expect).abs() < 0.05 * expect,
            "per_step={per_step} expect={expect}"
        );
    }
}

#[cfg(test)]
mod quantizer_tests {
    //! "Arbitrary compressors" (paper abstract): CSER with dense value
    //! quantizers — QSGD on the gradient path, sign-SGD on the error path.
    use super::*;
    use crate::compressor::{Qsgd, SignSgd};
    use crate::optimizer::DistOptimizer;

    #[test]
    fn cser_converges_with_dense_quantizers() {
        let d = 64;
        let c = vec![1.0f32; d];
        let mut o = Cser::new(
            &vec![0.0; d],
            4,
            0.0,
            Box::new(SignSgd),
            Box::new(Qsgd::new(4)),
            8,
        );
        for _ in 0..4000 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|i| o.worker_model(i).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            o.step(&g, 0.05);
        }
        let mut xbar = vec![0.0f32; d];
        o.mean_model(&mut xbar);
        let err: f64 = xbar
            .iter()
            .zip(&c)
            .map(|(x, ci)| ((x - ci) as f64).powi(2))
            .sum::<f64>()
            / d as f64;
        assert!(err < 5e-2, "err={err}");
    }

    #[test]
    fn lemma1_holds_with_quantizers_too() {
        // The bifurcation identity is compressor-agnostic.
        use crate::util::prop::Gen;
        let d = 32;
        let n = 3;
        let mut o = Cser::new(
            &vec![0.1; d],
            n,
            0.9,
            Box::new(Qsgd::new(2)),
            Box::new(SignSgd),
            2,
        );
        let mut g = Gen::replay(0xABCD, 0);
        for _ in 0..8 {
            let grads = g.worker_vecs_smooth(n, d);
            o.step(&grads, 0.05);
            let base: Vec<f32> = o
                .worker_model(0)
                .iter()
                .zip(o.local_error(0).unwrap())
                .map(|(x, e)| x - e)
                .collect();
            for i in 1..n {
                for (j, (x, e)) in o
                    .worker_model(i)
                    .iter()
                    .zip(o.local_error(i).unwrap())
                    .enumerate()
                {
                    assert!(((x - e) - base[j]).abs() < 1e-3, "worker {i} coord {j}");
                }
            }
        }
    }

    #[test]
    fn quantizer_bits_reflect_quantization() {
        let d = 1024;
        let mut o = Cser::new(
            &vec![0.0; d],
            2,
            0.0,
            Box::new(SignSgd),
            Box::new(Qsgd::new(4)),
            4,
        );
        let grads = vec![vec![1.0f32; d]; 2];
        let stats = o.step(&grads, 0.1);
        // QSGD s=4: ~3.17 bits/coord << 32
        assert!(stats.grad_bits < d as u64 * 8, "{}", stats.grad_bits);
        assert!(stats.grad_bits > d as u64 * 2);
    }
}
