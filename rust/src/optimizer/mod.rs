//! Distributed optimizers: the paper's algorithms and its baselines.
//!
//! Since the engine refactor every algorithm executes inside
//! [`crate::engine::ErrorResetEngine`] driven by a declarative
//! [`crate::engine::CommPlan`]; the types in this module are **thin
//! deprecated wrappers** kept for source compatibility (constructor
//! signatures unchanged, trajectories pinned bit-identical to the seed
//! implementations by `rust/tests/engine_parity.rs`).  New code should build
//! plans directly:
//!
//! | Legacy wrapper      | Paper algorithm                | `CommPlan` constructor |
//! |---------------------|--------------------------------|------------------------|
//! | `FullSgd`           | fully-synchronous SGD          | `CommPlan::full_sgd`   |
//! | `EfSgd`             | EF-SGD (Alg 10)                | `CommPlan::ef_sgd`     |
//! | `QsparseLocalSgd`   | QSparse-local-SGD (Alg 1/12)   | `CommPlan::qsparse`    |
//! | `QsparseLocalSgd::local_sgd` | local SGD (C1 = identity) | `CommPlan::local_sgd` |
//! | `Cser`              | CSER / M-CSER (Alg 2 / Alg 4)  | `CommPlan::cser`       |
//! | `Cser::csea`        | CSEA (Alg 7, H = 1, C2 = 0)    | `CommPlan::csea`       |
//! | `Cser::cser_pl`     | CSER-PL (Alg 8, C2 = 0)        | `CommPlan::cser_pl`    |
//! | `CserImpl2`         | CSER implementation II (Alg 13, GRBS) | `CommPlan::cser_impl2` |
//!
//! All of them implement [`DistOptimizer`]: the trainer computes per-worker
//! gradients on each worker's own local model and shard, then calls
//! `step(grads, eta)` — or, in worker-resident mode, hands the engine a
//! gradient oracle and lets each worker thread drive itself
//! (`ErrorResetEngine::run_resident`).  Momentum (paper §3.2, Nesterov in
//! the Sutskever form) is uniform across algorithms: every per-worker
//! descent message is p_i = η(β·m_i + g_i) with m_i ← β·m_i + g_i, reducing
//! to p_i = η·g_i at β = 0 (`engine::descent_into`; [`Momentum`] wraps it
//! for the legacy API).

pub mod cser;
pub mod cser_impl2;
pub mod ef_sgd;
pub mod qsparse;
pub mod sgd;

pub use cser::Cser;
pub use cser_impl2::CserImpl2;
pub use ef_sgd::EfSgd;
pub use qsparse::QsparseLocalSgd;
pub use sgd::FullSgd;

/// Communication performed by one optimizer step (one worker's upload view;
/// the trainer turns this into wire/time cost via `network::CostModel`).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundStats {
    /// Bits uploaded for gradient synchronization (C2 path or dense).
    pub grad_bits: u64,
    /// Bits uploaded for model/error synchronization (C1 path), nonzero only
    /// on reset rounds.
    pub model_bits: u64,
    /// Whether each path could use AllReduce (global support).
    pub grad_allreduce: bool,
    pub model_allreduce: bool,
    /// True if this step was an error-reset / model-sync round.
    pub synced: bool,
}

impl RoundStats {
    pub fn upload_bits(&self) -> u64 {
        self.grad_bits + self.model_bits
    }
}

/// A synchronous distributed optimizer over n workers and a flat d-vector.
/// (`Sync` so the trainer can read per-worker models from gradient threads.)
pub trait DistOptimizer: Send + Sync {
    /// Apply one iteration. `grads[i]` is worker i's stochastic gradient
    /// evaluated at `worker_model(i)`; `eta` is the current learning rate.
    fn step(&mut self, grads: &[Vec<f32>], eta: f32) -> RoundStats;

    /// Swap the communication backend (`transport::Collective`) this
    /// optimizer synchronizes over.  Default: no-op — algorithms that never
    /// communicate through PSync/exchange (plain SGD's dense mean is left on
    /// the in-process path) ignore it.
    fn set_collective(&mut self, _c: std::sync::Arc<dyn crate::transport::Collective>) {}

    fn n(&self) -> usize;
    fn dim(&self) -> usize;

    /// Worker i's current local model x_{i,t} (what its next gradient is
    /// computed on).
    fn worker_model(&self, i: usize) -> &[f32];

    /// x̄_t = mean_i x_{i,t} — the iterate the paper's analysis tracks and
    /// the model used for evaluation.
    fn mean_model(&self, out: &mut [f32]) {
        crate::util::math::fill(out, 0.0);
        let inv = 1.0 / self.n() as f32;
        for i in 0..self.n() {
            crate::util::math::axpy(inv, self.worker_model(i), out);
        }
    }

    /// Local residual error e_{i,t} if the algorithm maintains one
    /// (for the Lemma 1 invariant test).
    fn local_error(&self, _i: usize) -> Option<&[f32]> {
        None
    }

    /// Downcast to the generic engine, when this optimizer is one (all the
    /// built-in algorithms are).  The trainer uses this to route
    /// `Backend::Resident` runs through the worker-resident execution mode.
    fn as_engine(&mut self) -> Option<&mut crate::engine::ErrorResetEngine> {
        None
    }

    fn name(&self) -> String;
}

/// Nesterov momentum in the Sutskever form (paper §3.2):
///   m_t = β m_{t-1} + g_t,   update direction = β m_t + g_t.
///
/// Legacy API over [`crate::engine::descent_into`] (worker-centric code
/// holds one momentum buffer per `WorkerState` instead of a matrix here).
#[derive(Debug, Clone)]
pub struct Momentum {
    pub beta: f32,
    m: Vec<Vec<f32>>,
}

impl Momentum {
    pub fn new(beta: f32, n: usize, d: usize) -> Self {
        assert!((0.0..1.0).contains(&beta));
        let m = if beta > 0.0 { vec![vec![0.0; d]; n] } else { vec![] };
        Momentum { beta, m }
    }

    /// p_i = η(β m_i + g_i) written into `out`; updates m_i in place.
    pub fn descent(&mut self, i: usize, g: &[f32], eta: f32, out: &mut [f32]) {
        let empty: &mut [f32] = &mut [];
        let m = if self.beta == 0.0 { empty } else { self.m[i].as_mut_slice() };
        crate::engine::descent_into(self.beta, m, g, eta, out);
    }
}

/// Implements [`DistOptimizer`] for a newtype wrapper whose field 0 is a
/// [`crate::engine::ErrorResetEngine`] — the deprecated legacy algorithm
/// structs are all such wrappers.
macro_rules! delegate_to_engine {
    ($ty:ty) => {
        impl crate::optimizer::DistOptimizer for $ty {
            fn step(
                &mut self,
                grads: &[Vec<f32>],
                eta: f32,
            ) -> crate::optimizer::RoundStats {
                crate::optimizer::DistOptimizer::step(&mut self.0, grads, eta)
            }
            fn set_collective(
                &mut self,
                c: std::sync::Arc<dyn crate::transport::Collective>,
            ) {
                crate::optimizer::DistOptimizer::set_collective(&mut self.0, c)
            }
            fn n(&self) -> usize {
                crate::optimizer::DistOptimizer::n(&self.0)
            }
            fn dim(&self) -> usize {
                crate::optimizer::DistOptimizer::dim(&self.0)
            }
            fn worker_model(&self, i: usize) -> &[f32] {
                crate::optimizer::DistOptimizer::worker_model(&self.0, i)
            }
            fn mean_model(&self, out: &mut [f32]) {
                crate::optimizer::DistOptimizer::mean_model(&self.0, out)
            }
            fn local_error(&self, i: usize) -> Option<&[f32]> {
                crate::optimizer::DistOptimizer::local_error(&self.0, i)
            }
            fn as_engine(&mut self) -> Option<&mut crate::engine::ErrorResetEngine> {
                Some(&mut self.0)
            }
            fn name(&self) -> String {
                crate::optimizer::DistOptimizer::name(&self.0)
            }
        }
    };
}
pub(crate) use delegate_to_engine;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_beta_zero_is_plain_sgd_direction() {
        let mut mo = Momentum::new(0.0, 1, 3);
        let mut p = vec![0.0; 3];
        mo.descent(0, &[1.0, -2.0, 3.0], 0.1, &mut p);
        assert_eq!(p, vec![0.1, -0.2, 0.3]);
    }

    #[test]
    fn momentum_matches_sutskever_recursion() {
        // hand-roll two steps of m_t = b m + g; p = eta (b m_t + g_t)
        let beta = 0.9f32;
        let eta = 0.5f32;
        let mut mo = Momentum::new(beta, 1, 1);
        let mut p = vec![0.0f32];
        mo.descent(0, &[2.0], eta, &mut p);
        // m1 = 2; p1 = eta*(0.9*2 + 2) = 0.5*3.8 = 1.9
        assert!((p[0] - 1.9).abs() < 1e-6);
        mo.descent(0, &[1.0], eta, &mut p);
        // m2 = 0.9*2 + 1 = 2.8; p2 = 0.5*(0.9*2.8 + 1) = 0.5*3.52 = 1.76
        assert!((p[0] - 1.76).abs() < 1e-6);
    }
}
