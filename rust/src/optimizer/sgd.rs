//! Fully-synchronous SGD with momentum — the R_C = 1 baseline in every table.
//!
//! Every worker holds the identical model; the gradient is dense-AllReduced
//! each step; momentum is applied to the averaged gradient (equivalently,
//! per-worker on identical state — they coincide).

use super::{DistOptimizer, Momentum, RoundStats};
use crate::util::math;

pub struct FullSgd {
    n: usize,
    x: Vec<f32>,
    momentum: Momentum,
    gbar: Vec<f32>,
    p: Vec<f32>,
}

impl FullSgd {
    pub fn new(init: &[f32], n: usize, beta: f32) -> Self {
        FullSgd {
            n,
            x: init.to_vec(),
            momentum: Momentum::new(beta, 1, init.len()),
            gbar: vec![0.0; init.len()],
            p: vec![0.0; init.len()],
        }
    }
}

impl DistOptimizer for FullSgd {
    fn step(&mut self, grads: &[Vec<f32>], eta: f32) -> RoundStats {
        debug_assert_eq!(grads.len(), self.n);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        math::mean_rows(&refs, &mut self.gbar);
        self.momentum.descent(0, &self.gbar, eta, &mut self.p);
        math::axpy(-1.0, &self.p, &mut self.x);
        RoundStats {
            grad_bits: self.x.len() as u64 * 32,
            model_bits: 0,
            grad_allreduce: true,
            model_allreduce: true,
            synced: true,
        }
    }

    fn n(&self) -> usize {
        self.n
    }
    fn dim(&self) -> usize {
        self.x.len()
    }
    fn worker_model(&self, _i: usize) -> &[f32] {
        &self.x
    }
    fn mean_model(&self, out: &mut [f32]) {
        out.copy_from_slice(&self.x);
    }
    fn name(&self) -> String {
        "sgd".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_gradients() {
        let mut o = FullSgd::new(&[0.0, 0.0], 2, 0.0);
        o.step(&[vec![1.0, 0.0], vec![3.0, 2.0]], 0.5);
        // gbar = [2, 1]; x = -eta*gbar
        assert_eq!(o.worker_model(0), &[-1.0, -0.5]);
    }

    #[test]
    fn quadratic_converges() {
        // f(x) = 0.5 ||x - c||^2, grad = x - c
        let c = [3.0f32, -2.0];
        let mut o = FullSgd::new(&[0.0, 0.0], 4, 0.9);
        for _ in 0..200 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|_| o.worker_model(0).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            o.step(&g, 0.05);
        }
        let x = o.worker_model(0);
        assert!((x[0] - 3.0).abs() < 1e-2 && (x[1] + 2.0).abs() < 1e-2, "{x:?}");
    }
}
