//! Fully-synchronous SGD with momentum — the R_C = 1 baseline in every table.
//!
//! Every worker holds the identical model; the gradient is dense-AllReduced
//! each step; momentum is applied to the averaged gradient (equivalently,
//! per-worker on identical state — they coincide).
//!
//! Deprecated thin wrapper over [`crate::engine::ErrorResetEngine`] with
//! [`CommPlan::full_sgd`]; prefer building the plan directly.

use crate::engine::{CommPlan, ErrorResetEngine};

pub struct FullSgd(ErrorResetEngine);

impl FullSgd {
    pub fn new(init: &[f32], n: usize, beta: f32) -> Self {
        FullSgd(ErrorResetEngine::new(init, n, beta, CommPlan::full_sgd()))
    }
}

super::delegate_to_engine!(FullSgd);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::DistOptimizer;

    #[test]
    fn averages_gradients() {
        let mut o = FullSgd::new(&[0.0, 0.0], 2, 0.0);
        o.step(&[vec![1.0, 0.0], vec![3.0, 2.0]], 0.5);
        // gbar = [2, 1]; x = -eta*gbar
        assert_eq!(o.worker_model(0), &[-1.0, -0.5]);
    }

    #[test]
    fn quadratic_converges() {
        // f(x) = 0.5 ||x - c||^2, grad = x - c
        let c = [3.0f32, -2.0];
        let mut o = FullSgd::new(&[0.0, 0.0], 4, 0.9);
        for _ in 0..200 {
            let g: Vec<Vec<f32>> = (0..4)
                .map(|_| o.worker_model(0).iter().zip(&c).map(|(x, ci)| x - ci).collect())
                .collect();
            o.step(&g, 0.05);
        }
        let x = o.worker_model(0);
        assert!((x[0] - 3.0).abs() < 1e-2 && (x[1] + 2.0).abs() < 1e-2, "{x:?}");
    }

    #[test]
    fn all_worker_views_identical() {
        // replicated plan: every worker's model is the same vector, and
        // mean_model is an exact copy (not an n-way average re-rounding)
        let mut o = FullSgd::new(&[0.1, 0.2, 0.3], 3, 0.9);
        o.step(&[vec![1.0, 0.5, -0.5], vec![0.0, 1.0, 0.5], vec![-1.0, 0.5, 0.0]], 0.1);
        let mut xbar = vec![0.0f32; 3];
        o.mean_model(&mut xbar);
        for i in 0..3 {
            assert_eq!(o.worker_model(i), xbar.as_slice());
        }
        assert!(o.local_error(0).is_none());
    }
}
