//! Full-stack trainer: transformer LM gradients from the AOT JAX/Pallas
//! artifact via PJRT, the paper's optimizers on the flat parameter vector.
//!
//! This is the engine behind `examples/lm_e2e.rs` and `cser train-lm`: it
//! proves the three layers compose (L1 Pallas kernels inside the L2 HLO,
//! executed by the L3 coordinator) on a real training workload.  Workers are
//! simulated in-process: worker i's gradient is evaluated at the optimizer's
//! bifurcated local model x_i (exactly as in sim_trainer), the synchronous
//! step then applies CSER/PSync in Rust.

use super::metrics::{EpochPoint, RunRecord};
use crate::config::OptSpec;
use crate::data::LmCorpus;
use crate::runtime::{Executable, Manifest, ModelInfo, Runtime};
use crate::util::rng::Rng;
use anyhow::Result;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct LmCfg {
    pub workers: usize,
    pub steps: usize,
    pub eval_every: usize,
    pub lr: f64,
    pub beta: f32,
    pub seed: u64,
    /// Warmup fraction for a linear-then-constant schedule.
    pub warmup_frac: f64,
    pub verbose: bool,
}

impl Default for LmCfg {
    fn default() -> Self {
        LmCfg {
            workers: 4,
            steps: 200,
            eval_every: 20,
            lr: 0.25,
            beta: 0.9,
            seed: 0,
            warmup_frac: 0.05,
            verbose: true,
        }
    }
}

pub struct LmRun {
    pub record: RunRecord,
    /// Wall-clock seconds per training step (all workers), measured.
    pub step_seconds: f64,
    pub final_eval_loss: f64,
}

/// Train `spec` on the synthetic Markov corpus through the PJRT artifact.
pub fn train_lm(
    rt: &Runtime,
    manifest: &Manifest,
    info: &ModelInfo,
    spec: &OptSpec,
    cfg: &LmCfg,
) -> Result<LmRun> {
    let exe: Executable = rt.load(&info.train_step)?;
    let eval_exe: Executable = rt.load(&info.eval_loss)?;
    let init = manifest.load_init(info)?;
    let d = init.len();
    let (b, s) = (info.batch, info.seq_len);

    let corpus = LmCorpus::markov(info.vocab, 200_000.min(info.vocab * 400), 4, 0.05, cfg.seed);
    let mut worker_rngs: Vec<Rng> =
        (0..cfg.workers).map(|w| Rng::stream(cfg.seed ^ 0xE2E, w as u64)).collect();
    let mut eval_rng = Rng::stream(cfg.seed ^ 0xE2E, 0xFFFF);

    let mut opt = spec.build(&init, cfg.workers, cfg.beta, cfg.seed);
    let mut grads: Vec<Vec<f32>> = vec![vec![0.0; d]; cfg.workers];
    let (mut tok, mut tgt) = (Vec::new(), Vec::new());
    let mut points = Vec::new();
    let mut cum_bits = 0.0f64;
    let t0 = Instant::now();
    let mut diverged = false;

    // fixed held-out eval batches
    let mut eval_batches = Vec::new();
    for _ in 0..4 {
        let (mut et, mut eg) = (Vec::new(), Vec::new());
        corpus.sample_batch(b, s, &mut eval_rng, &mut et, &mut eg);
        eval_batches.push((et, eg));
    }
    let mut eval_loss = f64::NAN;

    for step in 1..=cfg.steps {
        let frac = step as f64 / cfg.steps as f64;
        let warm = (frac / cfg.warmup_frac).min(1.0);
        let eta = (cfg.lr * warm) as f32;

        let mut train_loss = 0.0f64;
        for w in 0..cfg.workers {
            corpus.sample_batch(b, s, &mut worker_rngs[w], &mut tok, &mut tgt);
            let (loss, grad) = exe.train_step(opt.worker_model(w), &tok, &tgt, b, s)?;
            train_loss += loss as f64 / cfg.workers as f64;
            grads[w].copy_from_slice(&grad);
        }
        if !train_loss.is_finite() {
            diverged = true;
        }
        let stats = opt.step(&grads, eta);
        cum_bits += stats.upload_bits() as f64;

        if step % cfg.eval_every == 0 || step == cfg.steps || diverged {
            let mut xbar = vec![0.0f32; d];
            opt.mean_model(&mut xbar);
            eval_loss = 0.0;
            for (et, eg) in &eval_batches {
                eval_loss +=
                    eval_exe.eval_loss(&xbar, et, eg, b, s)? as f64 / eval_batches.len() as f64;
            }
            points.push(EpochPoint {
                epoch: step,
                train_loss,
                test_acc: -eval_loss, // higher-is-better slot holds -loss
                cum_bits,
                cum_seconds: t0.elapsed().as_secs_f64(),
                wall_ms: t0.elapsed().as_millis() as u64,
            });
            if cfg.verbose {
                println!(
                    "step {step:>5}  train_loss {train_loss:.4}  eval_loss {eval_loss:.4}  \
                     eta {eta:.4}  upload_MB {:.2}  elapsed {:.1}s",
                    cum_bits / 8e6,
                    t0.elapsed().as_secs_f64()
                );
            }
            if diverged {
                break;
            }
        }
    }

    let step_seconds = t0.elapsed().as_secs_f64() / cfg.steps as f64;
    Ok(LmRun {
        record: RunRecord {
            name: format!("lm_{}", info.name),
            optimizer: opt.name(),
            overall_rc: spec.overall_rc(),
            lr: cfg.lr,
            seed: cfg.seed,
            points,
            diverged,
            phases: Vec::new(),
            elastic: None,
        },
        step_seconds,
        final_eval_loss: eval_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_lm_trains_through_pjrt_with_cser() {
        let Ok(manifest) = Manifest::load("artifacts") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let info = manifest.model("tiny").unwrap();
        let cfg = LmCfg {
            workers: 2,
            steps: 30,
            eval_every: 10,
            lr: 0.3,
            beta: 0.9,
            seed: 3,
            warmup_frac: 0.1,
            verbose: false,
        };
        let spec = OptSpec::Cser { rc1: 4.0, rc2: 16.0, h: 4 };
        let run = train_lm(&rt, &manifest, info, &spec, &cfg).unwrap();
        assert!(!run.record.diverged);
        let first = run.record.points.first().unwrap().train_loss;
        let last = run.record.points.last().unwrap().train_loss;
        assert!(
            last < first - 0.3,
            "LM loss did not drop through the full stack: {first} -> {last}"
        );
        assert!(run.record.points.last().unwrap().cum_bits > 0.0);
    }
}
