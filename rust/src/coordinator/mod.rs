//! The training coordinator: leader loop over simulated workers.
//!
//! * [`sim_trainer`] — fast path: pure-Rust models (the sweeps behind every
//!   paper table/figure).  Per-worker gradients run on a scoped thread pool;
//!   the optimizer step is the paper's synchronous algorithm; the timeline
//!   and bit accounting use `network::CostModel` at paper scale.
//! * [`lm_trainer`] — full-stack path: per-worker gradients come from the
//!   AOT-compiled JAX/Pallas artifact through PJRT (`runtime`), everything
//!   else identical.  This is the end-to-end driver's engine.
//! * [`metrics`] — run records and results-file output (JSON/CSV).

pub mod checkpoint;
pub mod lm_trainer;
pub mod metrics;
pub mod plot;
pub mod sim_trainer;

pub use metrics::{ElasticSummary, EpochEvent, EpochPoint, RunRecord};
pub use sim_trainer::{train_classifier, ChaosSpec, TrainCfg};
