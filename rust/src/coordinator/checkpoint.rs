//! Checkpointing: save/restore the flat training state.
//!
//! Long training runs (the paper's ImageNet runs take days) need restartable
//! state; a distributed `cser worker` process additionally needs its rank's
//! **complete** optimizer state, because the whole fleet restarts from the
//! same step and must continue bit-identically.  Because that state lives in
//! flat f32 vectors, a checkpoint is a tiny header + raw little-endian
//! payloads:
//!
//! ```text
//! magic "CSERCKPT" | version u32 (=2) | step u64 | n u32 | d u64 |
//! n × d f32 (models) |
//! flags u32 (bit0: errors, bit1: momentum, bit2: anchors) |
//! [n × d f32 errors] [n × d f32 momentum] [n × d f32 anchors]
//! ```
//!
//! Version 1 captured only models + errors — everything visible through the
//! `DistOptimizer` surface — which silently dropped the momentum buffers
//! and QSparse anchors, so a "resumed" run diverged from the uninterrupted
//! one on the first step.  [`Checkpoint::capture_engine`] reads the full
//! `ErrorResetEngine` state (including the step counter the sync schedules
//! key on) and [`Checkpoint::restore_engine`] puts it back, validated
//! against the plan; the roundtrip is pinned **bit-identical** by the tests
//! below (given the same gradient stream — the data pipeline is outside
//! the checkpoint's scope, so a resumed trainer draws fresh minibatches).
//!
//! Integrity is protected by a FNV-1a checksum trailer; truncated or
//! corrupted files fail loudly.

use crate::engine::ErrorResetEngine;
use crate::optimizer::DistOptimizer;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CSERCKPT";
const VERSION: u32 = 2;

const FLAG_ERRORS: u32 = 1;
const FLAG_MOMENTUM: u32 = 2;
const FLAG_ANCHORS: u32 = 4;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub models: Vec<Vec<f32>>,
    /// Per-worker residual errors e_i (plans that track them).
    pub errors: Option<Vec<Vec<f32>>>,
    /// Per-worker momentum buffers m_i (β > 0).
    pub momentum: Option<Vec<Vec<f32>>>,
    /// Per-worker consensus anchors x̂ (QSparse/local-SGD resync plans).
    pub anchors: Option<Vec<Vec<f32>>>,
}

fn fnv1a(data: &[u8], mut h: u64) -> u64 {
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    /// Capture what the `DistOptimizer` surface exposes: models + errors.
    ///
    /// **Insufficient for resume** whenever the optimizer carries momentum
    /// or anchors — prefer [`Checkpoint::capture_engine`], which sees the
    /// whole state (every built-in optimizer is an engine).
    pub fn capture(opt: &dyn DistOptimizer, step: u64) -> Self {
        let n = opt.n();
        let models = (0..n).map(|i| opt.worker_model(i).to_vec()).collect();
        let errors = if opt.local_error(0).is_some() {
            Some((0..n).map(|i| opt.local_error(i).unwrap().to_vec()).collect())
        } else {
            None
        };
        Checkpoint { step, models, errors, momentum: None, anchors: None }
    }

    /// Capture the complete engine state — models, errors, momentum,
    /// anchors, and the step counter — everything a bit-identical resume
    /// needs.
    pub fn capture_engine(e: &ErrorResetEngine) -> Self {
        let n = e.n();
        let grab = |f: &dyn Fn(usize) -> Option<Vec<f32>>| -> Option<Vec<Vec<f32>>> {
            f(0).is_some().then(|| (0..n).map(|i| f(i).unwrap()).collect())
        };
        Checkpoint {
            step: e.step_count(),
            models: (0..n).map(|i| e.worker_model(i).to_vec()).collect(),
            errors: grab(&|i| e.local_error(i).map(|v| v.to_vec())),
            momentum: grab(&|i| e.worker_momentum(i).map(|v| v.to_vec())),
            anchors: grab(&|i| e.worker_anchor(i).map(|v| v.to_vec())),
        }
    }

    /// Put a captured state back into a freshly-built engine (same plan,
    /// same n, same d — validated).  The restored engine continues
    /// bit-identically to the uninterrupted run.
    pub fn restore_engine(&self, e: &mut ErrorResetEngine) -> Result<(), String> {
        e.restore(
            self.step,
            &self.models,
            self.errors.as_deref(),
            self.momentum.as_deref(),
            self.anchors.as_deref(),
        )
    }

    /// Serialize to the checkpoint wire/file format (header + payloads +
    /// FNV-1a trailer).  This is also the **join blob**: rank 0 ships
    /// exactly these bytes in a rejoin grant, so an evicted rank resumes
    /// from the same state a file-based restart would see.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        let n = self.models.len() as u32;
        let d = self.models[0].len() as u64;
        buf.extend_from_slice(&n.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
        let write_mat = |buf: &mut Vec<u8>, mat: &[Vec<f32>]| {
            for row in mat {
                for v in row {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        };
        write_mat(&mut buf, &self.models);
        let mut flags = 0u32;
        for (bit, mat) in [
            (FLAG_ERRORS, &self.errors),
            (FLAG_MOMENTUM, &self.momentum),
            (FLAG_ANCHORS, &self.anchors),
        ] {
            if mat.is_some() {
                flags |= bit;
            }
        }
        buf.extend_from_slice(&flags.to_le_bytes());
        for mat in [&self.errors, &self.momentum, &self.anchors].into_iter().flatten() {
            write_mat(&mut buf, mat);
        }
        let sum = fnv1a(&buf, 0xcbf29ce484222325);
        buf.extend_from_slice(&sum.to_le_bytes());
        buf
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())
    }

    /// Parse the checkpoint format (see [`Checkpoint::to_bytes`]) from an
    /// untrusted byte slice — checksum first, then overflow-guarded
    /// dimensions.
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint, String> {
        if buf.len() < 8 + 4 + 8 + 4 + 8 + 4 + 8 {
            return Err("checkpoint truncated".into());
        }
        let (body, trailer) = buf.split_at(buf.len() - 8);
        let want = u64::from_le_bytes(trailer.try_into().unwrap());
        let got = fnv1a(body, 0xcbf29ce484222325);
        if want != got {
            return Err("checkpoint checksum mismatch".into());
        }
        let mut off = 0usize;
        let take = |off: &mut usize, k: usize| -> &[u8] {
            let s = &body[*off..*off + k];
            *off += k;
            s
        };
        if take(&mut off, 8) != MAGIC {
            return Err("bad magic".into());
        }
        let version = u32::from_le_bytes(take(&mut off, 4).try_into().unwrap());
        if version != VERSION {
            return Err(format!("unsupported checkpoint version {version}"));
        }
        let step = u64::from_le_bytes(take(&mut off, 8).try_into().unwrap());
        let n = u32::from_le_bytes(take(&mut off, 4).try_into().unwrap()) as usize;
        let d = u64::from_le_bytes(take(&mut off, 8).try_into().unwrap()) as usize;
        // Overflow-safe guards: a crafted header's n·d must stay on the Err
        // path, not wrap into an out-of-bounds slice (or a debug panic).
        let need = n
            .checked_mul(d)
            .and_then(|nd| nd.checked_mul(4))
            .ok_or("implausible checkpoint dimensions")?;
        if body.len().saturating_sub(off).saturating_sub(4) < need {
            return Err("checkpoint truncated (models)".into());
        }
        let read_mat = |off: &mut usize| -> Vec<Vec<f32>> {
            (0..n)
                .map(|_| {
                    let bytes = &body[*off..*off + d * 4];
                    *off += d * 4;
                    bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect()
                })
                .collect()
        };
        let models = read_mat(&mut off);
        let flags = u32::from_le_bytes(take(&mut off, 4).try_into().unwrap());
        if flags & !(FLAG_ERRORS | FLAG_MOMENTUM | FLAG_ANCHORS) != 0 {
            return Err(format!("unknown checkpoint section flags {flags:#x}"));
        }
        let mut read_section = |bit: u32, what: &str| -> Result<Option<Vec<Vec<f32>>>, String> {
            if flags & bit == 0 {
                return Ok(None);
            }
            if body.len().saturating_sub(off) < need {
                return Err(format!("checkpoint truncated ({what})"));
            }
            Ok(Some(read_mat(&mut off)))
        };
        let errors = read_section(FLAG_ERRORS, "errors")?;
        let momentum = read_section(FLAG_MOMENTUM, "momentum")?;
        let anchors = read_section(FLAG_ANCHORS, "anchors")?;
        Ok(Checkpoint { step, models, errors, momentum, anchors })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, String> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .and_then(|mut f| f.read_to_end(&mut buf))
            .map_err(|e| format!("reading checkpoint: {e}"))?;
        Checkpoint::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Compressor, Grbs, RandK, TopK};
    use crate::engine::CommPlan;
    use crate::optimizer::Cser;

    fn ckpt_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("cser_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_with_errors() {
        let init = vec![0.5f32; 24];
        let mut opt = Cser::cser_pl(&init, 3, 0.9, Box::new(Grbs::new(2.0, 4, 1)), 2);
        let grads = vec![vec![0.1f32; 24]; 3];
        for _ in 0..5 {
            opt.step(&grads, 0.1);
        }
        let ck = Checkpoint::capture(&opt, 5);
        let path = ckpt_dir().join("a.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.step, 5);
        assert_eq!(back.models.len(), 3);
        assert!(back.errors.is_some());
        assert!(back.momentum.is_none(), "the DistOptimizer surface cannot see momentum");
    }

    /// Deterministic per-worker gradient of a quadratic with a worker bias —
    /// a pure function of (worker, model), so two runs that agree on models
    /// agree on every subsequent gradient.
    fn grads_at(o: &dyn DistOptimizer, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|w| {
                o.worker_model(w)
                    .iter()
                    .enumerate()
                    .map(|(j, x)| x - 1.0 + 0.04 * ((w * 29 + 5 * j) % 13) as f32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn killed_and_resumed_engine_is_bit_identical() {
        // The distributed-run contract: capture mid-run (between resets, so
        // errors, momentum, and anchors are all live), save to disk, rebuild
        // a fresh engine, restore, continue — every worker's model and error
        // must equal the uninterrupted run bit for bit.  V1 checkpoints
        // dropped momentum/anchors and failed exactly this.
        type MkPlan = fn() -> CommPlan;
        let cases: [(&str, MkPlan); 3] = [
            ("cser-grbs", || {
                CommPlan::cser(Box::new(Grbs::new(2.0, 6, 7)), Box::new(Grbs::new(4.0, 6, 9)), 2)
            }),
            ("cser-perworker", || {
                CommPlan::cser(Box::new(RandK::new(4.0)), Box::new(TopK::new(4.0)), 2)
            }),
            ("qsparse", || CommPlan::qsparse(Box::new(Grbs::new(2.0, 6, 5)) as Box<dyn Compressor>, 3)),
        ];
        let (n, d) = (3, 24);
        let init: Vec<f32> = (0..d).map(|j| (j as f32 * 0.29).sin() * 0.3).collect();
        for (name, mk) in cases {
            let mut full = crate::engine::ErrorResetEngine::new(&init, n, 0.9, mk());
            for _ in 0..7 {
                let gs = grads_at(&full, n, d);
                full.step(&gs, 0.05);
            }
            let ck = Checkpoint::capture_engine(&full);
            assert_eq!(ck.step, 7, "{name}");
            assert!(ck.momentum.is_some(), "{name}: β > 0 must capture momentum");
            let path = ckpt_dir().join(format!("resume_{name}.ckpt"));
            ck.save(&path).unwrap();
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(back, ck, "{name}: disk roundtrip");

            let mut resumed = crate::engine::ErrorResetEngine::new(&init, n, 0.9, mk());
            back.restore_engine(&mut resumed).unwrap();
            assert_eq!(resumed.step_count(), 7, "{name}");
            for _ in 0..5 {
                let gs = grads_at(&full, n, d);
                full.step(&gs, 0.05);
                let gs = grads_at(&resumed, n, d);
                resumed.step(&gs, 0.05);
            }
            for i in 0..n {
                assert_eq!(
                    full.worker_model(i),
                    resumed.worker_model(i),
                    "{name}: worker {i} model diverged after resume"
                );
                assert_eq!(full.local_error(i), resumed.local_error(i), "{name}: error {i}");
            }
        }
    }

    #[test]
    fn restore_into_wrong_plan_is_rejected() {
        let init = vec![0.1f32; 16];
        let mk_cser =
            || CommPlan::cser(Box::new(Grbs::new(2.0, 4, 1)), Box::new(Grbs::new(2.0, 4, 2)), 2);
        let mut e = crate::engine::ErrorResetEngine::new(&init, 2, 0.9, mk_cser());
        let gs = grads_at(&e, 2, 16);
        e.step(&gs, 0.1);
        let ck = Checkpoint::capture_engine(&e);
        // β = 0 engine has no momentum buffers → section mismatch
        let mut other = crate::engine::ErrorResetEngine::new(&init, 2, 0.0, mk_cser());
        assert!(ck.restore_engine(&mut other).is_err());
        // different worker count
        let mut other = crate::engine::ErrorResetEngine::new(&init, 3, 0.9, mk_cser());
        assert!(ck.restore_engine(&mut other).is_err());
    }

    #[test]
    fn hostile_dimensions_error_instead_of_panicking() {
        // Checksum-valid files with absurd (n, d) headers must stay on the
        // Err path: both the n·d·4 product overflow and the offset+need
        // overflow the product check alone would miss.
        for (n, d) in [(u32::MAX as u64, u64::MAX), (1u64, (usize::MAX / 8) as u64)] {
            let mut buf = Vec::new();
            buf.extend_from_slice(MAGIC);
            buf.extend_from_slice(&VERSION.to_le_bytes());
            buf.extend_from_slice(&7u64.to_le_bytes());
            buf.extend_from_slice(&(n as u32).to_le_bytes());
            buf.extend_from_slice(&d.to_le_bytes());
            buf.extend_from_slice(&0u32.to_le_bytes());
            let sum = fnv1a(&buf, 0xcbf29ce484222325);
            buf.extend_from_slice(&sum.to_le_bytes());
            let path = ckpt_dir().join("hostile.ckpt");
            std::fs::write(&path, &buf).unwrap();
            assert!(Checkpoint::load(&path).is_err(), "n={n} d={d}");
        }
    }

    #[test]
    fn corruption_detected() {
        let ck = Checkpoint {
            step: 1,
            models: vec![vec![1.0, 2.0]],
            errors: None,
            momentum: None,
            anchors: None,
        };
        let path = ckpt_dir().join("b.ckpt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let ck = Checkpoint {
            step: 2,
            models: vec![vec![0.0; 64]; 2],
            errors: None,
            momentum: None,
            anchors: None,
        };
        let path = ckpt_dir().join("c.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
