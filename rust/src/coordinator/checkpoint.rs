//! Checkpointing: save/restore the flat training state.
//!
//! Long training runs (the paper's ImageNet runs take days) need restartable
//! state.  Because the whole optimizer state lives in flat f32 vectors, a
//! checkpoint is a tiny header + raw little-endian payloads:
//!
//! ```text
//! magic "CSERCKPT" | version u32 | step u64 | n u32 | d u64 |
//! n × d f32 (models) | flags u32 (bit0: has errors) | [n × d f32 errors]
//! ```
//!
//! Integrity is protected by a FNV-1a checksum trailer; truncated or
//! corrupted files fail loudly.

use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CSERCKPT";
const VERSION: u32 = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub models: Vec<Vec<f32>>,
    pub errors: Option<Vec<Vec<f32>>>,
}

fn fnv1a(data: &[u8], mut h: u64) -> u64 {
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    /// Capture from a running optimizer.
    pub fn capture(opt: &dyn crate::optimizer::DistOptimizer, step: u64) -> Self {
        let n = opt.n();
        let models = (0..n).map(|i| opt.worker_model(i).to_vec()).collect();
        let errors = if opt.local_error(0).is_some() {
            Some((0..n).map(|i| opt.local_error(i).unwrap().to_vec()).collect())
        } else {
            None
        };
        Checkpoint { step, models, errors }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        let n = self.models.len() as u32;
        let d = self.models[0].len() as u64;
        buf.extend_from_slice(&n.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
        for m in &self.models {
            for v in m {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let flags: u32 = self.errors.is_some() as u32;
        buf.extend_from_slice(&flags.to_le_bytes());
        if let Some(es) = &self.errors {
            for e in es {
                for v in e {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        let sum = fnv1a(&buf, 0xcbf29ce484222325);
        buf.extend_from_slice(&sum.to_le_bytes());
        let mut f = std::fs::File::create(path)?;
        f.write_all(&buf)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, String> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .and_then(|mut f| f.read_to_end(&mut buf))
            .map_err(|e| format!("reading checkpoint: {e}"))?;
        if buf.len() < 8 + 4 + 8 + 4 + 8 + 4 + 8 {
            return Err("checkpoint truncated".into());
        }
        let (body, trailer) = buf.split_at(buf.len() - 8);
        let want = u64::from_le_bytes(trailer.try_into().unwrap());
        let got = fnv1a(body, 0xcbf29ce484222325);
        if want != got {
            return Err("checkpoint checksum mismatch".into());
        }
        let mut off = 0usize;
        let take = |off: &mut usize, k: usize| -> &[u8] {
            let s = &body[*off..*off + k];
            *off += k;
            s
        };
        if take(&mut off, 8) != MAGIC {
            return Err("bad magic".into());
        }
        let version = u32::from_le_bytes(take(&mut off, 4).try_into().unwrap());
        if version != VERSION {
            return Err(format!("unsupported checkpoint version {version}"));
        }
        let step = u64::from_le_bytes(take(&mut off, 8).try_into().unwrap());
        let n = u32::from_le_bytes(take(&mut off, 4).try_into().unwrap()) as usize;
        let d = u64::from_le_bytes(take(&mut off, 8).try_into().unwrap()) as usize;
        let need = n * d * 4;
        if body.len() < off + need + 4 {
            return Err("checkpoint truncated (models)".into());
        }
        let read_mat = |off: &mut usize| -> Vec<Vec<f32>> {
            (0..n)
                .map(|_| {
                    let bytes = &body[*off..*off + d * 4];
                    *off += d * 4;
                    bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect()
                })
                .collect()
        };
        let models = read_mat(&mut off);
        let flags = u32::from_le_bytes(take(&mut off, 4).try_into().unwrap());
        let errors = if flags & 1 != 0 {
            if body.len() < off + need {
                return Err("checkpoint truncated (errors)".into());
            }
            Some(read_mat(&mut off))
        } else {
            None
        };
        Ok(Checkpoint { step, models, errors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::Grbs;
    use crate::optimizer::{Cser, DistOptimizer};

    #[test]
    fn roundtrip_with_errors() {
        let init = vec![0.5f32; 24];
        let mut opt = Cser::cser_pl(&init, 3, 0.9, Box::new(Grbs::new(2.0, 4, 1)), 2);
        let grads = vec![vec![0.1f32; 24]; 3];
        for _ in 0..5 {
            opt.step(&grads, 0.1);
        }
        let ck = Checkpoint::capture(&opt, 5);
        let dir = std::env::temp_dir().join("cser_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.step, 5);
        assert_eq!(back.models.len(), 3);
        assert!(back.errors.is_some());
    }

    #[test]
    fn corruption_detected() {
        let ck = Checkpoint { step: 1, models: vec![vec![1.0, 2.0]], errors: None };
        let dir = std::env::temp_dir().join("cser_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let ck = Checkpoint { step: 2, models: vec![vec![0.0; 64]; 2], errors: None };
        let dir = std::env::temp_dir().join("cser_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
