//! Run records: per-epoch curves + summary, with JSON/CSV emission.

use crate::obs::{Phase, PhaseStats, RingSnapshot};
use crate::util::json::JsonWriter;
use std::io::Write as _;
use std::path::Path;

/// One evaluation point (end of epoch).
#[derive(Debug, Clone, Copy)]
pub struct EpochPoint {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_acc: f64,
    /// Cumulative per-worker upload bits at *paper scale* (see
    /// `sim_trainer::Timeline`); the x-axis of Figures 5/9.
    pub cum_bits: f64,
    /// Cumulative simulated wall-clock seconds; the x-axis of Figures 4/8.
    pub cum_seconds: f64,
    /// *Measured* wall-clock milliseconds since the run started (as opposed
    /// to `cum_seconds`, which is the paper-scale simulated timeline).
    /// Additive field: records written before it existed read back as 0.
    pub wall_ms: u64,
}

/// Wall-clock summary of one traced phase (see [`crate::obs::Phase`]),
/// folded from this rank's ring buffers at the end of a traced run.
/// Empty unless the run was launched with `--trace`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseSummary {
    pub phase: String,
    pub count: u64,
    pub total_ns: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
}

/// Fold ring snapshots from every thread of this rank into one
/// [`PhaseSummary`] per phase that recorded at least one span.
pub fn phase_summaries(snaps: &[RingSnapshot]) -> Vec<PhaseSummary> {
    let mut merged: [PhaseStats; Phase::COUNT] = std::array::from_fn(|_| PhaseStats::default());
    for s in snaps {
        let folded = crate::obs::stats::fold(&s.events);
        for (m, f) in merged.iter_mut().zip(folded.iter()) {
            m.merge(f);
        }
    }
    Phase::ALL
        .iter()
        .zip(merged.iter())
        .filter(|(_, st)| st.count > 0)
        .map(|(p, st)| PhaseSummary {
            phase: p.name().to_string(),
            count: st.count,
            total_ns: st.total_ns,
            p50_ns: st.p50(),
            p99_ns: st.p99(),
        })
        .collect()
}

/// One membership transition as this rank observed it: which epoch took
/// force, at which step, and which ranks left / arrived.  A rejoining
/// rank records its own admission with `evicted == 0`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpochEvent {
    /// Epoch id that took force at this boundary.
    pub epoch: u64,
    /// Step (sync round) at which the boundary fired.
    pub step: u64,
    /// Bitmask of ranks evicted at this boundary.
    pub evicted: u64,
    /// Bitmask of ranks admitted at this boundary.
    pub joined: u64,
}

/// Elastic-membership summary of one rank's run (`Backend::Tcp` with
/// `TrainCfg::elastic`; DESIGN.md §8).  `None` on fixed-fleet runs.
///
/// The wire counters are this rank's ground truth for the exact bit
/// accounting under partial rounds: payload bits actually written to /
/// read from its sockets (the 17-byte frame headers excluded), so on a
/// parameter-server plan `payload_bits_received` at rank 0 equals the sum
/// of `payload_bits_sent` over every rank whose frames arrived — censored
/// rounds and dead peers contribute exactly nothing.  `links` refines the
/// totals per peer (ring-segment ground truth: on a ring plan, entry `p`
/// balances against peer `p`'s entry for this rank), and `events` records
/// each membership transition so joins/evictions are attributable per
/// epoch; both are additive and stay empty on runs that predate them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ElasticSummary {
    /// Membership epoch id in force when the run ended.
    pub final_epoch: u64,
    /// Effective live set at the end (bit `r` ⇔ rank `r` live, pending
    /// deaths already removed).
    pub live_mask: u64,
    /// Rounds this rank censored a peer (deaths + deadline misses).
    pub censor_events: u64,
    /// Evictions across the boundaries this rank observed.
    pub evictions: u64,
    /// Admissions across the boundaries this rank observed (a rejoining
    /// rank counts its own admission).
    pub joins: u64,
    pub payload_bits_sent: u64,
    pub payload_bits_received: u64,
    /// Every membership transition this rank observed, in order.
    pub events: Vec<EpochEvent>,
    /// Every leader handover this rank observed, in order (DESIGN.md
    /// §10).  Additive: empty on non-failover runs and on records that
    /// predate the field.
    pub leader_changes: Vec<crate::membership::LeaderChange>,
    /// Per-peer wire counters (index = physical rank; this rank's own
    /// slot stays zero).  Sums over the slots reproduce the totals above.
    pub links: Vec<crate::obs::PeerCounters>,
}

/// A full training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub name: String,
    pub optimizer: String,
    pub overall_rc: f64,
    pub lr: f64,
    pub seed: u64,
    pub points: Vec<EpochPoint>,
    pub diverged: bool,
    /// Per-phase timing summary; populated only on traced runs.
    pub phases: Vec<PhaseSummary>,
    /// Membership + wire accounting; populated only on elastic TCP runs.
    pub elastic: Option<ElasticSummary>,
}

impl RunRecord {
    pub fn final_acc(&self) -> f64 {
        if self.diverged {
            f64::NAN
        } else {
            self.points.last().map(|p| p.test_acc).unwrap_or(f64::NAN)
        }
    }

    pub fn best_acc(&self) -> f64 {
        self.points.iter().map(|p| p.test_acc).fold(f64::NAN, f64::max)
    }

    pub fn final_train_loss(&self) -> f64 {
        if self.diverged {
            f64::INFINITY
        } else {
            self.points.last().map(|p| p.train_loss).unwrap_or(f64::INFINITY)
        }
    }

    /// First simulated time at which test accuracy reached `target`
    /// (time-to-accuracy; the headline speedup metric).
    pub fn time_to_acc(&self, target: f64) -> Option<f64> {
        self.points.iter().find(|p| p.test_acc >= target).map(|p| p.cum_seconds)
    }

    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("name").str(&self.name);
        w.key("optimizer").str(&self.optimizer);
        w.key("overall_rc").num(self.overall_rc);
        w.key("lr").num(self.lr);
        w.key("seed").int(self.seed as i64);
        w.key("diverged").bool(self.diverged);
        w.key("final_acc").num(self.final_acc());
        // Additive field: consumers that predate tracing ignore it.
        w.key("phases").begin_arr();
        for p in &self.phases {
            w.begin_obj();
            w.key("phase").str(&p.phase);
            w.key("count").int(p.count as i64);
            w.key("total_ns").int(p.total_ns as i64);
            w.key("p50_ns").int(p.p50_ns as i64);
            w.key("p99_ns").int(p.p99_ns as i64);
            w.end_obj();
        }
        w.end_arr();
        // Additive object: present only on elastic runs.
        if let Some(e) = &self.elastic {
            w.key("elastic").begin_obj();
            w.key("final_epoch").int(e.final_epoch as i64);
            w.key("live_mask").int(e.live_mask as i64);
            w.key("censor_events").int(e.censor_events as i64);
            w.key("evictions").int(e.evictions as i64);
            w.key("joins").int(e.joins as i64);
            w.key("payload_bits_sent").int(e.payload_bits_sent as i64);
            w.key("payload_bits_received").int(e.payload_bits_received as i64);
            // Additive keys: per-epoch transitions and per-link counters.
            w.key("events").begin_arr();
            for ev in &e.events {
                w.begin_obj();
                w.key("epoch").int(ev.epoch as i64);
                w.key("step").int(ev.step as i64);
                w.key("evicted").int(ev.evicted as i64);
                w.key("joined").int(ev.joined as i64);
                w.end_obj();
            }
            w.end_arr();
            // Additive key: leader handovers under --failover.
            w.key("leader_changes").begin_arr();
            for lc in &e.leader_changes {
                w.begin_obj();
                w.key("step").int(lc.step as i64);
                w.key("from").int(lc.from as i64);
                w.key("to").int(lc.to as i64);
                w.key("generation").int(lc.generation as i64);
                w.end_obj();
            }
            w.end_arr();
            for (key, f) in [
                ("link_bits_sent", (|c: &crate::obs::PeerCounters| c.payload_bits_sent as f64)
                    as fn(&crate::obs::PeerCounters) -> f64),
                ("link_bits_received", |c| c.payload_bits_received as f64),
                ("link_stale_discards", |c| c.stale_discards as f64),
            ] {
                w.key(key).nums(&e.links.iter().map(f).collect::<Vec<_>>());
            }
            w.end_obj();
        }
        for (key, f) in [
            ("epoch", (|p: &EpochPoint| p.epoch as f64) as fn(&EpochPoint) -> f64),
            ("train_loss", |p| p.train_loss),
            ("test_acc", |p| p.test_acc),
            ("cum_bits", |p| p.cum_bits),
            ("cum_seconds", |p| p.cum_seconds),
            ("wall_ms", |p| p.wall_ms as f64),
        ] {
            w.key(key).nums(&self.points.iter().map(f).collect::<Vec<_>>());
        }
        w.end_obj();
        w.finish()
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("epoch,train_loss,test_acc,cum_bits,cum_seconds,wall_ms\n");
        for p in &self.points {
            s.push_str(&format!(
                "{},{},{},{},{},{}\n",
                p.epoch, p.train_loss, p.test_acc, p.cum_bits, p.cum_seconds, p.wall_ms
            ));
        }
        s
    }
}

/// Write a collection of runs as a JSON array into `results/<name>.json`.
pub fn write_results(dir: &str, name: &str, runs: &[RunRecord]) -> std::io::Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = Path::new(dir).join(format!("{name}.json"));
    let mut f = std::fs::File::create(&path)?;
    f.write_all(b"[")?;
    for (i, r) in runs.iter().enumerate() {
        if i > 0 {
            f.write_all(b",\n")?;
        }
        f.write_all(r.to_json().as_bytes())?;
    }
    f.write_all(b"]\n")?;
    Ok(path.to_string_lossy().into_owned())
}

/// mean ± std over a slice (ignoring NaN entries; returns NaN if all NaN).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let clean: Vec<f64> = xs.iter().cloned().filter(|x| x.is_finite()).collect();
    if clean.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let m = clean.iter().sum::<f64>() / clean.len() as f64;
    let v = clean.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / clean.len() as f64;
    (m, v.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn record() -> RunRecord {
        RunRecord {
            name: "t".into(),
            optimizer: "cser".into(),
            overall_rc: 32.0,
            lr: 0.1,
            seed: 1,
            diverged: false,
            phases: Vec::new(),
            elastic: None,
            points: (0..3)
                .map(|e| EpochPoint {
                    epoch: e,
                    train_loss: 2.0 - e as f64 * 0.5,
                    test_acc: 0.3 * (e + 1) as f64,
                    cum_bits: 1e6 * (e + 1) as f64,
                    cum_seconds: 10.0 * (e + 1) as f64,
                    wall_ms: 100 * (e as u64 + 1),
                })
                .collect(),
        }
    }

    #[test]
    fn json_roundtrips() {
        let r = record();
        let j = Json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("optimizer").unwrap().as_str(), Some("cser"));
        assert_eq!(j.get("test_acc").unwrap().as_arr().unwrap().len(), 3);
        assert!((j.get("final_acc").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-9);
        let wall = j.get("wall_ms").unwrap().as_arr().unwrap();
        assert_eq!(wall.len(), 3);
        assert_eq!(wall[2].as_f64(), Some(300.0));
    }

    #[test]
    fn phases_array_roundtrips() {
        let mut r = record();
        r.phases.push(PhaseSummary {
            phase: "exchange".into(),
            count: 4,
            total_ns: 400,
            p50_ns: 100,
            p99_ns: 130,
        });
        let j = Json::parse(&r.to_json()).unwrap();
        let arr = j.get("phases").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("phase").unwrap().as_str(), Some("exchange"));
        assert_eq!(arr[0].get("count").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn elastic_object_roundtrips_and_is_absent_by_default() {
        let r = record();
        let j = Json::parse(&r.to_json()).unwrap();
        assert!(j.get("elastic").is_none(), "fixed-fleet records carry no elastic object");
        let mut r = record();
        let mut links = vec![crate::obs::PeerCounters::default(); 3];
        links[1].payload_bits_sent = 4096;
        links[1].payload_bits_received = 12288;
        links[2].stale_discards = 2;
        r.elastic = Some(ElasticSummary {
            final_epoch: 2,
            live_mask: 0b0111,
            censor_events: 5,
            evictions: 1,
            joins: 1,
            payload_bits_sent: 4096,
            payload_bits_received: 12288,
            events: vec![
                EpochEvent { epoch: 1, step: 16, evicted: 0b1000, joined: 0 },
                EpochEvent { epoch: 2, step: 32, evicted: 0, joined: 0b0100 },
            ],
            leader_changes: vec![crate::membership::LeaderChange {
                step: 16,
                from: 0,
                to: 1,
                generation: 1,
            }],
            links,
        });
        let j = Json::parse(&r.to_json()).unwrap();
        let e = j.get("elastic").unwrap();
        assert_eq!(e.get("final_epoch").unwrap().as_usize(), Some(2));
        assert_eq!(e.get("live_mask").unwrap().as_usize(), Some(0b0111));
        assert_eq!(e.get("censor_events").unwrap().as_usize(), Some(5));
        assert_eq!(e.get("evictions").unwrap().as_usize(), Some(1));
        assert_eq!(e.get("joins").unwrap().as_usize(), Some(1));
        assert_eq!(e.get("payload_bits_sent").unwrap().as_usize(), Some(4096));
        assert_eq!(e.get("payload_bits_received").unwrap().as_usize(), Some(12288));
        let evs = e.get("events").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("epoch").unwrap().as_usize(), Some(1));
        assert_eq!(evs[0].get("evicted").unwrap().as_usize(), Some(0b1000));
        assert_eq!(evs[1].get("step").unwrap().as_usize(), Some(32));
        assert_eq!(evs[1].get("joined").unwrap().as_usize(), Some(0b0100));
        let lcs = e.get("leader_changes").unwrap().as_arr().unwrap();
        assert_eq!(lcs.len(), 1);
        assert_eq!(lcs[0].get("step").unwrap().as_usize(), Some(16));
        assert_eq!(lcs[0].get("from").unwrap().as_usize(), Some(0));
        assert_eq!(lcs[0].get("to").unwrap().as_usize(), Some(1));
        assert_eq!(lcs[0].get("generation").unwrap().as_usize(), Some(1));
        let sent = e.get("link_bits_sent").unwrap().as_arr().unwrap();
        assert_eq!(sent.len(), 3);
        assert_eq!(sent[1].as_f64(), Some(4096.0));
        let recv = e.get("link_bits_received").unwrap().as_arr().unwrap();
        assert_eq!(recv[1].as_f64(), Some(12288.0));
        let stale = e.get("link_stale_discards").unwrap().as_arr().unwrap();
        assert_eq!(stale[2].as_f64(), Some(2.0));
    }

    #[test]
    fn time_to_acc_finds_first_crossing() {
        let r = record();
        assert_eq!(r.time_to_acc(0.5), Some(20.0));
        assert_eq!(r.time_to_acc(0.95), None);
    }

    #[test]
    fn mean_std_ignores_nan() {
        let (m, s) = mean_std(&[1.0, f64::NAN, 3.0]);
        assert_eq!(m, 2.0);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = record().to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("epoch,"));
    }
}
