//! SVG line-chart writer: turns run records into actual figures.
//!
//! The paper's artifacts are *figures*; `cser plot` regenerates them as SVG
//! from the results/*.json run records (no plotting library offline).  One
//! chart = one (x-metric, y-metric) pair over a set of runs, with axes,
//! ticks, a legend, and log-x support for the bits axis.

use super::metrics::{EpochPoint, RunRecord};
use std::fmt::Write as _;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    Epoch,
    Seconds,
    Bits,
    Wall,
    TestAcc,
    TrainLoss,
}

impl Axis {
    pub fn value(&self, p: &EpochPoint) -> f64 {
        match self {
            Axis::Epoch => p.epoch as f64,
            Axis::Seconds => p.cum_seconds,
            Axis::Bits => p.cum_bits,
            Axis::Wall => p.wall_ms as f64 / 1000.0,
            Axis::TestAcc => p.test_acc * 100.0,
            Axis::TrainLoss => p.train_loss,
        }
    }
    pub fn label(&self) -> &'static str {
        match self {
            Axis::Epoch => "epoch",
            Axis::Seconds => "simulated training time (s)",
            Axis::Bits => "communicated bits (per worker)",
            Axis::Wall => "wall-clock time (s)",
            Axis::TestAcc => "test accuracy (%)",
            Axis::TrainLoss => "training loss",
        }
    }
    pub fn log_scale(&self) -> bool {
        matches!(self, Axis::Bits)
    }
    pub fn parse(s: &str) -> Option<Axis> {
        Some(match s {
            "epoch" => Axis::Epoch,
            "seconds" | "time" => Axis::Seconds,
            "bits" | "comm" => Axis::Bits,
            "wall" | "wall_ms" => Axis::Wall,
            "acc" | "test_acc" => Axis::TestAcc,
            "loss" | "train_loss" => Axis::TrainLoss,
            _ => return None,
        })
    }
}

const PALETTE: [&str; 8] =
    ["#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b", "#17becf", "#7f7f7f"];
const W: f64 = 640.0;
const H: f64 = 420.0;
const ML: f64 = 64.0; // margins
const MR: f64 = 16.0;
const MT: f64 = 34.0;
const MB: f64 = 48.0;

fn nice_ticks(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    if !(hi > lo) {
        return vec![lo];
    }
    let span = hi - lo;
    let raw = span / n as f64;
    let mag = 10f64.powf(raw.log10().floor());
    let step = [1.0, 2.0, 5.0, 10.0]
        .iter()
        .map(|m| m * mag)
        .find(|s| span / s <= n as f64)
        .unwrap_or(mag * 10.0);
    let start = (lo / step).ceil() * step;
    let mut t = vec![];
    let mut v = start;
    while v <= hi + 1e-9 * span {
        t.push(v);
        v += step;
    }
    t
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1e9 {
        format!("{:.0}G", v / 1e9)
    } else if v.abs() >= 1e6 {
        format!("{:.0}M", v / 1e6)
    } else if v.abs() >= 1e4 {
        format!("{:.0}k", v / 1e3)
    } else if v.abs() < 0.01 {
        format!("{v:.0e}")
    } else {
        let s = format!("{v:.2}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

/// Render one SVG chart of `runs` with the given axes.
pub fn svg_chart(title: &str, runs: &[RunRecord], x: Axis, y: Axis) -> String {
    let xt = |v: f64| if x.log_scale() { v.max(1.0).log10() } else { v };
    // data ranges
    let (mut xlo, mut xhi) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ylo, mut yhi) = (f64::INFINITY, f64::NEG_INFINITY);
    for r in runs {
        for p in &r.points {
            let (xv, yv) = (xt(x.value(p)), y.value(p));
            if xv.is_finite() && yv.is_finite() {
                xlo = xlo.min(xv);
                xhi = xhi.max(xv);
                ylo = ylo.min(yv);
                yhi = yhi.max(yv);
            }
        }
    }
    if !xlo.is_finite() {
        xlo = 0.0;
        xhi = 1.0;
        ylo = 0.0;
        yhi = 1.0;
    }
    if yhi - ylo < 1e-12 {
        yhi = ylo + 1.0;
    }
    if xhi - xlo < 1e-12 {
        xhi = xlo + 1.0;
    }
    let px = |v: f64| ML + (xt(v) - xlo) / (xhi - xlo) * (W - ML - MR);
    let py = |v: f64| H - MB - (v - ylo) / (yhi - ylo) * (H - MT - MB);

    let mut s = String::new();
    let _ = write!(
        s,
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}" font-family="sans-serif" font-size="11">"##
    );
    let _ = write!(s, r##"<rect width="{W}" height="{H}" fill="white"/>"##);
    let _ = write!(
        s,
        r##"<text x="{}" y="18" text-anchor="middle" font-size="14">{}</text>"##,
        W / 2.0,
        title
    );
    // axes box
    let _ = write!(
        s,
        r##"<rect x="{ML}" y="{MT}" width="{}" height="{}" fill="none" stroke="#999"/>"##,
        W - ML - MR,
        H - MT - MB
    );
    // y ticks + gridlines
    for t in nice_ticks(ylo, yhi, 6) {
        let yy = py(t);
        let _ = write!(
            s,
            r##"<line x1="{ML}" y1="{yy:.1}" x2="{:.1}" y2="{yy:.1}" stroke="#eee"/>"##,
            W - MR
        );
        let _ = write!(
            s,
            r##"<text x="{:.1}" y="{:.1}" text-anchor="end">{}</text>"##,
            ML - 6.0,
            yy + 4.0,
            fmt_tick(t)
        );
    }
    // x ticks (log: powers of 10)
    let xticks: Vec<f64> = if x.log_scale() {
        let lo = xlo.floor() as i32;
        let hi = xhi.ceil() as i32;
        (lo..=hi).map(|e| 10f64.powi(e)).collect()
    } else {
        nice_ticks(xlo, xhi, 7)
    };
    for t in xticks {
        let xv = if x.log_scale() { t } else { t };
        let xx = px(xv);
        if xx < ML - 0.5 || xx > W - MR + 0.5 {
            continue;
        }
        let _ = write!(
            s,
            r##"<line x1="{xx:.1}" y1="{MT}" x2="{xx:.1}" y2="{:.1}" stroke="#eee"/>"##,
            H - MB
        );
        let _ = write!(
            s,
            r##"<text x="{xx:.1}" y="{:.1}" text-anchor="middle">{}</text>"##,
            H - MB + 16.0,
            fmt_tick(t)
        );
    }
    // axis labels
    let _ = write!(
        s,
        r##"<text x="{}" y="{}" text-anchor="middle">{}</text>"##,
        W / 2.0,
        H - 10.0,
        x.label()
    );
    let _ = write!(
        s,
        r##"<text x="14" y="{}" text-anchor="middle" transform="rotate(-90 14 {})">{}</text>"##,
        H / 2.0,
        H / 2.0,
        y.label()
    );
    // series
    for (i, r) in runs.iter().enumerate() {
        let color = PALETTE[i % PALETTE.len()];
        let mut path = String::new();
        let mut first = true;
        for p in &r.points {
            let (xv, yv) = (x.value(p), y.value(p));
            if !xv.is_finite() || !yv.is_finite() {
                continue;
            }
            let _ = write!(path, "{}{:.1},{:.1} ", if first { "M" } else { "L" }, px(xv), py(yv));
            first = false;
        }
        let _ = write!(
            s,
            r##"<path d="{}" fill="none" stroke="{color}" stroke-width="1.8"/>"##,
            path.trim_end()
        );
        // legend
        let ly = MT + 14.0 + i as f64 * 15.0;
        let _ = write!(
            s,
            r##"<line x1="{:.1}" y1="{ly:.1}" x2="{:.1}" y2="{ly:.1}" stroke="{color}" stroke-width="2"/>"##,
            ML + 8.0,
            ML + 28.0
        );
        let label = if r.diverged {
            format!("{} (diverged)", r.optimizer)
        } else {
            r.optimizer.clone()
        };
        let _ = write!(
            s,
            r##"<text x="{:.1}" y="{:.1}">{}</text>"##,
            ML + 33.0,
            ly + 4.0,
            label
        );
    }
    s.push_str("</svg>");
    s
}

/// Parse run records back from a results/*.json file (written by
/// `metrics::write_results`).
pub fn load_records(path: &str) -> Result<Vec<RunRecord>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let j = crate::util::json::Json::parse(&text)?;
    let arr = j.as_arr().ok_or("expected a JSON array of runs")?;
    arr.iter()
        .map(|r| {
            let f = |k: &str| -> Result<Vec<f64>, String> {
                Ok(r.get(k)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| format!("missing {k}"))?
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(f64::NAN))
                    .collect())
            };
            let (ep, tl, ta, cb, cs) = (
                f("epoch")?,
                f("train_loss")?,
                f("test_acc")?,
                f("cum_bits")?,
                f("cum_seconds")?,
            );
            // Additive field: records written before wall_ms existed load as 0.
            let wall = f("wall_ms").unwrap_or_else(|_| vec![0.0; ep.len()]);
            let points = (0..ep.len())
                .map(|i| EpochPoint {
                    epoch: ep[i] as usize,
                    train_loss: tl[i],
                    test_acc: ta[i],
                    cum_bits: cb[i],
                    cum_seconds: cs[i],
                    wall_ms: wall.get(i).copied().unwrap_or(0.0) as u64,
                })
                .collect();
            Ok(RunRecord {
                name: r.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                optimizer: r
                    .get("optimizer")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string(),
                overall_rc: r.get("overall_rc").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                lr: r.get("lr").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                seed: r.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                diverged: r.get("diverged").and_then(|v| v.as_bool()).unwrap_or(false),
                points,
                phases: Vec::new(),
                elastic: None,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(name: &str) -> RunRecord {
        RunRecord {
            name: name.into(),
            optimizer: name.into(),
            overall_rc: 32.0,
            lr: 0.1,
            seed: 1,
            diverged: false,
            phases: Vec::new(),
            elastic: None,
            points: (1..=10)
                .map(|e| EpochPoint {
                    epoch: e,
                    train_loss: 2.0 / e as f64,
                    test_acc: 0.08 * e as f64,
                    cum_bits: 1e7 * e as f64,
                    cum_seconds: 3.0 * e as f64,
                    wall_ms: 250 * e as u64,
                })
                .collect(),
        }
    }

    #[test]
    fn svg_is_well_formed_and_has_series() {
        let runs = vec![fake("SGD"), fake("CSER")];
        let svg = svg_chart("acc vs epoch", &runs, Axis::Epoch, Axis::TestAcc);
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<path").count(), 2);
        assert!(svg.contains("CSER"));
        assert!(svg.contains("epoch"));
    }

    #[test]
    fn log_bits_axis() {
        let runs = vec![fake("CSER")];
        let svg = svg_chart("acc vs comm", &runs, Axis::Bits, Axis::TestAcc);
        assert!(svg.contains("communicated bits"));
        // power-of-ten tick labels like 10M/100M present
        assert!(svg.contains('M') || svg.contains('G'));
    }

    #[test]
    fn roundtrip_via_results_file() {
        let runs = vec![fake("SGD")];
        let dir = std::env::temp_dir().join("cser_plot_test");
        let p = crate::coordinator::metrics::write_results(
            dir.to_str().unwrap(),
            "plot_roundtrip",
            &runs,
        )
        .unwrap();
        let loaded = load_records(&p).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].optimizer, "SGD");
        assert_eq!(loaded[0].points.len(), 10);
        assert!((loaded[0].points[4].test_acc - 0.4).abs() < 1e-9);
        assert_eq!(loaded[0].points[4].wall_ms, 1250);
    }

    #[test]
    fn legacy_records_without_wall_ms_load_as_zero() {
        let json = concat!(
            r#"[{"name":"t","optimizer":"SGD","overall_rc":1.0,"lr":0.1,"seed":1,"#,
            r#""diverged":false,"phases":[],"epoch":[0,1],"train_loss":[1.0,0.5],"#,
            r#""test_acc":[0.1,0.2],"cum_bits":[8.0,16.0],"cum_seconds":[1.0,2.0]}]"#
        );
        let dir = std::env::temp_dir().join("cser_plot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("legacy.json");
        std::fs::write(&p, json).unwrap();
        let loaded = load_records(p.to_str().unwrap()).unwrap();
        assert_eq!(loaded[0].points.len(), 2);
        assert!(loaded[0].points.iter().all(|pt| pt.wall_ms == 0));
    }

    #[test]
    fn wall_axis_parses_and_scales_to_seconds() {
        assert_eq!(Axis::parse("wall"), Some(Axis::Wall));
        let p = fake("CSER").points[3];
        assert!((Axis::Wall.value(&p) - 1.0).abs() < 1e-9);
        let svg = svg_chart("acc vs wall", &[fake("CSER")], Axis::Wall, Axis::TestAcc);
        assert!(svg.contains("wall-clock time (s)"));
    }

    #[test]
    fn nice_ticks_cover_range() {
        let t = nice_ticks(0.0, 87.3, 6);
        assert!(t.len() >= 3 && t.len() <= 8);
        assert!(t[0] >= 0.0 && *t.last().unwrap() <= 87.3 + 1e-9);
    }
}
