//! Fast-path distributed training loop over the pure-Rust model zoo.
//!
//! One iteration = every worker samples a minibatch from its own shard,
//! computes a gradient at its *local* model (the optimizers maintain
//! bifurcated models — worker i's gradient must be evaluated at x_{i,t-1},
//! paper Algorithm 2 line 5), then one synchronous optimizer step.
//!
//! Timeline semantics (DESIGN.md §3): bits/time are accounted at *paper
//! scale* — the optimizer reports its upload bits for our model's dimension
//! d; we convert to the paper's model size via the per-step compressed
//! fraction, then price the round with the alpha-beta cost model.  The
//! resulting curves are the substitutes for Figures 4/5/8/9.

use super::checkpoint::Checkpoint;
use super::metrics::{phase_summaries, ElasticSummary, EpochPoint, PhaseSummary, RunRecord};
use crate::data::{ClassDataset, Shard};
use crate::engine::ErrorResetEngine;
use crate::membership::{Elastic, Epoch};
use crate::models::{GradModel, ModelScratch};
use crate::network::CostModel;
use crate::obs;
use crate::optimizer::{DistOptimizer, RoundStats};
use crate::transport::peer::{PeerTransport, Tag};
use crate::transport::{peer, rendezvous, Backend, TcpTransport};
use crate::util::pool::scope_zip;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub epochs: usize,
    pub batch_per_worker: usize,
    pub seed: u64,
    /// Base learning rate; multiplied by `lr_multiplier(progress)`.
    pub lr: f64,
    pub lr_multiplier: fn(&crate::config::LrSchedule, f64) -> f64,
    pub schedule: crate::config::LrSchedule,
    /// Paper-scale parameter count for bit/time accounting.
    pub paper_d: usize,
    pub cost: CostModel,
    /// Gradient-computation threads (<= workers).
    pub threads: usize,
    /// Stop early and mark diverged when train loss exceeds
    /// `divergence_factor * initial_loss` or becomes non-finite.
    pub divergence_factor: f64,
    /// Communication backend: the default in-process path or
    /// `Backend::Threaded` for the parallel-trainer mode (central step loop
    /// over the persistent serialized-message pool — `train_classifier`
    /// installs it on the optimizer, replacing any collective set earlier
    /// via `DistOptimizer::set_collective`); `Backend::Resident` for the
    /// worker-resident mode (engine optimizers only: persistent worker
    /// threads own their `WorkerState` and run gradient → compress → sync →
    /// apply end to end over peer-owned mesh collectives — no central
    /// gradients array, no per-step barrier, no installed `Collective`); or
    /// `Backend::Tcp` for real multi-process training (this process is one
    /// rank of a socket fleet; see `train_classifier_tcp`).
    pub backend: Backend,
    /// Checkpoint path for distributed runs: saved after every epoch,
    /// restored (and the run resumed) when the file already exists at
    /// startup.  Per-rank — every rank of a job needs its own file, and the
    /// whole fleet must restart together (validated at startup).  Restores
    /// the exact optimizer state; shard sampling and the run record restart
    /// (see `train_classifier_tcp`).
    pub ckpt: Option<std::path::PathBuf>,
    /// Gradient-bucket count for the synchronization pipeline (0 or 1 =
    /// whole-vector sync, the historical path).  With K > 1 and an
    /// engine-backed optimizer, every collective runs per bucket — bucket
    /// bounds come from the model's `param_layout()` (layer-aware), and the
    /// resident/TCP modes overlap each bucket's compression with the
    /// previous bucket's exchange (`engine::SyncPipeline`).
    pub buckets: usize,
    /// When set, phase tracing is enabled for the run and this rank's
    /// events are written to `<dir>/trace-rank<R>.jsonl` at the end
    /// (`obs::export`); the record's `phases` summary is populated.
    pub trace: Option<std::path::PathBuf>,
    /// Elastic membership for `Backend::Tcp` (DESIGN.md §8): wrap the
    /// transport in [`crate::membership::Elastic`], censor dead or
    /// deadline-missing peers for the round instead of erroring, and
    /// negotiate evictions/admissions at epoch boundaries through the
    /// standing rendezvous session.  Implied by `chaos` and `join`.
    pub elastic: bool,
    /// Per-gather deadline for elastic runs, in milliseconds: a live rank
    /// that misses it is censored for the round (not evicted — only
    /// observed deaths evict).
    pub round_deadline_ms: u64,
    /// Fault injection for elastic TCP runs (`cser launch --chaos`);
    /// loopback rendezvous only, enforced by the worker entry point.
    pub chaos: Option<ChaosSpec>,
    /// This rank was evicted (or started late) and is rejoining a running
    /// job: dial the rendezvous with a `CSER-JN2` join request, restore
    /// the granted checkpoint blob bit-exactly, and enter the epoch loop
    /// at the granted step.
    pub join: bool,
    /// Live telemetry for elastic TCP runs (`cser launch --metrics-addr`,
    /// DESIGN.md §9): every rank records into the `obs::metrics` registry
    /// and ships a delta snapshot to rank 0 at each epoch boundary
    /// (`Tag::Metrics`); rank 0 merges the fleet view and serves it at
    /// this address (Prometheus text at `/metrics`, `cser-metrics/v1`
    /// JSON elsewhere — what `cser top` polls).  Implies `elastic`.
    pub metrics_addr: Option<String>,
    /// Adaptive censoring (`--adaptive-tau <base>`): at every epoch
    /// boundary, re-derive the censoring threshold from the measured
    /// backpressure instead of the launch-time constant — rank 0 from the
    /// aggregated fleet view (`membership::censor_seed_from_fleet`), the
    /// others from their own mirrored counters
    /// (`membership::censor_seed_from_metrics`) — and install it via
    /// `ErrorResetEngine::set_cadence(Cadence::Censored { tau0, gamma: 1 })`.
    /// The censoring decision is per-worker-local, so per-rank thresholds
    /// are protocol-safe (rank 0 accounts whatever frames arrive).
    /// Requires a censorable plan (parameter-server-routed C2); implies
    /// `elastic`.  `None` keeps the configured cadence untouched.
    pub adaptive_tau: Option<f32>,
    /// Control-plane failover (`--failover`, DESIGN.md §10): replicate the
    /// leader's control state to its deterministic successor (the lowest
    /// live non-zero rank) each boundary, fence stale frames with leader
    /// generations, and on the leader's death let the successor assume all
    /// four leader roles — rendezvous listener, epoch broadcaster, PS
    /// aggregation, and the fleet metrics merge.  Unlocks rank-0 chaos
    /// (`kill:0@s`, `drop:0:p`, `flap:0@s:ms`).  Implies `elastic`.
    pub failover: bool,
}

impl TrainCfg {
    pub fn new(epochs: usize, batch_per_worker: usize, lr: f64, seed: u64) -> Self {
        TrainCfg {
            epochs,
            batch_per_worker,
            seed,
            lr,
            lr_multiplier: |s, f| s.multiplier(f),
            schedule: crate::config::LrSchedule::StepDecay { milestones: vec![], factor: 1.0 },
            paper_d: 1,
            cost: CostModel::default(),
            threads: crate::util::pool::default_threads(),
            divergence_factor: 5.0,
            backend: Backend::default(),
            ckpt: None,
            buckets: 0,
            trace: None,
            elastic: false,
            round_deadline_ms: 1000,
            chaos: None,
            join: false,
            metrics_addr: None,
            adaptive_tau: None,
            failover: false,
        }
    }
}

/// Fault matrix for elastic TCP runs, parsed from a comma-joined
/// `--chaos` list of directives:
///
/// * `kill:<rank>@<step>` — abort the rank's process at its `<step>`-th
///   gradient call (the launcher knows the plan and treats that death as
///   expected);
/// * `slow:<rank>:<ms>` — sleep before every gradient to provoke
///   round-deadline censoring;
/// * `drop:<rank>:<prob>` — drop each of the rank's outgoing frames with
///   probability `<prob>` ∈ [0, 1] ([`crate::transport::FaultTransport`];
///   dropped frames are unsent *and* unaccounted, so per-link bit balance
///   holds);
/// * `delay:<rank>:<ms>:<jitter>` — network-level latency: every outgoing
///   frame waits `ms + U[0, jitter]` milliseconds before hitting the wire;
/// * `flap:<rank>@<step>:<downtime_ms>` — kill at `<step>`, then the
///   launcher automatically respawns the rank with `--join` after
///   `<downtime_ms>` so it re-enters through the admission path.
///
/// Without `--failover`, rank 0 is the control plane: `kill`, `drop`, and
/// `flap` on it are rejected at parse time (workers wait on its frames
/// without a deadline by design).  With `--failover`
/// ([`ChaosSpec::parse_with`]), rank-0 faults are unlocked — the
/// membership layer hands leadership to a deterministic successor
/// (DESIGN.md §10).  [`ChaosSpec::validate`] additionally checks the plan
/// against the run's step budget at launch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosSpec {
    pub kill: Vec<(usize, u64)>,
    pub slow: Vec<(usize, u64)>,
    /// `(rank, probability)` per-frame drop faults.
    pub drop: Vec<(usize, f64)>,
    /// `(rank, base_ms, jitter_ms)` per-frame send latency.
    pub delay: Vec<(usize, u64, u64)>,
    /// `(rank, step, downtime_ms)` kill-then-rejoin cycles.
    pub flap: Vec<(usize, u64, u64)>,
}

impl ChaosSpec {
    pub fn parse(s: &str) -> Result<ChaosSpec, String> {
        ChaosSpec::parse_with(s, false)
    }

    /// [`ChaosSpec::parse`], with the rank-0 lock keyed on `--failover`:
    /// a failover run may kill, drop, or flap its leader.
    pub fn parse_with(s: &str, failover: bool) -> Result<ChaosSpec, String> {
        let rank_of = |tok: &str, part: &str, evictable: bool| -> Result<usize, String> {
            let rank: usize = tok.parse().map_err(|_| format!("bad chaos rank in '{part}'"))?;
            if evictable && rank == 0 && !failover {
                return Err(format!(
                    "chaos directive '{part}' targets rank 0 — without --failover the control \
                     plane is not evictable and workers wait on its frames without a deadline"
                ));
            }
            Ok(rank)
        };
        let mut spec = ChaosSpec::default();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            if let Some(rest) = part.strip_prefix("kill:") {
                let (rank, step) = rest
                    .split_once('@')
                    .ok_or_else(|| format!("bad chaos directive '{part}' (want kill:<rank>@<step>)"))?;
                let rank = rank_of(rank, part, true)?;
                let step = step.parse().map_err(|_| format!("bad chaos step in '{part}'"))?;
                spec.kill.push((rank, step));
            } else if let Some(rest) = part.strip_prefix("slow:") {
                let (rank, ms) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("bad chaos directive '{part}' (want slow:<rank>:<ms>)"))?;
                spec.slow.push((
                    rank_of(rank, part, false)?,
                    ms.parse().map_err(|_| format!("bad chaos delay in '{part}'"))?,
                ));
            } else if let Some(rest) = part.strip_prefix("drop:") {
                let (rank, prob) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("bad chaos directive '{part}' (want drop:<rank>:<prob>)"))?;
                let rank = rank_of(rank, part, true)?;
                let prob: f64 =
                    prob.parse().map_err(|_| format!("bad chaos probability in '{part}'"))?;
                if !(0.0..=1.0).contains(&prob) {
                    return Err(format!(
                        "chaos drop probability {prob} in '{part}' is outside [0, 1]"
                    ));
                }
                spec.drop.push((rank, prob));
            } else if let Some(rest) = part.strip_prefix("delay:") {
                let mut it = rest.splitn(3, ':');
                let (Some(rank), Some(ms), Some(jitter)) = (it.next(), it.next(), it.next())
                else {
                    return Err(format!(
                        "bad chaos directive '{part}' (want delay:<rank>:<ms>:<jitter>)"
                    ));
                };
                spec.delay.push((
                    rank_of(rank, part, false)?,
                    ms.parse().map_err(|_| format!("bad chaos delay in '{part}'"))?,
                    jitter.parse().map_err(|_| format!("bad chaos jitter in '{part}'"))?,
                ));
            } else if let Some(rest) = part.strip_prefix("flap:") {
                let (rank, rest) = rest.split_once('@').ok_or_else(|| {
                    format!("bad chaos directive '{part}' (want flap:<rank>@<step>:<downtime_ms>)")
                })?;
                let (step, down) = rest.split_once(':').ok_or_else(|| {
                    format!("bad chaos directive '{part}' (want flap:<rank>@<step>:<downtime_ms>)")
                })?;
                spec.flap.push((
                    rank_of(rank, part, true)?,
                    step.parse().map_err(|_| format!("bad chaos step in '{part}'"))?,
                    down.parse().map_err(|_| format!("bad chaos downtime in '{part}'"))?,
                ));
            } else {
                return Err(format!("unknown chaos directive '{part}'"));
            }
        }
        Ok(spec)
    }

    /// Launch-time cross-check against the run's shape: every `kill`/`flap`
    /// step must land inside the `total_steps` gradient calls the run will
    /// actually make (a fault beyond the end would silently never fire),
    /// and each rank may die at most once (one `kill` *or* one `flap`).
    /// Probability ranges and rank-0 targeting are parse-time errors.
    pub fn validate(&self, total_steps: u64) -> Result<(), String> {
        for &(rank, step) in &self.kill {
            if step >= total_steps {
                return Err(format!(
                    "chaos kill:{rank}@{step} never fires — the run makes only \
                     {total_steps} gradient calls per rank"
                ));
            }
        }
        for &(rank, step, _) in &self.flap {
            if step >= total_steps {
                return Err(format!(
                    "chaos flap:{rank}@{step} never fires — the run makes only \
                     {total_steps} gradient calls per rank"
                ));
            }
        }
        for rank in 0..crate::membership::MAX_RANKS {
            let deaths = self.kill.iter().filter(|(r, _)| *r == rank).count()
                + self.flap.iter().filter(|(r, _, _)| *r == rank).count();
            if deaths > 1 {
                return Err(format!(
                    "chaos plan kills rank {rank} {deaths} times — at most one kill or flap \
                     per rank"
                ));
            }
        }
        Ok(())
    }

    /// The gradient-call index at which `rank` dies, if it is marked
    /// (`kill` or the kill half of `flap`).
    pub fn kill_step(&self, rank: usize) -> Option<u64> {
        self.kill
            .iter()
            .find(|(r, _)| *r == rank)
            .map(|(_, s)| *s)
            .or_else(|| self.flap(rank).map(|(s, _)| s))
    }

    /// The per-gradient delay injected into `rank`, if it is marked.
    pub fn slow_ms(&self, rank: usize) -> Option<u64> {
        self.slow.iter().find(|(r, _)| *r == rank).map(|(_, m)| *m)
    }

    /// The per-frame drop probability armed on `rank`, if any.
    pub fn drop_prob(&self, rank: usize) -> Option<f64> {
        self.drop.iter().find(|(r, _)| *r == rank).map(|(_, p)| *p)
    }

    /// The `(base_ms, jitter_ms)` send latency armed on `rank`, if any.
    pub fn delay_ms(&self, rank: usize) -> Option<(u64, u64)> {
        self.delay.iter().find(|(r, _, _)| *r == rank).map(|(_, m, j)| (*m, *j))
    }

    /// The `(step, downtime_ms)` flap cycle armed on `rank`, if any.
    pub fn flap(&self, rank: usize) -> Option<(u64, u64)> {
        self.flap.iter().find(|(r, _, _)| *r == rank).map(|(_, s, d)| (*s, *d))
    }

    /// Every rank named anywhere in the plan (launch validates them).
    pub fn ranks(&self) -> impl Iterator<Item = usize> + '_ {
        self.kill
            .iter()
            .chain(self.slow.iter())
            .map(|(r, _)| *r)
            .chain(self.drop.iter().map(|(r, _)| *r))
            .chain(self.delay.iter().map(|(r, _, _)| *r))
            .chain(self.flap.iter().map(|(r, _, _)| *r))
    }
}

/// Arm the trace recorder for this run if `cfg.trace` is set.  The main
/// thread registers here; worker/pipeline threads register themselves at
/// their entry points (`engine::drive_worker`, `pipeline::helper_loop`).
fn trace_begin(cfg: &TrainCfg) {
    if cfg.trace.is_some() {
        obs::set_enabled(true);
        obs::register_thread("main");
    }
}

/// Drain the recorder at the end of a traced run: write this rank's JSONL
/// trace (spans from every registered thread plus the transport's per-peer
/// wire counters) and fold the events into the record's phase summaries.
/// No-op (empty summary) on untraced runs.
fn trace_finish(cfg: &TrainCfg, rank: usize, peers: &[obs::PeerCounters]) -> Vec<PhaseSummary> {
    let Some(dir) = &cfg.trace else {
        return Vec::new();
    };
    let snaps = obs::snapshot_all();
    let phases = phase_summaries(&snaps);
    if let Err(e) = obs::export::write_rank_jsonl(dir, rank, &snaps, peers) {
        eprintln!("warning: rank {rank}: writing trace to {}: {e}", dir.display());
    }
    obs::set_enabled(false);
    obs::reset();
    phases
}

/// Arm the metrics registry for a metered elastic run and, on rank 0,
/// build the fleet view — additionally binding the exposition server when
/// `--metrics-addr` is set (adaptive-τ-only runs aggregate without
/// serving).  Returns `None` on other ranks and on unmetered runs.
fn metrics_begin(
    cfg: &TrainCfg,
    job: &str,
    rank: usize,
    n: usize,
) -> Option<std::sync::Arc<Mutex<obs::metrics::FleetView>>> {
    if cfg.metrics_addr.is_none() && cfg.adaptive_tau.is_none() {
        return None;
    }
    obs::metrics::reset();
    obs::metrics::set_enabled(true);
    if rank != 0 {
        return None;
    }
    let view = std::sync::Arc::new(Mutex::new(obs::metrics::FleetView::new(job, n)));
    if let Some(addr) = &cfg.metrics_addr {
        match obs::metrics::spawn_exposition_server(addr, std::sync::Arc::clone(&view)) {
            Ok(bound) => eprintln!(
                "rank 0: serving metrics at http://{bound}/ (Prometheus at /metrics)"
            ),
            Err(e) => eprintln!("warning: rank 0: binding metrics server at {addr}: {e}"),
        }
    }
    Some(view)
}

/// Disarm the registry at the end of a metered run.  The exposition
/// thread keeps serving the final view until the process exits, so a
/// scrape that races run teardown still sees the last boundary's state.
fn metrics_finish(cfg: &TrainCfg) {
    if cfg.metrics_addr.is_some() || cfg.adaptive_tau.is_some() {
        obs::metrics::set_enabled(false);
    }
}

/// Price one optimizer step's communication at paper scale (DESIGN.md §3)
/// into the cumulative wire-bit and wall-clock counters — shared by the
/// central and worker-resident training loops.
fn price_step(
    cfg: &TrainCfg,
    scale: f64,
    stats: &RoundStats,
    cum_bits: &mut f64,
    cum_seconds: &mut f64,
) {
    *cum_seconds += cfg.cost.compute_step;
    if stats.grad_bits > 0 {
        let bits = stats.grad_bits as f64 * scale;
        let rt = cfg.cost.sync_round(bits as u64, stats.grad_allreduce, cfg.cost.n.min(8) as f64);
        *cum_bits += rt.wire.total_bits() as f64;
        *cum_seconds += rt.seconds;
    }
    if stats.model_bits > 0 {
        let bits = stats.model_bits as f64 * scale;
        let rt = cfg.cost.sync_round(bits as u64, stats.model_allreduce, cfg.cost.n.min(8) as f64);
        *cum_bits += rt.wire.total_bits() as f64;
        *cum_seconds += rt.seconds;
    }
}

/// Per-worker gradient-oracle resources for the resident/TCP paths: the
/// shard sampler plus reused minibatch and model-scratch buffers behind one
/// mutex (uncontended by construction — worker i is the only locker of
/// entry i), so the in-thread gradient calls allocate nothing per step.
struct GradRes {
    shard: Shard,
    batch: Vec<u32>,
    scratch: ModelScratch,
}

impl GradRes {
    fn new(shard: Shard) -> Mutex<GradRes> {
        Mutex::new(GradRes { shard, batch: Vec::new(), scratch: ModelScratch::new() })
    }
}

/// Train `opt` on `(train, test)`; returns the full run record.
///
/// With `cfg.backend == Backend::Resident` and an engine-backed optimizer
/// (all built-ins are), the step loop is handed to the worker threads via
/// [`ErrorResetEngine::run_resident`]; otherwise the classic central loop
/// below drives `step(grads, eta)` with `scope_zip`-parallel gradients into
/// persistent per-worker buffers.
pub fn train_classifier(
    model: &dyn GradModel,
    train: &ClassDataset,
    test: &ClassDataset,
    opt: &mut dyn DistOptimizer,
    cfg: &TrainCfg,
) -> RunRecord {
    if cfg.buckets > 1 {
        let engine = opt
            .as_engine()
            .expect("cfg.buckets requires an engine-backed optimizer (all built-ins are)");
        let bounds = model.param_layout().bucket_bounds(cfg.buckets);
        engine.set_bucketing(Some(crate::engine::SyncBuckets::from_bounds(bounds)));
    }
    if let Backend::Tcp { bind, peers, rank } = &cfg.backend {
        let (bind, peers, rank) = (bind.clone(), *peers, *rank);
        let engine = opt.as_engine().expect("Backend::Tcp requires an engine optimizer");
        if cfg.elastic
            || cfg.chaos.is_some()
            || cfg.join
            || cfg.metrics_addr.is_some()
            || cfg.adaptive_tau.is_some()
            || cfg.failover
        {
            return train_classifier_tcp_elastic(model, train, test, engine, cfg, &bind, peers, rank);
        }
        return train_classifier_tcp(model, train, test, engine, cfg, &bind, peers, rank);
    }
    if cfg.backend.worker_resident() {
        if let Some(engine) = opt.as_engine() {
            return train_classifier_resident(model, train, test, engine, cfg);
        }
        // non-engine optimizers fall through to the central loop (still over
        // the threaded wire collectives `Backend::Resident` selects)
    }
    let n = opt.n();
    let d = opt.dim();
    assert_eq!(d, model.dim());
    trace_begin(cfg);
    opt.set_collective(cfg.backend.collective());
    let mut shards = Shard::split(train.len(), n, cfg.seed);
    let iters_per_epoch = (train.len() / (cfg.batch_per_worker * n)).max(1);

    // Persistent per-worker contexts: gradient buffer, minibatch indices,
    // and the model's scratch arena are allocated once and reused every
    // step — the hot loop below performs no steady-state allocation.
    struct WorkerCtx {
        grad: Vec<f32>,
        batch: Vec<u32>,
        scratch: ModelScratch,
        loss: f32,
    }
    let mut ctxs: Vec<WorkerCtx> = (0..n)
        .map(|_| WorkerCtx {
            grad: vec![0.0; d],
            batch: Vec::new(),
            scratch: ModelScratch::new(),
            loss: 0.0,
        })
        .collect();
    // `step(&grads, ..)` wants `&[Vec<f32>]`; the buffers are swapped in
    // from the contexts around each call (pointer moves, no copies).
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); n];
    let mut xbar = vec![0.0f32; d];
    let run_start = std::time::Instant::now();
    let mut points = Vec::with_capacity(cfg.epochs);
    let mut diverged = false;
    let mut initial_loss = f64::NAN;
    let mut cum_bits = 0.0f64;
    let mut cum_seconds = 0.0f64;
    let scale = cfg.paper_d as f64 / d as f64;

    'outer: for epoch in 0..cfg.epochs {
        let frac = epoch as f64 / cfg.epochs as f64;
        let eta = (cfg.lr * (cfg.lr_multiplier)(&cfg.schedule, frac)) as f32;
        let mut loss_sum = 0.0f64;
        for _ in 0..iters_per_epoch {
            for (w, shard) in shards.iter_mut().enumerate() {
                shard.sample_batch(cfg.batch_per_worker, &mut ctxs[w].batch);
            }
            // parallel per-worker gradients at each worker's local model,
            // into each worker's persistent buffers
            {
                let opt_ref: &dyn DistOptimizer = opt;
                scope_zip(&mut ctxs, cfg.threads, |w, ctx| {
                    ctx.loss = model.loss_grad_scratch(
                        opt_ref.worker_model(w),
                        train,
                        &ctx.batch,
                        &mut ctx.grad,
                        &mut ctx.scratch,
                    );
                });
            }
            let mut step_loss = 0.0f64;
            for ctx in &ctxs {
                step_loss += ctx.loss as f64 / n as f64;
            }
            loss_sum += step_loss;
            if initial_loss.is_nan() {
                initial_loss = step_loss;
            }
            if !step_loss.is_finite() || step_loss > cfg.divergence_factor * initial_loss {
                diverged = true;
            }

            for (g, ctx) in grads.iter_mut().zip(ctxs.iter_mut()) {
                std::mem::swap(g, &mut ctx.grad);
            }
            let stats = opt.step(&grads, eta);
            for (g, ctx) in grads.iter_mut().zip(ctxs.iter_mut()) {
                std::mem::swap(g, &mut ctx.grad);
            }
            // paper-scale accounting
            price_step(cfg, scale, &stats, &mut cum_bits, &mut cum_seconds);
            if diverged {
                break;
            }
        }
        let train_loss = loss_sum / iters_per_epoch as f64;
        opt.mean_model(&mut xbar);
        let test_acc = if xbar.iter().all(|v| v.is_finite()) {
            model.accuracy(&xbar, test) as f64
        } else {
            diverged = true;
            f64::NAN
        };
        let wall_ms = run_start.elapsed().as_millis() as u64;
        points.push(EpochPoint { epoch, train_loss, test_acc, cum_bits, cum_seconds, wall_ms });
        if diverged {
            break 'outer;
        }
    }

    RunRecord {
        name: String::new(),
        optimizer: opt.name(),
        overall_rc: f64::NAN,
        lr: cfg.lr,
        seed: cfg.seed,
        points,
        diverged,
        phases: trace_finish(cfg, 0, &[]),
        elastic: None,
    }
}

/// Worker-resident training loop: the engine's worker threads own their
/// state and drive the whole iteration; this function only schedules epochs,
/// prices the per-step stats, and evaluates x̄ between epochs.  Each worker
/// samples from its own mutex-wrapped shard — uncontended by construction
/// (worker i is the only locker of shard i).
fn train_classifier_resident(
    model: &dyn GradModel,
    train: &ClassDataset,
    test: &ClassDataset,
    engine: &mut ErrorResetEngine,
    cfg: &TrainCfg,
) -> RunRecord {
    let n = engine.n();
    let d = engine.dim();
    assert_eq!(d, model.dim());
    trace_begin(cfg);
    // No collective is installed: resident workers execute the peer-owned
    // mesh collectives directly (`run_resident` never consults the central
    // `Collective`).
    let res: Vec<Mutex<GradRes>> =
        Shard::split(train.len(), n, cfg.seed).into_iter().map(GradRes::new).collect();
    let iters_per_epoch = (train.len() / (cfg.batch_per_worker * n)).max(1);
    let grad_fn = crate::engine::as_grad(|w, xw, out| {
        let mut r = res[w].lock().unwrap();
        let GradRes { shard, batch, scratch } = &mut *r;
        shard.sample_batch(cfg.batch_per_worker, batch);
        model.loss_grad_scratch(xw, train, batch, out, scratch)
    });

    let mut xbar = vec![0.0f32; d];
    let run_start = std::time::Instant::now();
    let mut points = Vec::with_capacity(cfg.epochs);
    let mut diverged = false;
    let mut initial_loss = f64::NAN;
    let mut cum_bits = 0.0f64;
    let mut cum_seconds = 0.0f64;
    let scale = cfg.paper_d as f64 / d as f64;

    for epoch in 0..cfg.epochs {
        let frac = epoch as f64 / cfg.epochs as f64;
        let eta = (cfg.lr * (cfg.lr_multiplier)(&cfg.schedule, frac)) as f32;
        // In-flight divergence brake: the engine stops all workers on the
        // same step when the mean loss trips this.  The first epoch has no
        // reference loss yet and runs unguarded; the re-check below catches
        // anything it let through.
        let stop_loss = if initial_loss.is_finite() {
            cfg.divergence_factor * initial_loss
        } else {
            f64::INFINITY
        };
        let reports = engine.run_resident(iters_per_epoch, eta, stop_loss, &grad_fn);
        let mut loss_sum = 0.0f64;
        for rep in &reports {
            if initial_loss.is_nan() {
                initial_loss = rep.loss;
            }
            loss_sum += rep.loss;
            if !rep.loss.is_finite() || rep.loss > cfg.divergence_factor * initial_loss {
                diverged = true;
            }
            price_step(cfg, scale, &rep.stats, &mut cum_bits, &mut cum_seconds);
        }
        let train_loss = loss_sum / reports.len().max(1) as f64;
        engine.mean_model(&mut xbar);
        let test_acc = if xbar.iter().all(|v| v.is_finite()) {
            model.accuracy(&xbar, test) as f64
        } else {
            diverged = true;
            f64::NAN
        };
        let wall_ms = run_start.elapsed().as_millis() as u64;
        points.push(EpochPoint { epoch, train_loss, test_acc, cum_bits, cum_seconds, wall_ms });
        if diverged {
            break;
        }
    }

    RunRecord {
        name: String::new(),
        optimizer: engine.name(),
        overall_rc: f64::NAN,
        lr: cfg.lr,
        seed: cfg.seed,
        points,
        diverged,
        phases: trace_finish(cfg, 0, &[]),
        elastic: None,
    }
}

/// Real multi-process training: this process is worker `rank` of an
/// `n_peers`-process job meeting at `rendezvous` (rank 0 hosts it).  The
/// engine holds exactly the local rank's `WorkerState`; every collective is
/// executed peer-owned over persistent TCP sockets
/// (`ErrorResetEngine::run_distributed`).
///
/// Every rank computes the full epoch schedule from the same `cfg`, so the
/// fleet stays on one control-flow path: the divergence brake rides the
/// in-step loss vote, the epoch-level divergence verdict is agreed by a
/// fleet-wide OR, and x̄ for evaluation is a dense (uncharged) mean across
/// ranks — bit-identical to the central trainer's `mean_model`.  The
/// returned `RunRecord` is therefore identical on every rank for plans that
/// synchronize every step, and rank 0's record is the job's record.
///
/// With `cfg.ckpt` set, the complete engine state is checkpointed after
/// every epoch and restored on startup when the file exists — a killed
/// fleet restarts from the last epoch boundary with the exact optimizer
/// state (models, errors, momentum, anchors, step counter).  Two scope
/// limits, by design: the shard samplers are not part of the checkpoint,
/// so post-resume minibatches are a fresh draw of the same distribution
/// rather than a replay; and the emitted `RunRecord` (points, cumulative
/// bit/time counters, divergence reference) covers only the post-resume
/// epochs.
#[allow(clippy::too_many_arguments)]
fn train_classifier_tcp(
    model: &dyn GradModel,
    train: &ClassDataset,
    test: &ClassDataset,
    engine: &mut ErrorResetEngine,
    cfg: &TrainCfg,
    rendezvous: &str,
    n_peers: usize,
    rank: usize,
) -> RunRecord {
    assert_eq!(engine.n(), 1, "a Backend::Tcp engine holds exactly the local rank's worker");
    let d = engine.dim();
    assert_eq!(d, model.dim());
    trace_begin(cfg);
    let n = n_peers;
    let mut tp = TcpTransport::connect(rendezvous, rank, n)
        .unwrap_or_else(|e| panic!("joining job at {rendezvous} as rank {rank}/{n}: {e}"));

    // Deterministic sharding: every rank derives the same split from the
    // shared seed and takes its own slice.
    let res = GradRes::new(Shard::split(train.len(), n, cfg.seed).swap_remove(rank));
    let iters_per_epoch = (train.len() / (cfg.batch_per_worker * n)).max(1);
    let grad_fn = crate::engine::as_grad(|_w, xw: &[f32], out: &mut [f32]| {
        let mut r = res.lock().unwrap();
        let GradRes { shard, batch, scratch } = &mut *r;
        shard.sample_batch(cfg.batch_per_worker, batch);
        model.loss_grad_scratch(xw, train, batch, out, scratch)
    });

    let mut start_epoch = 0usize;
    if let Some(path) = &cfg.ckpt {
        if path.exists() {
            let ck = Checkpoint::load(path)
                .unwrap_or_else(|e| panic!("rank {rank}: loading checkpoint: {e}"));
            ck.restore_engine(engine)
                .unwrap_or_else(|e| panic!("rank {rank}: restoring checkpoint: {e}"));
            start_epoch = (engine.step_count() / iters_per_epoch as u64) as usize;
        }
    }
    // The fleet must resume from one step; a rank missing its checkpoint
    // (or holding a stale one) would otherwise desynchronize the epoch
    // loop and wedge every collective.  Integer agreement — a float mean
    // would re-round and reject valid resumes at most fleet sizes.
    let same = peer::all_equal(&mut tp, start_epoch as u64, 0)
        .unwrap_or_else(|e| panic!("rank {rank}: start-epoch agreement: {e}"));
    assert!(
        same,
        "rank {rank} resumed at epoch {start_epoch} but the fleet disagrees — \
         restart all ranks from matching checkpoints"
    );

    let mut xbar = vec![0.0f32; d];
    let run_start = std::time::Instant::now();
    let mut points = Vec::with_capacity(cfg.epochs);
    let mut diverged = false;
    let mut initial_loss = f64::NAN;
    let mut cum_bits = 0.0f64;
    let mut cum_seconds = 0.0f64;
    let scale = cfg.paper_d as f64 / d as f64;

    for epoch in start_epoch..cfg.epochs {
        let frac = epoch as f64 / cfg.epochs as f64;
        let eta = (cfg.lr * (cfg.lr_multiplier)(&cfg.schedule, frac)) as f32;
        // In-flight divergence brake: the loss vote at each syncing step
        // broadcasts one verdict, so the fleet stops on the same step (only
        // rank 0's threshold is consulted).  The first epoch has no
        // reference loss yet and runs unguarded; the epoch-level check
        // below catches anything it let through.
        let stop_loss = if initial_loss.is_finite() {
            cfg.divergence_factor * initial_loss
        } else {
            f64::INFINITY
        };
        let reports = engine
            .run_distributed(&mut tp, iters_per_epoch, eta, stop_loss, &grad_fn)
            .unwrap_or_else(|e| panic!("rank {rank}: epoch {epoch}: {e}"));
        let mut loss_sum = 0.0f64;
        for rep in &reports {
            if initial_loss.is_nan() {
                initial_loss = rep.loss;
            }
            loss_sum += rep.loss;
            if !rep.loss.is_finite() || rep.loss > cfg.divergence_factor * initial_loss {
                diverged = true;
            }
            price_step(cfg, scale, &rep.stats, &mut cum_bits, &mut cum_seconds);
        }
        let train_loss = loss_sum / reports.len().max(1) as f64;
        // x̄ across the fleet, identical on every rank: replicated plans
        // already agree bit-exactly; otherwise a dense, uncharged mean in
        // rank order — the same arithmetic as the central `mean_model`.
        xbar.copy_from_slice(engine.worker_model(0));
        if !engine.comm_plan().replicated() {
            peer::mean_dense(&mut tp, &mut xbar, engine.step_count())
                .unwrap_or_else(|e| panic!("rank {rank}: evaluating mean model: {e}"));
        }
        let test_acc = if xbar.iter().all(|v| v.is_finite()) {
            model.accuracy(&xbar, test) as f64
        } else {
            diverged = true;
            f64::NAN
        };
        let wall_ms = run_start.elapsed().as_millis() as u64;
        points.push(EpochPoint { epoch, train_loss, test_acc, cum_bits, cum_seconds, wall_ms });
        if let Some(path) = &cfg.ckpt {
            if let Err(e) = Checkpoint::capture_engine(engine).save(path) {
                eprintln!("warning: rank {rank}: checkpoint save failed: {e}");
            }
        }
        // Liveness: local losses can differ on barrier-free local steps, so
        // the break must be a fleet-wide agreement, not a local decision.
        diverged = peer::agree(&mut tp, diverged, engine.step_count())
            .unwrap_or_else(|e| panic!("rank {rank}: divergence agreement: {e}"));
        if diverged {
            break;
        }
    }

    RunRecord {
        name: String::new(),
        optimizer: engine.name(),
        overall_rc: f64::NAN,
        lr: cfg.lr,
        seed: cfg.seed,
        points,
        diverged,
        phases: trace_finish(cfg, rank, &tp.per_peer),
        elastic: None,
    }
}

/// Elastic variant of [`train_classifier_tcp`] (DESIGN.md §8): the socket
/// transport is wrapped in [`Elastic`], so a dead or deadline-missing peer
/// is **censored for the round** — the parameter-server collectives
/// aggregate over the responders and rescale by the live count — instead
/// of killing the fleet, and membership changes are negotiated at each
/// epoch boundary through the standing rendezvous [`rendezvous::Session`]:
/// observed deaths are evicted, and rank 0 admits a *batch* of parked
/// joiners per boundary — every distinct non-live `CSER-JN2` request
/// waiting in the grace window is granted in rank order under one epoch
/// frame (grant = epoch, resume step, live mask, checkpoint blob; each
/// joiner re-dials the live mesh and every survivor installs the fresh
/// links in arrival order against the frame's joiner mask).  With
/// `cfg.join` this rank *is* a joiner: it restores the granted blob
/// bit-exactly and enters the epoch loop at the granted step.
///
/// Ring-routed plans participate fully (DESIGN.md §8): post-boundary
/// rings are built over the agreed `view_mask`, and a ring that stalls
/// mid-round (death or deadline miss) falls back to the parameter-server
/// path *at the same round* and latches the transport degraded until the
/// next boundary re-forms the ring.  The bucketed pipeline composes too —
/// each bucket runs the same view-aware collectives, and an aborted
/// bucket drains the prepare queue instead of wedging it.  Without
/// `--failover`, rank 0 is the control plane and is not evictable;
/// losing it is terminal.  With `--failover` (DESIGN.md §10) the leader
/// replicates its control state to a deterministic successor every
/// boundary, stamps frames with a leader generation so a zombie
/// ex-leader is fenced, and on the leader's death the successor redoes
/// the interrupted round as PS server and assumes every leader role:
/// rendezvous listener (re-bound on the advertised address), epoch
/// broadcaster, PS aggregation, and the fleet metrics merge (seeded
/// from the replicated snapshot so run-wide counters never regress).
/// Worker-local residuals are deliberately *not* replicated — error
/// reset makes them rebuildable state, exactly like any other eviction.
///
/// The `--chaos` fault matrix rides this path: `kill`/`flap` panic in
/// the gradient oracle (unwinding drops the socket, peers observe
/// `PeerDown`), `slow` sleeps there, and `drop`/`delay` wrap the socket
/// transport in a [`crate::transport::FaultTransport`] underneath the
/// membership layer.
///
/// The returned record carries an [`ElasticSummary`]: the final epoch
/// view, per-epoch membership events, and this rank's ground-truth
/// per-link wire counters, which is what the `elastic_equiv` tests audit
/// for exact bit accounting under partial rounds.
#[allow(clippy::too_many_arguments)]
fn train_classifier_tcp_elastic(
    model: &dyn GradModel,
    train: &ClassDataset,
    test: &ClassDataset,
    engine: &mut ErrorResetEngine,
    cfg: &TrainCfg,
    rendezvous_addr: &str,
    n_peers: usize,
    rank: usize,
) -> RunRecord {
    assert_eq!(engine.n(), 1, "a Backend::Tcp engine holds exactly the local rank's worker");
    let d = engine.dim();
    assert_eq!(d, model.dim());
    trace_begin(cfg);
    let metrics_on = cfg.metrics_addr.is_some() || cfg.adaptive_tau.is_some();
    let mut fleet = metrics_begin(cfg, &engine.name(), rank, n_peers);
    let mut tracker = obs::metrics::DeltaTracker::new();
    let n = n_peers;
    let deadline = Duration::from_millis(cfg.round_deadline_ms.max(1));
    let iters_per_epoch = (train.len() / (cfg.batch_per_worker * n)).max(1);
    let mut evictions = 0u64;
    let mut joins = 0u64;
    let mut events: Vec<super::metrics::EpochEvent> = Vec::new();

    // Network faults (`drop:`/`delay:`) live in a wrapper *under* the
    // membership layer, so Elastic sees a lossy wire exactly as it would in
    // production.  Unfaulted ranks wrap too (p = 0, no delay — a pass-
    // through) so the transport type is uniform across the fleet.
    let arm_faults = |tp: TcpTransport| {
        let mut f = crate::transport::FaultTransport::new(tp, cfg.seed ^ ((rank as u64) << 32));
        if let Some(chaos) = &cfg.chaos {
            if let Some(p) = chaos.drop_prob(rank) {
                f = f.with_drop(p);
            }
            if let Some((ms, jitter)) = chaos.delay_ms(rank) {
                f = f.with_delay(ms, jitter);
            }
        }
        f
    };

    let (mut el, mut session, start_epoch) = if cfg.join {
        // ---- the rejoin path: dial back into the running job ----
        let (links, grant, session) = rendezvous::rejoin(rendezvous_addr, rank, n)
            .unwrap_or_else(|e| panic!("rank {rank}: rejoining job at {rendezvous_addr}: {e}"));
        let ck = Checkpoint::from_bytes(&grant.blob)
            .unwrap_or_else(|e| panic!("rank {rank}: decoding the grant checkpoint: {e}"));
        ck.restore_engine(engine)
            .unwrap_or_else(|e| panic!("rank {rank}: restoring the grant checkpoint: {e}"));
        assert_eq!(engine.step_count(), grant.step, "grant step must match its checkpoint");
        assert_eq!(grant.step % iters_per_epoch as u64, 0, "admissions happen at epoch boundaries");
        let tp = TcpTransport::from_streams(rank, n, links)
            .unwrap_or_else(|e| panic!("rank {rank}: wrapping the rejoin mesh: {e}"));
        let view = Epoch::from_mask(grant.epoch, grant.live_mask, n);
        assert!(view.is_live(rank), "the granted view must include the joiner");
        let mut el = Elastic::with_epoch(arm_faults(tp), view, Some(deadline))
            .with_failover(cfg.failover)
            .with_generation(grant.generation);
        // The leader's boundary broadcast runs under the granted view, so
        // the admission frame arrives here too; consume it and cross-check
        // the grant against what the survivors were told.
        let ldr = el.leader();
        let m = el
            .recv(ldr, grant.step, Tag::Epoch)
            .unwrap_or_else(|e| panic!("rank {rank}: receiving the admission frame: {e}"));
        let (gen, epoch, joined) = crate::membership::decode_epoch_frame(&m, n)
            .unwrap_or_else(|e| panic!("rank {rank}: decoding the admission frame: {e}"));
        assert!(
            crate::membership::admits_generation(grant.generation, gen),
            "admission frame generation {gen} is fenced behind the grant's {}",
            grant.generation
        );
        assert!(
            (joined >> rank) & 1 == 1,
            "the admission frame's joiner mask {joined:#x} must include this rank"
        );
        assert_eq!(epoch, view, "grant and boundary frame disagree on the view");
        // Admitting a dead ex-leader back moves leadership at this very
        // boundary, so the frame may already carry a bumped generation;
        // adopt it or this rank's own later frames would be fenced.
        let el = el.with_generation(gen);
        joins += joined.count_ones() as u64;
        events.push(super::metrics::EpochEvent {
            epoch: epoch.id(),
            step: grant.step,
            evicted: 0,
            joined,
        });
        (el, session, (grant.step / iters_per_epoch as u64) as usize)
    } else {
        let (tp, session) = TcpTransport::connect_v2(rendezvous_addr, rank, n)
            .unwrap_or_else(|e| panic!("joining job at {rendezvous_addr} as rank {rank}/{n}: {e}"));
        let mut el = Elastic::new(arm_faults(tp), Some(deadline)).with_failover(cfg.failover);
        let mut start_epoch = 0usize;
        if let Some(path) = &cfg.ckpt {
            if path.exists() {
                let ck = Checkpoint::load(path)
                    .unwrap_or_else(|e| panic!("rank {rank}: loading checkpoint: {e}"));
                ck.restore_engine(engine)
                    .unwrap_or_else(|e| panic!("rank {rank}: restoring checkpoint: {e}"));
                start_epoch = (engine.step_count() / iters_per_epoch as u64) as usize;
            }
        }
        let same = peer::all_equal(&mut el, start_epoch as u64, 0)
            .unwrap_or_else(|e| panic!("rank {rank}: start-epoch agreement: {e}"));
        assert!(
            same,
            "rank {rank} resumed at epoch {start_epoch} but the fleet disagrees — \
             restart all ranks from matching checkpoints"
        );
        (el, session, start_epoch)
    };

    // Gradient oracle, with the chaos plan folded in: a marked kill panics
    // at its gradient call (unwinding drops the transport, so peers observe
    // the hangup as `PeerDown` and censor this rank); a marked slow sleeps
    // before every gradient to provoke deadline censoring.
    let res = GradRes::new(Shard::split(train.len(), n, cfg.seed).swap_remove(rank));
    let kill_at = cfg.chaos.as_ref().and_then(|c| c.kill_step(rank));
    let slow_ms = cfg.chaos.as_ref().and_then(|c| c.slow_ms(rank));
    let calls = AtomicU64::new(0);
    let grad_fn = crate::engine::as_grad(|_w, xw: &[f32], out: &mut [f32]| {
        let k = calls.fetch_add(1, Ordering::SeqCst);
        if kill_at.is_some_and(|at| k >= at) {
            panic!("chaos: rank {rank} killed at gradient call {k}");
        }
        if let Some(ms) = slow_ms {
            std::thread::sleep(Duration::from_millis(ms));
        }
        let mut r = res.lock().unwrap();
        let GradRes { shard, batch, scratch } = &mut *r;
        shard.sample_batch(cfg.batch_per_worker, batch);
        model.loss_grad_scratch(xw, train, batch, out, scratch)
    });

    let mut xbar = vec![0.0f32; d];
    let run_start = std::time::Instant::now();
    let mut points = Vec::with_capacity(cfg.epochs.saturating_sub(start_epoch));
    let mut diverged = false;
    let mut initial_loss = f64::NAN;
    let mut cum_bits = 0.0f64;
    let mut cum_seconds = 0.0f64;
    let scale = cfg.paper_d as f64 / d as f64;

    // Failover state: the successor's stash of the leader's last replicated
    // control state, the highest leader generation this rank has acted on
    // (a bump past it at a boundary means a handover was just agreed), and
    // the current censoring τ (part of the replicated state).
    let mut replicated: Option<crate::membership::ControlState> = None;
    let mut seen_gen = el.generation();
    let mut current_tau = cfg.adaptive_tau.unwrap_or(0.0);

    for epoch in start_epoch..cfg.epochs {
        let frac = epoch as f64 / cfg.epochs as f64;
        let eta = (cfg.lr * (cfg.lr_multiplier)(&cfg.schedule, frac)) as f32;
        let stop_loss = if initial_loss.is_finite() {
            cfg.divergence_factor * initial_loss
        } else {
            f64::INFINITY
        };
        let reports = engine
            .run_distributed(&mut el, iters_per_epoch, eta, stop_loss, &grad_fn)
            .unwrap_or_else(|e| panic!("rank {rank}: epoch {epoch}: {e}"));
        let mut loss_sum = 0.0f64;
        for rep in &reports {
            if initial_loss.is_nan() {
                initial_loss = rep.loss;
            }
            loss_sum += rep.loss;
            if !rep.loss.is_finite() || rep.loss > cfg.divergence_factor * initial_loss {
                diverged = true;
            }
            price_step(cfg, scale, &rep.stats, &mut cum_bits, &mut cum_seconds);
        }
        let train_loss = loss_sum / reports.len().max(1) as f64;
        xbar.copy_from_slice(engine.worker_model(0));
        if !engine.comm_plan().replicated() {
            peer::mean_dense(&mut el, &mut xbar, engine.step_count())
                .unwrap_or_else(|e| panic!("rank {rank}: evaluating mean model: {e}"));
        }
        let test_acc = if xbar.iter().all(|v| v.is_finite()) {
            model.accuracy(&xbar, test) as f64
        } else {
            diverged = true;
            f64::NAN
        };
        let wall_ms = run_start.elapsed().as_millis() as u64;
        points.push(EpochPoint { epoch, train_loss, test_acc, cum_bits, cum_seconds, wall_ms });
        if let Some(path) = &cfg.ckpt {
            if let Err(e) = Checkpoint::capture_engine(engine).save(path) {
                eprintln!("warning: rank {rank}: checkpoint save failed: {e}");
            }
        }
        diverged = peer::agree(&mut el, diverged, engine.step_count())
            .unwrap_or_else(|e| panic!("rank {rank}: divergence agreement: {e}"));
        if diverged {
            break;
        }

        // ---- the epoch boundary: the only place membership changes ----
        let round = engine.step_count();
        let mut admit = 0u64;
        // The leader entering this boundary: it polls the rendezvous and
        // grants admissions; everyone else accepts the joiners' re-dials.
        // Rank 0 always, unless `--failover` already moved leadership.
        let ldr = el.leader();
        if rank == ldr && el.pending_down() == 0 && el.live_count() < n {
            // Short-handed with the pending deaths already flushed: give
            // restarting ranks one deadline window to park at the
            // rendezvous, then admit every distinct non-live request as a
            // batch — granted in rank order under one epoch frame.  A
            // boundary with deaths still pending evicts first and admits
            // at the next one (the epoch mask algebra keeps evict and
            // admit disjoint per transition); a full fleet skips the poll
            // — the happy path costs nothing here.
            let mut reqs: Vec<rendezvous::JoinRequest> = Vec::new();
            let capacity = n - el.live_count();
            let mut window = deadline;
            while reqs.len() < capacity {
                match session.poll_join_deadline(window) {
                    Ok(Some(req))
                        if !el.is_live(req.rank)
                            && !reqs.iter().any(|r| r.rank == req.rank) =>
                    {
                        reqs.push(req);
                        // First parked joiner found: the rest of the batch
                        // is whatever is already waiting — sweep, don't
                        // wait another window.
                        window = Duration::ZERO;
                    }
                    Ok(Some(req)) => {
                        eprintln!(
                            "warning: rank {rank}: live or duplicate rank {} asked to join — \
                             ignored",
                            req.rank
                        );
                        window = Duration::ZERO;
                    }
                    Ok(None) => break,
                    Err(e) => {
                        eprintln!("warning: rank {rank}: join poll failed: {e}");
                        break;
                    }
                }
            }
            if !reqs.is_empty() {
                reqs.sort_by_key(|r| r.rank);
                let joiners = reqs.iter().fold(0u64, |m, r| m | 1u64 << r.rank);
                let next =
                    el.epoch().advance(el.pending_down() & el.epoch().live_mask(), joiners);
                let blob = Checkpoint::capture_engine(engine).to_bytes();
                for req in reqs {
                    let j = req.rank;
                    let granted = session
                        .grant_join(
                            req,
                            el.generation(),
                            next.id(),
                            round,
                            next.live_mask(),
                            joiners,
                            &blob,
                        )
                        .and_then(|()| session.accept_rejoin());
                    match granted {
                        Ok((peer, stream)) if peer == j => {
                            el.inner_mut()
                                .inner_mut()
                                .install_link(j, stream)
                                .unwrap_or_else(|e| panic!("rank {rank}: relinking rank {j}: {e}"));
                            admit |= 1u64 << j;
                        }
                        Ok((peer, _)) => eprintln!(
                            "warning: rank {rank}: rank {peer} re-dialed while rank {j} held \
                             the grant — admission dropped"
                        ),
                        Err(e) => {
                            eprintln!("warning: rank {rank}: admitting rank {j} failed: {e}")
                        }
                    }
                }
            }
        }
        let mut just_joined = 0u64;
        if let Some(tr) = el
            .epoch_boundary(round, admit)
            .unwrap_or_else(|e| panic!("rank {rank}: epoch boundary at step {round}: {e}"))
        {
            evictions += u64::from(tr.evicted.count_ones());
            for r in 0..n {
                if (tr.evicted >> r) & 1 == 1 {
                    el.inner_mut().inner_mut().drop_link(r);
                }
            }
            joins += u64::from(tr.joined.count_ones());
            just_joined = tr.joined;
            if tr.joined != 0 && rank != ldr {
                // Every joiner re-dialed this rank's data listener when its
                // grant arrived; adopt the fresh streams.  Dials land in
                // whatever order the joiners raced, so match them against
                // the frame's mask instead of assuming rank order.
                let mut expect = tr.joined;
                while expect != 0 {
                    let (peer, stream) = session.accept_rejoin().unwrap_or_else(|e| {
                        panic!("rank {rank}: accepting rejoined ranks {expect:#x}: {e}")
                    });
                    assert!(
                        peer < 64 && (expect >> peer) & 1 == 1,
                        "rejoin handshake from rank {peer} outside the joiner mask {expect:#x}"
                    );
                    expect &= !(1u64 << peer);
                    el.inner_mut()
                        .inner_mut()
                        .install_link(peer, stream)
                        .unwrap_or_else(|e| panic!("rank {rank}: relinking rank {peer}: {e}"));
                }
            }
            events.push(super::metrics::EpochEvent {
                epoch: tr.epoch.id(),
                step: round,
                evicted: tr.evicted,
                joined: tr.joined,
            });
        }

        // ---- leader handover: a generation bump at this boundary means
        // the fleet just agreed a new leader.  If it is this rank, assume
        // every leader role (DESIGN.md §10): re-bind the rendezvous on the
        // advertised address so joiners and `cser top` can follow, stand
        // up the fleet metrics merge seeded from the replicated snapshot,
        // and resume the dead leader's last agreed censoring τ.  PS
        // aggregation and the epoch broadcast moved already — every
        // collective roots at `leader()`. ----
        let ldr_now = el.leader();
        if cfg.failover && el.generation() > seen_gen {
            seen_gen = el.generation();
            if rank == ldr_now {
                eprintln!(
                    "rank {rank}: assuming leadership at generation {} (step {round})",
                    el.generation()
                );
                if let Err(e) = session.assume_rendezvous(rendezvous_addr) {
                    eprintln!(
                        "warning: rank {rank}: re-binding rendezvous {rendezvous_addr}: {e}"
                    );
                }
                if metrics_on && fleet.is_none() {
                    let view = replicated
                        .as_ref()
                        .and_then(|cs| match obs::metrics::decode_fleet(&cs.metrics) {
                            Ok(v) => Some(v),
                            Err(e) => {
                                eprintln!("warning: rank {rank}: replicated fleet blob: {e}");
                                None
                            }
                        })
                        .unwrap_or_else(|| obs::metrics::FleetView::new(&engine.name(), n));
                    let view = std::sync::Arc::new(Mutex::new(view));
                    if let Some(addr) = &cfg.metrics_addr {
                        match obs::metrics::spawn_exposition_server(
                            addr,
                            std::sync::Arc::clone(&view),
                        ) {
                            Ok(bound) => eprintln!(
                                "rank {rank}: serving metrics at http://{bound}/ \
                                 (Prometheus at /metrics)"
                            ),
                            Err(e) => eprintln!(
                                "warning: rank {rank}: binding metrics server at {addr}: {e}"
                            ),
                        }
                    }
                    fleet = Some(view);
                }
                if cfg.adaptive_tau.is_some() {
                    if let Some(cs) = &replicated {
                        if cs.tau > 0.0 {
                            current_tau = cs.tau;
                            engine.set_cadence(crate::engine::Cadence::Censored {
                                tau0: cs.tau,
                                gamma: 1.0,
                            });
                        }
                    }
                }
            }
        }

        // ---- telemetry: ship this boundary's delta snapshot to the
        // leader, riding the control plane right behind the epoch
        // broadcast ----
        if metrics_on {
            obs::metrics::sync_from_peers(&el.inner().inner().per_peer);
            obs::metrics::gauge_set(obs::metrics::Gauge::LiveRanks, el.live_count() as f64);
            obs::metrics::gauge_set(obs::metrics::Gauge::EpochId, el.epoch().id() as f64);
            obs::metrics::gauge_set(
                obs::metrics::Gauge::CensorEvents,
                el.censor_events() as f64,
            );
            let snap = tracker.snapshot(rank);
            if rank == ldr_now {
                let view = fleet.as_ref().expect("the leader owns the fleet view");
                let mut v = view.lock().expect("fleet view");
                v.merge(&snap);
                let pending = el.pending_down();
                let epoch_view = el.epoch();
                for r in epoch_view.live_ranks() {
                    // The joiner admitted *at* this boundary enters the
                    // loop next epoch and ships nothing yet; pending-down
                    // ranks are dead in all but name.
                    if r == rank || (just_joined >> r) & 1 == 1 || (pending >> r) & 1 == 1 {
                        continue;
                    }
                    // Inner transport on purpose: a missed metrics frame
                    // is telemetry loss, not a censor event, and must not
                    // pollute the ElasticSummary accounting.  A frame that
                    // lands after the window is discarded as stale by the
                    // per-link round check, so the data plane never sees it.
                    match el.inner_mut().recv_deadline(r, round, Tag::Metrics, Some(deadline))
                    {
                        Ok(Some(m)) => match obs::metrics::decode_snapshot(&m) {
                            Ok(s) => v.merge(&s),
                            Err(e) => eprintln!(
                                "warning: rank {rank}: metrics frame from rank {r}: {e}"
                            ),
                        },
                        Ok(None) => {} // missed the window; the next delta covers it
                        Err(_) => {}   // death is the membership plane's problem
                    }
                }
            } else if let Err(e) =
                el.send(ldr_now, round, Tag::Metrics, obs::metrics::encode_snapshot(&snap))
            {
                eprintln!("warning: rank {rank}: shipping metrics snapshot: {e}");
            }
        }

        // ---- adaptive censoring: re-seed τ from measured backpressure —
        // rank 0 from the aggregated fleet view, others from their own
        // mirrored counters (per-rank τ divergence is protocol-safe: the
        // censoring decision is local, and rank 0 accounts whatever
        // frames actually arrive) ----
        if let Some(base) = cfg.adaptive_tau {
            let tau = match &fleet {
                Some(view) => crate::membership::censor_seed_from_fleet(
                    &view.lock().expect("fleet view"),
                    base,
                ),
                None => crate::membership::censor_seed_from_metrics(base),
            };
            current_tau = tau;
            engine.set_cadence(crate::engine::Cadence::Censored { tau0: tau, gamma: 1.0 });
        }

        // ---- control-state replication: the leader ships its epoch
        // state (generation, view, τ, grant blob, fleet metrics) to its
        // deterministic successor each boundary, so a later handover
        // resumes the run where it stood instead of restarting the
        // control plane cold.  Worker-local residuals are deliberately
        // absent: error reset makes them rebuildable (DESIGN.md §10). ----
        if cfg.failover && el.live_count() > 1 {
            let succ = el.successor();
            if rank == ldr_now {
                if let Some(succ) = succ {
                    let metrics_blob = fleet
                        .as_ref()
                        .map(|v| obs::metrics::encode_fleet(&v.lock().expect("fleet view")))
                        .unwrap_or_default();
                    let cs = crate::membership::ControlState {
                        generation: el.generation(),
                        epoch: el.epoch().id(),
                        live: el.epoch().live_mask(),
                        pending_down: el.pending_down(),
                        parked: 0, // joiners are granted in-boundary, never parked across one
                        tau: current_tau,
                        grant_blob: Checkpoint::capture_engine(engine).to_bytes(),
                        metrics: metrics_blob,
                    };
                    let frame = crate::membership::encode_control_state(&cs);
                    if let Err(e) = el.send(succ, round, Tag::ControlState, frame) {
                        eprintln!("warning: rank {rank}: replicating control state: {e}");
                    }
                }
            } else if succ == Some(rank) {
                // Inner transport for the same reason as the metrics path:
                // a missed replication frame is not a censor event, and a
                // late one is discarded as stale by the per-link round
                // check.
                match el.inner_mut().recv_deadline(
                    ldr_now,
                    round,
                    Tag::ControlState,
                    Some(deadline),
                ) {
                    Ok(Some(m)) => match crate::membership::decode_control_state(&m) {
                        Ok(cs) => replicated = Some(cs),
                        Err(e) => eprintln!("warning: rank {rank}: control-state frame: {e}"),
                    },
                    Ok(None) => {} // missed the window; the next boundary's supersedes it
                    Err(_) => {}   // death is the membership plane's problem
                }
            }
        }
    }

    let final_view = el.epoch();
    let live_mask = final_view.live_mask() & !el.pending_down();
    let censor_events = el.censor_events();
    let leader_changes = el.leader_changes().to_vec();
    let tp = el.into_inner().into_inner();
    metrics_finish(cfg);
    RunRecord {
        name: String::new(),
        optimizer: engine.name(),
        overall_rc: f64::NAN,
        lr: cfg.lr,
        seed: cfg.seed,
        points,
        diverged,
        phases: trace_finish(cfg, rank, &tp.per_peer),
        elastic: Some(ElasticSummary {
            final_epoch: final_view.id(),
            live_mask,
            censor_events,
            evictions,
            joins,
            payload_bits_sent: tp.per_peer.iter().map(|p| p.payload_bits_sent).sum(),
            payload_bits_received: tp.per_peer.iter().map(|p| p.payload_bits_received).sum(),
            events,
            leader_changes,
            links: tp.per_peer.clone(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LrSchedule, OptSpec};
    use crate::models::Mlp;

    fn quick_cfg(epochs: usize, lr: f64, seed: u64) -> TrainCfg {
        let mut c = TrainCfg::new(epochs, 16, lr, seed);
        c.schedule = LrSchedule::StepDecay { milestones: vec![0.5], factor: 0.2 };
        c.paper_d = 1_000_000;
        c.threads = 4;
        c
    }

    #[test]
    fn sgd_learns_the_synthetic_mixture() {
        let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 2048, 512, 1.2, 0.8, 0.0, 3);
        let m = Mlp::new(16, 32, 10);
        let init = m.init(1);
        let mut opt = OptSpec::Sgd.build(&init, 4, 0.9, 7);
        let rec = train_classifier(&m, &tr, &te, opt.as_mut(), &quick_cfg(8, 0.1, 3));
        assert!(!rec.diverged);
        assert!(rec.final_acc() > 0.8, "acc={}", rec.final_acc());
        // curves monotone-ish: bits and seconds strictly increasing
        for w in rec.points.windows(2) {
            assert!(w[1].cum_bits > w[0].cum_bits);
            assert!(w[1].cum_seconds > w[0].cum_seconds);
        }
    }

    #[test]
    fn cser_matches_sgd_accuracy_at_moderate_compression() {
        let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 2048, 512, 1.2, 0.8, 0.0, 4);
        let m = Mlp::new(16, 32, 10);
        let init = m.init(2);
        let cfg = quick_cfg(8, 0.1, 4);
        let mut sgd = OptSpec::Sgd.build(&init, 4, 0.9, 7);
        let acc_sgd = train_classifier(&m, &tr, &te, sgd.as_mut(), &cfg).final_acc();
        let mut cser = OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 }.build(&init, 4, 0.9, 7);
        let acc_cser = train_classifier(&m, &tr, &te, cser.as_mut(), &cfg).final_acc();
        assert!(acc_cser > acc_sgd - 0.08, "sgd={acc_sgd} cser={acc_cser}");
    }

    #[test]
    fn cser_communicates_fewer_bits_than_sgd() {
        let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 1024, 256, 1.2, 0.8, 0.0, 5);
        let m = Mlp::new(16, 32, 10);
        let init = m.init(2);
        let cfg = quick_cfg(3, 0.1, 5);
        let mut sgd = OptSpec::Sgd.build(&init, 4, 0.9, 7);
        let bits_sgd = train_classifier(&m, &tr, &te, sgd.as_mut(), &cfg)
            .points
            .last()
            .unwrap()
            .cum_bits;
        let mut cser = OptSpec::Cser { rc1: 8.0, rc2: 64.0, h: 8 }.build(&init, 4, 0.9, 7);
        let bits_cser = train_classifier(&m, &tr, &te, cser.as_mut(), &cfg)
            .points
            .last()
            .unwrap()
            .cum_bits;
        let ratio = bits_sgd / bits_cser;
        assert!(ratio > 16.0, "only {ratio:.1}x fewer bits");
    }

    #[test]
    fn threaded_backend_trains_like_in_process() {
        // Parallel-trainer mode: the same CSER run over real threaded
        // collectives must land within a small accuracy band of the
        // in-process reference (GRBS rides the ring, so trajectories agree
        // only up to f32 reduction order — not bit-exactly).
        let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 1024, 256, 1.2, 0.8, 0.0, 7);
        let m = Mlp::new(16, 32, 10);
        let init = m.init(4);
        let spec = OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 };
        let mut cfg = quick_cfg(4, 0.1, 7);
        let mut opt = spec.build(&init, 4, 0.9, 7);
        let acc_inproc = train_classifier(&m, &tr, &te, opt.as_mut(), &cfg).final_acc();
        cfg.backend = crate::transport::Backend::Threaded;
        let mut opt = spec.build(&init, 4, 0.9, 7);
        let acc_threaded = train_classifier(&m, &tr, &te, opt.as_mut(), &cfg).final_acc();
        assert!(
            (acc_inproc - acc_threaded).abs() < 0.05,
            "in-process {acc_inproc} vs threaded {acc_threaded}"
        );
    }

    #[test]
    fn resident_backend_trains_like_in_process() {
        // Worker-resident mode: persistent worker threads drive their own
        // gradient→sync→apply loop over the threaded wire collectives; the
        // run must land in the same accuracy band as the central reference,
        // and communicate a comparable number of accounted bits.
        let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 1024, 256, 1.2, 0.8, 0.0, 9);
        let m = Mlp::new(16, 32, 10);
        let init = m.init(5);
        let spec = OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 };
        let mut cfg = quick_cfg(4, 0.1, 9);
        let mut opt = spec.build(&init, 4, 0.9, 9);
        let rec_central = train_classifier(&m, &tr, &te, opt.as_mut(), &cfg);
        cfg.backend = crate::transport::Backend::Resident;
        let mut opt = spec.build(&init, 4, 0.9, 9);
        let rec_res = train_classifier(&m, &tr, &te, opt.as_mut(), &cfg);
        assert!(!rec_res.diverged);
        assert!(
            (rec_central.final_acc() - rec_res.final_acc()).abs() < 0.06,
            "central {} vs resident {}",
            rec_central.final_acc(),
            rec_res.final_acc()
        );
        let b_central = rec_central.points.last().unwrap().cum_bits;
        let b_res = rec_res.points.last().unwrap().cum_bits;
        let ratio = b_res / b_central;
        assert!((0.5..2.0).contains(&ratio), "bit accounting drifted: {ratio}");
    }

    #[test]
    fn bucketed_pipeline_trains_like_whole_vector() {
        // Bucketing changes the compressor schedule (per-bucket ratios), so
        // trajectories differ from the whole-vector run — but training must
        // land in the same accuracy band, and the central-bucketed and
        // resident-pipelined runs of the *same* schedule must account the
        // identical number of bits.
        let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 1024, 256, 1.2, 0.8, 0.0, 11);
        let m = Mlp::new(16, 32, 10);
        let init = m.init(6);
        let spec = OptSpec::Cser { rc1: 2.0, rc2: 4.0, h: 2 };
        let mut cfg = quick_cfg(4, 0.1, 11);
        let mut opt = spec.build(&init, 4, 0.9, 11);
        let acc_whole = train_classifier(&m, &tr, &te, opt.as_mut(), &cfg).final_acc();
        cfg.buckets = 3;
        let mut opt = spec.build(&init, 4, 0.9, 11);
        let rec_bucketed = train_classifier(&m, &tr, &te, opt.as_mut(), &cfg);
        assert!(!rec_bucketed.diverged);
        assert!(
            (acc_whole - rec_bucketed.final_acc()).abs() < 0.10,
            "whole {acc_whole} vs bucketed {}",
            rec_bucketed.final_acc()
        );
        cfg.backend = crate::transport::Backend::Resident;
        let mut opt = spec.build(&init, 4, 0.9, 11);
        let rec_res = train_classifier(&m, &tr, &te, opt.as_mut(), &cfg);
        assert!(!rec_res.diverged);
        assert!(
            (rec_bucketed.final_acc() - rec_res.final_acc()).abs() < 0.06,
            "central-bucketed {} vs resident-pipelined {}",
            rec_bucketed.final_acc(),
            rec_res.final_acc()
        );
        // Accounting is pipeline-invariant: same schedule, same bits.
        assert_eq!(
            rec_bucketed.points.last().unwrap().cum_bits,
            rec_res.points.last().unwrap().cum_bits,
            "bucketed accounting drifted between central and resident"
        );
    }

    #[test]
    fn chaos_matrix_parses_and_validates() {
        let spec = ChaosSpec::parse(
            "kill:1@5,slow:2:40,drop:3:0.25,delay:2:10:5,flap:4@8:250",
        )
        .unwrap();
        assert_eq!(spec.kill, vec![(1, 5)]);
        assert_eq!(spec.slow, vec![(2, 40)]);
        assert_eq!(spec.drop, vec![(3, 0.25)]);
        assert_eq!(spec.delay, vec![(2, 10, 5)]);
        assert_eq!(spec.flap, vec![(4, 8, 250)]);
        assert_eq!(spec.kill_step(1), Some(5));
        assert_eq!(spec.kill_step(4), Some(8), "flap's kill half counts as a death");
        assert_eq!(spec.drop_prob(3), Some(0.25));
        assert_eq!(spec.delay_ms(2), Some((10, 5)));
        assert_eq!(spec.flap(4), Some((8, 250)));
        let mut ranks: Vec<usize> = spec.ranks().collect();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![1, 2, 2, 3, 4]);
        // In-budget plans validate; out-of-budget steps are launch errors.
        spec.validate(10).unwrap();
        assert!(spec.validate(8).unwrap_err().contains("flap:4@8"));
        assert!(spec.validate(5).unwrap_err().contains("kill:1@5"));
        assert!(ChaosSpec::parse("kill:2@3,flap:2@7:100")
            .unwrap()
            .validate(10)
            .unwrap_err()
            .contains("2 times"));
    }

    #[test]
    fn chaos_matrix_rejects_malformed_directives() {
        // Rank 0 is the control plane: kill/drop/flap on it are refused
        // without --failover ...
        assert!(ChaosSpec::parse("kill:0@3").is_err());
        assert!(ChaosSpec::parse("drop:0:0.5").is_err());
        assert!(ChaosSpec::parse("flap:0@3:100").is_err());
        // ... unlocked with it (the successor absorbs the leader's death) ...
        let spec = ChaosSpec::parse_with("kill:0@3,drop:0:0.5,flap:0@4:100", true).unwrap();
        assert_eq!(spec.kill, vec![(0, 3)]);
        assert_eq!(spec.drop, vec![(0, 0.5)]);
        assert_eq!(spec.flap, vec![(0, 4, 100)]);
        // ... while shape and range errors stay errors either way.
        assert!(ChaosSpec::parse_with("drop:0:1.5", true).is_err());
        // slow/delay on rank 0 are legal even without failover (latency,
        // not loss).
        assert!(ChaosSpec::parse("slow:0:20,delay:0:5:0").is_ok());
        // Probability range and shape errors are parse-time.
        assert!(ChaosSpec::parse("drop:2:1.5").unwrap_err().contains("outside [0, 1]"));
        assert!(ChaosSpec::parse("drop:2:-0.1").is_err());
        assert!(ChaosSpec::parse("delay:2:10").is_err(), "delay wants rank:ms:jitter");
        assert!(ChaosSpec::parse("flap:2@5").is_err(), "flap wants rank@step:downtime");
        assert!(ChaosSpec::parse("teleport:2@5").is_err());
    }

    #[test]
    fn divergence_is_detected() {
        let (tr, te) = ClassDataset::gaussian_mixture(10, 16, 512, 128, 1.2, 0.8, 0.0, 6);
        let m = Mlp::new(16, 32, 10);
        let init = m.init(3);
        let mut opt = OptSpec::Sgd.build(&init, 2, 0.9, 7);
        let rec = train_classifier(&m, &tr, &te, opt.as_mut(), &quick_cfg(10, 50.0, 6));
        assert!(rec.diverged);
        assert!(rec.final_acc().is_nan());
    }
}
