//! Observability: per-rank phase tracing and wire-level comm metrics.
//!
//! CSER's claims are statements about *where wall time goes* — how much
//! exchange the pipeline hides, how long ranks block on slow peers,
//! whether compression cost eats the bits it saves.  This layer measures
//! that directly, under two hard contracts:
//!
//! * **zero overhead when disabled** — every span site checks the
//!   runtime flag once (`recorder::enabled`, one relaxed load) and reads
//!   no timestamp when it is off;
//! * **zero allocation when enabled** — rings are preallocated at
//!   thread registration; steady-state recording is two atomics and a
//!   32-byte store, so the counting-allocator pin in
//!   `rust/tests/hotpath_alloc.rs` holds with tracing on.
//!
//! Submodules: [`phase`] (the taxonomy), [`recorder`] (per-thread
//! lock-free rings + the `Span` guard), [`stats`] (fixed-bin histogram
//! folds), [`export`] (per-rank JSONL, merged Chrome trace JSON, the
//! `cser trace` summary), [`metrics`] (the live telemetry plane: the
//! run-wide counter/gauge/histogram registry, delta snapshots shipped to
//! rank 0 as `Tag::Metrics` frames, and the Prometheus/JSON exposition
//! server behind `cser launch --metrics-addr` / `cser top`).  Transports
//! keep [`PeerCounters`] — frames, payload bits, blocked-send time per
//! remote rank — which ride along in the JSONL meta line and are mirrored
//! into the metrics registry at round boundaries.
//!
//! Typical wiring: `set_enabled(true)` + `register_thread("main")` at
//! run start, `Span::enter(Phase::X)` guards in the hot paths,
//! `snapshot_all()` + `export::write_rank_jsonl` at run end, then
//! `cser trace summarize --trace <dir>` to merge and summarize.

pub mod export;
pub mod metrics;
pub mod phase;
pub mod recorder;
pub mod stats;

pub use phase::Phase;
pub use recorder::{
    enabled, now_ns, record_counter, register_thread, reset, set_enabled, snapshot_all, Event,
    PeerCounters, RingSnapshot, Span, NO_ARG,
};
pub use stats::PhaseStats;
