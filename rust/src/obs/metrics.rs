//! Run-wide metrics registry and the live telemetry plane (DESIGN.md §9).
//!
//! Where [`super::recorder`] answers "where did wall time go" *after* a
//! run, this module answers "is the algorithm healthy" *during* one: how
//! fast each rank steps, how large the error-reset residual is before and
//! after each reset, what fraction of dense bits the compressors actually
//! ship, who is censored, and how much backpressure every link carries.
//!
//! The registry follows the recorder's two hard contracts:
//!
//! * **one relaxed load when disabled** — every recording call checks
//!   [`enabled`] first and touches nothing else when it is off;
//! * **no allocation when enabled** — all storage is `static` atomics
//!   (counters, gauge bit-patterns, one log2 step-duration histogram
//!   reusing [`super::stats::PhaseStats`]'s binning, and a
//!   `[[u64; 5]; 64]` lane array mirroring the transports'
//!   [`PeerCounters`]).  `rust/tests/hotpath_alloc.rs` pins both.
//!
//! The plane on top of the registry:
//!
//! * [`DeltaTracker::snapshot`] turns the registry into a
//!   [`MetricsSnapshot`] of *deltas* (counters/histogram) and *absolutes*
//!   (gauges, carried with a per-rank sequence number);
//! * snapshots travel rank → the leader as `Tag::Metrics` frames
//!   ([`encode_snapshot`]/[`decode_snapshot`]: plain u64 words, so the
//!   frame is self-describing and byte-exact);
//! * the leader folds them into a [`FleetView`] — counter deltas add
//!   (order-independent and associative over disjoint snapshot sets; see
//!   [`FleetView::merge`]/[`FleetView::absorb`]), gauges resolve by
//!   highest sequence number;
//! * under `--failover` the whole view is replicated to the leader's
//!   successor each boundary ([`encode_fleet`]/[`decode_fleet`] inside
//!   the membership layer's control-state frame), so a handover resumes
//!   the merged counters instead of restarting them from zero;
//! * [`spawn_exposition_server`] serves the view over a std
//!   `TcpListener` as Prometheus text (`GET /metrics`) and as a
//!   `cser-metrics/v1` JSON document (anything else); `cser top` polls
//!   the JSON endpoint.

use super::stats::{PhaseStats, BINS};
use super::PeerCounters;
use crate::transport::wire::WireMsg;
use crate::util::json::JsonWriter;
use std::fmt::Write as _;
use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Largest fleet a snapshot can describe (mirrors `membership::MAX_RANKS`;
/// the per-peer lane array is sized by it).
pub const MAX_PEERS: usize = 64;

/// Fields per peer lane, in [`PeerCounters`] declaration order.
const PEER_FIELDS: usize = 6;

/// Monotone counters.  Static IDs: the discriminant is the storage index,
/// so recording is a single `fetch_add` into a fixed slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Optimizer steps executed by this rank.
    StepsTotal = 0,
    /// Steps on which a data-plane collective ran (`RoundStats::synced`).
    RoundsSynced = 1,
    /// Accounted per-worker gradient-path upload bits.
    GradBits = 2,
    /// Accounted per-worker model/error-path upload bits.
    ModelBits = 3,
    /// Dense reference bits (32·d per synced round): the denominator of
    /// the compressed-bits ratio.
    DenseRefBits = 4,
    /// Uploads this worker dropped under the censoring cadence.
    CensoredUploads = 5,
    /// Error-reset rounds executed (C1 fired).
    ErrorResets = 6,
}

impl Counter {
    pub const COUNT: usize = 7;
    pub const ALL: [Counter; Counter::COUNT] = [
        Counter::StepsTotal,
        Counter::RoundsSynced,
        Counter::GradBits,
        Counter::ModelBits,
        Counter::DenseRefBits,
        Counter::CensoredUploads,
        Counter::ErrorResets,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Counter::StepsTotal => "steps_total",
            Counter::RoundsSynced => "rounds_synced_total",
            Counter::GradBits => "grad_bits_total",
            Counter::ModelBits => "model_bits_total",
            Counter::DenseRefBits => "dense_ref_bits_total",
            Counter::CensoredUploads => "censored_uploads_total",
            Counter::ErrorResets => "error_resets_total",
        }
    }
}

/// Last-value gauges (f64 bit patterns in the registry; shipped absolute,
/// resolved by sequence number on merge).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// ℓ2 norm of this rank's latest local gradient.
    GradNorm = 0,
    /// ℓ2 norm of the residual error immediately before the last reset.
    ResidualNormPre = 1,
    /// ℓ2 norm of the residual error immediately after the last reset.
    ResidualNormPost = 2,
    /// Live ranks under the current membership epoch.
    LiveRanks = 3,
    /// Current membership epoch id.
    EpochId = 4,
    /// Censor events absorbed so far (`membership::Elastic`): deaths plus
    /// deadline misses, mirrored from the control plane each boundary.
    CensorEvents = 5,
}

impl Gauge {
    pub const COUNT: usize = 6;
    pub const ALL: [Gauge; Gauge::COUNT] = [
        Gauge::GradNorm,
        Gauge::ResidualNormPre,
        Gauge::ResidualNormPost,
        Gauge::LiveRanks,
        Gauge::EpochId,
        Gauge::CensorEvents,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Gauge::GradNorm => "grad_norm",
            Gauge::ResidualNormPre => "residual_norm_pre",
            Gauge::ResidualNormPost => "residual_norm_post",
            Gauge::LiveRanks => "live_ranks",
            Gauge::EpochId => "epoch_id",
            Gauge::CensorEvents => "censor_events",
        }
    }
}

// --- the registry -----------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
/// `obs::now_ns` at the moment the registry was enabled (uptime base).
static ENABLED_AT_NS: AtomicU64 = AtomicU64::new(0);
static COUNTERS: [AtomicU64; Counter::COUNT] =
    [const { AtomicU64::new(0) }; Counter::COUNT];
static GAUGES: [AtomicU64; Gauge::COUNT] = [const { AtomicU64::new(0) }; Gauge::COUNT];
static HIST_COUNT: AtomicU64 = AtomicU64::new(0);
static HIST_TOTAL_NS: AtomicU64 = AtomicU64::new(0);
static HIST_MIN_NS: AtomicU64 = AtomicU64::new(u64::MAX);
static HIST_MAX_NS: AtomicU64 = AtomicU64::new(0);
static HIST_BINS: [AtomicU64; BINS] = [const { AtomicU64::new(0) }; BINS];
/// Mirrored transport [`PeerCounters`], one lane of [`PEER_FIELDS`] words
/// per remote rank ([`sync_from_peers`] stores absolutes).
static PEER_LANES: [[AtomicU64; PEER_FIELDS]; MAX_PEERS] =
    [const { [const { AtomicU64::new(0) }; PEER_FIELDS] }; MAX_PEERS];
static N_PEERS: AtomicU64 = AtomicU64::new(0);

/// Is metrics recording on?  One relaxed load — the only cost every
/// instrumentation site pays when the registry is disabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the registry on/off.  Enabling pins the shared observability
/// epoch (so `obs::now_ns` is valid even when tracing itself stays off)
/// and records the uptime base.
pub fn set_enabled(on: bool) {
    if on {
        super::recorder::pin_epoch();
        ENABLED_AT_NS.store(super::now_ns(), Ordering::Relaxed);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Milliseconds since the registry was (last) enabled; 0 while disabled.
pub fn uptime_ms() -> u64 {
    if !enabled() {
        return 0;
    }
    super::now_ns().saturating_sub(ENABLED_AT_NS.load(Ordering::Relaxed)) / 1_000_000
}

/// Add `by` to a counter.  No-op (one relaxed load) while disabled.
#[inline]
pub fn inc(c: Counter, by: u64) {
    if !enabled() {
        return;
    }
    COUNTERS[c as usize].fetch_add(by, Ordering::Relaxed);
}

/// Set a gauge.  No-op (one relaxed load) while disabled.
#[inline]
pub fn gauge_set(g: Gauge, v: f64) {
    if !enabled() {
        return;
    }
    GAUGES[g as usize].store(v.to_bits(), Ordering::Relaxed);
}

/// Record one step duration into the log2 histogram (bins shared with
/// [`PhaseStats`]).  No-op (one relaxed load) while disabled.
#[inline]
pub fn observe_step_ns(dur_ns: u64) {
    if !enabled() {
        return;
    }
    HIST_COUNT.fetch_add(1, Ordering::Relaxed);
    HIST_TOTAL_NS.fetch_add(dur_ns, Ordering::Relaxed);
    HIST_MIN_NS.fetch_min(dur_ns, Ordering::Relaxed);
    HIST_MAX_NS.fetch_max(dur_ns, Ordering::Relaxed);
    HIST_BINS[PhaseStats::bin_index(dur_ns)].fetch_add(1, Ordering::Relaxed);
}

/// Mirror the transports' per-peer wire counters into the registry
/// (absolute stores; the transports keep cumulative counts).  Called at
/// round boundaries, never inside a collective.
pub fn sync_from_peers(peers: &[PeerCounters]) {
    if !enabled() {
        return;
    }
    let n = peers.len().min(MAX_PEERS);
    N_PEERS.store(n as u64, Ordering::Relaxed);
    for (lane, c) in PEER_LANES.iter().zip(peers.iter().take(n)) {
        lane[0].store(c.frames_sent, Ordering::Relaxed);
        lane[1].store(c.payload_bits_sent, Ordering::Relaxed);
        lane[2].store(c.blocked_send_ns, Ordering::Relaxed);
        lane[3].store(c.frames_received, Ordering::Relaxed);
        lane[4].store(c.payload_bits_received, Ordering::Relaxed);
        lane[5].store(c.stale_discards, Ordering::Relaxed);
    }
}

/// Read the mirrored per-peer counters back out (one slot per remote rank
/// as of the last [`sync_from_peers`]) — the input `membership::
/// censor_seed_from_metrics` aggregates over.
pub fn peer_counters() -> Vec<PeerCounters> {
    let n = N_PEERS.load(Ordering::Relaxed) as usize;
    PEER_LANES
        .iter()
        .take(n)
        .map(|lane| PeerCounters {
            frames_sent: lane[0].load(Ordering::Relaxed),
            payload_bits_sent: lane[1].load(Ordering::Relaxed),
            blocked_send_ns: lane[2].load(Ordering::Relaxed),
            frames_received: lane[3].load(Ordering::Relaxed),
            payload_bits_received: lane[4].load(Ordering::Relaxed),
            stale_discards: lane[5].load(Ordering::Relaxed),
        })
        .collect()
}

/// Zero the whole registry (counters, gauges, histogram, peer lanes).
/// Leaves the enabled flag alone; callers must ensure recording threads
/// are quiescent (between runs / bench sections).
pub fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
    for g in &GAUGES {
        g.store(0, Ordering::Relaxed);
    }
    HIST_COUNT.store(0, Ordering::Relaxed);
    HIST_TOTAL_NS.store(0, Ordering::Relaxed);
    HIST_MIN_NS.store(u64::MAX, Ordering::Relaxed);
    HIST_MAX_NS.store(0, Ordering::Relaxed);
    for b in &HIST_BINS {
        b.store(0, Ordering::Relaxed);
    }
    for lane in &PEER_LANES {
        for f in lane {
            f.store(0, Ordering::Relaxed);
        }
    }
    N_PEERS.store(0, Ordering::Relaxed);
}

// --- snapshots and deltas ---------------------------------------------------

/// Step-duration histogram section of a snapshot or view: `count`,
/// `total_ns`, and the bins are deltas/sums; `min_ns`/`max_ns` are
/// absolutes folded by min/max (`u64::MAX`/0 when empty).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistDelta {
    pub count: u64,
    pub total_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
    pub bins: [u64; BINS],
}

impl HistDelta {
    pub fn empty() -> HistDelta {
        HistDelta { count: 0, total_ns: 0, min_ns: u64::MAX, max_ns: 0, bins: [0; BINS] }
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64
        }
    }

    /// Histogram quantile with [`PhaseStats`] semantics (bin midpoint of
    /// the `ceil(q·count)`-th sample, clamped to the observed range).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            cum += c;
            if cum >= target {
                let lo = PhaseStats::bin_lo(i);
                let hi =
                    if i + 1 < BINS { PhaseStats::bin_lo(i + 1) } else { self.max_ns.max(lo) };
                return (lo + (hi - lo) / 2).clamp(self.min_ns, self.max_ns);
            }
        }
        self.max_ns
    }
}

/// One rank's registry delta since its previous snapshot: counters and
/// the histogram ship as non-negative deltas (so merged totals never
/// regress), gauges ship absolute with the sequence number deciding which
/// snapshot's gauges win a merge.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub rank: u32,
    /// Per-rank monotone sequence number (1, 2, ...).
    pub seq: u64,
    pub uptime_ms: u64,
    pub counters: [u64; Counter::COUNT],
    pub gauges: [f64; Gauge::COUNT],
    pub hist: HistDelta,
    /// Per-peer wire counter deltas, indexed by remote rank.
    pub peers: Vec<PeerCounters>,
}

fn peer_delta(cur: &PeerCounters, last: &PeerCounters) -> PeerCounters {
    PeerCounters {
        frames_sent: cur.frames_sent.saturating_sub(last.frames_sent),
        payload_bits_sent: cur.payload_bits_sent.saturating_sub(last.payload_bits_sent),
        blocked_send_ns: cur.blocked_send_ns.saturating_sub(last.blocked_send_ns),
        frames_received: cur.frames_received.saturating_sub(last.frames_received),
        payload_bits_received: cur
            .payload_bits_received
            .saturating_sub(last.payload_bits_received),
        stale_discards: cur.stale_discards.saturating_sub(last.stale_discards),
    }
}

fn peer_add(acc: &mut PeerCounters, d: &PeerCounters) {
    acc.frames_sent += d.frames_sent;
    acc.payload_bits_sent += d.payload_bits_sent;
    acc.blocked_send_ns += d.blocked_send_ns;
    acc.frames_received += d.frames_received;
    acc.payload_bits_received += d.payload_bits_received;
    acc.stale_discards += d.stale_discards;
}

/// Per-rank shipping state: remembers the registry values at the last
/// snapshot so each [`Tag::Metrics`] frame carries only the delta.
/// Owned by the trainer loop — the registry itself stays stateless.
#[derive(Clone, Debug, Default)]
pub struct DeltaTracker {
    seq: u64,
    counters: [u64; Counter::COUNT],
    hist_count: u64,
    hist_total_ns: u64,
    bins: [u64; BINS],
    peers: Vec<PeerCounters>,
}

impl DeltaTracker {
    pub fn new() -> DeltaTracker {
        DeltaTracker {
            seq: 0,
            counters: [0; Counter::COUNT],
            hist_count: 0,
            hist_total_ns: 0,
            bins: [0; BINS],
            peers: Vec::new(),
        }
    }

    /// Read the registry and produce this rank's next delta snapshot.
    pub fn snapshot(&mut self, rank: usize) -> MetricsSnapshot {
        self.seq += 1;
        let mut counters = [0u64; Counter::COUNT];
        for (i, out) in counters.iter_mut().enumerate() {
            let cur = COUNTERS[i].load(Ordering::Relaxed);
            *out = cur.saturating_sub(self.counters[i]);
            self.counters[i] = cur;
        }
        let gauges: [f64; Gauge::COUNT] =
            std::array::from_fn(|i| f64::from_bits(GAUGES[i].load(Ordering::Relaxed)));
        let count = HIST_COUNT.load(Ordering::Relaxed);
        let total = HIST_TOTAL_NS.load(Ordering::Relaxed);
        let mut bins = [0u64; BINS];
        for (i, out) in bins.iter_mut().enumerate() {
            let cur = HIST_BINS[i].load(Ordering::Relaxed);
            *out = cur.saturating_sub(self.bins[i]);
            self.bins[i] = cur;
        }
        let hist = HistDelta {
            count: count.saturating_sub(self.hist_count),
            total_ns: total.saturating_sub(self.hist_total_ns),
            min_ns: HIST_MIN_NS.load(Ordering::Relaxed),
            max_ns: HIST_MAX_NS.load(Ordering::Relaxed),
            bins,
        };
        self.hist_count = count;
        self.hist_total_ns = total;
        let cur_peers = peer_counters();
        self.peers.resize(cur_peers.len(), PeerCounters::default());
        let peers: Vec<PeerCounters> =
            cur_peers.iter().zip(self.peers.iter()).map(|(c, l)| peer_delta(c, l)).collect();
        self.peers = cur_peers;
        MetricsSnapshot {
            rank: rank as u32,
            seq: self.seq,
            uptime_ms: uptime_ms(),
            counters,
            gauges,
            hist,
            peers,
        }
    }
}

// --- wire format ------------------------------------------------------------

/// Fixed word count of a snapshot frame before the per-peer lanes:
/// rank, seq, uptime, counters, gauges, 4 histogram scalars, bins,
/// peer count.
const SNAP_FIXED_WORDS: usize = 3 + Counter::COUNT + Gauge::COUNT + 4 + BINS + 1;

/// Serialize a snapshot as a `Tag::Metrics` frame payload.  Every field
/// is one little-endian u64 word (gauges as f64 bit patterns), so
/// `bit_len` is exactly `64 · (fixed + 6·n_peers)`.
pub fn encode_snapshot(s: &MetricsSnapshot) -> WireMsg {
    let mut words = Vec::with_capacity(SNAP_FIXED_WORDS + PEER_FIELDS * s.peers.len());
    words.push(s.rank as u64);
    words.push(s.seq);
    words.push(s.uptime_ms);
    words.extend_from_slice(&s.counters);
    words.extend(s.gauges.iter().map(|g| g.to_bits()));
    words.push(s.hist.count);
    words.push(s.hist.total_ns);
    words.push(s.hist.min_ns);
    words.push(s.hist.max_ns);
    words.extend_from_slice(&s.hist.bins);
    words.push(s.peers.len() as u64);
    for p in &s.peers {
        words.push(p.frames_sent);
        words.push(p.payload_bits_sent);
        words.push(p.blocked_send_ns);
        words.push(p.frames_received);
        words.push(p.payload_bits_received);
        words.push(p.stale_discards);
    }
    let bit_len = words.len() as u64 * 64;
    WireMsg { words, bit_len }
}

/// Parse a `Tag::Metrics` frame back into a snapshot, validating the
/// declared peer count against the frame length.
pub fn decode_snapshot(m: &WireMsg) -> Result<MetricsSnapshot, String> {
    let w = &m.words;
    if m.bit_len % 64 != 0 || w.len() < SNAP_FIXED_WORDS {
        return Err(format!("metrics frame too short: {} bits", m.bit_len));
    }
    let mut i = 0usize;
    let mut next = || {
        let v = w[i];
        i += 1;
        v
    };
    let rank = next() as u32;
    let seq = next();
    let uptime_ms = next();
    let mut counters = [0u64; Counter::COUNT];
    for c in counters.iter_mut() {
        *c = next();
    }
    let mut gauges = [0f64; Gauge::COUNT];
    for g in gauges.iter_mut() {
        *g = f64::from_bits(next());
    }
    let count = next();
    let total_ns = next();
    let min_ns = next();
    let max_ns = next();
    let mut bins = [0u64; BINS];
    for b in bins.iter_mut() {
        *b = next();
    }
    let n_peers = next() as usize;
    if n_peers > MAX_PEERS || w.len() != SNAP_FIXED_WORDS + PEER_FIELDS * n_peers {
        return Err(format!(
            "metrics frame declares {n_peers} peers but carries {} words",
            w.len()
        ));
    }
    let mut peers = Vec::with_capacity(n_peers);
    for _ in 0..n_peers {
        peers.push(PeerCounters {
            frames_sent: next(),
            payload_bits_sent: next(),
            blocked_send_ns: next(),
            frames_received: next(),
            payload_bits_received: next(),
            stale_discards: next(),
        });
    }
    Ok(MetricsSnapshot {
        rank,
        seq,
        uptime_ms,
        counters,
        gauges,
        hist: HistDelta { count, total_ns, min_ns, max_ns, bins },
        peers,
    })
}

// --- the fleet view ---------------------------------------------------------

/// One rank's merged state inside a [`FleetView`]: counters/histogram are
/// running sums of the merged deltas, gauges are the values from the
/// highest-sequence snapshot seen.
#[derive(Clone, Debug, PartialEq)]
pub struct RankView {
    pub seq: u64,
    pub uptime_ms: u64,
    pub counters: [u64; Counter::COUNT],
    pub gauges: [f64; Gauge::COUNT],
    pub hist: HistDelta,
    pub peers: Vec<PeerCounters>,
}

impl RankView {
    fn empty() -> RankView {
        RankView {
            seq: 0,
            uptime_ms: 0,
            counters: [0; Counter::COUNT],
            gauges: [0.0; Gauge::COUNT],
            hist: HistDelta::empty(),
            peers: Vec::new(),
        }
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        self.gauges[g as usize]
    }

    /// Mean steps per second over this rank's uptime.
    pub fn step_rate(&self) -> f64 {
        if self.uptime_ms == 0 {
            0.0
        } else {
            self.counter(Counter::StepsTotal) as f64 / (self.uptime_ms as f64 / 1000.0)
        }
    }

    /// Mean accounted upload bits per second over this rank's uptime.
    pub fn bits_per_s(&self) -> f64 {
        if self.uptime_ms == 0 {
            0.0
        } else {
            (self.counter(Counter::GradBits) + self.counter(Counter::ModelBits)) as f64
                / (self.uptime_ms as f64 / 1000.0)
        }
    }

    /// Total blocked-send nanoseconds across this rank's links — the
    /// aggregated backpressure gauge the adaptive censor threshold reads.
    pub fn backpressure_ns(&self) -> u64 {
        self.peers.iter().map(|p| p.blocked_send_ns).sum()
    }
}

/// Rank 0's merged picture of the fleet, fed by [`FleetView::merge`] and
/// served by the exposition endpoints.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetView {
    /// Job label carried into every Prometheus sample (the optimizer
    /// name in practice — escaped, since plan names contain punctuation).
    pub job: String,
    ranks: Vec<Option<RankView>>,
}

impl FleetView {
    pub fn new(job: &str, n: usize) -> FleetView {
        FleetView { job: job.to_string(), ranks: vec![None; n] }
    }

    /// Fold one delta snapshot in.  Counter/histogram deltas add, so the
    /// result is independent of arrival order and associative over
    /// disjoint snapshot sets; gauges take the highest-`seq` snapshot's
    /// values (sequence numbers are per-rank monotone, so "latest wins"
    /// is well-defined without wall clocks).
    pub fn merge(&mut self, s: &MetricsSnapshot) {
        let r = s.rank as usize;
        if r >= self.ranks.len() {
            self.ranks.resize(r + 1, None);
        }
        let v = self.ranks[r].get_or_insert_with(RankView::empty);
        for (acc, d) in v.counters.iter_mut().zip(s.counters.iter()) {
            *acc += d;
        }
        v.hist.count += s.hist.count;
        v.hist.total_ns += s.hist.total_ns;
        v.hist.min_ns = v.hist.min_ns.min(s.hist.min_ns);
        v.hist.max_ns = v.hist.max_ns.max(s.hist.max_ns);
        for (acc, d) in v.hist.bins.iter_mut().zip(s.hist.bins.iter()) {
            *acc += d;
        }
        if s.peers.len() > v.peers.len() {
            v.peers.resize(s.peers.len(), PeerCounters::default());
        }
        for (acc, d) in v.peers.iter_mut().zip(s.peers.iter()) {
            peer_add(acc, d);
        }
        if s.seq >= v.seq {
            v.gauges = s.gauges;
        }
        v.seq = v.seq.max(s.seq);
        v.uptime_ms = v.uptime_ms.max(s.uptime_ms);
    }

    /// Fold another view in (hierarchical aggregation).  Correct only
    /// when the two views merged *disjoint* snapshot sets — counters add,
    /// gauges resolve by sequence number, exactly as [`merge`] would have
    /// produced from the union.
    ///
    /// [`merge`]: FleetView::merge
    pub fn absorb(&mut self, other: &FleetView) {
        if other.ranks.len() > self.ranks.len() {
            self.ranks.resize(other.ranks.len(), None);
        }
        for (slot, o) in self.ranks.iter_mut().zip(other.ranks.iter()) {
            let Some(o) = o else { continue };
            let v = slot.get_or_insert_with(RankView::empty);
            for (acc, d) in v.counters.iter_mut().zip(o.counters.iter()) {
                *acc += d;
            }
            v.hist.count += o.hist.count;
            v.hist.total_ns += o.hist.total_ns;
            v.hist.min_ns = v.hist.min_ns.min(o.hist.min_ns);
            v.hist.max_ns = v.hist.max_ns.max(o.hist.max_ns);
            for (acc, d) in v.hist.bins.iter_mut().zip(o.hist.bins.iter()) {
                *acc += d;
            }
            if o.peers.len() > v.peers.len() {
                v.peers.resize(o.peers.len(), PeerCounters::default());
            }
            for (acc, d) in v.peers.iter_mut().zip(o.peers.iter()) {
                peer_add(acc, d);
            }
            if o.seq >= v.seq {
                v.gauges = o.gauges;
            }
            v.seq = v.seq.max(o.seq);
            v.uptime_ms = v.uptime_ms.max(o.uptime_ms);
        }
    }

    /// Ranks that have reported at least one snapshot, ascending.
    pub fn ranks(&self) -> impl Iterator<Item = (usize, &RankView)> {
        self.ranks.iter().enumerate().filter_map(|(r, v)| v.as_ref().map(|v| (r, v)))
    }

    pub fn rank(&self, r: usize) -> Option<&RankView> {
        self.ranks.get(r).and_then(|v| v.as_ref())
    }

    /// Prometheus text exposition (text format 0.0.4): one family block
    /// per counter/gauge with `job`/`rank` labels, per-peer wire counters
    /// with an additional `peer` label, and the step-duration summary as
    /// derived gauges.
    pub fn prometheus_text(&self) -> String {
        let job = escape_label(&self.job);
        let mut s = String::new();
        for c in Counter::ALL {
            let _ = writeln!(s, "# TYPE cser_{} counter", c.name());
            for (r, v) in self.ranks() {
                let _ = writeln!(
                    s,
                    "cser_{}{{job=\"{job}\",rank=\"{r}\"}} {}",
                    c.name(),
                    v.counter(c)
                );
            }
        }
        for g in Gauge::ALL {
            let _ = writeln!(s, "# TYPE cser_{} gauge", g.name());
            for (r, v) in self.ranks() {
                let _ = writeln!(
                    s,
                    "cser_{}{{job=\"{job}\",rank=\"{r}\"}} {}",
                    g.name(),
                    v.gauge(g)
                );
            }
        }
        for (name, get) in [
            ("step_rate", RankView::step_rate as fn(&RankView) -> f64),
            ("bits_per_s", RankView::bits_per_s),
            ("step_p50_ns", |v: &RankView| v.hist.quantile(0.50) as f64),
            ("step_p99_ns", |v: &RankView| v.hist.quantile(0.99) as f64),
        ] {
            let _ = writeln!(s, "# TYPE cser_{name} gauge");
            for (r, v) in self.ranks() {
                let _ = writeln!(s, "cser_{name}{{job=\"{job}\",rank=\"{r}\"}} {}", get(v));
            }
        }
        for (f, get) in [
            ("frames_sent", |p: &PeerCounters| p.frames_sent),
            ("payload_bits_sent", |p: &PeerCounters| p.payload_bits_sent),
            ("blocked_send_ns", |p: &PeerCounters| p.blocked_send_ns),
            ("frames_received", |p: &PeerCounters| p.frames_received),
            ("payload_bits_received", |p: &PeerCounters| p.payload_bits_received),
            ("stale_discards", |p: &PeerCounters| p.stale_discards),
        ] {
            let _ = writeln!(s, "# TYPE cser_peer_{f}_total counter");
            for (r, v) in self.ranks() {
                for (peer, p) in v.peers.iter().enumerate() {
                    if peer == r {
                        continue; // self slot stays zero by construction
                    }
                    let _ = writeln!(
                        s,
                        "cser_peer_{f}_total{{job=\"{job}\",rank=\"{r}\",peer=\"{peer}\"}} {}",
                        get(p)
                    );
                }
            }
        }
        s
    }

    /// The `cser-metrics/v1` JSON document `cser top` polls.
    pub fn json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("schema").str("cser-metrics/v1");
        w.key("job").str(&self.job);
        w.key("ranks").begin_arr();
        for (r, v) in self.ranks() {
            w.begin_obj();
            w.key("rank").int(r as i64);
            w.key("seq").int(v.seq as i64);
            w.key("uptime_ms").int(v.uptime_ms as i64);
            w.key("step_rate").num(v.step_rate());
            w.key("bits_per_s").num(v.bits_per_s());
            w.key("step_p50_ns").int(v.hist.quantile(0.50) as i64);
            w.key("step_p99_ns").int(v.hist.quantile(0.99) as i64);
            w.key("backpressure_ns").int(v.backpressure_ns() as i64);
            w.key("counters").begin_obj();
            for c in Counter::ALL {
                w.key(c.name()).int(v.counter(c) as i64);
            }
            w.end_obj();
            w.key("gauges").begin_obj();
            for g in Gauge::ALL {
                w.key(g.name()).num(v.gauge(g));
            }
            w.end_obj();
            w.key("peers").begin_arr();
            for (peer, p) in v.peers.iter().enumerate() {
                if peer == r {
                    continue;
                }
                w.begin_obj();
                w.key("peer").int(peer as i64);
                w.key("frames_sent").int(p.frames_sent as i64);
                w.key("payload_bits_sent").int(p.payload_bits_sent as i64);
                w.key("blocked_send_ns").int(p.blocked_send_ns as i64);
                w.key("frames_received").int(p.frames_received as i64);
                w.key("payload_bits_received").int(p.payload_bits_received as i64);
                w.end_obj();
            }
            w.end_arr();
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
        w.finish()
    }
}

// --- control-state replication ----------------------------------------------

/// Serialize a [`FleetView`] into the opaque byte blob that rides the
/// membership layer's `Tag::ControlState` frame (DESIGN.md §10): the job
/// label, the rank-slot count, a presence mask, and one
/// [`encode_snapshot`]-format record per reporting rank.  The successor
/// rebuilds the view with [`decode_fleet`] so run-wide counters never
/// regress across a leader handover.
pub fn encode_fleet(view: &FleetView) -> Vec<u8> {
    let mut out = Vec::new();
    let job = view.job.as_bytes();
    out.extend_from_slice(&(job.len() as u64).to_le_bytes());
    out.extend_from_slice(job);
    out.extend_from_slice(&(view.ranks.len() as u64).to_le_bytes());
    let mut mask = 0u64;
    for (r, _) in view.ranks() {
        debug_assert!(r < MAX_PEERS, "fleet views are capped at {MAX_PEERS} ranks");
        mask |= 1u64 << r;
    }
    out.extend_from_slice(&mask.to_le_bytes());
    for (r, v) in view.ranks() {
        let snap = MetricsSnapshot {
            rank: r as u32,
            seq: v.seq,
            uptime_ms: v.uptime_ms,
            counters: v.counters,
            gauges: v.gauges,
            hist: v.hist.clone(),
            peers: v.peers.clone(),
        };
        let m = encode_snapshot(&snap);
        out.extend_from_slice(&(m.words.len() as u64).to_le_bytes());
        for w in &m.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    out
}

fn take_u64(bytes: &[u8], i: &mut usize) -> Result<u64, String> {
    let end = *i + 8;
    let b = bytes.get(*i..end).ok_or_else(|| "fleet blob truncated".to_string())?;
    *i = end;
    Ok(u64::from_le_bytes(b.try_into().unwrap()))
}

/// Rebuild a [`FleetView`] from its [`encode_fleet`] blob — the successor's
/// side of the handover.  Bit-exact: the decoded view compares equal to
/// the one the dead leader encoded.
pub fn decode_fleet(bytes: &[u8]) -> Result<FleetView, String> {
    let mut i = 0usize;
    let job_len = take_u64(bytes, &mut i)? as usize;
    if job_len > bytes.len().saturating_sub(i) {
        return Err(format!("fleet blob declares a {job_len}-byte job label"));
    }
    let job = std::str::from_utf8(&bytes[i..i + job_len])
        .map_err(|_| "fleet job label is not UTF-8".to_string())?
        .to_string();
    i += job_len;
    let n = take_u64(bytes, &mut i)? as usize;
    if n > MAX_PEERS {
        return Err(format!("fleet blob declares {n} rank slots (cap {MAX_PEERS})"));
    }
    let mask = take_u64(bytes, &mut i)?;
    let mut view = FleetView { job, ranks: vec![None; n] };
    for r in 0..MAX_PEERS as u32 {
        if (mask >> r) & 1 == 0 {
            continue;
        }
        let words = take_u64(bytes, &mut i)? as usize;
        if words > (bytes.len() - i) / 8 {
            return Err(format!("fleet blob rank {r} record overruns the blob"));
        }
        let mut w = Vec::with_capacity(words);
        for _ in 0..words {
            w.push(take_u64(bytes, &mut i)?);
        }
        let m = WireMsg { words: w, bit_len: words as u64 * 64 };
        let snap = decode_snapshot(&m)?;
        if snap.rank != r {
            return Err(format!("fleet blob rank {r} record names rank {}", snap.rank));
        }
        // Merging into an empty slot reconstructs the rank view exactly:
        // counters add from zero, gauges are taken (seq >= 0), min/max
        // fold against the empty sentinels.
        view.merge(&snap);
    }
    if i != bytes.len() {
        return Err(format!("fleet blob has {} trailing bytes", bytes.len() - i));
    }
    Ok(view)
}

/// Escape a Prometheus label value: backslash, double quote, newline.
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

// --- exposition server + poll client ----------------------------------------

/// Serve `view` over `addr` (e.g. `127.0.0.1:9090`) on a detached thread:
/// `GET /metrics` returns Prometheus text, any other path the
/// `cser-metrics/v1` JSON.  Minimal HTTP/1.0, connection-per-request —
/// this is a telemetry tap, not a web server.  Returns the bound address
/// (port 0 resolves to a real port).  The thread runs until process exit.
pub fn spawn_exposition_server(
    addr: &str,
    view: Arc<Mutex<FleetView>>,
) -> std::io::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::Builder::new().name("cser-metrics".into()).spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { continue };
            let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
            let mut buf = [0u8; 1024];
            let n = s.read(&mut buf).unwrap_or(0);
            let req = String::from_utf8_lossy(&buf[..n]);
            let path = req.split_whitespace().nth(1).unwrap_or("/json").to_string();
            let (body, ctype) = {
                let v = view.lock().expect("metrics view");
                if path.starts_with("/metrics") {
                    (v.prometheus_text(), "text/plain; version=0.0.4")
                } else {
                    (v.json(), "application/json")
                }
            };
            let _ = write!(
                s,
                "HTTP/1.0 200 OK\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n\
                 Connection: close\r\n\r\n{body}",
                body.len()
            );
        }
    })?;
    Ok(local)
}

/// One-shot HTTP/1.0 GET against an exposition server; returns the body.
/// Used by `cser top` and the smoke tests — std sockets only.
pub fn http_get(addr: &str, path: &str) -> Result<String, String> {
    let mut s = TcpStream::connect(addr).map_err(|e| format!("connecting {addr}: {e}"))?;
    let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
    write!(s, "GET {path} HTTP/1.0\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
        .map_err(|e| format!("sending request: {e}"))?;
    let mut buf = String::new();
    s.read_to_string(&mut buf).map_err(|e| format!("reading response: {e}"))?;
    match buf.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => Err("malformed HTTP response (no header terminator)".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::json::Json;
    use crate::util::prop::{forall, Gen};

    fn gen_snapshot(g: &mut Gen, rank: u32, seq: u64) -> MetricsSnapshot {
        let mut counters = [0u64; Counter::COUNT];
        for c in counters.iter_mut() {
            *c = g.rng.next_u64() % 1_000;
        }
        let gauges: [f64; Gauge::COUNT] =
            std::array::from_fn(|_| (g.rng.next_u64() % 4096) as f64 / 8.0);
        let mut bins = [0u64; BINS];
        let mut count = 0u64;
        for b in bins.iter_mut().take(12) {
            *b = g.rng.next_u64() % 5;
            count += *b;
        }
        let hist = if count == 0 {
            HistDelta::empty()
        } else {
            HistDelta {
                count,
                total_ns: count * (1 + g.rng.next_u64() % 100),
                min_ns: 1 + g.rng.next_u64() % 8,
                max_ns: 2_048 + g.rng.next_u64() % 100,
                bins,
            }
        };
        let peers = (0..g.usize_in(1, 5))
            .map(|_| PeerCounters {
                frames_sent: g.rng.next_u64() % 50,
                payload_bits_sent: g.rng.next_u64() % 10_000,
                blocked_send_ns: g.rng.next_u64() % 1_000,
                frames_received: g.rng.next_u64() % 50,
                payload_bits_received: g.rng.next_u64() % 10_000,
                stale_discards: g.rng.next_u64() % 10,
            })
            .collect();
        MetricsSnapshot {
            rank,
            seq,
            uptime_ms: seq * (10 + g.rng.next_u64() % 90),
            counters,
            gauges,
            hist,
            peers,
        }
    }

    #[test]
    fn merge_is_order_independent_associative_and_never_regresses() {
        forall(120, 0xF1EE7, |g| {
            let n_ranks = g.usize_in(1, 4);
            let mut snaps = Vec::new();
            for r in 0..n_ranks {
                for seq in 1..=g.usize_in(1, 5) as u64 {
                    snaps.push(gen_snapshot(g, r as u32, seq));
                }
            }
            // Reference: natural order.
            let mut a = FleetView::new("t", n_ranks);
            for s in &snaps {
                a.merge(s);
            }
            // Shuffled order, with the no-regress invariant checked as we
            // fold: merged counters are running sums of u64 deltas, so no
            // merge may ever decrease one.
            let mut order: Vec<usize> = (0..snaps.len()).collect();
            for i in (1..order.len()).rev() {
                order.swap(i, g.usize_in(0, i));
            }
            let mut b = FleetView::new("t", n_ranks);
            for &i in &order {
                let before: Vec<[u64; Counter::COUNT]> =
                    (0..n_ranks).map(|r| b.rank(r).map_or([0; 7], |v| v.counters)).collect();
                b.merge(&snaps[i]);
                for r in 0..n_ranks {
                    let after = b.rank(r).map_or([0; 7], |v| v.counters);
                    for k in 0..Counter::COUNT {
                        prop_assert!(
                            after[k] >= before[r][k],
                            "rank {r} counter {k} regressed: {} -> {}",
                            before[r][k],
                            after[k]
                        );
                    }
                }
            }
            prop_assert!(a == b, "merge must be independent of arrival order");
            // Associativity over disjoint splits: fold each half, absorb.
            let cut = g.usize_in(0, snaps.len());
            let mut left = FleetView::new("t", n_ranks);
            let mut right = FleetView::new("t", n_ranks);
            for (i, s) in snaps.iter().enumerate() {
                if i < cut {
                    left.merge(s);
                } else {
                    right.merge(s);
                }
            }
            left.absorb(&right);
            prop_assert!(a == left, "absorb(fold(A), fold(B)) must equal fold(A ∪ B)");
            Ok(())
        });
    }

    #[test]
    fn snapshot_wire_roundtrip() {
        forall(150, 0x3E7A1C5, |g| {
            let s = gen_snapshot(g, g.usize_in(0, 63) as u32, 1 + g.rng.next_u64() % 100);
            let m = encode_snapshot(&s);
            prop_assert!(
                m.bit_len == m.words.len() as u64 * 64,
                "metrics frames are word-aligned"
            );
            let back = decode_snapshot(&m).map_err(|e| e.to_string())?;
            prop_assert!(back == s, "wire roundtrip must be exact");
            // Truncated frames must fail loudly, not decode garbage.
            let mut bad = m.clone();
            bad.words.pop();
            bad.bit_len -= 64;
            prop_assert!(decode_snapshot(&bad).is_err(), "truncated frame must be rejected");
            Ok(())
        });
    }

    #[test]
    fn fleet_blob_roundtrip_survives_a_handover() {
        forall(80, 0xF7EE7, |g| {
            let n_ranks = g.usize_in(1, 5);
            let mut view = FleetView::new("handover(h=8)", n_ranks);
            for r in 0..n_ranks {
                if g.usize_in(0, 3) == 0 {
                    continue; // some ranks never reported
                }
                for seq in 1..=g.usize_in(1, 3) as u64 {
                    view.merge(&gen_snapshot(g, r as u32, seq));
                }
            }
            let blob = encode_fleet(&view);
            let back = decode_fleet(&blob)?;
            prop_assert!(back == view, "a successor must rebuild the exact view");
            let mut bad = blob.clone();
            bad.pop();
            prop_assert!(decode_fleet(&bad).is_err(), "truncated blob must be rejected");
            Ok(())
        });
    }

    #[test]
    fn prometheus_output_escapes_hostile_label_values() {
        let hostile = "cser{h=2,\"quoted\"}\\\nnewline";
        let mut view = FleetView::new(hostile, 1);
        let mut g = Gen::replay(0xE5C, 0);
        view.merge(&gen_snapshot(&mut g, 0, 1));
        let text = view.prometheus_text();
        assert!(
            text.contains("job=\"cser{h=2,\\\"quoted\\\"}\\\\\\nnewline\""),
            "label must carry escaped quote/backslash/newline:\n{text}"
        );
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(
                line.matches('\n').count(),
                0,
                "no raw newline may survive inside a sample line"
            );
            assert!(line.ends_with(|c: char| c.is_ascii_digit()), "sample line: {line}");
        }
        // escape_label is involutive-free but must roundtrip the common
        // cases exactly once.
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
    }

    #[test]
    fn json_document_carries_schema_and_per_rank_rates() {
        let mut view = FleetView::new("cser(h=32)", 2);
        let mut g = Gen::replay(0x15D0C, 0);
        view.merge(&gen_snapshot(&mut g, 0, 1));
        view.merge(&gen_snapshot(&mut g, 1, 1));
        let j = Json::parse(&view.json()).expect("exposition JSON parses");
        assert_eq!(j.get("schema").unwrap().as_str(), Some("cser-metrics/v1"));
        let ranks = j.get("ranks").unwrap().as_arr().unwrap();
        assert_eq!(ranks.len(), 2);
        for r in ranks {
            assert!(r.get("step_rate").unwrap().as_f64().is_some());
            assert!(r.get("counters").unwrap().get("steps_total").is_some());
            assert!(r.get("gauges").unwrap().get("residual_norm_pre").is_some());
        }
    }

    #[test]
    fn exposition_server_serves_both_formats() {
        let mut view = FleetView::new("smoke", 1);
        let mut g = Gen::replay(0x5E4E, 0);
        view.merge(&gen_snapshot(&mut g, 0, 1));
        let shared = Arc::new(Mutex::new(view));
        let addr = spawn_exposition_server("127.0.0.1:0", Arc::clone(&shared))
            .expect("bind loopback");
        let addr = addr.to_string();
        let json = http_get(&addr, "/json").expect("GET /json");
        let j = Json::parse(&json).expect("served JSON parses");
        assert_eq!(j.get("schema").unwrap().as_str(), Some("cser-metrics/v1"));
        let prom = http_get(&addr, "/metrics").expect("GET /metrics");
        assert!(prom.contains("# TYPE cser_steps_total counter"), "{prom}");
        assert!(prom.contains("rank=\"0\""), "{prom}");
    }

    // One registry test only: the statics are process-global, so
    // concurrent tests toggling the flag would race each other's
    // assertions (same discipline as `recorder::tests`).
    #[test]
    fn metrics_protocol() {
        assert!(!enabled());
        // Disabled: every recording call is a no-op.
        inc(Counter::StepsTotal, 5);
        gauge_set(Gauge::GradNorm, 1.5);
        observe_step_ns(100);
        sync_from_peers(&[PeerCounters { frames_sent: 9, ..Default::default() }]);
        set_enabled(true);
        reset();
        let mut tracker = DeltaTracker::new();
        let first = tracker.snapshot(3);
        assert_eq!(first.counters[Counter::StepsTotal as usize], 0, "disabled calls recorded");
        assert!(first.peers.is_empty(), "disabled sync_from_peers recorded");

        // Enabled: counters add, gauges overwrite, histogram bins fill,
        // peer lanes mirror the transport counters exactly.
        inc(Counter::StepsTotal, 2);
        inc(Counter::StepsTotal, 1);
        inc(Counter::GradBits, 640);
        gauge_set(Gauge::ResidualNormPre, 4.0);
        gauge_set(Gauge::ResidualNormPre, 2.5);
        observe_step_ns(1_000);
        observe_step_ns(3_000);
        let peers = vec![
            PeerCounters::default(),
            PeerCounters {
                frames_sent: 7,
                payload_bits_sent: 4096,
                blocked_send_ns: 5_000,
                frames_received: 6,
                payload_bits_received: 2048,
                stale_discards: 3,
            },
        ];
        sync_from_peers(&peers);
        assert_eq!(peer_counters(), peers, "lanes must roundtrip the transport counters");
        // Adaptive censoring reads its threshold straight off these lanes.
        assert_eq!(
            crate::membership::censor_seed_from_metrics(0.5),
            crate::membership::censor_seed(&peers, 0.5)
        );
        assert!(crate::membership::censor_seed_from_metrics(0.5) > 0.0);

        // Delta shipping: the first snapshot carries everything, the next
        // only what happened in between; wire roundtrip is exact.
        let snap = tracker.snapshot(3);
        assert_eq!(snap.rank, 3);
        assert_eq!(snap.counters[Counter::StepsTotal as usize], 3);
        assert_eq!(snap.counters[Counter::GradBits as usize], 640);
        assert_eq!(snap.gauges[Gauge::ResidualNormPre as usize], 2.5);
        assert_eq!(snap.hist.count, 2);
        assert_eq!(snap.hist.total_ns, 4_000);
        assert_eq!(snap.peers[1].frames_sent, 7);
        let back = decode_snapshot(&encode_snapshot(&snap)).unwrap();
        assert_eq!(back, snap);

        inc(Counter::StepsTotal, 4);
        let snap2 = tracker.snapshot(3);
        assert_eq!(snap2.seq, snap.seq + 1);
        assert_eq!(snap2.counters[Counter::StepsTotal as usize], 4, "delta, not total");
        assert_eq!(snap2.hist.count, 0);
        assert_eq!(snap2.peers[1].frames_sent, 0, "unchanged lanes ship zero deltas");

        // A fleet view fed both snapshots reconstructs the totals, and
        // the adaptive-censor input survives the trip.
        let mut view = FleetView::new("proto", 4);
        view.merge(&snap);
        view.merge(&snap2);
        let v = view.rank(3).expect("rank 3 reported");
        assert_eq!(v.counter(Counter::StepsTotal), 7);
        assert_eq!(v.gauge(Gauge::ResidualNormPre), 2.5);
        assert_eq!(v.peers[1].blocked_send_ns, 5_000);
        assert!(v.step_rate() >= 0.0);

        set_enabled(false);
        reset();
        assert_eq!(peer_counters().len(), 0, "reset clears the peer lanes");
    }
}
