//! Fixed-bin duration histograms: folding trace events into `PhaseStats`.
//!
//! One `PhaseStats` per phase: count, total, min/max, and a 64-bin log2
//! histogram (bin 0 holds zero-duration events; bin i ≥ 1 holds
//! `[2^(i-1), 2^i)` ns; the last bin is open-ended).  Quantiles walk the
//! bins and clamp to the recorded `[min, max]`, so p50/p99 are estimates
//! with at most one-octave resolution but can never leave the observed
//! range.  Everything is plain `u64` arithmetic — fold once after a run,
//! never in the hot path.

use super::phase::Phase;
use super::recorder::{Event, KIND_SPAN};

/// Histogram bins per phase (log2-spaced; see module docs).
pub const BINS: usize = 64;

/// Duration statistics for one phase.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseStats {
    pub count: u64,
    pub total_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
    bins: [u64; BINS],
}

impl Default for PhaseStats {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseStats {
    pub fn new() -> Self {
        Self { count: 0, total_ns: 0, min_ns: u64::MAX, max_ns: 0, bins: [0; BINS] }
    }

    /// Lower edge of bin `i` (valid for `i <= BINS`): 0, 1, 2, 4, ... —
    /// strictly monotone, so bins partition `[0, ∞)`.
    pub fn bin_lo(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    /// Bin holding `dur_ns`: `bin_lo(i) <= dur_ns < bin_lo(i + 1)` (the
    /// last bin is open-ended).
    pub fn bin_index(dur_ns: u64) -> usize {
        if dur_ns == 0 {
            0
        } else {
            (64 - dur_ns.leading_zeros() as usize).min(BINS - 1)
        }
    }

    pub fn record(&mut self, dur_ns: u64) {
        self.count += 1;
        self.total_ns += dur_ns;
        self.min_ns = self.min_ns.min(dur_ns);
        self.max_ns = self.max_ns.max(dur_ns);
        self.bins[Self::bin_index(dur_ns)] += 1;
    }

    pub fn merge(&mut self, o: &PhaseStats) {
        self.count += o.count;
        self.total_ns += o.total_ns;
        self.min_ns = self.min_ns.min(o.min_ns);
        self.max_ns = self.max_ns.max(o.max_ns);
        for (b, ob) in self.bins.iter_mut().zip(o.bins.iter()) {
            *b += ob;
        }
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64
        }
    }

    /// Histogram quantile (`0 < q <= 1`): midpoint of the bin holding the
    /// `ceil(q·count)`-th sample, clamped to `[min_ns, max_ns]`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            cum += c;
            if cum >= target {
                let lo = Self::bin_lo(i);
                let hi = if i + 1 < BINS { Self::bin_lo(i + 1) } else { self.max_ns.max(lo) };
                let mid = lo + (hi - lo) / 2;
                return mid.clamp(self.min_ns, self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn bin_counts(&self) -> &[u64; BINS] {
        &self.bins
    }
}

/// Fold span events into one `PhaseStats` per phase (indexable by
/// `Phase as usize`).  Counter events and unknown phase bytes (from a
/// newer trace format) are skipped.
pub fn fold(events: &[Event]) -> [PhaseStats; Phase::COUNT] {
    let mut out: [PhaseStats; Phase::COUNT] = std::array::from_fn(|_| PhaseStats::new());
    for ev in events {
        if ev.kind != KIND_SPAN {
            continue;
        }
        if let Some(p) = Phase::from_u8(ev.phase) {
            out[p as usize].record(ev.dur_ns);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::forall;

    #[test]
    fn bin_edges_are_strictly_monotone_and_consistent() {
        for i in 1..=BINS {
            assert!(
                PhaseStats::bin_lo(i) > PhaseStats::bin_lo(i - 1),
                "bin_lo({i}) must exceed bin_lo({})",
                i - 1
            );
        }
        // Every duration lands in the bin whose range contains it.
        for dur in [0u64, 1, 2, 3, 4, 1023, 1024, u64::MAX] {
            let i = PhaseStats::bin_index(dur);
            assert!(PhaseStats::bin_lo(i) <= dur, "dur {dur} below bin {i} lower edge");
            if i + 1 < BINS {
                assert!(dur < PhaseStats::bin_lo(i + 1), "dur {dur} above bin {i} upper edge");
            }
        }
    }

    #[test]
    fn histogram_properties() {
        forall(300, 0x0B57A75, |g| {
            let n = g.usize_in(1, 400);
            let mut s = PhaseStats::new();
            let mut durs = Vec::with_capacity(n);
            for _ in 0..n {
                // Durations spanning many magnitudes (≤ 2^48 so the u64
                // total cannot overflow over 400 draws), zero included.
                let shift = g.usize_in(16, 64);
                let dur = if shift == 63 { 0 } else { g.rng.next_u64() >> shift };
                s.record(dur);
                durs.push(dur);
            }
            let (lo, hi) =
                (*durs.iter().min().unwrap(), *durs.iter().max().unwrap());
            // Conservation: every recorded event is in exactly one bin.
            let binned: u64 = s.bin_counts().iter().sum();
            prop_assert!(binned == n as u64, "bin sum {binned} != count {n}");
            prop_assert!(s.count == n as u64, "count {} != {n}", s.count);
            let want: u64 = durs.iter().sum();
            prop_assert!(s.total_ns == want, "total {} != {want}", s.total_ns);
            prop_assert!(
                (s.min_ns, s.max_ns) == (lo, hi),
                "min/max {:?} != {:?}",
                (s.min_ns, s.max_ns),
                (lo, hi)
            );
            // Quantiles stay inside the recorded range and are ordered.
            let (p50, p99) = (s.p50(), s.p99());
            prop_assert!(lo <= p50 && p50 <= hi, "p50 {p50} outside [{lo}, {hi}]");
            prop_assert!(lo <= p99 && p99 <= hi, "p99 {p99} outside [{lo}, {hi}]");
            prop_assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
            // Merge conserves counts and bins exactly.
            let mut m = PhaseStats::new();
            m.merge(&s);
            m.merge(&s);
            let msum: u64 = m.bin_counts().iter().sum();
            prop_assert!(msum == 2 * n as u64, "merged bin sum {msum} != {}", 2 * n);
            prop_assert!(
                m.count == 2 * n as u64 && m.total_ns == 2 * want,
                "merge must add counts and totals"
            );
            Ok(())
        });
    }

    #[test]
    fn empty_stats_are_inert() {
        let s = PhaseStats::new();
        assert_eq!((s.count, s.p50(), s.p99()), (0, 0, 0));
        assert_eq!(s.mean_ns(), 0.0);
    }
}
