//! The phase taxonomy: where a worker's wall time can go.
//!
//! Every traced span carries exactly one `Phase`.  The set is closed and
//! small on purpose — each phase is a *mutually exclusive* slice of a
//! training round, so per-phase totals add up to attributable wall time
//! and a missing phase in a trace is a bug, not a configuration choice:
//!
//! * `GradCompute` — minibatch forward/backward (`GradFn`);
//! * `Select` — compressor support selection (`select_with`);
//! * `Encode` — gathering/serializing the selected payload;
//! * `Exchange` — the collective exchange proper (ring segments or the
//!   parameter-server gather/broadcast); per-bucket under the pipeline,
//!   with the bucket index in the span's `arg`;
//! * `Decode` — turning received payloads back into dense updates;
//! * `ApplyReset` — the O(d) local update: descent, error fold,
//!   CSER reset add/sub;
//! * `BarrierWait` — blocked on a peer: the divergence vote, a blocking
//!   recv inside a control collective, or waiting on the pipeline's
//!   prepare thread;
//! * `PipelinePrepare` — the `BucketPipeline` helper thread preparing
//!   bucket k+1 while bucket k exchanges (its overlap with `Exchange`
//!   spans on the owning worker's track is the pipeline's win, visible
//!   directly in the merged Chrome trace);
//! * `Censor` — a censoring-cadence round this worker sat out: the
//!   compressed update's norm missed the threshold, so an empty frame
//!   shipped instead (the span's `arg` carries the rank; always nested
//!   inside the surrounding `Exchange`, so phase totals still partition
//!   wall time at the top level).

/// One attributable slice of a training round.  Discriminants are stable
/// and double as indices into per-phase arrays (`Phase::ALL[p as usize]
/// == p`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    GradCompute = 0,
    Select = 1,
    Encode = 2,
    Exchange = 3,
    Decode = 4,
    ApplyReset = 5,
    BarrierWait = 6,
    PipelinePrepare = 7,
    Censor = 8,
}

impl Phase {
    pub const COUNT: usize = 9;

    /// Every phase, in discriminant order.
    pub const ALL: [Phase; Phase::COUNT] = [
        Phase::GradCompute,
        Phase::Select,
        Phase::Encode,
        Phase::Exchange,
        Phase::Decode,
        Phase::ApplyReset,
        Phase::BarrierWait,
        Phase::PipelinePrepare,
        Phase::Censor,
    ];

    /// Stable wire/export name (used in JSONL, Chrome trace events, and
    /// the summary schema).
    pub fn name(self) -> &'static str {
        match self {
            Phase::GradCompute => "grad_compute",
            Phase::Select => "select",
            Phase::Encode => "encode",
            Phase::Exchange => "exchange",
            Phase::Decode => "decode",
            Phase::ApplyReset => "apply_reset",
            Phase::BarrierWait => "barrier_wait",
            Phase::PipelinePrepare => "pipeline_prepare",
            Phase::Censor => "censor",
        }
    }

    pub fn from_u8(b: u8) -> Option<Phase> {
        Phase::ALL.get(b as usize).copied()
    }

    pub fn from_name(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.name() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discriminants_index_all() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(*p as usize, i);
            assert_eq!(Phase::from_u8(i as u8), Some(*p));
            assert_eq!(Phase::from_name(p.name()), Some(*p));
        }
        assert_eq!(Phase::from_u8(Phase::COUNT as u8), None);
        assert_eq!(Phase::from_name("nope"), None);
    }
}
