//! Lock-free, preallocated per-thread trace recorder.
//!
//! # Memory model
//!
//! Each recording thread owns one `ThreadRing`: a fixed-capacity
//! (`RING_CAPACITY`) preallocated event buffer plus an atomic length.
//! Only the owning thread writes; the exporter reads completed prefixes.
//! The protocol is single-writer/multi-reader publication:
//!
//! * **writer** (owning thread): load `len` (Relaxed) → write slot `len`
//!   → store `len + 1` (Release);
//! * **reader** (exporter): load `len` (Acquire) → copy `events[..len]`.
//!
//! The buffer is *bounded, not wrapping*: once full, new events are
//! dropped and counted (`dropped`) rather than overwriting history —
//! a trace that silently lost its warmup would misattribute every
//! steady-state number, while a counted tail drop is visible in the
//! export.  Nothing in the steady state allocates or locks: the ring is
//! preallocated at registration (one allocation per thread, during
//! warmup), `push` is two atomic ops plus a 32-byte store, and the
//! global registry mutex is touched only at registration/export time.
//! The counting-allocator pin in `rust/tests/hotpath_alloc.rs` runs its
//! engine-step section with tracing enabled to hold this contract.
//!
//! # Overhead when disabled
//!
//! `Span::enter` checks the global `enabled()` flag **once** (one
//! relaxed atomic load) and, when disabled, neither reads a timestamp
//! nor records on drop.  Timestamps are `Instant`-based monotonic
//! nanoseconds relative to a process-wide epoch pinned by
//! `set_enabled(true)`.

use super::phase::Phase;
use std::cell::{RefCell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events per thread ring (fixed at registration; ~2 MiB per thread).
pub const RING_CAPACITY: usize = 1 << 16;

/// `Event::arg` value meaning "no argument".
pub const NO_ARG: u64 = u64::MAX;

pub const KIND_SPAN: u8 = 0;
pub const KIND_COUNTER: u8 = 1;

/// One fixed-size trace event (a completed span or a counter sample).
#[derive(Debug, Clone, Copy, Default)]
pub struct Event {
    /// `Phase` discriminant.
    pub phase: u8,
    /// `KIND_SPAN` or `KIND_COUNTER`.
    pub kind: u8,
    /// Span argument (bucket index, worker id, ...) or counter value;
    /// `NO_ARG` when absent.
    pub arg: u64,
    /// Nanoseconds since the trace epoch.
    pub start_ns: u64,
    /// Span duration (0 for counters).
    pub dur_ns: u64,
}

struct ThreadRing {
    name: String,
    capacity: usize,
    events: UnsafeCell<Box<[Event]>>,
    len: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: `events` is written only by the owning thread below `len`
// published with Release; readers copy only the Acquire-loaded prefix.
unsafe impl Sync for ThreadRing {}

impl ThreadRing {
    fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            capacity: RING_CAPACITY,
            events: UnsafeCell::new(vec![Event::default(); RING_CAPACITY].into_boxed_slice()),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    #[inline]
    fn push(&self, ev: Event) {
        let len = self.len.load(Ordering::Relaxed);
        if len >= self.capacity {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: single-writer protocol — only the owning thread pushes,
        // and slot `len` is not yet visible to readers.
        unsafe {
            (*self.events.get())[len] = ev;
        }
        self.len.store(len + 1, Ordering::Release);
    }

    fn snapshot(&self) -> RingSnapshot {
        let len = self.len.load(Ordering::Acquire).min(self.capacity);
        // SAFETY: every slot below the Acquire-loaded `len` was published
        // by a Release store after being fully written.
        let events = unsafe { (*self.events.get())[..len].to_vec() };
        RingSnapshot {
            name: self.name.clone(),
            events,
            dropped: self.dropped.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        self.len.store(0, Ordering::Release);
        self.dropped.store(0, Ordering::Relaxed);
    }
}

/// An exported copy of one thread's ring.
#[derive(Debug, Clone)]
pub struct RingSnapshot {
    pub name: String,
    pub events: Vec<Event>,
    pub dropped: u64,
}

/// Per-peer wire counters kept by the transports (one slot per remote
/// rank; the self slot stays zero).  Plain `u64`s owned by the transport
/// — no atomics, no recording cost beyond the adds, and the blocked-send
/// timer only runs when `enabled()`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeerCounters {
    pub frames_sent: u64,
    pub payload_bits_sent: u64,
    /// Nanoseconds spent inside blocking sends to this peer (TCP only;
    /// measured only while tracing is enabled — backpressure made
    /// visible).
    pub blocked_send_ns: u64,
    pub frames_received: u64,
    pub payload_bits_received: u64,
    /// Frames from rounds older than the one the receiver was waiting on,
    /// read and dropped by `recv_deadline`'s stale-frame drain (leftovers
    /// of censored rounds; their payload bits still count as received —
    /// they crossed the wire).
    pub stale_discards: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Vec<Arc<ThreadRing>>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static HANDLE: RefCell<Option<Arc<ThreadRing>>> = const { RefCell::new(None) };
}

/// Is tracing on?  One relaxed load — the only cost every span site pays
/// when tracing is disabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off.  Enabling pins the trace epoch (idempotent).
pub fn set_enabled(on: bool) {
    if on {
        pin_epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Pin the shared observability epoch (idempotent).  Tracing and the
/// metrics registry share one clock so their timestamps compare —
/// `metrics::set_enabled` calls this too, making [`now_ns`] valid even
/// when tracing itself stays off.
pub(crate) fn pin_epoch() {
    let _ = EPOCH.set(Instant::now());
}

/// Monotonic nanoseconds since the trace epoch (0 before the first
/// `set_enabled(true)`).
#[inline]
pub fn now_ns() -> u64 {
    match EPOCH.get() {
        Some(t0) => t0.elapsed().as_nanos() as u64,
        None => 0,
    }
}

/// Register the calling thread under `name`, preallocating its ring.
/// Idempotent: a thread that already has a ring keeps it (first name
/// wins).  Call during warmup — this is the one allocation the recorder
/// ever makes per thread.
pub fn register_thread(name: &str) {
    HANDLE.with(|h| {
        let mut h = h.borrow_mut();
        if h.is_some() {
            return;
        }
        let ring = Arc::new(ThreadRing::new(name));
        REGISTRY.lock().expect("obs registry").push(Arc::clone(&ring));
        *h = Some(ring);
    });
}

fn record(ev: Event) {
    HANDLE.with(|h| {
        if let Some(ring) = h.borrow().as_ref() {
            ring.push(ev);
            return;
        }
        // First event from an unregistered thread: fall back to a
        // generic name (allocates once — registration, not steady state).
        let ring = Arc::new(ThreadRing::new("thread"));
        REGISTRY.lock().expect("obs registry").push(Arc::clone(&ring));
        ring.push(ev);
        *h.borrow_mut() = Some(ring);
    });
}

/// Record an instantaneous counter sample for `phase`.
#[inline]
pub fn record_counter(phase: Phase, value: u64) {
    if enabled() {
        record(Event {
            phase: phase as u8,
            kind: KIND_COUNTER,
            arg: value,
            start_ns: now_ns(),
            dur_ns: 0,
        });
    }
}

/// RAII span guard: construct with [`Span::enter`] at the top of a phase,
/// drop at the end.  Disabled tracing costs one flag load — no timestamp
/// read, nothing recorded on drop.
pub struct Span {
    phase: Phase,
    arg: u64,
    start_ns: u64,
    armed: bool,
}

impl Span {
    #[inline]
    pub fn enter(phase: Phase) -> Span {
        Span::enter_arg(phase, NO_ARG)
    }

    #[inline]
    pub fn enter_arg(phase: Phase, arg: u64) -> Span {
        if enabled() {
            Span { phase, arg, start_ns: now_ns(), armed: true }
        } else {
            Span { phase, arg, start_ns: 0, armed: false }
        }
    }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if self.armed {
            let end = now_ns();
            record(Event {
                phase: self.phase as u8,
                kind: KIND_SPAN,
                arg: self.arg,
                start_ns: self.start_ns,
                dur_ns: end.saturating_sub(self.start_ns),
            });
        }
    }
}

/// Copy out every registered thread's events, in registration order
/// (the export `tid`).  Readers see each ring's completed prefix.
pub fn snapshot_all() -> Vec<RingSnapshot> {
    let rings: Vec<Arc<ThreadRing>> = REGISTRY.lock().expect("obs registry").clone();
    rings.iter().map(|r| r.snapshot()).collect()
}

/// Clear every registered ring (length + dropped count).  The rings stay
/// registered and owned by their threads; callers must ensure recording
/// threads are quiescent (between runs / bench sections).
pub fn reset() {
    for r in REGISTRY.lock().expect("obs registry").iter() {
        r.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test only: `ENABLED` and the registry are process-global, so
    // concurrent tests toggling the flag would race each other's
    // assertions.  Everything runs in sequence here.
    #[test]
    fn recorder_protocol() {
        // Disabled: spans are unarmed — nothing recorded, no epoch read.
        register_thread("obs-recorder-test");
        let before = my_ring_len();
        {
            let _s = Span::enter(Phase::Exchange);
        }
        record_counter(Phase::Exchange, 42);
        assert_eq!(my_ring_len(), before, "disabled tracing must record nothing");

        // Enabled: spans land with end >= start, counters carry values.
        set_enabled(true);
        {
            let _s = Span::enter_arg(Phase::Exchange, 3);
        }
        record_counter(Phase::Decode, 99);
        set_enabled(false);
        let snap = my_ring();
        assert_eq!(snap.events.len(), before + 2);
        let sp = &snap.events[before];
        assert_eq!((sp.phase, sp.kind, sp.arg), (Phase::Exchange as u8, KIND_SPAN, 3));
        let ct = &snap.events[before + 1];
        assert_eq!((ct.phase, ct.kind, ct.arg), (Phase::Decode as u8, KIND_COUNTER, 99));
        assert!(ct.start_ns >= sp.start_ns, "timestamps must be monotone");

        // Overflow: a full ring drops and counts instead of wrapping.
        set_enabled(true);
        let start = my_ring_len();
        for _ in start..RING_CAPACITY + 10 {
            record_counter(Phase::Select, 1);
        }
        set_enabled(false);
        let snap = my_ring();
        assert_eq!(snap.events.len(), RING_CAPACITY, "ring must stop at capacity");
        assert_eq!(snap.dropped, 10, "overflow must be counted, not wrapped");
        assert_eq!(
            snap.events[before].phase,
            Phase::Exchange as u8,
            "early events must survive overflow (bounded, not wrapping)"
        );

        // Reset clears length and dropped for reuse.
        reset();
        let snap = my_ring();
        assert_eq!((snap.events.len(), snap.dropped), (0, 0));
    }

    fn my_ring() -> RingSnapshot {
        snapshot_all()
            .into_iter()
            .find(|s| s.name == "obs-recorder-test")
            .expect("test ring registered")
    }

    fn my_ring_len() -> usize {
        my_ring().events.len()
    }
}
