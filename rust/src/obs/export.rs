//! Trace export: per-rank JSONL files and the merged Chrome trace.
//!
//! # File formats
//!
//! **Per-rank JSONL** (`trace-rank<R>.jsonl`, written by each worker at
//! the end of a `--trace` run): one JSON object per line, one line per
//! event —
//!
//! ```text
//! {"tid":0,"phase":"exchange","kind":"span","start_ns":98,"dur_ns":13,"arg":2}
//! ```
//!
//! — closed by a single meta line carrying the rank id, per-thread names
//! and dropped-event counts, and the transport's per-peer wire counters:
//!
//! ```text
//! {"meta":true,"rank":1,"threads":[{"tid":0,"name":"worker","events":840,
//!  "dropped":0}],"peers":[{"peer":0,"frames_sent":64,...}]}
//! ```
//!
//! **Merged Chrome trace** (`trace.json`, written by `cser trace
//! summarize`): the Trace Event Format consumed by Perfetto /
//! `chrome://tracing` — `{"traceEvents": [...]}` with one complete
//! (`"ph":"X"`) event per span, `"ph":"C"` counter samples, and
//! `"ph":"M"` metadata naming each rank (`pid`) and thread (`tid`), so
//! every rank×thread gets its own labeled track.  Timestamps are µs
//! relative to each rank's own trace epoch (clocks are per-process; the
//! `pid` split keeps cross-rank comparisons honest).
//!
//! The summary (`cser-trace-summary/v1`) folds each rank's spans into
//! per-phase [`PhaseStats`] rows.

use super::phase::Phase;
use super::recorder::{Event, PeerCounters, RingSnapshot, KIND_COUNTER, KIND_SPAN, NO_ARG};
use super::stats::{self, PhaseStats};
use crate::util::json::{Json, JsonWriter};
use std::io::Write as _;
use std::path::{Path, PathBuf};

pub const SUMMARY_SCHEMA: &str = "cser-trace-summary/v1";

/// One parsed trace event (a JSONL line).
#[derive(Debug, Clone)]
pub struct LineEvent {
    pub tid: usize,
    pub phase: Phase,
    pub kind: u8,
    pub arg: Option<u64>,
    pub start_ns: u64,
    pub dur_ns: u64,
}

#[derive(Debug, Clone)]
pub struct ThreadMeta {
    pub tid: usize,
    pub name: String,
    pub events: u64,
    pub dropped: u64,
}

/// One rank's full trace (events + meta), as read back from JSONL.
#[derive(Debug, Clone)]
pub struct RankTrace {
    pub rank: usize,
    pub threads: Vec<ThreadMeta>,
    pub events: Vec<LineEvent>,
    /// Wire counters indexed by peer rank (self slot zero).
    pub peers: Vec<PeerCounters>,
}

/// Write one rank's rings + transport counters as
/// `<dir>/trace-rank<rank>.jsonl`.  Returns the path written.
pub fn write_rank_jsonl(
    dir: &Path,
    rank: usize,
    snaps: &[RingSnapshot],
    peers: &[PeerCounters],
) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("trace-rank{rank}.jsonl"));
    let mut out = std::io::BufWriter::new(std::fs::File::create(&path)?);
    for (tid, snap) in snaps.iter().enumerate() {
        for ev in &snap.events {
            let mut w = JsonWriter::new();
            w.begin_obj();
            w.key("tid").int(tid as i64);
            let phase =
                Phase::from_u8(ev.phase).map(Phase::name).unwrap_or("unknown");
            w.key("phase").str(phase);
            w.key("kind").str(if ev.kind == KIND_COUNTER { "counter" } else { "span" });
            w.key("start_ns").int(ev.start_ns as i64);
            w.key("dur_ns").int(ev.dur_ns as i64);
            if ev.arg != NO_ARG {
                w.key("arg").int(ev.arg as i64);
            }
            w.end_obj();
            writeln!(out, "{}", w.finish())?;
        }
    }
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("meta").bool(true);
    w.key("rank").int(rank as i64);
    w.key("threads").begin_arr();
    for (tid, snap) in snaps.iter().enumerate() {
        w.begin_obj();
        w.key("tid").int(tid as i64);
        w.key("name").str(&snap.name);
        w.key("events").int(snap.events.len() as i64);
        w.key("dropped").int(snap.dropped as i64);
        w.end_obj();
    }
    w.end_arr();
    w.key("peers").begin_arr();
    for (peer, c) in peers.iter().enumerate() {
        w.begin_obj();
        w.key("peer").int(peer as i64);
        w.key("frames_sent").int(c.frames_sent as i64);
        w.key("payload_bits_sent").int(c.payload_bits_sent as i64);
        w.key("blocked_send_ns").int(c.blocked_send_ns as i64);
        w.key("frames_received").int(c.frames_received as i64);
        w.key("payload_bits_received").int(c.payload_bits_received as i64);
        w.key("stale_discards").int(c.stale_discards as i64);
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
    writeln!(out, "{}", w.finish())?;
    out.flush()?;
    Ok(path)
}

fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
}

/// Parse one rank's JSONL file.
pub fn read_rank_jsonl(path: &Path) -> Result<RankTrace, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let mut trace =
        RankTrace { rank: usize::MAX, threads: Vec::new(), events: Vec::new(), peers: Vec::new() };
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| format!("{} line {}: {e}", path.display(), lineno + 1))?;
        if j.get("meta").and_then(Json::as_bool) == Some(true) {
            trace.rank = get_u64(&j, "rank") as usize;
            for t in j.get("threads").and_then(Json::as_arr).unwrap_or(&[]) {
                trace.threads.push(ThreadMeta {
                    tid: get_u64(t, "tid") as usize,
                    name: t
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or("thread")
                        .to_string(),
                    events: get_u64(t, "events"),
                    dropped: get_u64(t, "dropped"),
                });
            }
            for p in j.get("peers").and_then(Json::as_arr).unwrap_or(&[]) {
                trace.peers.push(PeerCounters {
                    frames_sent: get_u64(p, "frames_sent"),
                    payload_bits_sent: get_u64(p, "payload_bits_sent"),
                    blocked_send_ns: get_u64(p, "blocked_send_ns"),
                    frames_received: get_u64(p, "frames_received"),
                    payload_bits_received: get_u64(p, "payload_bits_received"),
                    stale_discards: get_u64(p, "stale_discards"),
                });
            }
            continue;
        }
        let phase = j
            .get("phase")
            .and_then(Json::as_str)
            .and_then(Phase::from_name);
        let Some(phase) = phase else {
            continue; // unknown phase from a newer writer: skip, don't fail
        };
        trace.events.push(LineEvent {
            tid: get_u64(&j, "tid") as usize,
            phase,
            kind: if j.get("kind").and_then(Json::as_str) == Some("counter") {
                KIND_COUNTER
            } else {
                KIND_SPAN
            },
            arg: j.get("arg").and_then(Json::as_f64).map(|v| v as u64),
            start_ns: get_u64(&j, "start_ns"),
            dur_ns: get_u64(&j, "dur_ns"),
        });
    }
    if trace.rank == usize::MAX {
        return Err(format!("{}: missing meta line", path.display()));
    }
    Ok(trace)
}

/// Load every `trace-rank<R>.jsonl` under `dir`, sorted by rank.
pub fn load_trace_dir(dir: &Path) -> Result<Vec<RankTrace>, String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for ent in entries {
        let ent = ent.map_err(|e| format!("{}: {e}", dir.display()))?;
        let name = ent.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("trace-rank") && name.ends_with(".jsonl") {
            paths.push(ent.path());
        }
    }
    if paths.is_empty() {
        return Err(format!("{}: no trace-rank*.jsonl files", dir.display()));
    }
    let mut ranks: Vec<RankTrace> =
        paths.iter().map(|p| read_rank_jsonl(p)).collect::<Result<_, _>>()?;
    ranks.sort_by_key(|r| r.rank);
    Ok(ranks)
}

/// Render the merged Chrome trace-event JSON (Perfetto-loadable): one
/// `pid` per rank, one `tid` per thread, `"X"` spans, `"C"` counters,
/// and `"M"` metadata naming every track.
pub fn chrome_trace_json(ranks: &[RankTrace]) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("traceEvents").begin_arr();
    for r in ranks {
        w.begin_obj();
        w.key("ph").str("M");
        w.key("pid").int(r.rank as i64);
        w.key("tid").int(0);
        w.key("name").str("process_name");
        w.key("args").begin_obj();
        w.key("name").str(&format!("rank {}", r.rank));
        w.end_obj();
        w.end_obj();
        for t in &r.threads {
            w.begin_obj();
            w.key("ph").str("M");
            w.key("pid").int(r.rank as i64);
            w.key("tid").int(t.tid as i64);
            w.key("name").str("thread_name");
            w.key("args").begin_obj();
            w.key("name").str(&t.name);
            w.end_obj();
            w.end_obj();
        }
        for ev in &r.events {
            w.begin_obj();
            w.key("ph").str(if ev.kind == KIND_COUNTER { "C" } else { "X" });
            w.key("pid").int(r.rank as i64);
            w.key("tid").int(ev.tid as i64);
            w.key("name").str(ev.phase.name());
            w.key("cat").str("phase");
            w.key("ts").num(ev.start_ns as f64 / 1000.0);
            if ev.kind == KIND_SPAN {
                w.key("dur").num(ev.dur_ns as f64 / 1000.0);
            }
            if ev.kind == KIND_COUNTER || ev.arg.is_some() {
                w.key("args").begin_obj();
                if ev.kind == KIND_COUNTER {
                    w.key("value").int(ev.arg.unwrap_or(0) as i64);
                } else if let Some(a) = ev.arg {
                    w.key("arg").int(a as i64);
                }
                w.end_obj();
            }
            w.end_obj();
        }
    }
    w.end_arr();
    w.end_obj();
    w.finish()
}

fn phase_stats_obj(w: &mut JsonWriter, phase: &str, s: &PhaseStats) {
    w.begin_obj();
    w.key("phase").str(phase);
    w.key("count").int(s.count as i64);
    w.key("total_ns").int(s.total_ns as i64);
    w.key("mean_ns").num(s.mean_ns());
    w.key("min_ns").int(if s.count == 0 { 0 } else { s.min_ns as i64 });
    w.key("max_ns").int(s.max_ns as i64);
    w.key("p50_ns").int(s.p50() as i64);
    w.key("p99_ns").int(s.p99() as i64);
    w.end_obj();
}

/// Fold one rank's spans into per-phase stats.
pub fn fold_rank(r: &RankTrace) -> [PhaseStats; Phase::COUNT] {
    let events: Vec<Event> = r
        .events
        .iter()
        .map(|e| Event {
            phase: e.phase as u8,
            kind: e.kind,
            arg: e.arg.unwrap_or(NO_ARG),
            start_ns: e.start_ns,
            dur_ns: e.dur_ns,
        })
        .collect();
    stats::fold(&events)
}

/// Render the `cser-trace-summary/v1` JSON for a set of rank traces.
pub fn summary_json(ranks: &[RankTrace], trace_path: Option<&Path>) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("schema").str(SUMMARY_SCHEMA);
    if let Some(p) = trace_path {
        w.key("trace").str(&p.to_string_lossy());
    }
    w.key("ranks").begin_arr();
    for r in ranks {
        let folded = fold_rank(r);
        w.begin_obj();
        w.key("rank").int(r.rank as i64);
        w.key("threads").int(r.threads.len() as i64);
        w.key("dropped").int(r.threads.iter().map(|t| t.dropped).sum::<u64>() as i64);
        w.key("phases").begin_arr();
        for p in Phase::ALL {
            phase_stats_obj(&mut w, p.name(), &folded[p as usize]);
        }
        w.end_arr();
        w.key("peers").begin_arr();
        for (peer, c) in r.peers.iter().enumerate() {
            w.begin_obj();
            w.key("peer").int(peer as i64);
            w.key("frames_sent").int(c.frames_sent as i64);
            w.key("payload_bits_sent").int(c.payload_bits_sent as i64);
            w.key("blocked_send_ns").int(c.blocked_send_ns as i64);
            w.key("frames_received").int(c.frames_received as i64);
            w.key("payload_bits_received").int(c.payload_bits_received as i64);
            w.key("stale_discards").int(c.stale_discards as i64);
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
    w.finish()
}

/// `cser trace summarize`: merge `<dir>/trace-rank*.jsonl` into
/// `<dir>/trace.json` (Chrome trace) and return the summary JSON.
pub fn summarize(dir: &Path) -> Result<String, String> {
    let ranks = load_trace_dir(dir)?;
    let trace_path = dir.join("trace.json");
    std::fs::write(&trace_path, chrome_trace_json(&ranks))
        .map_err(|e| format!("{}: {e}", trace_path.display()))?;
    Ok(summary_json(&ranks, Some(&trace_path)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::forall;

    fn hostile_name(g: &mut crate::util::prop::Gen) -> String {
        let palette = [
            "a", "Z", "0", " ", "\"", "\\", "\n", "\t", "\r", "\u{1}", "\u{8}", "\u{c}",
            "\u{7f}", "é", "🦀", "{", "}", "[", "]", ",", ":", "/",
        ];
        let n = g.usize_in(0, 24);
        (0..n).map(|_| palette[g.usize_in(0, palette.len())]).collect()
    }

    fn sample_trace(thread_name: &str) -> RankTrace {
        RankTrace {
            rank: 2,
            threads: vec![ThreadMeta {
                tid: 0,
                name: thread_name.to_string(),
                events: 2,
                dropped: 1,
            }],
            events: vec![
                LineEvent {
                    tid: 0,
                    phase: Phase::Exchange,
                    kind: KIND_SPAN,
                    arg: Some(3),
                    start_ns: 1500,
                    dur_ns: 2500,
                },
                LineEvent {
                    tid: 0,
                    phase: Phase::Decode,
                    kind: KIND_COUNTER,
                    arg: Some(99),
                    start_ns: 4000,
                    dur_ns: 0,
                },
            ],
            peers: vec![PeerCounters::default(), PeerCounters {
                frames_sent: 7,
                payload_bits_sent: 4096,
                blocked_send_ns: 12,
                frames_received: 7,
                payload_bits_received: 4096,
                stale_discards: 2,
            }],
        }
    }

    #[test]
    fn chrome_trace_escapes_names() {
        // Hostile thread names must always yield parseable JSON that
        // round-trips the name exactly.
        forall(200, 0xE5CA9E, |g| {
            let name = hostile_name(g);
            let tr = sample_trace(&name);
            let s = chrome_trace_json(std::slice::from_ref(&tr));
            let j = Json::parse(&s).map_err(|e| format!("invalid chrome JSON: {e}"))?;
            let evs = j
                .get("traceEvents")
                .and_then(Json::as_arr)
                .ok_or("missing traceEvents")?;
            let thread_meta = evs
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some("thread_name"))
                .ok_or("missing thread_name metadata")?;
            let got = thread_meta
                .get("args")
                .and_then(|a| a.get("name"))
                .and_then(Json::as_str)
                .ok_or("missing args.name")?;
            prop_assert!(got == name, "thread name mangled: {got:?} != {name:?}");
            Ok(())
        });
    }

    #[test]
    fn chrome_trace_event_shape() {
        let tr = sample_trace("worker");
        let s = chrome_trace_json(std::slice::from_ref(&tr));
        let j = Json::parse(&s).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 events
        assert_eq!(evs.len(), 4);
        let x = evs
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .expect("complete event");
        assert_eq!(x.get("pid").unwrap().as_usize(), Some(2));
        assert_eq!(x.get("name").unwrap().as_str(), Some("exchange"));
        assert_eq!(x.get("ts").unwrap().as_f64(), Some(1.5));
        assert_eq!(x.get("dur").unwrap().as_f64(), Some(2.5));
        assert_eq!(x.get("args").unwrap().get("arg").unwrap().as_usize(), Some(3));
        let c = evs
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
            .expect("counter event");
        assert_eq!(c.get("args").unwrap().get("value").unwrap().as_usize(), Some(99));
    }

    #[test]
    fn jsonl_roundtrip_and_summary() {
        forall(40, 0x10C4_11, |g| {
            let name = hostile_name(g);
            let dir = std::env::temp_dir().join(format!("cser-obs-test-{}", g.case));
            let snaps = vec![RingSnapshot {
                name: name.clone(),
                events: vec![
                    Event {
                        phase: Phase::GradCompute as u8,
                        kind: KIND_SPAN,
                        arg: NO_ARG,
                        start_ns: 10,
                        dur_ns: 30,
                    },
                    Event {
                        phase: Phase::Exchange as u8,
                        kind: KIND_SPAN,
                        arg: 1,
                        start_ns: 50,
                        dur_ns: 20,
                    },
                ],
                dropped: 3,
            }];
            let peers = vec![
                PeerCounters::default(),
                PeerCounters {
                    frames_sent: 2,
                    payload_bits_sent: 128,
                    blocked_send_ns: 0,
                    frames_received: 2,
                    payload_bits_received: 128,
                    stale_discards: 0,
                },
            ];
            let path = write_rank_jsonl(&dir, 1, &snaps, &peers)
                .map_err(|e| format!("write: {e}"))?;
            let tr = read_rank_jsonl(&path)?;
            let _ = std::fs::remove_file(&path);
            let _ = std::fs::remove_dir(&dir);
            prop_assert!(tr.rank == 1, "rank {} != 1", tr.rank);
            prop_assert!(
                tr.threads.len() == 1 && tr.threads[0].name == name,
                "thread meta mangled: {:?}",
                tr.threads
            );
            prop_assert!(tr.threads[0].dropped == 3, "dropped {}", tr.threads[0].dropped);
            prop_assert!(tr.events.len() == 2, "events {}", tr.events.len());
            prop_assert!(
                tr.events[0].phase == Phase::GradCompute && tr.events[0].arg.is_none(),
                "event 0 mangled"
            );
            prop_assert!(
                tr.events[1].arg == Some(1) && tr.events[1].dur_ns == 20,
                "event 1 mangled"
            );
            prop_assert!(
                tr.peers.len() == 2 && tr.peers[1] == peers[1],
                "peer counters mangled: {:?}",
                tr.peers
            );
            // Summary folds spans per phase and carries the schema.
            let sum = summary_json(std::slice::from_ref(&tr), None);
            let j = Json::parse(&sum).map_err(|e| format!("summary JSON: {e}"))?;
            prop_assert!(
                j.get("schema").and_then(Json::as_str) == Some(SUMMARY_SCHEMA),
                "summary schema missing"
            );
            let ranks = j.get("ranks").and_then(Json::as_arr).ok_or("ranks")?;
            let phases = ranks[0].get("phases").and_then(Json::as_arr).ok_or("phases")?;
            prop_assert!(phases.len() == Phase::COUNT, "phase rows {}", phases.len());
            let grad = phases
                .iter()
                .find(|p| p.get("phase").and_then(Json::as_str) == Some("grad_compute"))
                .ok_or("grad_compute row")?;
            prop_assert!(
                grad.get("count").and_then(Json::as_usize) == Some(1),
                "grad_compute count"
            );
            Ok(())
        });
    }
}
