//! In-process peer links: a full mesh of mpsc channels.
//!
//! [`channel_mesh`] hands out one [`MeshTransport`] per worker; each is the
//! channel-backed [`PeerTransport`] a persistent worker thread owns for its
//! whole life.  Frames are `Arc<WireMsg>` so a broadcast (the parameter
//! server's aggregate downlink) shares one allocation across all receivers
//! instead of deep-cloning bench-scale dense aggregates.
//!
//! Failure semantics replace the old rendezvous poison protocol: when a
//! worker thread dies, its `MeshTransport` drops, every channel it touched
//! disconnects, and any peer blocked in (or later entering) a collective
//! gets a [`TransportError`] instead of deadlocking.  Resident workers turn
//! that error into a panic, which `std::thread::scope` then propagates.

use super::peer::{PeerTransport, Tag, TransportError};
use super::wire::WireMsg;
use crate::obs::PeerCounters;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

type Frame = (u64, Tag, Arc<WireMsg>);

/// One worker's channel endpoints into the fleet (index = peer rank; the
/// self slot is empty).
pub struct MeshTransport {
    rank: usize,
    n: usize,
    txs: Vec<Option<Sender<Frame>>>,
    rxs: Vec<Option<Receiver<Frame>>>,
    /// Per-peer wire counters, mirroring `TcpTransport::per_peer` so the
    /// two transports export identical metrics (both feed
    /// `obs::metrics::sync_from_peers` the same way).  Channel sends are
    /// unbounded and never block, so `blocked_send_ns` stays zero here —
    /// a structural statement, not a measurement gap.
    pub per_peer: Vec<PeerCounters>,
}

/// Build the full n-way mesh: n·(n−1) channels, one per directed pair.
pub fn channel_mesh(n: usize) -> Vec<MeshTransport> {
    assert!(n >= 1);
    let mut eps: Vec<MeshTransport> = (0..n)
        .map(|rank| MeshTransport {
            rank,
            n,
            txs: (0..n).map(|_| None).collect(),
            rxs: (0..n).map(|_| None).collect(),
            per_peer: vec![PeerCounters::default(); n],
        })
        .collect();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let (tx, rx) = channel();
            eps[i].txs[j] = Some(tx);
            eps[j].rxs[i] = Some(rx);
        }
    }
    eps
}

impl MeshTransport {
    /// Is this frame stale for a receiver waiting on (`round`, `tag`)?
    /// Rounds below `round` are leftovers of censored rounds; same-round
    /// [`Tag::Chunk`] frames against a non-Chunk expectation are leftovers
    /// of a ring attempt that aborted into the parameter-server fallback
    /// (Chunk is ring-only, so the mismatch is unambiguous).
    fn is_stale(frame: &Frame, round: u64, tag: Tag) -> bool {
        frame.0 < round || (frame.0 == round && frame.1 == Tag::Chunk && tag != Tag::Chunk)
    }

    /// Count a discarded stale frame: its payload still crossed the
    /// channel, so its bits count as received — mirroring TCP, where
    /// `read_frame` counts every frame before the staleness check.
    fn count_stale(&mut self, from: usize, frame: &Frame) {
        self.per_peer[from].frames_received += 1;
        self.per_peer[from].payload_bits_received += frame.2.bit_len;
        self.per_peer[from].stale_discards += 1;
    }

    fn hangup(&self, peer: usize) -> TransportError {
        TransportError::peer_down(
            peer,
            format!("hung up on worker {} (its thread died mid-run)", self.rank),
        )
    }

    fn validate(
        &mut self,
        from: usize,
        round: u64,
        tag: Tag,
        frame: Frame,
    ) -> Result<Arc<WireMsg>, TransportError> {
        let (r, tg, msg) = frame;
        if r != round || tg != tag {
            return Err(TransportError::failed(format!(
                "worker {} desynchronized: expected (round {round}, {tag:?}) from peer {from}, \
                 got (round {r}, {tg:?})",
                self.rank
            )));
        }
        self.per_peer[from].frames_received += 1;
        self.per_peer[from].payload_bits_received += msg.bit_len;
        Ok(msg)
    }
}

impl PeerTransport for MeshTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, round: u64, tag: Tag, msg: WireMsg) -> Result<(), TransportError> {
        let bit_len = msg.bit_len;
        self.txs[to]
            .as_ref()
            .expect("mesh has no self-links")
            .send((round, tag, Arc::new(msg)))
            .map_err(|_| self.hangup(to))?;
        self.per_peer[to].frames_sent += 1;
        self.per_peer[to].payload_bits_sent += bit_len;
        Ok(())
    }

    fn broadcast(&mut self, round: u64, tag: Tag, msg: WireMsg) -> Result<(), TransportError> {
        let bit_len = msg.bit_len;
        let shared = Arc::new(msg);
        for j in 0..self.n {
            if j != self.rank {
                self.txs[j]
                    .as_ref()
                    .expect("mesh has no self-links")
                    .send((round, tag, Arc::clone(&shared)))
                    .map_err(|_| self.hangup(j))?;
                self.per_peer[j].frames_sent += 1;
                self.per_peer[j].payload_bits_sent += bit_len;
            }
        }
        Ok(())
    }

    fn recv(&mut self, from: usize, round: u64, tag: Tag) -> Result<Arc<WireMsg>, TransportError> {
        let frame = self.rxs[from]
            .as_ref()
            .expect("mesh has no self-links")
            .recv()
            .map_err(|_| self.hangup(from))?;
        self.validate(from, round, tag, frame)
    }

    fn recv_deadline(
        &mut self,
        from: usize,
        round: u64,
        tag: Tag,
        timeout: Option<std::time::Duration>,
    ) -> Result<Option<Arc<WireMsg>>, TransportError> {
        let Some(timeout) = timeout else {
            // No deadline: plain blocking semantics, but still drop stale
            // frames (leftovers from a round the caller censored).
            loop {
                let frame = self.rxs[from]
                    .as_ref()
                    .expect("mesh has no self-links")
                    .recv()
                    .map_err(|_| self.hangup(from))?;
                if Self::is_stale(&frame, round, tag) {
                    self.count_stale(from, &frame);
                    continue;
                }
                return self.validate(from, round, tag, frame).map(Some);
            }
        };
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            let frame = match self.rxs[from]
                .as_ref()
                .expect("mesh has no self-links")
                .recv_timeout(left)
            {
                Ok(f) => f,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => return Ok(None),
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(self.hangup(from))
                }
            };
            if Self::is_stale(&frame, round, tag) {
                self.count_stale(from, &frame);
                continue;
            }
            return self.validate(from, round, tag, frame).map(Some);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{exchange_mean, psync};
    use crate::compressor::{
        BlockTopK, Compressor, Grbs, Identity, Qsgd, RandK, SignSgd, TopK, Zero,
    };
    use crate::transport::peer;
    use crate::util::prop::{forall, slices_close, Gen};

    /// Run `f(rank, transport)` on n threads, one per mesh endpoint.
    fn run_peers<T: Send, F: Fn(usize, &mut MeshTransport) -> T + Sync>(
        n: usize,
        f: F,
    ) -> Vec<T> {
        let eps = channel_mesh(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(w, mut tp)| {
                    let f = &f;
                    s.spawn(move || f(w, &mut tp))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("peer thread panicked")).collect()
        })
    }

    fn compressor_set(d: usize) -> Vec<std::sync::Arc<dyn Compressor>> {
        vec![
            std::sync::Arc::new(Grbs::new(4.0, (d / 4).max(1), 77)),
            std::sync::Arc::new(RandK::new(4.0)),
            std::sync::Arc::new(TopK::new(4.0)),
            std::sync::Arc::new(BlockTopK::new(4.0, (d / 8).max(1))),
            std::sync::Arc::new(Qsgd::new(4)),
            std::sync::Arc::new(SignSgd),
            std::sync::Arc::new(Identity),
            std::sync::Arc::new(Zero),
        ]
    }

    #[test]
    fn prop_peer_psync_matches_in_process() {
        // Peer-owned collectives over the mesh: PS-path compressors must
        // match the in-process reference bit-for-bit, ring-path within f32
        // reduction tolerance — the same contract the old runner-thread
        // backend carried, now with zero per-call spawns.
        forall(10, 0x9E51, |g: &mut Gen| {
            let n = g.usize_in(2, 6);
            let d = g.usize_in(8, 96);
            let case = g.case;
            let vs = g.worker_vecs(n, d);
            for c in compressor_set(d) {
                let ring = c.globally_synchronized() && !c.is_dense();
                let mut a = vs.clone();
                let mut ra = vec![vec![0.0f32; d]; n];
                let ia = psync(&mut a, Some(&mut ra), c.as_ref(), case);
                let out = run_peers(n, |w, tp| {
                    let mut v = vs[w].clone();
                    let mut r = vec![0.0f32; d];
                    let round =
                        peer::psync(tp, &mut v, Some(&mut r), c.as_ref(), case).unwrap();
                    (v, r, round)
                });
                let tol = if ring { 1e-5 } else { 0.0 };
                for (i, (v, r, round)) in out.iter().enumerate() {
                    slices_close(&a[i], v, tol)
                        .map_err(|e| format!("{} psync w{i}: {e}", c.name()))?;
                    slices_close(&ra[i], r, tol)
                        .map_err(|e| format!("{} resid w{i}: {e}", c.name()))?;
                    crate::prop_assert!(
                        round.upload_bits_per_worker == ia.upload_bits_per_worker,
                        "{} w{i}: accounted bits differ: {} vs {}",
                        c.name(),
                        round.upload_bits_per_worker,
                        ia.upload_bits_per_worker
                    );
                    crate::prop_assert!(
                        round.allreduce_compatible == ia.allreduce_compatible,
                        "{} w{i}: allreduce flag differs",
                        c.name()
                    );
                }
                // exchange_mean too
                let mut a = vs.clone();
                exchange_mean(&mut a, None, c.as_ref(), case);
                let out = run_peers(n, |w, tp| {
                    let mut v = vs[w].clone();
                    peer::exchange_mean(tp, &mut v, None, c.as_ref(), case).unwrap();
                    v
                });
                for (i, v) in out.iter().enumerate() {
                    slices_close(&a[i], v, tol)
                        .map_err(|e| format!("{} exch w{i}: {e}", c.name()))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mean_dense_is_bit_identical_to_mean_rows() {
        let n = 5;
        let d = 33;
        let mut g = Gen::replay(0x3E, 0);
        let vs = g.worker_vecs(n, d);
        let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
        let mut expect = vec![0.0f32; d];
        crate::util::math::mean_rows(&refs, &mut expect);
        let out = run_peers(n, |w, tp| {
            let mut v = vs[w].clone();
            peer::mean_dense(tp, &mut v, 9).unwrap();
            v
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(&expect, v, "worker {i}");
        }
    }

    #[test]
    fn vote_verdict_is_uniform_and_exact() {
        let n = 3;
        let out = run_peers(n, |w, tp| {
            peer::vote(tp, 10.0 + w as f64, 5.0, 1).unwrap()
        });
        let expect = (10.0 + 11.0 + 12.0) / 3.0;
        for (mean, stop) in &out {
            assert!((*mean - expect).abs() < 1e-12);
            assert!(*stop, "mean 11 > 5 must stop");
        }
        // NaN losses must trip the brake even though NaN > x is false
        let out = run_peers(n, |w, tp| {
            let loss = if w == 1 { f64::NAN } else { 0.0 };
            peer::vote(tp, loss, 5.0, 2).unwrap()
        });
        assert!(out.iter().all(|(_, stop)| *stop));
    }

    #[test]
    fn agree_is_an_or_across_the_fleet() {
        let n = 4;
        let out = run_peers(n, |w, tp| peer::agree(tp, w == 2, 3).unwrap());
        assert!(out.iter().all(|&b| b));
        let out = run_peers(n, |_, tp| peer::agree(tp, false, 4).unwrap());
        assert!(out.iter().all(|&b| !b));
    }

    #[test]
    fn all_equal_detects_mismatched_ranks_exactly() {
        // Integer agreement: exact for every (n, value), including the
        // fleet sizes where a float mean would re-round (n = 3, value 7).
        for n in [2usize, 3, 5] {
            let out = run_peers(n, |_, tp| peer::all_equal(tp, 7, 5).unwrap());
            assert!(out.iter().all(|&b| b), "n={n}: equal values must agree");
            let out = run_peers(n, |w, tp| {
                peer::all_equal(tp, if w == n - 1 { 8 } else { 7 }, 6).unwrap()
            });
            assert!(out.iter().all(|&b| !b), "n={n}: one stray rank must be detected");
        }
    }

    #[test]
    fn dead_peer_errors_instead_of_deadlocking() {
        // Worker 1 dies before its collective; the survivor's recv must
        // surface a TransportError (its resident wrapper then panics),
        // not block forever.
        let mut eps = channel_mesh(2);
        let tp1 = eps.pop().unwrap();
        let mut tp0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            drop(tp1); // rank 1 "dies"
        });
        h.join().unwrap();
        let c = Identity;
        let mut v = vec![1.0f32; 4];
        let err = peer::psync(&mut tp0, &mut v, None, &c, 1);
        assert!(err.is_err(), "collective against a dead peer must error");
        // The death is attributable without string-matching: the error is
        // the distinguishable PeerDown variant naming rank 1.
        assert_eq!(err.unwrap_err().downed_peer(), Some(1));
    }

    #[test]
    fn desynchronized_frames_are_rejected() {
        let mut eps = channel_mesh(2);
        let mut tp1 = eps.pop().unwrap();
        let mut tp0 = eps.pop().unwrap();
        tp0.send(1, 7, Tag::Loss, WireMsg { words: vec![0], bit_len: 64 }).unwrap();
        let err = tp1.recv(0, 8, Tag::Loss).unwrap_err();
        assert!(err.to_string().contains("desynchronized"), "{err}");
        // A framing failure is terminal, never attributable as a death.
        assert_eq!(err.downed_peer(), None);
    }

    #[test]
    fn recv_deadline_times_out_and_discards_stale_rounds() {
        let mut eps = channel_mesh(2);
        let mut tp1 = eps.pop().unwrap();
        let mut tp0 = eps.pop().unwrap();
        let short = Some(std::time::Duration::from_millis(10));
        // Nothing queued: the deadline expires with Ok(None).
        let got = tp1.recv_deadline(0, 3, Tag::Loss, short).unwrap();
        assert!(got.is_none(), "empty channel must time out, not block");
        // A stale round-2 frame (censored earlier) is silently discarded;
        // the round-3 frame behind it is delivered.
        tp0.send(1, 2, Tag::Upload, WireMsg { words: vec![1], bit_len: 64 }).unwrap();
        tp0.send(1, 3, Tag::Loss, WireMsg { words: vec![2], bit_len: 64 }).unwrap();
        let got = tp1.recv_deadline(0, 3, Tag::Loss, short).unwrap();
        assert_eq!(got.expect("round-3 frame must arrive").words, vec![2]);
    }
}
