//! The bucketed synchronization pipeline: overlap compression with the
//! collective exchange.
//!
//! The whole-vector peer path serializes every round as
//! `select → encode → exchange → apply` over one monolithic vector, leaving
//! the CPU idle during socket/channel waits and the network idle during
//! compression.  [`pipelined_sync`] splits the vector into
//! [`SyncBuckets`] and double-buffers: a persistent per-worker **prepare
//! thread** compresses bucket k+1 (selection, gather/encode, self-decode)
//! while the transport-owning thread runs bucket k's ring or
//! parameter-server exchange — so rank 0's serial aggregation work overlaps
//! every other rank's (and its own) compression, and two buckets can be in
//! flight on one link (frames are tagged with the per-bucket
//! [`SyncBuckets::sub_round`]).
//!
//! The wire protocol per bucket is byte-identical to the whole-vector
//! path's — the exchange phases (`peer::ring_rounds`, `peer::ps_rounds`)
//! and the compression phase (`peer::ps_prepare`, `peer::gather`) are the
//! *same functions* the sequential path runs, just driven per bucket from
//! two threads.  Numerics: PS-path buckets are bit-identical to the
//! bucketed sequential reference (the central engine loop with the same
//! bucket schedule); ring-path buckets agree within the documented f32
//! reduction-order tolerance.  `rust/tests/pipeline_parity.rs` pins both
//! across every plan family.
//!
//! Queue discipline: jobs and results ride two SPSC mpsc channels in
//! strict bucket order (at most one bucket being prepared while one is on
//! the wire — the "double buffer").  When an exchange fails mid-round,
//! [`pipelined_sync`] drains every still-in-flight prepared bucket off
//! the result queue (recycling its buffers) before propagating the error,
//! so the prepare thread parks cleanly and the pipeline stays reusable —
//! an elastic trainer that censors a round and carries on does not wedge
//! the SPSC queues.
//!
//! Elastic views: ring-routed buckets consult the transport's
//! [`PeerTransport::view_mask`] and [`PeerTransport::ring_degraded`]
//! exactly like the whole-vector path.  A bucket whose ring stalls
//! mid-flight (peer death or deadline miss) latches the transport's
//! degraded flag and re-runs *the same sub-round* as a parameter-server
//! exchange — tags disambiguate the two shapes on the wire, and the PS
//! server path censors-and-rescales dead peers, so a censored peer simply
//! contributes zero to that bucket's mean.

use super::peer::{self, Mode, PeerTransport, TransportError};
use crate::collective::bucket::{SyncBuckets, SyncInfo};
use crate::collective::{PsyncRound, WireCost};
use crate::compressor::{payload_bits_wire, Compressor, Ctx, Scratch, Selection};
use crate::kernel::dense as math;
use crate::obs::{self, Phase};
use crate::transport::wire::WireMsg;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// One bucket's compression request (main thread → prepare thread).
struct PrepJob {
    bucket: usize,
    /// Ring route (shared support) vs parameter server.
    ring: bool,
    c: Arc<dyn Compressor>,
    /// `round` is the bucket's sub-round; `worker` the sender's rank.
    ctx: Ctx,
    /// Copy of the bucket's values (taken before any mutation this round).
    data: Vec<f32>,
    /// Recycled working buffer (becomes `compact` or the decoded `own`).
    buf: Vec<f32>,
}

/// One bucket's compressed form (prepare thread → main thread).
struct Prepared {
    bucket: usize,
    sel: Selection,
    /// Accounted upload bits for this bucket's message.
    bits: u64,
    /// The bucket's original values (returned for residual arithmetic).
    data: Vec<f32>,
    payload: Payload,
}

enum Payload {
    /// Shared-support route: gathered selected values, ready for the ring.
    Ring { compact: Vec<f32> },
    /// PS route: encoded upload + its decoded form (the exact bits the
    /// server aggregates).
    Ps { msg: WireMsg, own: Vec<f32> },
    /// Empty selection: nothing travels (buffer returned for recycling).
    Empty { buf: Vec<f32> },
}

fn prepare(job: PrepJob, scratch: &mut Scratch) -> Prepared {
    let PrepJob { bucket, ring, c, ctx, data, mut buf } = job;
    let d = data.len();
    if ring {
        // Globally-synchronized selections ignore the worker id.
        let sel = {
            let _s = obs::Span::enter(Phase::Select);
            c.select_with(Ctx { round: ctx.round, worker: 0 }, &data, scratch)
        };
        let bits = payload_bits_wire(c.wire_scheme(), &sel, d);
        if sel.count(d) == 0 {
            buf.clear();
            return Prepared { bucket, sel, bits: 0, data, payload: Payload::Empty { buf } };
        }
        {
            let _s = obs::Span::enter(Phase::Encode);
            peer::gather(&sel, &data, &mut buf);
        }
        Prepared { bucket, sel, bits, data, payload: Payload::Ring { compact: buf } }
    } else {
        let up = peer::ps_prepare(c.as_ref(), ctx, &data, buf, scratch)
            .expect("self-encoded frame must decode");
        let bits = up.msg.bit_len;
        Prepared { bucket, sel: up.sel, bits, data, payload: Payload::Ps { msg: up.msg, own: up.own } }
    }
}

fn helper_loop(rx: Receiver<PrepJob>, tx: Sender<Prepared>) {
    obs::register_thread("cser-bucket-prep");
    let mut scratch = Scratch::new();
    while let Ok(job) = rx.recv() {
        let prep = {
            let _s = obs::Span::enter_arg(Phase::PipelinePrepare, job.bucket as u64);
            prepare(job, &mut scratch)
        };
        if tx.send(prep).is_err() {
            break; // driver dropped mid-run: stop quietly
        }
    }
}

/// A persistent per-worker prepare thread plus the buffers and scratch the
/// transport-side half of the pipeline needs.  One per worker, living for
/// one worker-driver run — a full `run_resident`/`run_distributed` call,
/// i.e. an epoch of steps in the trainers — parking on its queue between
/// syncs.  No per-round (and certainly no per-bucket) spawns; the cost is
/// one thread spawn+join per worker per driver call.
pub struct BucketPipeline {
    tx: Option<Sender<PrepJob>>,
    rx: Receiver<Prepared>,
    handle: Option<JoinHandle<()>>,
    /// Recycled f32 buffers (bucket copies, compacts, own/agg staging).
    spare: Vec<Vec<f32>>,
    /// Transport-side scratch (PS server buffers).
    scratch: Scratch,
}

impl BucketPipeline {
    pub fn new() -> Self {
        let (jtx, jrx) = channel::<PrepJob>();
        let (ptx, prx) = channel::<Prepared>();
        let handle = std::thread::Builder::new()
            .name("cser-bucket-prep".into())
            .spawn(move || helper_loop(jrx, ptx))
            .expect("spawning the bucket-prepare thread");
        BucketPipeline {
            tx: Some(jtx),
            rx: prx,
            handle: Some(handle),
            spare: Vec::new(),
            scratch: Scratch::new(),
        }
    }

    fn take_buf(&mut self) -> Vec<f32> {
        self.spare.pop().unwrap_or_default()
    }

    fn submit(&mut self, job: PrepJob) -> Result<(), TransportError> {
        self.tx
            .as_ref()
            .expect("pipeline sender lives until drop")
            .send(job)
            .map_err(|_| TransportError::failed("bucket-prepare thread died"))
    }

    fn recv_prepared(&mut self, bucket: usize) -> Result<Prepared, TransportError> {
        let prep = self
            .rx
            .recv()
            .map_err(|_| TransportError::failed("bucket-prepare thread died"))?;
        if prep.bucket != bucket {
            return Err(TransportError::failed(format!(
                "bucket pipeline desynchronized: expected bucket {bucket}, got {}",
                prep.bucket
            )));
        }
        Ok(prep)
    }

    /// Pull `in_flight` still-queued prepared buckets off the result
    /// channel and recycle their buffers, leaving the queues empty and the
    /// prepare thread parked.  Called on the error path of
    /// [`pipelined_sync`] so an aborted round (e.g. a censored elastic
    /// peer) leaves the pipeline reusable for the next one.  A closed
    /// channel (prepare thread died) just ends the drain — every queued
    /// result is delivered before `recv` reports the hangup.
    fn drain(&mut self, in_flight: usize) {
        for _ in 0..in_flight {
            let Ok(prep) = self.rx.recv() else { return };
            match prep.payload {
                Payload::Ring { compact } => self.spare.push(compact),
                Payload::Ps { own, .. } => self.spare.push(own),
                Payload::Empty { buf } => self.spare.push(buf),
            }
            self.spare.push(prep.data);
        }
    }
}

impl Default for BucketPipeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for BucketPipeline {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the job queue; the helper exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Queue bucket `b`'s compression job (copying the bucket's current values).
#[allow(clippy::too_many_arguments)]
fn submit_job(
    pipe: &mut BucketPipeline,
    buckets: &SyncBuckets,
    t_round: u64,
    rank: usize,
    ring: bool,
    c: &Arc<dyn Compressor>,
    v: &[f32],
    b: usize,
) -> Result<(), TransportError> {
    let (s, e) = buckets.range(b);
    let mut data = pipe.take_buf();
    data.clear();
    data.extend_from_slice(&v[s..e]);
    let buf = pipe.take_buf();
    pipe.submit(PrepJob {
        bucket: b,
        ring,
        c: Arc::clone(c),
        ctx: Ctx { round: buckets.sub_round(t_round, b), worker: rank as u32 },
        data,
        buf,
    })
}

/// Run bucket `b`'s exchange + apply on the transport thread.  The wire
/// traffic and arithmetic are identical to the whole-vector path's,
/// restricted to the bucket (see the module docs).
#[allow(clippy::too_many_arguments)]
fn exchange_bucket(
    t: &mut dyn PeerTransport,
    prep: Prepared,
    mode: Mode,
    c: &Arc<dyn Compressor>,
    wire_round: u64,
    v: &mut [f32],
    resid: Option<&mut [f32]>,
    scratch: &mut Scratch,
    spare: &mut Vec<Vec<f32>>,
) -> Result<PsyncRound, TransportError> {
    let db = v.len();
    let bkt = prep.bucket as u64;
    match prep.payload {
        Payload::Empty { buf } => {
            // C = 0 on this bucket: nothing travels.
            if let Some(r) = resid {
                r.copy_from_slice(v);
            }
            if mode == Mode::Exchange {
                math::fill(v, 0.0);
            }
            spare.push(buf);
            spare.push(prep.data);
            Ok(PsyncRound {
                selections: vec![prep.sel],
                upload_bits_per_worker: 0,
                allreduce_compatible: true,
                wire: Some(WireCost { up_bits: 0, down_bits: 0, steps: 0 }),
            })
        }
        Payload::Ring { mut compact } => {
            // A degraded view (pending censor or an earlier stall this
            // epoch) skips the ring outright; otherwise attempt it and fall
            // back if it stalls mid-round.  Either way the fallback re-runs
            // this bucket as a PS exchange at the SAME sub-round — tags
            // keep the two shapes apart on the wire, and the PS server
            // censors-and-rescales the dead peer.  `v`/`resid` are still
            // untouched here (only the compact staging saw partial sums),
            // so re-preparing from the bucket's saved `data` is exact.
            if !t.ring_degraded() {
                let rr = {
                    let _s = obs::Span::enter_arg(Phase::Exchange, bkt);
                    peer::ring_rounds(t, &mut compact, wire_round)?
                };
                if let Some((up, down)) = rr {
                    let l = peer::ring_members(&*t).len() as u32;
                    let _s = obs::Span::enter_arg(Phase::Decode, bkt);
                    // Residual (v off support) before the mean overwrites
                    // the selected ranges; v itself was untouched while the
                    // bucket was in flight.
                    if let Some(r) = resid {
                        r.copy_from_slice(v);
                        prep.sel.for_each_range(db, |s, e| math::fill(&mut r[s..e], 0.0));
                    }
                    if mode == Mode::Exchange {
                        math::fill(v, 0.0);
                    }
                    let mut cursor = 0usize;
                    prep.sel.for_each_range(db, |s, e| {
                        v[s..e].copy_from_slice(&compact[cursor..cursor + (e - s)]);
                        cursor += e - s;
                    });
                    drop(_s); // Decode span ends; buffer recycling untimed.
                    spare.push(compact);
                    spare.push(prep.data);
                    return Ok(PsyncRound {
                        selections: vec![prep.sel],
                        upload_bits_per_worker: prep.bits,
                        allreduce_compatible: true,
                        wire: Some(WireCost {
                            up_bits: up,
                            down_bits: down,
                            steps: 2 * (l - 1),
                        }),
                    });
                }
                t.on_ring_stall();
            }
            // Fallback: recycle the ring staging (its partial sums are
            // abandoned) and re-encode the bucket as a PS upload.
            compact.clear();
            let up = peer::ps_prepare(
                c.as_ref(),
                Ctx { round: wire_round, worker: t.rank() as u32 },
                &prep.data,
                compact,
                scratch,
            )?;
            let ps = Prepared {
                bucket: prep.bucket,
                sel: up.sel,
                bits: up.msg.bit_len,
                data: prep.data,
                payload: Payload::Ps { msg: up.msg, own: up.own },
            };
            exchange_bucket(t, ps, mode, c, wire_round, v, resid, scratch, spare)
        }
        Payload::Ps { msg, own } => {
            let mut agg = spare.pop().unwrap_or_default();
            let (acct, up, down) = {
                let _s = obs::Span::enter_arg(Phase::Exchange, bkt);
                peer::ps_rounds(t, c.as_ref(), wire_round, msg, &own, &mut agg, scratch)?
            };
            let _s = obs::Span::enter_arg(Phase::Decode, bkt);
            // Apply: v' = mean + (v − C(v)), the residual computed against
            // the exact decoded upload — same expressions as the
            // whole-vector path, element by element.
            match mode {
                Mode::Psync => {
                    if let Some(r) = resid {
                        for j in 0..db {
                            let rj = prep.data[j] - own[j];
                            r[j] = rj;
                            v[j] = agg[j] + rj;
                        }
                    } else {
                        for j in 0..db {
                            v[j] = agg[j] + (prep.data[j] - own[j]);
                        }
                    }
                }
                Mode::Exchange => {
                    if let Some(r) = resid {
                        for j in 0..db {
                            r[j] = prep.data[j] - own[j];
                        }
                    }
                    v.copy_from_slice(&agg);
                }
            }
            drop(_s); // Decode span ends here; buffer recycling is untimed.
            spare.push(agg);
            spare.push(own);
            spare.push(prep.data);
            Ok(PsyncRound {
                selections: vec![prep.sel],
                upload_bits_per_worker: acct,
                allreduce_compatible: false,
                wire: Some(WireCost { up_bits: up, down_bits: down, steps: 2 }),
            })
        }
    }
}

/// Degenerate single-peer fleet: no exchange — each bucket runs the
/// in-process collective locally (identical to the central bucketed
/// reference at n = 1).
fn local_sync(
    pipe: &mut BucketPipeline,
    mode: Mode,
    v: &mut [f32],
    mut resid: Option<&mut [f32]>,
    c: &Arc<dyn Compressor>,
    t_round: u64,
    buckets: &SyncBuckets,
) -> Result<SyncInfo, TransportError> {
    let mut info = SyncInfo::new();
    for b in 0..buckets.k() {
        let (s, e) = buckets.range(b);
        let sub = buckets.sub_round(t_round, b);
        let mut data = pipe.take_buf();
        data.clear();
        data.extend_from_slice(&v[s..e]);
        let mut vs = vec![data];
        let round = if let Some(r) = resid.as_deref_mut() {
            let mut rs = vec![vec![0.0f32; e - s]];
            let round = match mode {
                Mode::Psync => {
                    crate::collective::psync_with(&mut vs, Some(&mut rs), c.as_ref(), sub, &mut pipe.scratch)
                }
                Mode::Exchange => crate::collective::exchange_mean_with(
                    &mut vs,
                    Some(&mut rs),
                    c.as_ref(),
                    sub,
                    &mut pipe.scratch,
                ),
            };
            r[s..e].copy_from_slice(&rs[0]);
            round
        } else {
            match mode {
                Mode::Psync => crate::collective::psync_with(&mut vs, None, c.as_ref(), sub, &mut pipe.scratch),
                Mode::Exchange => {
                    crate::collective::exchange_mean_with(&mut vs, None, c.as_ref(), sub, &mut pipe.scratch)
                }
            }
        };
        v[s..e].copy_from_slice(&vs[0]);
        pipe.spare.push(vs.pop().unwrap());
        info.push(s, e, round);
    }
    Ok(info)
}

/// This worker's side of a bucketed, double-buffered PSync/exchange round:
/// bucket k+1 compresses on the prepare thread while bucket k's exchange
/// runs here.  `v` (and `resid`) cover the full flat vector; the returned
/// [`SyncInfo`] carries one [`PsyncRound`] per bucket plus the merged
/// accounting (the exact per-bucket sum — see `collective::bucket` for the
/// sum-invariance contract).
#[allow(clippy::too_many_arguments)]
pub fn pipelined_sync(
    pipe: &mut BucketPipeline,
    t: &mut dyn PeerTransport,
    mode: Mode,
    v: &mut [f32],
    mut resid: Option<&mut [f32]>,
    c: &Arc<dyn Compressor>,
    t_round: u64,
    buckets: &SyncBuckets,
) -> Result<SyncInfo, TransportError> {
    debug_assert_eq!(v.len(), buckets.dim());
    if t.n() == 1 {
        return local_sync(pipe, mode, v, resid, c, t_round, buckets);
    }
    let rank = t.rank();
    let ring = c.globally_synchronized() && !c.is_dense();
    let k = buckets.k();
    let mut info = SyncInfo::new();
    // `in_flight` counts jobs submitted but whose result has not been
    // pulled off the queue yet.  Every error path drains that many results
    // before propagating, so the queues end the call empty and the
    // pipeline can serve the next round (see the module docs).
    submit_job(pipe, buckets, t_round, rank, ring, c, v, 0)?;
    let mut in_flight = 1usize;
    for b in 0..k {
        if b + 1 < k {
            if let Err(e) = submit_job(pipe, buckets, t_round, rank, ring, c, v, b + 1) {
                pipe.drain(in_flight);
                return Err(e);
            }
            in_flight += 1;
        }
        // Time spent here is the pipeline stalling on its own compression —
        // the complement of the overlap the double buffer exists to win.
        let prep = {
            let _s = obs::Span::enter_arg(Phase::BarrierWait, b as u64);
            pipe.recv_prepared(b)
        };
        // `recv_prepared` pulled one result off the queue even when it
        // reports a desync (a closed-channel error pulled nothing, but then
        // the drain's own recv fails immediately too — still clean).
        in_flight -= 1;
        let prep = match prep {
            Ok(p) => p,
            Err(e) => {
                pipe.drain(in_flight);
                return Err(e);
            }
        };
        let (s, e) = buckets.range(b);
        let wire_round = buckets.sub_round(t_round, b);
        let rb = resid.as_deref_mut().map(|r| &mut r[s..e]);
        let round = match exchange_bucket(
            t,
            prep,
            mode,
            c,
            wire_round,
            &mut v[s..e],
            rb,
            &mut pipe.scratch,
            &mut pipe.spare,
        ) {
            Ok(r) => r,
            Err(e) => {
                pipe.drain(in_flight);
                return Err(e);
            }
        };
        info.push(s, e, round);
    }
    debug_assert_eq!(in_flight, 0, "every submitted bucket must be consumed");
    Ok(info)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Grbs, Identity, Qsgd, RandK, TopK, Zero};
    use crate::transport::mesh::channel_mesh;
    use crate::util::prop::{slices_close, Gen};

    /// Sequential bucketed reference: the central in-process collective run
    /// bucket by bucket with the same sub-rounds.
    fn sequential_bucketed(
        vs: &[Vec<f32>],
        c: &Arc<dyn Compressor>,
        t_round: u64,
        buckets: &SyncBuckets,
        exchange: bool,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, u64) {
        let n = vs.len();
        let d = vs[0].len();
        let mut out = vs.to_vec();
        let mut res = vec![vec![0.0f32; d]; n];
        let mut bits = 0u64;
        for b in 0..buckets.k() {
            let (s, e) = buckets.range(b);
            let mut stage: Vec<Vec<f32>> = out.iter().map(|v| v[s..e].to_vec()).collect();
            let mut rstage: Vec<Vec<f32>> = vec![vec![0.0f32; e - s]; n];
            let round = if exchange {
                crate::collective::exchange_mean(
                    &mut stage,
                    Some(&mut rstage),
                    c.as_ref(),
                    buckets.sub_round(t_round, b),
                )
            } else {
                crate::collective::psync(
                    &mut stage,
                    Some(&mut rstage),
                    c.as_ref(),
                    buckets.sub_round(t_round, b),
                )
            };
            bits += round.upload_bits_per_worker;
            for i in 0..n {
                out[i][s..e].copy_from_slice(&stage[i]);
                res[i][s..e].copy_from_slice(&rstage[i]);
            }
        }
        (out, res, bits)
    }

    fn run_pipelined(
        vs: &[Vec<f32>],
        c: &Arc<dyn Compressor>,
        t_round: u64,
        buckets: &SyncBuckets,
        mode: Mode,
    ) -> Vec<(Vec<f32>, Vec<f32>, u64)> {
        let n = vs.len();
        let d = vs[0].len();
        let eps = channel_mesh(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(w, mut tp)| {
                    let c = Arc::clone(c);
                    let buckets = buckets.clone();
                    let mut v = vs[w].clone();
                    s.spawn(move || {
                        let mut pipe = BucketPipeline::new();
                        let mut r = vec![0.0f32; d];
                        let info = pipelined_sync(
                            &mut pipe,
                            &mut tp,
                            mode,
                            &mut v,
                            Some(&mut r),
                            &c,
                            t_round,
                            &buckets,
                        )
                        .unwrap();
                        (v, r, info.upload_bits_per_worker)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pipelined peer panicked")).collect()
        })
    }

    #[test]
    fn pipelined_matches_sequential_bucketed_reference() {
        // PS-path compressors bit-identical, ring within f32 tolerance,
        // accounting exactly equal — per mode, per compressor, with uneven
        // bucket bounds.
        let (n, d) = (4, 103);
        let mut g = Gen::replay(0xB0C4, 0);
        let vs = g.worker_vecs(n, d);
        let buckets = SyncBuckets::from_bounds(vec![0, 37, 64, 103]);
        let comps: Vec<(Arc<dyn Compressor>, bool)> = vec![
            (Arc::new(TopK::new(4.0)), true),
            (Arc::new(RandK::new(4.0)), true),
            (Arc::new(Qsgd::new(4)), true),
            (Arc::new(Grbs::new(2.0, 8, 5)), false),
            (Arc::new(Identity), false),
            (Arc::new(Zero), false),
        ];
        for (c, exact) in &comps {
            for (mode, exchange) in [(Mode::Psync, false), (Mode::Exchange, true)] {
                let (want_v, want_r, want_bits) =
                    sequential_bucketed(&vs, c, 9, &buckets, exchange);
                let got = run_pipelined(&vs, c, 9, &buckets, mode);
                let tol = if *exact { 0.0 } else { 1e-5 };
                for (i, (v, r, bits)) in got.iter().enumerate() {
                    slices_close(&want_v[i], v, tol)
                        .unwrap_or_else(|e| panic!("{} {mode:?} w{i}: {e}", c.name()));
                    slices_close(&want_r[i], r, tol)
                        .unwrap_or_else(|e| panic!("{} {mode:?} resid w{i}: {e}", c.name()));
                    assert_eq!(*bits, want_bits, "{} {mode:?} w{i}: accounted bits", c.name());
                }
            }
        }
    }

    #[test]
    fn bucket_sum_accounting_equals_whole_vector_for_shared_support() {
        // GRBS with bucket-tiling blocks: per-bucket accounted bits sum to
        // exactly the whole-vector accounting (SharedSupport charges
        // 32·count either way), and Identity trivially so.
        let (n, d) = (4, 1024);
        let mut g = Gen::replay(0xACC7, 1);
        let vs = g.worker_vecs(n, d);
        let k = 4;
        let buckets = SyncBuckets::even(d, k);
        // Whole vector: 64 blocks of 16, keep 16 -> 256 values.  Per
        // bucket: 16 blocks of 16, keep 4 -> 64 values x 4 buckets = 256.
        let whole: Arc<dyn Compressor> = Arc::new(Grbs::new(4.0, 64, 7));
        let per_bucket: Arc<dyn Compressor> = Arc::new(Grbs::new(4.0, 16, 7));
        let mut vs_whole = vs.clone();
        let whole_round = crate::collective::psync(&mut vs_whole, None, whole.as_ref(), 3);
        let got = run_pipelined(&vs, &per_bucket, 3, &buckets, Mode::Psync);
        for (_, _, bits) in &got {
            assert_eq!(
                *bits, whole_round.upload_bits_per_worker,
                "bucket-sum accounting must equal whole-vector accounting"
            );
        }
        let ident: Arc<dyn Compressor> = Arc::new(Identity);
        let got = run_pipelined(&vs, &ident, 4, &buckets, Mode::Psync);
        for (_, _, bits) in &got {
            assert_eq!(*bits, d as u64 * 32);
        }
    }

    #[test]
    fn single_peer_pipelined_psync_is_identity() {
        let d = 40;
        let mut g = Gen::replay(0x51, 2);
        let v0 = g.vec(d);
        let buckets = SyncBuckets::even(d, 3);
        let c: Arc<dyn Compressor> = Arc::new(Grbs::new(2.0, 4, 3));
        let mut eps = channel_mesh(1);
        let mut tp = eps.pop().unwrap();
        let mut pipe = BucketPipeline::new();
        let mut v = v0.clone();
        let info =
            pipelined_sync(&mut pipe, &mut tp, Mode::Psync, &mut v, None, &c, 5, &buckets).unwrap();
        assert_eq!(v, v0, "n = 1 PSync is compress + decompress = identity");
        assert_eq!(info.parts().len(), 3);
    }
}
