//! TCP peer transport: the same bit-packed frames, over real sockets.
//!
//! [`TcpTransport`] is the [`PeerTransport`] of one OS process acting as one
//! worker rank.  It holds a persistent full mesh of loopback/LAN sockets
//! built by [`super::rendezvous::establish`] and moves every collective
//! frame as:
//!
//! ```text
//! | round: u64 LE | tag: u8 | bit_len: u64 LE | payload: ceil(bit_len/8) bytes |
//! ```
//!
//! The payload is the [`WireMsg`]'s bit-packed words, little-endian,
//! truncated to the byte length — so the bytes on the socket are exactly
//! the accounted payload (`encoded bits ≡ accounted bits` holds on the real
//! network, measured by the `payload_bits_*` counters) plus the fixed
//! 17-byte header the counters report separately.  Receivers validate the
//! header against the (round, tag) they expect and cap `bit_len` before
//! allocating, then hand the rebuilt message to the hardened
//! `transport::wire` decoders — a corrupt or desynchronized stream fails
//! loudly in release builds.

use super::peer::{PeerTransport, Tag, TransportError};
use super::wire::WireMsg;
use crate::obs::{self, PeerCounters};
use std::io::{BufRead, BufReader, IoSlice, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// Refuse frames claiming more than 64 MiB of payload — a corrupt length
/// header must not become an allocation bomb (`recv` allocates the byte
/// and word buffers before `read_exact` can fail).  Legitimate frames top
/// out at one dense vector (32·d bits: ~4 MB at d = 2²⁰); raise this if
/// models beyond ~16M dense values are ever driven over TCP.
const MAX_FRAME_BITS: u64 = 1 << 29;

/// Fixed frame header size in bytes (round + tag + bit length).
pub const FRAME_HEADER_BYTES: u64 = 17;

struct Link {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Reusable serialization buffer: the payload's little-endian bytes.
    wbuf: Vec<u8>,
}

/// Write `hdr` then `payload` through as few syscalls as the kernel allows —
/// one `writev` in the common case (the old path buffered the header and
/// the payload word-by-word through a `BufWriter`, costing a second syscall
/// whenever a frame outgrew the 8 KiB buffer, i.e. on every large bucket).
/// Loops on partial/interrupted writes.
fn write_all_vectored(w: &mut TcpStream, hdr: &[u8], payload: &[u8]) -> std::io::Result<()> {
    let (mut h, mut p) = (0usize, 0usize);
    while h < hdr.len() || p < payload.len() {
        let bufs = [IoSlice::new(&hdr[h..]), IoSlice::new(&payload[p..])];
        match w.write_vectored(&bufs) {
            Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
            Ok(n) => {
                let adv_h = n.min(hdr.len() - h);
                h += adv_h;
                p += n - adv_h;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

pub struct TcpTransport {
    rank: usize,
    n: usize,
    links: Vec<Option<Link>>,
    /// Payload bits moved through this process's sockets (headers excluded)
    /// — the quantity that must equal the accounted `payload_bits_wire`.
    pub payload_bits_sent: u64,
    pub payload_bits_received: u64,
    /// Raw bytes written including the 17-byte frame headers.
    pub frame_bytes_sent: u64,
    /// Per-peer wire counters (indexed by remote rank; the self slot
    /// stays zero).  Frames and payload bits are always counted — plain
    /// adds on paths that already count aggregates — while
    /// `blocked_send_ns` (time inside the blocking socket write, i.e.
    /// backpressure) is measured only while `obs` tracing or the
    /// `obs::metrics` registry is enabled so the disabled path reads no
    /// timestamps.  The trainer mirrors these into the metrics registry
    /// at round boundaries (`obs::metrics::sync_from_peers`), which is
    /// where the adaptive censor threshold reads backpressure from.
    pub per_peer: Vec<PeerCounters>,
}

impl TcpTransport {
    /// Join job `rendezvous` as worker `rank` of `n`: run the bootstrap and
    /// wrap the mesh sockets in links (buffered reads; writes go out as one
    /// vectored header+payload write per frame).
    pub fn connect(rendezvous: &str, rank: usize, n: usize) -> Result<TcpTransport, TransportError> {
        let streams = super::rendezvous::establish(rendezvous, rank, n)?;
        Self::from_streams(rank, n, streams)
    }

    /// [`TcpTransport::connect`] keeping the rendezvous/data listeners
    /// alive (rendezvous v2): the returned [`super::rendezvous::Session`]
    /// is what admits rejoining ranks at later epoch boundaries.
    pub fn connect_v2(
        rendezvous: &str,
        rank: usize,
        n: usize,
    ) -> Result<(TcpTransport, super::rendezvous::Session), TransportError> {
        let (streams, session) = super::rendezvous::establish_v2(rendezvous, rank, n)?;
        Ok((Self::from_streams(rank, n, streams)?, session))
    }

    /// Wrap already-established mesh sockets (index = peer rank, self slot
    /// `None`).  Slots may also be `None` for not-yet-joined ranks; their
    /// links are installed later via [`TcpTransport::install_link`].
    pub fn from_streams(
        rank: usize,
        n: usize,
        streams: Vec<Option<TcpStream>>,
    ) -> Result<TcpTransport, TransportError> {
        let mut links = Vec::with_capacity(n);
        for s in streams {
            links.push(s.map(Self::make_link).transpose()?);
        }
        Ok(TcpTransport {
            rank,
            n,
            links,
            payload_bits_sent: 0,
            payload_bits_received: 0,
            frame_bytes_sent: 0,
            per_peer: vec![PeerCounters::default(); n],
        })
    }

    fn make_link(stream: TcpStream) -> Result<Link, TransportError> {
        let reader = BufReader::new(
            stream
                .try_clone()
                .map_err(|e| TransportError::failed(format!("splitting socket: {e}")))?,
        );
        Ok(Link { reader, writer: stream, wbuf: Vec::new() })
    }

    /// Install (or replace) the link to `peer` — a rank rejoining at an
    /// epoch boundary redials every survivor, which accepts on its kept
    /// data listener and installs the fresh socket here.
    pub fn install_link(&mut self, peer: usize, stream: TcpStream) -> Result<(), TransportError> {
        if peer == self.rank || peer >= self.n {
            return Err(TransportError::failed(format!(
                "rank {} cannot link to peer {peer}",
                self.rank
            )));
        }
        self.links[peer] = Some(Self::make_link(stream)?);
        Ok(())
    }

    /// Drop the link to a dead peer (its socket is unusable; a rejoin
    /// installs a fresh one).
    pub fn drop_link(&mut self, peer: usize) {
        if peer < self.links.len() && peer != self.rank {
            self.links[peer] = None;
        }
    }

    fn link(&mut self, peer: usize) -> Result<&mut Link, TransportError> {
        if peer == self.rank || peer >= self.n {
            return Err(TransportError::failed(format!(
                "rank {} has no link to peer {peer}",
                self.rank
            )));
        }
        self.links[peer]
            .as_mut()
            .ok_or_else(|| TransportError::peer_down(peer, "no live link (left the fleet)"))
    }

    fn send_ref(
        &mut self,
        to: usize,
        round: u64,
        tag: Tag,
        msg: &WireMsg,
    ) -> Result<(), TransportError> {
        let nbytes = msg.byte_len() as usize;
        let link = self.link(to)?;
        let mut hdr = [0u8; FRAME_HEADER_BYTES as usize];
        hdr[..8].copy_from_slice(&round.to_le_bytes());
        hdr[8] = tag as u8;
        hdr[9..].copy_from_slice(&msg.bit_len.to_le_bytes());
        // Serialize the payload into the link's reusable buffer, then move
        // header + payload with one vectored write (two syscalls → one).
        link.wbuf.clear();
        link.wbuf.reserve(nbytes);
        for w in &msg.words {
            let bytes = w.to_le_bytes();
            let take = (nbytes - link.wbuf.len()).min(8);
            link.wbuf.extend_from_slice(&bytes[..take]);
            if link.wbuf.len() == nbytes {
                break;
            }
        }
        let io = |e: std::io::Error| {
            TransportError::peer_down(to, format!("sending failed: {e}"))
        };
        let timed = obs::enabled() || obs::metrics::enabled();
        let t0 = if timed { obs::now_ns() } else { 0 };
        write_all_vectored(&mut link.writer, &hdr, &link.wbuf).map_err(io)?;
        if timed {
            self.per_peer[to].blocked_send_ns += obs::now_ns().saturating_sub(t0);
        }
        self.payload_bits_sent += msg.bit_len;
        self.frame_bytes_sent += FRAME_HEADER_BYTES + nbytes as u64;
        self.per_peer[to].frames_sent += 1;
        self.per_peer[to].payload_bits_sent += msg.bit_len;
        Ok(())
    }
}

impl PeerTransport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, round: u64, tag: Tag, msg: WireMsg) -> Result<(), TransportError> {
        self.send_ref(to, round, tag, &msg)
    }

    fn broadcast(&mut self, round: u64, tag: Tag, msg: WireMsg) -> Result<(), TransportError> {
        for j in 0..self.n {
            if j != self.rank {
                self.send_ref(j, round, tag, &msg)?;
            }
        }
        Ok(())
    }

    fn recv(&mut self, from: usize, round: u64, tag: Tag) -> Result<Arc<WireMsg>, TransportError> {
        let rank = self.rank;
        let (r, tg, msg) = self.read_frame(from)?;
        if r != round || tg != tag {
            return Err(TransportError::failed(format!(
                "rank {rank} desynchronized: expected (round {round}, {tag:?}) from peer {from}, \
                 got (round {r}, {tg:?})"
            )));
        }
        Ok(Arc::new(msg))
    }

    fn recv_deadline(
        &mut self,
        from: usize,
        round: u64,
        tag: Tag,
        timeout: Option<std::time::Duration>,
    ) -> Result<Option<Arc<WireMsg>>, TransportError> {
        let rank = self.rank;
        // One deadline for the whole call, stale drain included: a peer
        // that floods stale rounds burns the caller's budget, not the
        // caller's lifetime.  Each wait gets only the time remaining.
        let deadline = timeout.map(|t| std::time::Instant::now() + t);
        loop {
            if let Some(dl) = deadline {
                let left = dl.saturating_duration_since(std::time::Instant::now());
                if left.is_zero() {
                    return Ok(None); // budget exhausted draining stale frames
                }
                // The deadline applies only to the *first byte* of the next
                // frame: once a frame starts arriving the peer is alive, and
                // timing out a partial read would desynchronize the stream.
                let link = self.link(from)?;
                let set = |s: &TcpStream, d: Option<std::time::Duration>| {
                    s.set_read_timeout(d)
                        .map_err(|e| TransportError::failed(format!("setting read timeout: {e}")))
                };
                set(link.reader.get_ref(), Some(left))?;
                let arrived = loop {
                    match link.reader.fill_buf() {
                        Ok([]) => {
                            break Err(TransportError::peer_down(from, "connection closed"))
                        }
                        Ok(_) => break Ok(true),
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(e)
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                            ) =>
                        {
                            break Ok(false)
                        }
                        Err(e) => {
                            break Err(TransportError::peer_down(
                                from,
                                format!("receiving failed: {e}"),
                            ))
                        }
                    }
                };
                set(link.reader.get_ref(), None)?;
                match arrived {
                    Ok(true) => {}
                    Ok(false) => return Ok(None), // deadline expired
                    Err(e) => return Err(e),
                }
            }
            let (r, tg, msg) = self.read_frame(from)?;
            // Stale frames: rounds below the one we wait on (leftovers of
            // censored rounds) and same-round ring chunks when we expect a
            // non-Chunk tag (leftovers of a ring attempt that aborted into
            // the parameter-server fallback — Chunk is ring-only, so the
            // mismatch is unambiguous).  Discard, counted — the payload
            // crossed the wire and the drain is bounded by the deadline
            // above, so a stale flood surfaces as a censor, never a spin.
            if r < round || (r == round && tg == Tag::Chunk && tag != Tag::Chunk) {
                self.per_peer[from].stale_discards += 1;
                continue;
            }
            if r != round || tg != tag {
                return Err(TransportError::failed(format!(
                    "rank {rank} desynchronized: expected (round {round}, {tag:?}) from peer \
                     {from}, got (round {r}, {tg:?})"
                )));
            }
            return Ok(Some(Arc::new(msg)));
        }
    }
}

impl TcpTransport {
    /// Read one complete frame from `from`: header, cap check, payload.
    /// No (round, tag) validation — callers decide what is stale vs
    /// desynchronized.  Socket-level failures are attributed to the peer
    /// ([`TransportError::PeerDown`]); framing violations are terminal.
    fn read_frame(&mut self, from: usize) -> Result<(u64, Tag, WireMsg), TransportError> {
        let link = self.link(from)?;
        let io =
            |e: std::io::Error| TransportError::peer_down(from, format!("receiving failed: {e}"));
        let mut hdr = [0u8; FRAME_HEADER_BYTES as usize];
        link.reader.read_exact(&mut hdr).map_err(io)?;
        let r = u64::from_le_bytes(hdr[..8].try_into().unwrap());
        let tg = Tag::from_u8(hdr[8]).ok_or_else(|| {
            TransportError::failed(format!("unknown frame tag {} from peer {from}", hdr[8]))
        })?;
        let bit_len = u64::from_le_bytes(hdr[9..].try_into().unwrap());
        if bit_len > MAX_FRAME_BITS {
            return Err(TransportError::failed(format!(
                "frame from peer {from} claims {bit_len} bits (cap {MAX_FRAME_BITS})"
            )));
        }
        let nbytes = bit_len.div_ceil(8) as usize;
        let mut buf = vec![0u8; nbytes];
        link.reader.read_exact(&mut buf).map_err(io)?;
        let mut words = vec![0u64; bit_len.div_ceil(64) as usize];
        for (w, chunk) in words.iter_mut().zip(buf.chunks(8)) {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            *w = u64::from_le_bytes(b);
        }
        self.payload_bits_received += bit_len;
        self.per_peer[from].frames_received += 1;
        self.per_peer[from].payload_bits_received += bit_len;
        Ok((r, tg, WireMsg { words, bit_len }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::psync as in_process_psync;
    use crate::compressor::{Compressor, Grbs, TopK};
    use crate::transport::rendezvous::free_loopback_addr;
    use crate::transport::peer;
    use crate::util::prop::{slices_close, Gen};

    /// Run `f(rank, transport)` in n threads joined over a fresh loopback
    /// rendezvous — real sockets, one process, n "workers".
    fn run_tcp_peers<T: Send, F: Fn(usize, &mut TcpTransport) -> T + Sync>(
        n: usize,
        f: F,
    ) -> Vec<T> {
        let addr = free_loopback_addr().unwrap();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let addr = addr.clone();
                    let f = &f;
                    s.spawn(move || {
                        let mut tp = TcpTransport::connect(&addr, r, n).unwrap();
                        f(r, &mut tp)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("tcp peer panicked")).collect()
        })
    }

    #[test]
    fn tcp_psync_matches_in_process_and_measures_exact_bits() {
        let n = 4;
        let d = 96;
        let mut g = Gen::replay(0x7C9, 0);
        let vs = g.worker_vecs(n, d);

        // Ring path (GRBS): within f32 reduction tolerance; the frames on
        // the socket carry exactly the encoded chunk bits.
        let c = Grbs::new(4.0, 12, 7);
        let mut expect = vs.clone();
        in_process_psync(&mut expect, None, &c, 3);
        let out = run_tcp_peers(n, |w, tp| {
            let mut v = vs[w].clone();
            let round = peer::psync(tp, &mut v, None, &c, 3).unwrap();
            let per_peer: u64 = tp.per_peer.iter().map(|p| p.payload_bits_sent).sum();
            assert_eq!(per_peer, tp.payload_bits_sent, "per-peer sums must equal the aggregate");
            (v, round, tp.payload_bits_sent)
        });
        for (i, (v, round, sent)) in out.iter().enumerate() {
            slices_close(&expect[i], v, 1e-5).unwrap_or_else(|e| panic!("worker {i}: {e}"));
            let wire = round.wire.expect("tcp measures traffic");
            assert_eq!(
                wire.up_bits + wire.down_bits,
                *sent,
                "worker {i}: socket payload bits != protocol accounting"
            );
        }

        // PS path (top-k): bit-identical, upload == accounted payload.
        let c = TopK::new(8.0);
        let mut expect = vs.clone();
        let ia = in_process_psync(&mut expect, None, &c, 4);
        let out = run_tcp_peers(n, |w, tp| {
            let mut v = vs[w].clone();
            let round = peer::psync(tp, &mut v, None, &c, 4).unwrap();
            (v, round)
        });
        for (i, (v, round)) in out.iter().enumerate() {
            assert_eq!(&expect[i], v, "worker {i}: PS path must be bit-identical over TCP");
            assert_eq!(round.upload_bits_per_worker, ia.upload_bits_per_worker);
            let sel = c.select(crate::compressor::Ctx { round: 4, worker: i as u32 }, &vs[i]);
            assert_eq!(
                round.wire.unwrap().up_bits,
                crate::compressor::payload_bits_wire(c.wire_scheme(), &sel, d),
                "worker {i}: encoded bits must equal accounted bits on the socket"
            );
        }
    }

    #[test]
    fn vote_and_agree_work_over_sockets() {
        let out = run_tcp_peers(3, |w, tp| {
            let v = peer::vote(tp, w as f64, 10.0, 1).unwrap();
            let a = peer::agree(tp, w == 0, 2).unwrap();
            (v, a)
        });
        for ((mean, stop), any) in &out {
            assert!((*mean - 1.0).abs() < 1e-12);
            assert!(!*stop);
            assert!(*any);
        }
    }
}
