//! Multi-threaded collectives over serialized messages.
//!
//! One OS thread per worker (std::thread::scope + std::sync::mpsc only, the
//! same no-dependency discipline as `util::pool`), every payload an actual
//! bit-packed [`WireMsg`]:
//!
//! * **Ring** (AllReduce-compatible compressors — shared support, no index
//!   metadata): the selected values are gathered into a compact vector and
//!   reduce-scattered/all-gathered around the ring in `2(n−1)` steps,
//!   exactly the schedule `collective::ring_allreduce_cost` prices.  Chunk
//!   sums accumulate in ring order, so results match the in-process backend
//!   up to f32 reduction-order error (documented tolerance).
//! * **Parameter server** (per-worker supports and dense quantizers): each
//!   worker encodes its message and sends it to the server (the calling
//!   thread); the server decodes in worker order, accumulates the mean,
//!   and broadcasts the aggregate over the *union* support — the actual
//!   quantity `CostModel::sync_round` approximates with a union factor.
//!   Because decode∘encode is bit-identical to `compress_into` and the
//!   accumulation order matches, this path is **bit-identical** to
//!   [`super::InProcess`].
//!
//! The returned [`PsyncRound::wire`] carries the measured per-worker traffic
//! (ceiling of the mean across workers): serialized bits, not a formula.

use super::wire::{self, WireMsg};
use super::{Collective, InProcess};
use crate::collective::{PsyncRound, WireCost};
use crate::compressor::{payload_bits_wire, Compressor, Ctx, Selection};
use crate::util::math;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, Default)]
pub struct Threaded;

impl Threaded {
    pub fn new() -> Self {
        Threaded
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// vs[i] ← mean + residual_i (PSync proper).
    Psync,
    /// qs[i] ← mean; residual only reported.
    Exchange,
}

impl Collective for Threaded {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn psync(
        &self,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &dyn Compressor,
        round: u64,
    ) -> PsyncRound {
        self.run(Mode::Psync, vs, resid_out, c, round)
    }

    fn exchange_mean(
        &self,
        qs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &dyn Compressor,
        round: u64,
    ) -> PsyncRound {
        self.run(Mode::Exchange, qs, resid_out, c, round)
    }
}

impl Threaded {
    fn run(
        &self,
        mode: Mode,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &dyn Compressor,
        round: u64,
    ) -> PsyncRound {
        let n = vs.len();
        assert!(n > 0);
        if n == 1 {
            // Degenerate "cluster": nothing travels; keep reference numerics.
            return match mode {
                Mode::Psync => InProcess.psync(vs, resid_out, c, round),
                Mode::Exchange => InProcess.exchange_mean(vs, resid_out, c, round),
            };
        }
        if c.globally_synchronized() && !c.is_dense() {
            ring_round(mode, vs, resid_out, c, round)
        } else {
            ps_round(mode, vs, resid_out, c, round)
        }
    }
}

/// Balanced chunk bounds: chunk `k` of a length-`m` vector split `n` ways.
fn chunk_bounds(m: usize, n: usize, k: usize) -> (usize, usize) {
    (k * m / n, (k + 1) * m / n)
}

/// Gather `v`'s selected ranges into a compact vector of length `sel.count`.
fn gather(sel: &Selection, v: &[f32], compact: &mut Vec<f32>) {
    compact.clear();
    sel.for_each_range(v.len(), |s, e| compact.extend_from_slice(&v[s..e]));
}

fn ring_round(
    mode: Mode,
    vs: &mut [Vec<f32>],
    mut resid_out: Option<&mut [Vec<f32>]>,
    c: &dyn Compressor,
    round: u64,
) -> PsyncRound {
    let n = vs.len();
    let d = vs[0].len();
    let sel = c.select(Ctx { round, worker: 0 }, &vs[0]);
    let bits = payload_bits_wire(c.wire_scheme(), &sel, d);
    let m = sel.count(d);

    if m == 0 {
        // C = 0 everywhere (e.g. the Zero compressor): nothing travels.
        if let Some(res) = resid_out.as_deref_mut() {
            for (r, v) in res.iter_mut().zip(vs.iter()) {
                r.copy_from_slice(v);
            }
        }
        if mode == Mode::Exchange {
            for v in vs.iter_mut() {
                math::fill(v, 0.0);
            }
        }
        return PsyncRound {
            selections: vec![sel],
            upload_bits_per_worker: 0,
            allreduce_compatible: true,
            wire: Some(WireCost { up_bits: 0, down_bits: 0, steps: 0 }),
        };
    }

    // One mpsc channel per worker; worker i sends to (i+1) % n.
    let mut txs: Vec<Option<Sender<WireMsg>>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<WireMsg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(Some(tx));
        rxs.push(Some(rx));
    }
    let mut resid_slots: Vec<Option<&mut Vec<f32>>> = match resid_out.as_deref_mut() {
        Some(res) => res.iter_mut().map(Some).collect(),
        None => (0..n).map(|_| None).collect(),
    };
    // Grab the senders first (txs is also indexed by the loop below).
    let next_tx: Vec<Sender<WireMsg>> =
        (0..n).map(|i| txs[(i + 1) % n].take().unwrap()).collect();

    let sel_ref = &sel;
    let mut traffic: Vec<(u64, u64)> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n);
        for (i, ((v, res), tx)) in
            vs.iter_mut().zip(resid_slots.drain(..)).zip(next_tx).enumerate()
        {
            let rx = rxs[i].take().unwrap();
            handles.push(s.spawn(move || -> (u64, u64) {
                let mut compact = Vec::with_capacity(m);
                gather(sel_ref, v, &mut compact);
                // Traffic split follows `ring_allreduce_cost`'s convention:
                // `up` = bits sent during reduce-scatter, `down` = bits sent
                // during all-gather (each worker also receives the same
                // volumes from its other neighbor).
                let (mut up, mut down) = (0u64, 0u64);

                // Reduce-scatter: after n-1 steps this worker owns the fully
                // reduced chunk (i+1) % n.
                for step in 0..n - 1 {
                    let (cs, ce) = chunk_bounds(m, n, (i + n - step) % n);
                    let msg = wire::encode_f32s(&compact[cs..ce]);
                    up += msg.bit_len;
                    tx.send(msg).expect("ring send");
                    let msg = rx.recv().expect("ring recv");
                    let (cs, ce) = chunk_bounds(m, n, (i + n - step - 1) % n);
                    wire::decode_f32s_add(&msg, &mut compact[cs..ce]);
                }
                // All-gather: circulate the completed chunks.
                for step in 0..n - 1 {
                    let (cs, ce) = chunk_bounds(m, n, (i + 1 + n - step) % n);
                    let msg = wire::encode_f32s(&compact[cs..ce]);
                    down += msg.bit_len;
                    tx.send(msg).expect("ring send");
                    let msg = rx.recv().expect("ring recv");
                    let (cs, ce) = chunk_bounds(m, n, (i + n - step) % n);
                    wire::decode_f32s(&msg, &mut compact[cs..ce]);
                }

                let inv = 1.0 / n as f32;
                for x in compact.iter_mut() {
                    *x *= inv;
                }
                // Residual (v off support) must be captured before the mean
                // overwrites the selected ranges.
                if let Some(r) = res {
                    r.copy_from_slice(v);
                    sel_ref.for_each_range(v.len(), |s0, e0| math::fill(&mut r[s0..e0], 0.0));
                }
                if mode == Mode::Exchange {
                    math::fill(v, 0.0);
                }
                let mut cursor = 0usize;
                sel_ref.for_each_range(v.len(), |s0, e0| {
                    v[s0..e0].copy_from_slice(&compact[cursor..cursor + (e0 - s0)]);
                    cursor += e0 - s0;
                });
                (up, down)
            }));
        }
        for h in handles {
            traffic.push(h.join().expect("ring worker panicked"));
        }
    });

    let steps = 2 * (n as u32 - 1);
    let total_up: u64 = traffic.iter().map(|t| t.0).sum();
    let total_down: u64 = traffic.iter().map(|t| t.1).sum();
    PsyncRound {
        selections: vec![sel],
        upload_bits_per_worker: bits,
        allreduce_compatible: true,
        wire: Some(WireCost {
            up_bits: total_up.div_ceil(n as u64),
            down_bits: total_down.div_ceil(n as u64),
            steps,
        }),
    }
}

fn ps_round(
    mode: Mode,
    vs: &mut [Vec<f32>],
    mut resid_out: Option<&mut [Vec<f32>]>,
    c: &dyn Compressor,
    round: u64,
) -> PsyncRound {
    let n = vs.len();
    let d = vs[0].len();
    let (tx_up, rx_up) = channel::<(usize, WireMsg)>();
    // The aggregate is broadcast behind an Arc: workers only read it, and at
    // bench scale (dense d=2^20 aggregates) per-worker deep clones would be
    // tens of MB of memcpy charged to the backend under test.
    let mut down_txs: Vec<Sender<Arc<WireMsg>>> = Vec::with_capacity(n);
    let mut down_rxs: Vec<Option<Receiver<Arc<WireMsg>>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        down_txs.push(tx);
        down_rxs.push(Some(rx));
    }
    let mut resid_slots: Vec<Option<&mut Vec<f32>>> = match resid_out.as_deref_mut() {
        Some(res) => res.iter_mut().map(Some).collect(),
        None => (0..n).map(|_| None).collect(),
    };

    let mut selections: Vec<Selection> = Vec::with_capacity(n);
    let mut traffic: Vec<(u64, u64)> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n);
        for (i, (v, res)) in vs.iter_mut().zip(resid_slots.drain(..)).enumerate() {
            let tx_up = tx_up.clone();
            let rx_down = down_rxs[i].take().unwrap();
            handles.push(s.spawn(move || -> (Selection, u64, u64) {
                let ctx = Ctx { round, worker: i as u32 };
                let sel = c.select(ctx, v);
                let msg = wire::encode_with_selection(c, ctx, v, Some(&sel));
                let up = msg.bit_len;
                // Decode our own upload so the residual is computed against
                // the exact bits the server aggregates.
                let mut cq = vec![0.0f32; d];
                wire::decode(c, ctx, &msg, &mut cq);
                tx_up.send((i, msg)).expect("gather send");
                // residual r = v − C(v)
                for (vj, kj) in v.iter_mut().zip(&cq) {
                    *vj -= *kj;
                }
                if let Some(r) = res {
                    r.copy_from_slice(v);
                }
                let agg = rx_down.recv().expect("broadcast recv");
                let down = agg.bit_len;
                // reuse cq as the decoded aggregate (mean over the union)
                if c.is_dense() {
                    wire::decode_f32s(&agg, &mut cq);
                } else {
                    wire::decode_union(&agg, &mut cq);
                }
                match mode {
                    // v currently holds the residual: v' = mean + residual.
                    Mode::Psync => math::axpy(1.0, &cq, v),
                    Mode::Exchange => v.copy_from_slice(&cq),
                }
                (sel, up, down)
            }));
        }
        drop(tx_up);

        // ---- server (the calling thread) ----
        let mut msgs: Vec<Option<WireMsg>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, m) = rx_up.recv().expect("gather recv");
            msgs[i] = Some(m);
        }
        let mut mean = vec![0.0f32; d];
        let mut scratch = vec![0.0f32; d];
        let mut mask = vec![false; d];
        let inv = 1.0 / n as f32;
        // Accumulate in worker order — the same order as the in-process
        // backend, so the mean is bit-identical to `collective::exchange_mean`.
        for (i, msg) in msgs.iter().enumerate() {
            let msg = msg.as_ref().unwrap();
            wire::decode(c, Ctx { round, worker: i as u32 }, msg, &mut scratch);
            for ((mj, sj), uj) in mean.iter_mut().zip(&scratch).zip(mask.iter_mut()) {
                *mj += inv * *sj;
                *uj |= *sj != 0.0;
            }
        }
        let agg = Arc::new(if c.is_dense() {
            wire::encode_f32s(&mean)
        } else {
            wire::encode_union(&mean, &mask)
        });
        for tx in &down_txs {
            tx.send(Arc::clone(&agg)).expect("broadcast send");
        }

        for h in handles {
            let (sel, up, down) = h.join().expect("ps worker panicked");
            selections.push(sel);
            traffic.push((up, down));
        }
    });

    let total_up: u64 = traffic.iter().map(|t| t.0).sum();
    let total_down: u64 = traffic.iter().map(|t| t.1).sum();
    PsyncRound {
        selections,
        upload_bits_per_worker: total_up.div_ceil(n as u64),
        allreduce_compatible: false,
        wire: Some(WireCost {
            up_bits: total_up.div_ceil(n as u64),
            down_bits: total_down.div_ceil(n as u64),
            steps: 2,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::ring_allreduce_cost;
    use crate::compressor::{BlockTopK, Grbs, Identity, Qsgd, RandK, SignSgd, TopK, Zero};
    use crate::util::prop::{forall, slices_close, Gen};

    fn mean_of(vs: &[Vec<f32>]) -> Vec<f32> {
        let d = vs[0].len();
        let mut m = vec![0.0f32; d];
        for v in vs {
            for (a, b) in m.iter_mut().zip(v) {
                *a += b / vs.len() as f32;
            }
        }
        m
    }

    fn compressor_set(d: usize) -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(Grbs::new(4.0, (d / 4).max(1), 77)),
            Box::new(RandK::new(4.0)),
            Box::new(TopK::new(4.0)),
            Box::new(BlockTopK::new(4.0, (d / 8).max(1))),
            Box::new(Qsgd::new(4)),
            Box::new(SignSgd),
            Box::new(Identity),
            Box::new(Zero),
        ]
    }

    #[test]
    fn prop_threaded_psync_preserves_means() {
        forall(15, 0x711, |g: &mut Gen| {
            let n = g.usize_in(1, 7);
            let d = g.usize_in(8, 120);
            let vs = g.worker_vecs(n, d);
            let before = mean_of(&vs);
            for c in compressor_set(d) {
                let mut copy = vs.clone();
                Threaded.psync(&mut copy, None, c.as_ref(), g.case);
                let after = mean_of(&copy);
                slices_close(&before, &after, 1e-4)
                    .map_err(|e| format!("{}: mean not preserved: {e}", c.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_threaded_matches_in_process() {
        // PS-path compressors must match bit-for-bit; the ring path within
        // f32 reduction-order tolerance.
        forall(15, 0x712, |g: &mut Gen| {
            let n = g.usize_in(2, 7);
            let d = g.usize_in(8, 120);
            let vs = g.worker_vecs(n, d);
            for c in compressor_set(d) {
                let ring = c.globally_synchronized() && !c.is_dense();
                let mut a = vs.clone();
                let mut ra = vec![vec![0.0f32; d]; n];
                let ia = InProcess.psync(&mut a, Some(&mut ra), c.as_ref(), g.case);
                let mut b = vs.clone();
                let mut rb = vec![vec![0.0f32; d]; n];
                let ib = Threaded.psync(&mut b, Some(&mut rb), c.as_ref(), g.case);
                crate::prop_assert!(
                    ia.allreduce_compatible == ib.allreduce_compatible,
                    "{}: allreduce flag differs",
                    c.name()
                );
                let tol = if ring { 1e-5 } else { 0.0 };
                for i in 0..n {
                    slices_close(&a[i], &b[i], tol)
                        .map_err(|e| format!("{} psync w{i}: {e}", c.name()))?;
                    slices_close(&ra[i], &rb[i], tol)
                        .map_err(|e| format!("{} resid w{i}: {e}", c.name()))?;
                }
                // exchange_mean too
                let mut a = vs.clone();
                let ia = InProcess.exchange_mean(&mut a, None, c.as_ref(), g.case);
                let mut b = vs.clone();
                let ib = Threaded.exchange_mean(&mut b, None, c.as_ref(), g.case);
                for i in 0..n {
                    slices_close(&a[i], &b[i], tol)
                        .map_err(|e| format!("{} exch w{i}: {e}", c.name()))?;
                }
                crate::prop_assert!(
                    ia.upload_bits_per_worker == ib.upload_bits_per_worker,
                    "{}: accounted bits differ: {} vs {}",
                    c.name(),
                    ia.upload_bits_per_worker,
                    ib.upload_bits_per_worker
                );
            }
            Ok(())
        });
    }

    #[test]
    fn ring_wire_traffic_matches_cost_model() {
        // m divisible by n → equal chunks → measured serialized bits equal
        // the ring formula exactly.
        let n = 4;
        let d = 1024; // GRBS R=2 on 16 blocks of 64 → m = 512, divisible by 4
        let c = Grbs::new(2.0, 16, 9);
        let mut g = Gen::replay(0x41, 0);
        let mut vs = g.worker_vecs_smooth(n, d);
        let round = Threaded.psync(&mut vs, None, &c, 3);
        let sel = round.selections[0].clone();
        let m = sel.count(d) as u64;
        assert_eq!(m % n as u64, 0, "test setup: chunks must divide evenly");
        let wire = round.wire.expect("threaded reports measured traffic");
        let expect = ring_allreduce_cost(m * 32, n);
        assert_eq!(wire.up_bits, expect.up_bits, "serialized bits != ring formula");
        assert_eq!(wire.down_bits, expect.down_bits);
        assert_eq!(wire.steps, expect.steps);
        assert_eq!(round.upload_bits_per_worker, m * 32);
    }

    #[test]
    fn ps_wire_traffic_reports_union_aggregate() {
        let n = 4;
        let d = 256;
        let c = TopK::new(8.0); // k = 32 per worker
        let mut g = Gen::replay(0x42, 0);
        let mut vs = g.worker_vecs_smooth(n, d);
        let round = Threaded.psync(&mut vs, None, &c, 5);
        let wire = round.wire.expect("measured traffic");
        // upload: exactly the accounted payload (index+value pairs)
        let pair = wire::index_width(d) as u64 + 32;
        assert_eq!(wire.up_bits, 32 * pair);
        assert_eq!(round.upload_bits_per_worker, 32 * pair);
        // download: the union support — between one worker's support and n×
        assert!(wire.down_bits >= 32 * pair && wire.down_bits <= n as u64 * 32 * pair);
        assert_eq!(wire.steps, 2);
    }

    #[test]
    fn single_worker_delegates_to_in_process() {
        let mut vs = vec![vec![1.0f32, -2.0, 3.0, -4.0]];
        let orig = vs.clone();
        let round = Threaded.psync(&mut vs, None, &Grbs::new(2.0, 2, 3), 7);
        assert_eq!(vs, orig); // n=1: v' = C(v) + (v − C(v)) = v
        assert!(round.wire.is_none());
    }

    #[test]
    fn zero_compressor_moves_no_bits() {
        let mut vs = vec![vec![1.0f32; 8]; 3];
        let orig = vs.clone();
        let round = Threaded.psync(&mut vs, None, &Zero, 1);
        assert_eq!(vs, orig);
        assert_eq!(round.wire.unwrap().total_bits(), 0);
        let mut qs = vs.clone();
        let mut resid = vec![vec![0.0f32; 8]; 3];
        Threaded.exchange_mean(&mut qs, Some(&mut resid), &Zero, 1);
        assert!(qs.iter().all(|q| q.iter().all(|&x| x == 0.0)));
        assert_eq!(resid, orig);
    }
}
