//! Multi-threaded collectives over serialized messages — now with
//! **persistent** worker threads.
//!
//! The first version of this backend spawned 2n fresh OS threads on every
//! collective call (`std::thread::scope` per round), the per-call cost
//! DESIGN.md §5 documented.  It is now a thin facade over the peer-owned
//! protocol: a pool of n long-lived worker threads (built lazily on the
//! first call, reused for every subsequent round, resized only if the
//! worker count changes) each owns a [`mesh::MeshTransport`] endpoint and
//! executes its own ring segment / parameter-server exchange via
//! [`peer::run`].  A call moves each worker's vector into its thread (a
//! pointer swap, not a copy), the threads run the round concurrently, and
//! the facade reassembles the fleet-view [`PsyncRound`] the central
//! `Collective` interface promises.
//!
//! Protocol and numerics are unchanged from the spawning version (the ring
//! chunk schedule and server accumulation order moved verbatim into
//! `transport::peer`): the parameter-server path stays **bit-identical** to
//! [`super::InProcess`], the ring path stays within f32 reduction-order
//! tolerance.  `benches/transport.rs` shows the before/after: construct a
//! fresh `Threaded` per call to re-measure the old spawn cost.

use super::mesh::channel_mesh;
use super::peer::{self, Mode, TransportError};
use super::{Collective, InProcess};
use crate::collective::{PsyncRound, WireCost};
use crate::compressor::Compressor;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

pub struct Threaded {
    pool: Mutex<Option<Pool>>,
}

impl Threaded {
    pub fn new() -> Self {
        Threaded { pool: Mutex::new(None) }
    }
}

impl Default for Threaded {
    fn default() -> Self {
        Self::new()
    }
}

struct Job {
    mode: Mode,
    v: Vec<f32>,
    resid: Option<Vec<f32>>,
    c: Arc<dyn Compressor>,
    round: u64,
}

type JobResult = Result<(Vec<f32>, Option<Vec<f32>>, PsyncRound), TransportError>;

/// The persistent worker fleet: one thread per worker slot, fed over a
/// per-worker job channel, answering on a shared completion channel.
struct Pool {
    n: usize,
    jobs: Vec<Sender<Job>>,
    done: Receiver<(usize, JobResult)>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    fn new(n: usize) -> Pool {
        let (done_tx, done_rx) = channel();
        let mut jobs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (w, mut tp) in channel_mesh(n).into_iter().enumerate() {
            let (tx, rx) = channel::<Job>();
            jobs.push(tx);
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                // Persistent per-thread scratch: selection/codec working
                // buffers are reused across every round this worker runs.
                let mut scratch = crate::kernel::Scratch::new();
                while let Ok(mut job) = rx.recv() {
                    let out = peer::run(
                        &mut tp,
                        job.mode,
                        &mut job.v,
                        job.resid.as_mut(),
                        job.c.as_ref(),
                        job.round,
                        &mut scratch,
                    );
                    let out = out.map(|round| (job.v, job.resid, round));
                    if done.send((w, out)).is_err() {
                        break; // facade gone: shut down
                    }
                }
            }));
        }
        Pool { n, jobs, done: done_rx, handles }
    }

    fn shutdown(self) {
        drop(self.jobs); // workers' `rx.recv()` errors → loops exit
        for h in self.handles {
            let _ = h.join();
        }
    }
}

impl Drop for Threaded {
    fn drop(&mut self) {
        let pool = match self.pool.get_mut() {
            Ok(p) => p.take(),
            Err(poisoned) => poisoned.into_inner().take(),
        };
        if let Some(p) = pool {
            p.shutdown();
        }
    }
}

impl Collective for Threaded {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn psync(
        &self,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
    ) -> PsyncRound {
        self.run(Mode::Psync, vs, resid_out, c, round)
    }

    fn exchange_mean(
        &self,
        qs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
    ) -> PsyncRound {
        self.run(Mode::Exchange, qs, resid_out, c, round)
    }
}

impl Threaded {
    fn run(
        &self,
        mode: Mode,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
    ) -> PsyncRound {
        let n = vs.len();
        assert!(n > 0);
        if n == 1 {
            // Degenerate "cluster": nothing travels; keep reference numerics.
            return match mode {
                Mode::Psync => InProcess.psync(vs, resid_out, c, round),
                Mode::Exchange => InProcess.exchange_mean(vs, resid_out, c, round),
            };
        }
        let mut guard = self.pool.lock().unwrap();
        if guard.as_ref().map(|p| p.n) != Some(n) {
            if let Some(old) = guard.take() {
                old.shutdown();
            }
            *guard = Some(Pool::new(n));
        }
        let pool = guard.as_ref().expect("pool just built");

        let mut resid = resid_out;
        for (i, v) in vs.iter_mut().enumerate() {
            let job = Job {
                mode,
                v: std::mem::take(v),
                resid: resid.as_deref_mut().map(|rs| std::mem::take(&mut rs[i])),
                c: Arc::clone(c),
                round,
            };
            pool.jobs[i].send(job).expect("pool worker hung up");
        }
        let mut rounds: Vec<Option<PsyncRound>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            // A worker that panics (rather than returning a TransportError)
            // dies without sending its result, and the done channel stays
            // connected through the survivors' sender clones — poll for
            // dead threads so the run panics instead of hanging forever
            // (the old scoped-thread design surfaced this via join).
            let (i, res) = loop {
                match pool.done.recv_timeout(std::time::Duration::from_millis(200)) {
                    Ok(msg) => break msg,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        assert!(
                            !pool.handles.iter().any(|h| h.is_finished()),
                            "threaded pool worker died mid-collective"
                        );
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        panic!("threaded pool shut down mid-collective")
                    }
                }
            };
            let (v, r, info) =
                res.unwrap_or_else(|e| panic!("threaded worker {i} collective failed: {e}"));
            vs[i] = v;
            if let Some(rs) = resid.as_deref_mut() {
                rs[i] = r.expect("residual travels with its job");
            }
            rounds[i] = Some(info);
        }
        combine(rounds.into_iter().map(|r| r.expect("one result per worker")).collect())
    }
}

/// Reassemble the fleet-view round from the per-peer views: per-worker
/// selections in worker order (a single shared one on the ring path), the
/// fleet-uniform accounting, and the per-worker mean of the measured wire
/// traffic (ceiling), matching the spawning backend's reporting exactly.
fn combine(mut rounds: Vec<PsyncRound>) -> PsyncRound {
    let n = rounds.len() as u64;
    let allreduce = rounds[0].allreduce_compatible;
    let upload_bits_per_worker = rounds[0].upload_bits_per_worker;
    let steps = rounds[0].wire.expect("peer rounds measure traffic").steps;
    let total_up: u64 = rounds.iter().map(|r| r.wire.expect("measured").up_bits).sum();
    let total_down: u64 = rounds.iter().map(|r| r.wire.expect("measured").down_bits).sum();
    // Selections move out of the per-peer rounds — no per-collective clones
    // of index vectors on this path.
    let selections = if allreduce {
        rounds.swap_remove(0).selections
    } else {
        rounds.into_iter().map(|mut r| r.selections.swap_remove(0)).collect()
    };
    PsyncRound {
        selections,
        upload_bits_per_worker,
        allreduce_compatible: allreduce,
        wire: Some(WireCost {
            up_bits: total_up.div_ceil(n),
            down_bits: total_down.div_ceil(n),
            steps,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::ring_allreduce_cost;
    use crate::compressor::{BlockTopK, Grbs, Identity, Qsgd, RandK, SignSgd, TopK, Zero};
    use crate::transport::wire;
    use crate::util::prop::{forall, slices_close, Gen};

    fn mean_of(vs: &[Vec<f32>]) -> Vec<f32> {
        let d = vs[0].len();
        let mut m = vec![0.0f32; d];
        for v in vs {
            for (a, b) in m.iter_mut().zip(v) {
                *a += b / vs.len() as f32;
            }
        }
        m
    }

    fn compressor_set(d: usize) -> Vec<Arc<dyn Compressor>> {
        vec![
            Arc::new(Grbs::new(4.0, (d / 4).max(1), 77)),
            Arc::new(RandK::new(4.0)),
            Arc::new(TopK::new(4.0)),
            Arc::new(BlockTopK::new(4.0, (d / 8).max(1))),
            Arc::new(Qsgd::new(4)),
            Arc::new(SignSgd),
            Arc::new(Identity),
            Arc::new(Zero),
        ]
    }

    #[test]
    fn prop_threaded_psync_preserves_means() {
        let coll = Threaded::new();
        forall(15, 0x711, |g: &mut Gen| {
            let n = g.usize_in(1, 7);
            let d = g.usize_in(8, 120);
            let vs = g.worker_vecs(n, d);
            let before = mean_of(&vs);
            for c in compressor_set(d) {
                let mut copy = vs.clone();
                coll.psync(&mut copy, None, &c, g.case);
                let after = mean_of(&copy);
                slices_close(&before, &after, 1e-4)
                    .map_err(|e| format!("{}: mean not preserved: {e}", c.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_threaded_matches_in_process() {
        // PS-path compressors must match bit-for-bit; the ring path within
        // f32 reduction-order tolerance.  One persistent pool serves every
        // case — rounds reuse the same threads.
        let coll = Threaded::new();
        forall(15, 0x712, |g: &mut Gen| {
            let n = g.usize_in(2, 7);
            let d = g.usize_in(8, 120);
            let vs = g.worker_vecs(n, d);
            for c in compressor_set(d) {
                let ring = c.globally_synchronized() && !c.is_dense();
                let mut a = vs.clone();
                let mut ra = vec![vec![0.0f32; d]; n];
                let ia = InProcess.psync(&mut a, Some(&mut ra), &c, g.case);
                let mut b = vs.clone();
                let mut rb = vec![vec![0.0f32; d]; n];
                let ib = coll.psync(&mut b, Some(&mut rb), &c, g.case);
                crate::prop_assert!(
                    ia.allreduce_compatible == ib.allreduce_compatible,
                    "{}: allreduce flag differs",
                    c.name()
                );
                let tol = if ring { 1e-5 } else { 0.0 };
                for i in 0..n {
                    slices_close(&a[i], &b[i], tol)
                        .map_err(|e| format!("{} psync w{i}: {e}", c.name()))?;
                    slices_close(&ra[i], &rb[i], tol)
                        .map_err(|e| format!("{} resid w{i}: {e}", c.name()))?;
                }
                // exchange_mean too
                let mut a = vs.clone();
                let ia = InProcess.exchange_mean(&mut a, None, &c, g.case);
                let mut b = vs.clone();
                let ib = coll.exchange_mean(&mut b, None, &c, g.case);
                for i in 0..n {
                    slices_close(&a[i], &b[i], tol)
                        .map_err(|e| format!("{} exch w{i}: {e}", c.name()))?;
                }
                crate::prop_assert!(
                    ia.upload_bits_per_worker == ib.upload_bits_per_worker,
                    "{}: accounted bits differ: {} vs {}",
                    c.name(),
                    ia.upload_bits_per_worker,
                    ib.upload_bits_per_worker
                );
            }
            Ok(())
        });
    }

    #[test]
    fn ring_wire_traffic_matches_cost_model() {
        // m divisible by n → equal chunks → measured serialized bits equal
        // the ring formula exactly.
        let n = 4;
        let d = 1024; // GRBS R=2 on 16 blocks of 64 → m = 512, divisible by 4
        let c: Arc<dyn Compressor> = Arc::new(Grbs::new(2.0, 16, 9));
        let mut g = Gen::replay(0x41, 0);
        let mut vs = g.worker_vecs_smooth(n, d);
        let round = Threaded::new().psync(&mut vs, None, &c, 3);
        let sel = round.selections[0].clone();
        let m = sel.count(d) as u64;
        assert_eq!(m % n as u64, 0, "test setup: chunks must divide evenly");
        let wire = round.wire.expect("threaded reports measured traffic");
        let expect = ring_allreduce_cost(m * 32, n);
        assert_eq!(wire.up_bits, expect.up_bits, "serialized bits != ring formula");
        assert_eq!(wire.down_bits, expect.down_bits);
        assert_eq!(wire.steps, expect.steps);
        assert_eq!(round.upload_bits_per_worker, m * 32);
    }

    #[test]
    fn ps_wire_traffic_reports_union_aggregate() {
        let n = 4;
        let d = 256;
        let c: Arc<dyn Compressor> = Arc::new(TopK::new(8.0)); // k = 32 per worker
        let mut g = Gen::replay(0x42, 0);
        let mut vs = g.worker_vecs_smooth(n, d);
        let round = Threaded::new().psync(&mut vs, None, &c, 5);
        let wire = round.wire.expect("measured traffic");
        // upload: exactly the accounted payload (index+value pairs)
        let pair = wire::index_width(d) as u64 + 32;
        assert_eq!(wire.up_bits, 32 * pair);
        assert_eq!(round.upload_bits_per_worker, 32 * pair);
        // download: the union support — between one worker's support and n×
        assert!(wire.down_bits >= 32 * pair && wire.down_bits <= n as u64 * 32 * pair);
        assert_eq!(wire.steps, 2);
        assert_eq!(round.selections.len(), n, "per-worker selections in worker order");
    }

    #[test]
    fn single_worker_delegates_to_in_process() {
        let mut vs = vec![vec![1.0f32, -2.0, 3.0, -4.0]];
        let orig = vs.clone();
        let c: Arc<dyn Compressor> = Arc::new(Grbs::new(2.0, 2, 3));
        let round = Threaded::new().psync(&mut vs, None, &c, 7);
        assert_eq!(vs, orig); // n=1: v' = C(v) + (v − C(v)) = v
        assert!(round.wire.is_none());
    }

    #[test]
    fn zero_compressor_moves_no_bits() {
        let coll = Threaded::new();
        let mut vs = vec![vec![1.0f32; 8]; 3];
        let orig = vs.clone();
        let c: Arc<dyn Compressor> = Arc::new(Zero);
        let round = coll.psync(&mut vs, None, &c, 1);
        assert_eq!(vs, orig);
        assert_eq!(round.wire.unwrap().total_bits(), 0);
        let mut qs = vs.clone();
        let mut resid = vec![vec![0.0f32; 8]; 3];
        coll.exchange_mean(&mut qs, Some(&mut resid), &c, 1);
        assert!(qs.iter().all(|q| q.iter().all(|&x| x == 0.0)));
        assert_eq!(resid, orig);
    }

    #[test]
    fn pool_survives_worker_count_changes() {
        // One facade, three fleet sizes: the pool rebuilds only when n
        // changes and keeps serving rounds correctly.
        let coll = Threaded::new();
        let c: Arc<dyn Compressor> = Arc::new(Identity);
        for &n in &[2usize, 5, 2] {
            let mut vs: Vec<Vec<f32>> = (0..n).map(|w| vec![w as f32; 6]).collect();
            coll.psync(&mut vs, None, &c, 1);
            let expect: f32 = (0..n).map(|w| w as f32).sum::<f32>() / n as f32;
            for v in &vs {
                assert!(v.iter().all(|x| (x - expect).abs() < 1e-6), "n={n}");
            }
        }
    }
}
