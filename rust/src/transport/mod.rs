//! The wire layer: real serialized collectives under PSync.
//!
//! The seed executed every synchronization as in-place mutation of shared
//! `Vec<Vec<f32>>` and merely *accounted* communication.  This subsystem
//! makes the transport explicit and swappable:
//!
//! * [`wire`] — bit-packed codecs for every compressor payload, with the
//!   invariant that the encoded length equals the accounted bits;
//! * [`Collective`] — the aggregation abstraction every optimizer now runs
//!   over, with two backends:
//!   * [`InProcess`] — the original single-address-space fast path
//!     (delegates to [`crate::collective::psync`]); zero serialization,
//!     bit accounting only;
//!   * [`Threaded`] — one OS thread per worker exchanging *serialized*
//!     [`wire::WireMsg`]s over std channels: a reduce-scatter/all-gather
//!     ring for AllReduce-compatible compressors (GRBS — shared support, no
//!     index metadata) and a gather/broadcast parameter-server path for
//!     index-carrying or dense-quantizing compressors.  This demonstrates
//!     the paper's headline systems claim end-to-end: GRBS rides the ring,
//!     Qsparse/EF-style sparsifiers must pay the PS round trip.
//!
//! Numerics: the parameter-server path is **bit-identical** to `InProcess`
//! (messages decode to the exact `C(q_i)` bits and the server accumulates in
//! worker order).  The ring path reduces chunks in ring order, so results
//! agree with `InProcess` only up to f32 reduction-order error (~1e-7
//! relative per element; the equivalence tests pin a 1e-4 trajectory
//! tolerance on training workloads).

pub mod threaded;
pub mod wire;

pub use threaded::Threaded;
pub use wire::{BitReader, BitWriter, WireMsg};

use crate::collective::{exchange_mean, psync, PsyncRound};
use crate::compressor::Compressor;
use std::sync::Arc;

/// A synchronization backend: how per-worker vectors are aggregated.
///
/// Both methods are *collective calls*: `vs`/`qs` hold one vector per worker
/// and every worker's slot is updated as if each worker ran its side of the
/// protocol.  `round` seeds the compressor's selection schedule.
pub trait Collective: Send + Sync {
    fn name(&self) -> &'static str;

    /// PSync (paper Algorithm 3/6): `vs[i] ← (1/n) Σ_j C(v_j) + (v_i −
    /// C(v_i))`; `resid_out[i] = v_i − C(v_i)` when requested.
    fn psync(
        &self,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &dyn Compressor,
        round: u64,
    ) -> PsyncRound;

    /// The mean-of-compressed exchange under PSync: `qs[i] ← (1/n) Σ_j
    /// C(q_j)` (identical on every worker), residuals as above.  EF-SGD and
    /// QSparse-local-SGD consume the mean and the residual separately.
    fn exchange_mean(
        &self,
        qs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &dyn Compressor,
        round: u64,
    ) -> PsyncRound;
}

/// The original single-address-space path: no serialization, no threads,
/// exact bit accounting.  This is the reference backend every other backend
/// is tested against.
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcess;

impl Collective for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn psync(
        &self,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &dyn Compressor,
        round: u64,
    ) -> PsyncRound {
        psync(vs, resid_out, c, round)
    }

    fn exchange_mean(
        &self,
        qs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &dyn Compressor,
        round: u64,
    ) -> PsyncRound {
        exchange_mean(qs, resid_out, c, round)
    }
}

/// Backend selector for configs/CLIs (a `Copy` tag that builds the trait
/// object on demand).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    #[default]
    InProcess,
    Threaded,
    /// The `Threaded` wire collectives driven in **worker-resident** mode:
    /// each worker is a persistent OS thread owning its
    /// `engine::WorkerState`, running gradient → compress → sync → apply end
    /// to end and meeting the other workers only at the collective — no
    /// central gradients array, no lock-step barrier in the trainer
    /// (`coordinator::sim_trainer` routes engine optimizers through
    /// `ErrorResetEngine::run_resident` when this backend is selected).
    Resident,
}

impl Backend {
    pub fn collective(self) -> Arc<dyn Collective> {
        match self {
            Backend::InProcess => Arc::new(InProcess),
            Backend::Threaded | Backend::Resident => Arc::new(Threaded::new()),
        }
    }

    /// True when the trainer should hand the step loop to the worker threads
    /// (`ErrorResetEngine::run_resident`) instead of driving it centrally.
    pub fn worker_resident(self) -> bool {
        matches!(self, Backend::Resident)
    }
}

/// Shared default used by optimizers constructed without an explicit
/// backend.
pub fn default_collective() -> Arc<dyn Collective> {
    Arc::new(InProcess)
}
