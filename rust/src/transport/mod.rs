//! The wire layer: real serialized collectives under PSync.
//!
//! The seed executed every synchronization as in-place mutation of shared
//! `Vec<Vec<f32>>` and merely *accounted* communication.  This subsystem
//! makes the transport explicit and swappable:
//!
//! * [`wire`] — bit-packed codecs for every compressor payload, with the
//!   invariant that the encoded length equals the accounted bits; decoders
//!   validate untrusted frames (`Result`, not `debug_assert!`);
//! * [`peer`] — the **peer-owned** protocol: each worker executes its own
//!   ring segment / parameter-server exchange over a [`peer::PeerTransport`]
//!   it holds, instead of a rendezvous electing runner threads per call.
//!   [`pipeline`] drives the same protocol per gradient *bucket* with a
//!   persistent per-worker prepare thread, overlapping bucket k+1's
//!   compression with bucket k's exchange (DESIGN.md §2.2).
//!   Three transports implement it:
//!   * [`mesh`] — a full mesh of mpsc channels for workers living in one
//!     process (persistent resident threads, the [`Threaded`] pool);
//!   * [`tcp`] — persistent loopback/LAN sockets between N independent OS
//!     processes, bootstrapped by [`rendezvous`] (rank 0 hosts a peer-table
//!     exchange); frames are length-prefixed `(round, tag, bit length)`
//!     headers over the same bit-packed payloads, so measured wire traffic
//!     stays `encoded bits ≡ accounted bits`;
//! * [`Collective`] — the central aggregation interface optimizers run
//!   over, with two backends: [`InProcess`] (the original single-address-
//!   space fast path; zero serialization, bit accounting only) and
//!   [`Threaded`] (a persistent pool of mesh workers moving serialized
//!   [`wire::WireMsg`]s — ring reduce-scatter/all-gather for shared-support
//!   compressors, gather/broadcast parameter server otherwise).
//!
//! Numerics: the parameter-server path is **bit-identical** to `InProcess`
//! (messages decode to the exact `C(q_i)` bits and the server accumulates in
//! worker order).  The ring path reduces chunks in ring order, so results
//! agree with `InProcess` only up to f32 reduction-order error (~1e-7
//! relative per element; the equivalence tests pin a 1e-4 trajectory
//! tolerance on training workloads).

pub mod fault;
pub mod mesh;
pub mod peer;
pub mod pipeline;
pub mod rendezvous;
pub mod tcp;
pub mod threaded;
pub mod wire;

pub use fault::FaultTransport;
pub use peer::{PeerTransport, Tag, TransportError};
pub use pipeline::{pipelined_sync, BucketPipeline};
pub use tcp::TcpTransport;
pub use threaded::Threaded;
pub use wire::{BitReader, BitWriter, WireError, WireMsg};

use crate::collective::{exchange_mean_with, psync_censored_with, psync_with, PsyncRound};
use crate::compressor::Compressor;
use crate::kernel::with_thread_scratch;
use std::sync::Arc;

/// A synchronization backend: how per-worker vectors are aggregated.
///
/// Both methods are *collective calls*: `vs`/`qs` hold one vector per worker
/// and every worker's slot is updated as if each worker ran its side of the
/// protocol.  `round` seeds the compressor's selection schedule.  The
/// compressor travels as `&Arc<dyn Compressor>` so backends with persistent
/// worker threads can hand each thread a handle without re-spawning per
/// call.
pub trait Collective: Send + Sync {
    fn name(&self) -> &'static str;

    /// PSync (paper Algorithm 3/6): `vs[i] ← (1/n) Σ_j C(v_j) + (v_i −
    /// C(v_i))`; `resid_out[i] = v_i − C(v_i)` when requested.
    fn psync(
        &self,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
    ) -> PsyncRound;

    /// The mean-of-compressed exchange under PSync: `qs[i] ← (1/n) Σ_j
    /// C(q_j)` (identical on every worker), residuals as above.  EF-SGD and
    /// QSparse-local-SGD consume the mean and the residual separately.
    fn exchange_mean(
        &self,
        qs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
    ) -> PsyncRound;

    /// PSync under the censoring cadence (Li et al., PAPERS.md): worker `i`
    /// contributes `C(v_i)` only when `‖C(v_i)‖ ≥ tau`
    /// ([`crate::collective::censors`]); censored workers upload zero bits
    /// and keep the whole update as residual.  The default runs the
    /// in-process reference — since the parameter-server wire path is
    /// bit-identical to it, every backend inherits the identical censoring
    /// verdicts and this default is exact for `Threaded` too.
    fn psync_censored(
        &self,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
        tau: f32,
    ) -> PsyncRound {
        with_thread_scratch(|s| psync_censored_with(vs, resid_out, c.as_ref(), round, tau, s))
    }
}

/// The original single-address-space path: no serialization, no threads,
/// exact bit accounting.  This is the reference backend every other backend
/// is tested against.
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcess;

impl Collective for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn psync(
        &self,
        vs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
    ) -> PsyncRound {
        // `&self` cannot hold a scratch; the calling thread's persistent one
        // gives the same cross-step reuse (the central step loop is
        // single-threaded per engine).
        with_thread_scratch(|s| psync_with(vs, resid_out, c.as_ref(), round, s))
    }

    fn exchange_mean(
        &self,
        qs: &mut [Vec<f32>],
        resid_out: Option<&mut [Vec<f32>]>,
        c: &Arc<dyn Compressor>,
        round: u64,
    ) -> PsyncRound {
        with_thread_scratch(|s| exchange_mean_with(qs, resid_out, c.as_ref(), round, s))
    }
}

/// Backend selector for configs/CLIs.
///
/// **Migration note:** `Backend` is no longer `Copy` — the [`Backend::Tcp`]
/// variant carries the rendezvous address.  Clone it where it used to be
/// copied.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    #[default]
    InProcess,
    Threaded,
    /// Worker-resident mode: each worker is a persistent OS thread owning
    /// its `engine::WorkerState`, running gradient → compress → sync → apply
    /// end to end, and executing **its own side** of every collective over a
    /// [`mesh`] channel endpoint — no central gradients array, no lock-step
    /// barrier in the trainer, no per-call thread spawns
    /// (`coordinator::sim_trainer` routes engine optimizers through
    /// `ErrorResetEngine::run_resident` when this backend is selected).
    Resident,
    /// Real multi-process training over TCP: this process is worker `rank`
    /// of `peers`, joining the job at rendezvous address `bind` (rank 0
    /// hosts it).  The trainer routes through the peer-owned
    /// [`tcp::TcpTransport`]; the `cser worker` / `cser launch` subcommands
    /// surface this from the CLI.
    Tcp { bind: String, peers: usize, rank: usize },
}

impl Backend {
    /// The central [`Collective`] this backend drives `DistOptimizer::step`
    /// through.  `Tcp` has none — each process owns only its local rank's
    /// state, so the trainer routes it through the peer-owned transport
    /// instead of a central call path.
    pub fn collective(&self) -> Arc<dyn Collective> {
        match self {
            Backend::InProcess => Arc::new(InProcess),
            Backend::Threaded | Backend::Resident => Arc::new(Threaded::new()),
            Backend::Tcp { .. } => panic!(
                "Backend::Tcp has no central collective; route through the distributed trainer"
            ),
        }
    }

    /// True when the trainer should hand the step loop to the worker threads
    /// (`ErrorResetEngine::run_resident`) instead of driving it centrally.
    pub fn worker_resident(&self) -> bool {
        matches!(self, Backend::Resident)
    }
}

/// Shared default used by optimizers constructed without an explicit
/// backend.
pub fn default_collective() -> Arc<dyn Collective> {
    Arc::new(InProcess)
}
