//! Peer-owned collectives: each worker executes its own side of the
//! protocol over its own links.
//!
//! The original `Threaded` backend was *rendezvous-elects-a-runner*: every
//! collective call spawned 2n fresh OS threads to move the messages, the
//! per-call cost DESIGN.md §5 documented.  This module turns the protocol
//! inside out: a worker — a persistent mesh thread (`transport::mesh`), a
//! pool thread inside the rewritten [`super::Threaded`], or an entire OS
//! process (`transport::tcp`) — calls [`psync`]/[`exchange_mean`] with *its
//! own* vector, and the function runs that worker's segment of the exchange
//! over whatever [`PeerTransport`] it holds.  No thread is ever spawned per
//! call; the transport is the only thing that varies.
//!
//! Protocol (identical to the old `Threaded` schedules, so the numerics
//! carry over):
//!
//! * **Ring** — globally-synchronized sparsifiers (shared support, zero
//!   index metadata): gather the selected values into a compact vector,
//!   reduce-scatter then all-gather around the ring in `2(n−1)` steps.
//!   Chunk sums accumulate in ring order ⇒ results match the in-process
//!   reference up to f32 reduction-order error (documented tolerance).
//! * **Parameter server** — per-worker supports and dense quantizers:
//!   every peer uploads its encoded message to the leader (rank 0 on a
//!   fixed fleet, [`PeerTransport::leader`] under failover), which decodes
//!   in **worker order** (bit-identical to the in-process accumulation),
//!   broadcasts the union/dense aggregate plus an accounting frame carrying
//!   the fleet-wide `upload_bits_per_worker`, so every rank reports the
//!   same accounting the in-process backend would.  An absorbed leader
//!   death re-roots the round on the deterministic successor and redoes
//!   the exchange (DESIGN.md §10).
//!
//! [`vote`] and [`agree`] are the control-plane collectives: the loss-mean
//! divergence verdict that used to piggyback on the resident rendezvous,
//! and a boolean OR used by the distributed trainer to keep every process
//! on the same control-flow path.  [`mean_dense`] is the dense gather/mean/
//! broadcast used for SGD's gradient average and for evaluating x̄ across
//! processes (worker-order arithmetic — bit-identical to
//! `util::math::mean_rows`).

use super::wire::{self, WireError, WireMsg};
use crate::collective::{PsyncRound, WireCost};
use crate::compressor::{payload_bits_wire, Compressor, Ctx, Scratch, Selection};
use crate::kernel::dense as math;
use crate::obs::{self, Phase};
use std::sync::Arc;

/// A transport-level failure: a peer hung up, a frame failed validation, or
/// the underlying socket/channel errored.  In-process transports surface
/// this when a worker thread dies (the panic cascades instead of
/// deadlocking); the TCP transport surfaces network and framing errors.
///
/// Worker death is a *distinguishable* case ([`TransportError::PeerDown`])
/// so the membership layer can downgrade it to "censored this round"
/// without string-matching; everything else is terminal
/// ([`TransportError::Failed`]).
#[derive(Debug, Clone)]
pub enum TransportError {
    /// Terminal failure: framing, validation, desynchronization, or an
    /// unrecoverable socket/rendezvous error.
    Failed(String),
    /// Peer `rank` is gone — its thread died or its socket closed.
    /// Recoverable under partial participation, terminal otherwise.
    PeerDown { rank: usize, detail: String },
}

impl TransportError {
    /// A terminal failure.
    pub fn failed(detail: impl Into<String>) -> Self {
        TransportError::Failed(detail.into())
    }

    /// A dead-peer failure attributable to `rank`.
    pub fn peer_down(rank: usize, detail: impl Into<String>) -> Self {
        TransportError::PeerDown { rank, detail: detail.into() }
    }

    /// The dead peer's rank, when this failure is attributable to one.
    pub fn downed_peer(&self) -> Option<usize> {
        match self {
            TransportError::PeerDown { rank, .. } => Some(*rank),
            TransportError::Failed(_) => None,
        }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Failed(detail) => write!(f, "transport error: {detail}"),
            TransportError::PeerDown { rank, detail } => {
                write!(f, "transport error: peer {rank} down: {detail}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> Self {
        TransportError::Failed(e.to_string())
    }
}

/// Frame kind, carried in every frame header so a desynchronized stream
/// fails validation instead of decoding garbage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Tag {
    /// Ring reduce-scatter / all-gather chunk (raw f32s).
    Chunk = 0,
    /// Parameter-server upload: one worker's encoded `C(v)`.
    Upload = 1,
    /// Accounting broadcast: fleet-wide `upload_bits_per_worker` (u64).
    AggInfo = 2,
    /// Parameter-server downlink: the union/dense aggregate.
    Aggregate = 3,
    /// Dense gather/mean/broadcast payload ([`mean_dense`]).
    Dense = 4,
    /// Per-worker loss vote (f64 bits).
    Loss = 5,
    /// Loss-mean + stop verdict broadcast (f64 bits + 1 bit).
    Verdict = 6,
    /// Boolean agreement frame ([`agree`]).
    Flag = 7,
    /// Membership view update at a round boundary: epoch id, live mask,
    /// joiner mask (`membership::epoch_boundary`).
    Epoch = 8,
    /// Telemetry delta snapshot shipped to the leader every K rounds
    /// (`obs::metrics::encode_snapshot`).  Control-plane only — a late or
    /// lost metrics frame never stalls the data plane (stale frames are
    /// discarded by the per-link round check).
    Metrics = 9,
    /// Control-state replication frame: the leader's generation-stamped
    /// epoch/admission/censoring state, shipped to the deterministic
    /// successor at every epoch boundary so a leader death hands over
    /// without regressing run-wide state (`membership::ControlState`).
    ControlState = 10,
}

impl Tag {
    pub fn from_u8(b: u8) -> Option<Tag> {
        use Tag::*;
        Some(match b {
            0 => Chunk,
            1 => Upload,
            2 => AggInfo,
            3 => Aggregate,
            4 => Dense,
            5 => Loss,
            6 => Verdict,
            7 => Flag,
            8 => Epoch,
            9 => Metrics,
            10 => ControlState,
            _ => return None,
        })
    }
}

/// One worker's endpoints into the fleet.  `send`/`recv` address peers by
/// rank; implementations must deliver frames per-link in FIFO order (mpsc
/// channels and TCP streams both do), which is what lets consecutive
/// collectives share (round, tag) headers without ambiguity.
pub trait PeerTransport: Send {
    fn rank(&self) -> usize;
    fn n(&self) -> usize;

    fn send(&mut self, to: usize, round: u64, tag: Tag, msg: WireMsg)
        -> Result<(), TransportError>;

    /// Send `msg` to every other peer.  The default clones per peer;
    /// in-process transports override to share one allocation.
    fn broadcast(&mut self, round: u64, tag: Tag, msg: WireMsg) -> Result<(), TransportError> {
        for j in 0..self.n() {
            if j != self.rank() {
                self.send(j, round, tag, msg.clone())?;
            }
        }
        Ok(())
    }

    /// Blocking receive of the next frame from `from`; fails if its header
    /// does not carry exactly (`round`, `tag`).
    fn recv(&mut self, from: usize, round: u64, tag: Tag) -> Result<Arc<WireMsg>, TransportError>;

    // --- membership hooks (partial participation) -----------------------
    //
    // Fixed-fleet transports keep the defaults: everyone is live forever,
    // a dead peer is a terminal error, receives block without deadline.
    // `membership::Elastic` overrides all four to run an epoch-based view.

    /// Is `rank` live under the current membership view?
    fn is_live(&self, _rank: usize) -> bool {
        true
    }

    /// Number of live ranks this round — the aggregate scale under partial
    /// participation (`1/n_live` replaces `1/n` in every mean).
    fn live_count(&self) -> usize {
        self.n()
    }

    /// A peer was found dead mid-collective.  Returns true when the
    /// transport absorbs the death (the caller then censors the peer for
    /// this round and carries on); false keeps the historical fail-stop.
    fn on_peer_down(&mut self, _rank: usize) -> bool {
        false
    }

    /// Per-gather deadline for rank-0 receives; `None` blocks forever.
    fn round_timeout(&self) -> Option<std::time::Duration> {
        None
    }

    /// [`PeerTransport::recv`] with an optional timeout: `Ok(None)` means
    /// the deadline expired (the caller censors the peer for this round).
    /// Implementations honoring the timeout must also discard stale frames
    /// from `from`: rounds *lower* than `round` (leftovers of censored
    /// rounds) and same-round [`Tag::Chunk`] frames when `tag` is not
    /// `Chunk` (leftovers of a ring attempt that aborted into the
    /// parameter-server fallback — `Chunk` is ring-only, so the mismatch is
    /// unambiguous).  The default ignores the timeout.
    fn recv_deadline(
        &mut self,
        from: usize,
        round: u64,
        tag: Tag,
        timeout: Option<std::time::Duration>,
    ) -> Result<Option<Arc<WireMsg>>, TransportError> {
        let _ = timeout;
        self.recv(from, round, tag).map(Some)
    }

    /// The agreed membership view as a bitmask over physical ranks: bit `r`
    /// set means rank `r` participates in ring schedules this epoch.  Every
    /// participant must report the identical mask (it is what ring order is
    /// derived from), so elastic transports return the *boundary-agreed*
    /// view, never a locally-suspected one.  Fixed fleets are fully live;
    /// fleets wider than 64 ranks saturate the mask and ring callers treat
    /// the out-of-mask high ranks as live.
    fn view_mask(&self) -> u64 {
        if self.n() >= 64 {
            u64::MAX
        } else {
            (1u64 << self.n()) - 1
        }
    }

    /// True while the transport believes a ring over the current view
    /// cannot complete (a death or stall was observed mid-epoch).  Ring-
    /// routed collectives consult this before each attempt and route the
    /// round over the parameter-server path instead; the next epoch
    /// boundary re-forms the ring and clears the latch.  Fixed fleets never
    /// degrade.
    fn ring_degraded(&self) -> bool {
        false
    }

    /// A ring attempt aborted (recv deadline expired, or a peer death was
    /// absorbed mid-ring).  Elastic transports latch degraded mode here so
    /// subsequent rounds skip the doomed attempt instead of burning a full
    /// deadline each; fixed fleets ignore it — for them the stall already
    /// surfaced as an error.
    fn on_ring_stall(&mut self) {}

    /// The rank every rooted collective (parameter server, dense mean,
    /// vote, agreement) treats as its root this round.  Fixed fleets pin
    /// rank 0 forever; `membership::Elastic` under `--failover` reports
    /// the lowest live rank, so after a leader death is absorbed every
    /// survivor re-roots on the identical deterministic successor.
    fn leader(&self) -> usize {
        0
    }
}

/// Did `e` take down the leader this collective was rooted on, and does the
/// transport absorb that death?  When true the caller redoes the whole
/// attempt: `t.leader()` has already moved to the deterministic successor,
/// and every survivor observes the same dead root at the same round, so
/// they all redo together (the leader-stall analogue of the ring stall).
/// Fixed-fleet transports return false from `on_peer_down`, keeping the
/// historical fail-stop.
fn leader_loss_absorbed(t: &mut dyn PeerTransport, e: &TransportError, ldr: usize) -> bool {
    match e.downed_peer() {
        Some(r) if r == ldr => t.on_peer_down(r),
        _ => false,
    }
}

/// Rank-0 gather receive under partial participation: `Ok(None)` means
/// peer `from`'s contribution is censored this round — it is outside the
/// live view, its frame missed the round deadline, or it died and the
/// transport absorbs deaths.  Fixed-fleet transports never censor: the
/// timeout is `None` and a death stays an error.
fn recv_or_censor(
    t: &mut dyn PeerTransport,
    from: usize,
    round: u64,
    tag: Tag,
) -> Result<Option<Arc<WireMsg>>, TransportError> {
    if !t.is_live(from) {
        return Ok(None);
    }
    let timeout = t.round_timeout();
    match t.recv_deadline(from, round, tag, timeout) {
        Ok(m) => Ok(m),
        Err(e) => match e.downed_peer() {
            Some(r) if t.on_peer_down(r) => {
                let _s = obs::Span::enter_arg(Phase::Censor, r as u64);
                Ok(None)
            }
            _ => Err(e),
        },
    }
}

/// PSync vs bare mean-of-compressed (the two `Collective` entry points).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// v ← mean + own residual (PSync proper).
    Psync,
    /// v ← mean; residual only reported.
    Exchange,
}

/// This worker's side of PSync: `v ← (1/n) Σ_j C(v_j) + (v − C(v))`;
/// `resid = v − C(v)` when requested.  The returned [`PsyncRound`] carries
/// this worker's selection (`selections.len() == 1`), the fleet-uniform
/// accounted upload bits, and this worker's measured wire traffic.
pub fn psync(
    t: &mut dyn PeerTransport,
    v: &mut Vec<f32>,
    resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
) -> Result<PsyncRound, TransportError> {
    run(t, Mode::Psync, v, resid, c, round, &mut Scratch::new())
}

/// [`psync`] with a caller-owned [`Scratch`] — the steady-state entry (the
/// engine threads each worker's scratch through here, so selection/codec
/// working buffers are reused across steps).
pub fn psync_with(
    t: &mut dyn PeerTransport,
    v: &mut Vec<f32>,
    resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
    scratch: &mut Scratch,
) -> Result<PsyncRound, TransportError> {
    run(t, Mode::Psync, v, resid, c, round, scratch)
}

/// This worker's side of the mean-of-compressed exchange:
/// `v ← (1/n) Σ_j C(v_j)`, residual as above.
pub fn exchange_mean(
    t: &mut dyn PeerTransport,
    v: &mut Vec<f32>,
    resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
) -> Result<PsyncRound, TransportError> {
    run(t, Mode::Exchange, v, resid, c, round, &mut Scratch::new())
}

/// [`exchange_mean`] with a caller-owned [`Scratch`] (see [`psync_with`]).
pub fn exchange_mean_with(
    t: &mut dyn PeerTransport,
    v: &mut Vec<f32>,
    resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
    scratch: &mut Scratch,
) -> Result<PsyncRound, TransportError> {
    run(t, Mode::Exchange, v, resid, c, round, scratch)
}

/// [`psync_with`] under the censoring cadence (Li et al., PAPERS.md): this
/// worker transmits only when its compressed update's norm clears `tau`
/// (see [`crate::collective::censors`]); a censored worker uploads an
/// empty frame, keeps its *whole* update as residual, and still receives
/// the aggregate.  Parameter-server routing only — a globally-synchronized
/// sparse C derives one shared support and cannot drop per-worker uploads
/// (`CommPlan::validate` rejects such pairings).
pub fn psync_censored_with(
    t: &mut dyn PeerTransport,
    v: &mut Vec<f32>,
    resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
    tau: f32,
    scratch: &mut Scratch,
) -> Result<PsyncRound, TransportError> {
    debug_assert!(
        !(c.globally_synchronized() && !c.is_dense()),
        "censoring cadence is parameter-server-routed"
    );
    if t.n() == 1 {
        let vs = std::slice::from_mut(v);
        let rs = resid.map(std::slice::from_mut);
        return Ok(crate::collective::psync_censored_with(vs, rs, c, round, tau, scratch));
    }
    ps(t, Mode::Psync, v, resid, c, round, Some(tau), scratch)
}

pub(crate) fn run(
    t: &mut dyn PeerTransport,
    mode: Mode,
    v: &mut Vec<f32>,
    resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
    scratch: &mut Scratch,
) -> Result<PsyncRound, TransportError> {
    if t.n() == 1 {
        // Degenerate fleet: nothing travels; keep reference numerics.
        let vs = std::slice::from_mut(v);
        let rs = resid.map(std::slice::from_mut);
        return Ok(match mode {
            Mode::Psync => crate::collective::psync_with(vs, rs, c, round, scratch),
            Mode::Exchange => crate::collective::exchange_mean_with(vs, rs, c, round, scratch),
        });
    }
    if c.globally_synchronized() && !c.is_dense() {
        let mut resid = resid;
        // Ring-routed family.  While the membership layer reports the ring
        // degraded (a death latched mid-epoch), skip the doomed attempt
        // entirely; otherwise attempt the ring, and when a mid-cycle stall
        // aborts it, redo the *same* round over the parameter-server path.
        // The dead rank cuts the cycle for everyone, so every survivor
        // falls back together: tags keep the two protocols unambiguous on
        // the wire (ring frames are Chunk-tagged, leftovers are drained as
        // stale), live-but-late uploads censor at rank 0's deadline, and
        // the accounting broadcast keeps reported bits fleet-uniform — the
        // same censor-and-rescale the PS family always ran.  The shared
        // support of a globally-synchronized compressor means the PS union
        // aggregate equals the ring mean over the responders.
        if !t.ring_degraded() {
            if let Some(done) =
                ring(t, mode, v, resid.as_mut().map(|r| &mut **r), c, round, scratch)?
            {
                return Ok(done);
            }
        }
        ps(t, mode, v, resid, c, round, None, scratch)
    } else {
        ps(t, mode, v, resid, c, round, None, scratch)
    }
}

/// Balanced chunk bounds: chunk `k` of a length-`m` vector split `n` ways.
pub(crate) fn chunk_bounds(m: usize, n: usize, k: usize) -> (usize, usize) {
    (k * m / n, (k + 1) * m / n)
}

/// Ring chunks travel in segments of at most this many values (32 KiB of
/// payload).  With blocking sockets, every peer sending its whole chunk
/// before receiving would deadlock as soon as a chunk outgrows the kernel
/// socket buffers (the in-process mesh masks this — mpsc channels are
/// unbounded); alternating bounded segments keeps at most ~2 segments in
/// flight per link, far below default buffer sizes, at the cost of one
/// frame header per segment.  Payload bits and reduction order are
/// unchanged, so accounting and numerics are identical to an unsegmented
/// exchange.
const RING_SEGMENT_F32S: usize = 8192;

/// One ring step: send `compact[send]` to `next` while receiving the same
/// peer-count of segments from `prev` into `compact[recv]`, segment by
/// segment.  `reduce` accumulates (reduce-scatter) instead of overwriting
/// (all-gather).  Returns the bits this peer sent, or `None` when the
/// attempt stalled: the recv deadline expired, or a neighbor's death was
/// absorbed by the membership layer.  A dead rank cuts the cycle, so *no*
/// survivor can complete the schedule — every one of them stalls at this
/// round and falls back together (see [`run`]).  Fixed fleets have no
/// deadline and never absorb deaths, so for them `None` is unreachable.
#[allow(clippy::too_many_arguments)]
pub(crate) fn ring_exchange(
    t: &mut dyn PeerTransport,
    compact: &mut [f32],
    next: usize,
    prev: usize,
    round: u64,
    send: (usize, usize),
    recv: (usize, usize),
    reduce: bool,
) -> Result<Option<u64>, TransportError> {
    let seg = RING_SEGMENT_F32S;
    let timeout = t.round_timeout();
    // Both ends derive the segment count from the chunk length, which both
    // can compute — no count header needed.
    let send_segs = (send.1 - send.0).div_ceil(seg);
    let recv_segs = (recv.1 - recv.0).div_ceil(seg);
    let mut bits = 0u64;
    for k in 0..send_segs.max(recv_segs) {
        if k < send_segs {
            let s0 = send.0 + k * seg;
            let s1 = (s0 + seg).min(send.1);
            let msg = wire::encode_f32s(&compact[s0..s1]);
            bits += msg.bit_len;
            match t.send(next, round, Tag::Chunk, msg) {
                Ok(()) => {}
                Err(e) => match e.downed_peer() {
                    Some(r) if t.on_peer_down(r) => return Ok(None),
                    _ => return Err(e),
                },
            }
        }
        if k < recv_segs {
            let r0 = recv.0 + k * seg;
            let r1 = (r0 + seg).min(recv.1);
            let msg = match t.recv_deadline(prev, round, Tag::Chunk, timeout) {
                Ok(Some(m)) => m,
                Ok(None) => return Ok(None),
                Err(e) => match e.downed_peer() {
                    Some(r) if t.on_peer_down(r) => return Ok(None),
                    _ => return Err(e),
                },
            };
            if reduce {
                wire::decode_f32s_add(&msg, &mut compact[r0..r1])?;
            } else {
                wire::decode_f32s(&msg, &mut compact[r0..r1])?;
            }
        }
    }
    Ok(Some(bits))
}

/// Gather `v`'s selected ranges into a compact vector of length `sel.count`.
pub(crate) fn gather(sel: &Selection, v: &[f32], compact: &mut Vec<f32>) {
    compact.clear();
    sel.for_each_range(v.len(), |s, e| compact.extend_from_slice(&v[s..e]));
}

/// Ranks participating in ring schedules under the transport's agreed
/// view, in ascending rank order — the ring order every participant
/// derives independently from the identical [`PeerTransport::view_mask`].
/// Fleets wider than the 64-bit mask treat the high ranks as always live.
pub(crate) fn ring_members(t: &dyn PeerTransport) -> Vec<usize> {
    let view = t.view_mask();
    (0..t.n()).filter(|&r| r >= 64 || (view >> r) & 1 == 1).collect()
}

/// The ring's data movement for one already-gathered compact vector:
/// reduce-scatter, all-gather, then the 1/l mean scale over the l live
/// ranks — exactly the chunk schedule and reduction order of the
/// whole-vector path (this *is* the whole-vector path's core; the bucketed
/// pipeline drives it per bucket).  On a fully-live view the schedule is
/// bit-identical to the historical fixed-fleet ring.  Returns
/// (reduce-scatter bits sent, all-gather bits sent), or `None` when the
/// attempt stalled mid-cycle (see [`ring_exchange`]) — `compact` is then
/// partially reduced garbage and must be discarded by the caller.
pub(crate) fn ring_rounds(
    t: &mut dyn PeerTransport,
    compact: &mut [f32],
    round: u64,
) -> Result<Option<(u64, u64)>, TransportError> {
    let i = t.rank();
    let m = compact.len();
    let live = ring_members(t);
    let l = live.len();
    let pos = live.iter().position(|&r| r == i).ok_or_else(|| {
        TransportError::failed(format!("rank {i} is outside the agreed ring view"))
    })?;
    if l == 1 {
        // Sole survivor: the ring is this rank alone, the mean of one.
        return Ok(Some((0, 0)));
    }
    let next = live[(pos + 1) % l];
    let prev = live[(pos + l - 1) % l];
    // Traffic split follows `ring_allreduce_cost`'s convention: `up` = bits
    // sent during reduce-scatter, `down` = bits sent during all-gather.
    let (mut up, mut down) = (0u64, 0u64);
    // Reduce-scatter: after l-1 steps this peer owns the fully reduced
    // chunk (pos+1) % l.
    for step in 0..l - 1 {
        let send = chunk_bounds(m, l, (pos + l - step) % l);
        let recv = chunk_bounds(m, l, (pos + l - step - 1) % l);
        match ring_exchange(t, compact, next, prev, round, send, recv, true)? {
            Some(b) => up += b,
            None => return Ok(None),
        }
    }
    // All-gather: circulate the completed chunks.
    for step in 0..l - 1 {
        let send = chunk_bounds(m, l, (pos + 1 + l - step) % l);
        let recv = chunk_bounds(m, l, (pos + l - step) % l);
        match ring_exchange(t, compact, next, prev, round, send, recv, false)? {
            Some(b) => down += b,
            None => return Ok(None),
        }
    }
    let inv = 1.0 / l as f32;
    for x in compact.iter_mut() {
        *x *= inv;
    }
    Ok(Some((up, down)))
}

/// The compression phase of the parameter-server path: select, encode, and
/// self-decode (so downstream arithmetic sees the exact bits the server
/// aggregates).  `own` is an owned staging buffer (recycled by callers);
/// it returns holding the decoded `C(v)`.
pub(crate) struct PsUpload {
    pub sel: Selection,
    pub msg: WireMsg,
    pub own: Vec<f32>,
}

pub(crate) fn ps_prepare(
    c: &dyn Compressor,
    ctx: Ctx,
    v: &[f32],
    mut own: Vec<f32>,
    scratch: &mut Scratch,
) -> Result<PsUpload, WireError> {
    let sel = {
        let _s = obs::Span::enter(Phase::Select);
        c.select_with(ctx, v, scratch)
    };
    let msg = {
        let _s = obs::Span::enter(Phase::Encode);
        wire::encode_with_selection(c, ctx, v, Some(&sel))
    };
    own.clear();
    own.resize(v.len(), 0.0);
    {
        let _s = obs::Span::enter(Phase::Decode);
        wire::decode(c, ctx, &msg, &mut own)?;
    }
    Ok(PsUpload { sel, msg, own })
}

/// The exchange phase of the parameter-server path: upload → worker-order
/// accumulate at the leader → accounting + aggregate broadcast.  `own`
/// must be this worker's decoded `C(v)` (from [`ps_prepare`]); `agg`
/// receives the decoded union/dense aggregate.  Returns (fleet accounted
/// bits per worker, up bits, down bits).  Server staging buffers live in
/// `scratch` (`vb`/`vc`/`mask`).
///
/// The round is rooted on [`PeerTransport::leader`].  When the leader dies
/// mid-exchange and the transport absorbs the death (failover), the whole
/// exchange is redone at the same round rooted on the successor: the
/// compression phase already ran, so the identical `msg`/`own` re-enter,
/// and the erstwhile client that finds itself the new leader serves the
/// redo.  Frames sent to the dead leader die with its sockets, so no stale
/// frame survives onto a live link.
pub(crate) fn ps_rounds(
    t: &mut dyn PeerTransport,
    c: &dyn Compressor,
    round: u64,
    msg: WireMsg,
    own: &[f32],
    agg: &mut Vec<f32>,
    scratch: &mut Scratch,
) -> Result<(u64, u64, u64), TransportError> {
    loop {
        let ldr = t.leader();
        match ps_rounds_at(t, c, round, &msg, own, agg, scratch, ldr) {
            Err(e) if leader_loss_absorbed(t, &e, ldr) => continue,
            r => return r,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn ps_rounds_at(
    t: &mut dyn PeerTransport,
    c: &dyn Compressor,
    round: u64,
    msg: &WireMsg,
    own: &[f32],
    agg: &mut Vec<f32>,
    scratch: &mut Scratch,
    ldr: usize,
) -> Result<(u64, u64, u64), TransportError> {
    let n = t.n();
    let d = own.len();
    let up = msg.bit_len;
    agg.clear();
    agg.resize(d, 0.0);
    if t.rank() == ldr {
        // ---- server (the leader, in its own step) ----
        // All three O(d) server buffers come from the scratch (returned at
        // the end of the branch; error exits abort the run, so losing the
        // capacity there is moot).
        let mut mean = std::mem::take(&mut scratch.vb);
        mean.clear();
        mean.resize(d, 0.0);
        let mut stage = std::mem::take(&mut scratch.vc);
        stage.clear();
        stage.resize(d, 0.0);
        let mut mask = std::mem::take(&mut scratch.mask);
        mask.clear();
        mask.resize(d, false);
        // Under partial participation the mean runs over the live view:
        // dead ranks are excluded from the scale, live-but-censored ranks
        // (deadline miss, cadence skip, mid-round death) contribute zero
        // over the live scale.  A fully-live fleet reduces to the
        // historical 1/n arithmetic bit-for-bit.
        let live = t.live_count();
        let inv = 1.0 / live as f32;
        let mut total_up = 0u64;
        // Accumulate in worker (rank) order — the same order as the
        // in-process backend, so the mean is bit-identical to
        // `collective::exchange_mean` whichever rank serves.
        for j in 0..n {
            if j == ldr {
                total_up += up;
                accumulate(own, inv, &mut mean, &mut mask);
                continue;
            }
            let Some(m) = recv_or_censor(t, j, round, Tag::Upload)? else {
                continue;
            };
            total_up += m.bit_len;
            if m.bit_len == 0 {
                // self-censored this round (cadence): no contribution
                continue;
            }
            wire::decode(c, Ctx { round, worker: j as u32 }, &m, &mut stage)?;
            accumulate(&stage, inv, &mut mean, &mut mask);
        }
        let a = if c.is_dense() {
            wire::encode_f32s(&mean)
        } else {
            wire::encode_union(&mean, &mask)
        };
        let down = a.bit_len;
        // Fleet-wide accounting rides a tiny control frame so every rank
        // reports the identical `upload_bits_per_worker` the in-process
        // backend computes (ceiling of the per-live-worker mean; only bits
        // actually received enter the total).
        let acct = total_up.div_ceil(live as u64);
        let mut w = wire::BitWriter::new();
        w.write(acct, 64);
        t.broadcast(round, Tag::AggInfo, w.finish())?;
        if c.is_dense() {
            wire::decode_f32s(&a, agg)?;
        } else {
            wire::decode_union(&a, agg)?;
        }
        t.broadcast(round, Tag::Aggregate, a)?;
        scratch.vb = mean;
        scratch.vc = stage;
        scratch.mask = mask;
        Ok((acct, up, down))
    } else {
        t.send(ldr, round, Tag::Upload, msg.clone())?;
        // Deadline-less `recv_deadline` rather than `recv`: same blocking
        // semantics, but it drains stale frames — after a ring aborts into
        // this path, leftover same-round Chunk frames may sit ahead of the
        // control broadcasts on the leader link.
        let info = t
            .recv_deadline(ldr, round, Tag::AggInfo, None)?
            .ok_or_else(|| TransportError::failed("accounting frame missed with no deadline"))?;
        if info.bit_len != 64 {
            return Err(TransportError::failed(format!(
                "accounting frame is {} bits, expected 64",
                info.bit_len
            )));
        }
        let acct = info.reader().read(64);
        let a = t
            .recv_deadline(ldr, round, Tag::Aggregate, None)?
            .ok_or_else(|| TransportError::failed("aggregate frame missed with no deadline"))?;
        let down = a.bit_len;
        if c.is_dense() {
            wire::decode_f32s(&a, agg)?;
        } else {
            wire::decode_union(&a, agg)?;
        }
        Ok((acct, up, down))
    }
}

/// One ring-routed round.  `Ok(None)` means the attempt aborted mid-cycle
/// (a peer died or stalled): `v` and `resid` are untouched — only the
/// compact staging buffer saw partial sums — so the caller can redo the
/// identical round over the parameter-server path.
fn ring(
    t: &mut dyn PeerTransport,
    mode: Mode,
    v: &mut Vec<f32>,
    mut resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
    scratch: &mut Scratch,
) -> Result<Option<PsyncRound>, TransportError> {
    let d = v.len();
    let l = ring_members(t).len();
    // Globally-synchronized selections ignore both the vector and the worker
    // id, so every peer derives the identical shared support locally.
    let sel = {
        let _s = obs::Span::enter(Phase::Select);
        c.select_with(Ctx { round, worker: 0 }, v, scratch)
    };
    let bits = payload_bits_wire(c.wire_scheme(), &sel, d);
    let m = sel.count(d);

    if m == 0 {
        // C = 0 everywhere (e.g. the Zero compressor): nothing travels.
        if let Some(r) = resid.as_deref_mut() {
            r.copy_from_slice(v);
        }
        if mode == Mode::Exchange {
            math::fill(v, 0.0);
        }
        return Ok(Some(PsyncRound {
            selections: vec![sel],
            upload_bits_per_worker: 0,
            allreduce_compatible: true,
            wire: Some(WireCost { up_bits: 0, down_bits: 0, steps: 0 }),
        }));
    }

    // The O(d/R) gather buffer lives in the scratch (returned before the
    // success exit; error exits abort the run, so the lost capacity is moot).
    // Chunk schedule and reduction order inside `ring_rounds` are identical
    // to the retired runner-thread ring, so the f32 results carry over.
    let mut compact = std::mem::take(&mut scratch.vb);
    {
        let _s = obs::Span::enter(Phase::Encode);
        gather(&sel, v, &mut compact);
    }
    let rr = {
        let _s = obs::Span::enter(Phase::Exchange);
        ring_rounds(t, &mut compact, round)?
    };
    let Some((up, down)) = rr else {
        // Stalled mid-cycle: latch degraded mode (the boundary clears it)
        // and hand the round back for the parameter-server fallback.
        t.on_ring_stall();
        scratch.vb = compact;
        return Ok(None);
    };
    {
        let _s = obs::Span::enter(Phase::Decode);
        // Residual (v off support) must be captured before the mean
        // overwrites the selected ranges.
        if let Some(r) = resid.as_deref_mut() {
            r.copy_from_slice(v);
            sel.for_each_range(d, |s, e| math::fill(&mut r[s..e], 0.0));
        }
        if mode == Mode::Exchange {
            math::fill(v, 0.0);
        }
        let mut cursor = 0usize;
        sel.for_each_range(d, |s, e| {
            v[s..e].copy_from_slice(&compact[cursor..cursor + (e - s)]);
            cursor += e - s;
        });
    }
    scratch.vb = compact;
    Ok(Some(PsyncRound {
        selections: vec![sel],
        upload_bits_per_worker: bits,
        allreduce_compatible: true,
        wire: Some(WireCost { up_bits: up, down_bits: down, steps: 2 * (l as u32 - 1) }),
    }))
}

/// Accumulate one decoded message into the running mean and union mask —
/// the exact loop the in-process backend runs, in the same worker order.
fn accumulate(src: &[f32], inv: f32, mean: &mut [f32], mask: &mut [bool]) {
    for ((mj, sj), uj) in mean.iter_mut().zip(src).zip(mask.iter_mut()) {
        *mj += inv * *sj;
        *uj |= *sj != 0.0;
    }
}

#[allow(clippy::too_many_arguments)]
fn ps(
    t: &mut dyn PeerTransport,
    mode: Mode,
    v: &mut Vec<f32>,
    mut resid: Option<&mut Vec<f32>>,
    c: &dyn Compressor,
    round: u64,
    censor: Option<f32>,
    scratch: &mut Scratch,
) -> Result<PsyncRound, TransportError> {
    let i = t.rank();
    let d = v.len();
    let ctx = Ctx { round, worker: i as u32 };
    // Compression phase: select, encode, and self-decode (the residual must
    // be computed against the exact bits the server aggregates).  The `own`
    // staging buffer comes from the scratch — reused across rounds
    // (returned before the success exit below).
    let own_buf = scratch.take_dense(d);
    let PsUpload { sel, msg, mut own } = ps_prepare(c, ctx, v, own_buf, scratch)?;
    // Censoring cadence: when ‖C(v)‖ misses the threshold, transmit an
    // empty frame instead — the whole update stays in the residual, the
    // server skips this rank, and zero bits are accounted.  The decision
    // rides the decoded bits, which every backend sees identically.
    let msg = match censor {
        Some(tau) if crate::collective::censors(&own, tau) => {
            let _s = obs::Span::enter_arg(Phase::Censor, i as u64);
            obs::metrics::inc(obs::metrics::Counter::CensoredUploads, 1);
            math::fill(&mut own, 0.0);
            WireMsg { words: Vec::new(), bit_len: 0 }
        }
        _ => msg,
    };
    // r = v − C(v), captured before the aggregate overwrites anything.
    for (vj, kj) in v.iter_mut().zip(&own) {
        *vj -= *kj;
    }
    if let Some(r) = resid.as_deref_mut() {
        r.copy_from_slice(v);
    }
    // Exchange phase: upload / serve, aggregate broadcast, decode into the
    // scratch's aggregate buffer.
    let mut agg = std::mem::take(&mut scratch.vd);
    let (acct_bits, up, down) = {
        let _s = obs::Span::enter(Phase::Exchange);
        ps_rounds(t, c, round, msg, &own, &mut agg, scratch)?
    };
    match mode {
        // v currently holds the residual: v' = mean + residual.
        Mode::Psync => math::axpy(1.0, &agg, v),
        Mode::Exchange => v.copy_from_slice(&agg),
    }
    scratch.vd = agg;
    scratch.put_dense(own);
    Ok(PsyncRound {
        selections: vec![sel],
        upload_bits_per_worker: acct_bits,
        allreduce_compatible: false,
        wire: Some(WireCost { up_bits: up, down_bits: down, steps: 2 }),
    })
}

/// Dense gather → `mean_rows` in worker order at the leader → broadcast.
/// On return every peer's `v` holds the identical mean, bit-identical to
/// `util::math::mean_rows` over the per-worker vectors — this is SGD's
/// gradient average and the cross-process x̄ evaluation.  Uncharged: callers
/// account it themselves where it represents paid traffic.  A mid-gather
/// leader death absorbed by the transport redoes the round on the
/// successor (`v` is untouched until the final decode, so the redo
/// re-encodes the identical input).
pub fn mean_dense(
    t: &mut dyn PeerTransport,
    v: &mut [f32],
    round: u64,
) -> Result<(), TransportError> {
    let n = t.n();
    if n == 1 {
        return Ok(());
    }
    let _s = obs::Span::enter(Phase::BarrierWait);
    loop {
        let ldr = t.leader();
        match mean_dense_at(t, v, round, ldr) {
            Err(e) if leader_loss_absorbed(t, &e, ldr) => continue,
            r => return r,
        }
    }
}

fn mean_dense_at(
    t: &mut dyn PeerTransport,
    v: &mut [f32],
    round: u64,
    ldr: usize,
) -> Result<(), TransportError> {
    let n = t.n();
    let d = v.len();
    if t.rank() == ldr {
        // Partial participation: the mean runs over the responders only
        // (`mean_rows` divides by however many rows arrive), in rank order
        // with the leader's own row in its rank slot.
        let mut rows: Vec<Option<Vec<f32>>> = Vec::with_capacity(n - 1);
        for j in 0..n {
            if j == ldr {
                continue;
            }
            let Some(m) = recv_or_censor(t, j, round, Tag::Dense)? else {
                rows.push(None);
                continue;
            };
            let mut x = vec![0.0f32; d];
            wire::decode_f32s(&m, &mut x)?;
            rows.push(Some(x));
        }
        let mut out = vec![0.0f32; d];
        {
            let mut refs: Vec<&[f32]> = Vec::with_capacity(n);
            let mut it = rows.iter();
            for j in 0..n {
                if j == ldr {
                    refs.push(&*v);
                } else if let Some(Some(x)) = it.next() {
                    refs.push(x.as_slice());
                }
            }
            math::mean_rows(&refs, &mut out);
        }
        t.broadcast(round, Tag::Dense, wire::encode_f32s(&out))?;
        v.copy_from_slice(&out);
    } else {
        t.send(ldr, round, Tag::Dense, wire::encode_f32s(v))?;
        let m = t
            .recv_deadline(ldr, round, Tag::Dense, None)?
            .ok_or_else(|| TransportError::failed("dense mean missed with no deadline"))?;
        wire::decode_f32s(&m, v)?;
    }
    Ok(())
}

/// Divergence vote: the leader folds every peer's loss into the mean
/// `Σ_j loss_j / n` (worker order, the central trainer's expression) and
/// broadcasts `(mean, stop)`; `stop` is true when the mean is non-finite or
/// exceeds `stop_loss`.  Every peer leaves with the same verdict, so the
/// fleet halts on the same step with no extra barrier.  An absorbed leader
/// death redoes the vote on the successor.
pub fn vote(
    t: &mut dyn PeerTransport,
    loss: f64,
    stop_loss: f64,
    round: u64,
) -> Result<(f64, bool), TransportError> {
    let n = t.n();
    if n == 1 {
        return Ok((loss, !loss.is_finite() || loss > stop_loss));
    }
    let _s = obs::Span::enter(Phase::BarrierWait);
    loop {
        let ldr = t.leader();
        match vote_at(t, loss, stop_loss, round, ldr) {
            Err(e) if leader_loss_absorbed(t, &e, ldr) => continue,
            r => return r,
        }
    }
}

fn vote_at(
    t: &mut dyn PeerTransport,
    loss: f64,
    stop_loss: f64,
    round: u64,
    ldr: usize,
) -> Result<(f64, bool), TransportError> {
    let n = t.n();
    if t.rank() == ldr {
        // Divide by the live count term-by-term (the central trainer's
        // exact expression on a fully-live fleet); when a live rank still
        // misses the round, rescale so the mean is over the responders.
        let nl = t.live_count();
        let mut mean = 0f64;
        let mut got = 0usize;
        for j in 0..n {
            if j == ldr {
                mean += loss / nl as f64;
                got += 1;
                continue;
            }
            let Some(m) = recv_or_censor(t, j, round, Tag::Loss)? else {
                continue;
            };
            if m.bit_len != 64 {
                return Err(TransportError::failed(format!(
                    "loss frame is {} bits, expected 64",
                    m.bit_len
                )));
            }
            mean += f64::from_bits(m.reader().read(64)) / nl as f64;
            got += 1;
        }
        if got < nl {
            mean *= nl as f64 / got as f64;
        }
        let stop = !mean.is_finite() || mean > stop_loss;
        let mut w = wire::BitWriter::new();
        w.write(mean.to_bits(), 64);
        w.write(stop as u64, 1);
        t.broadcast(round, Tag::Verdict, w.finish())?;
        Ok((mean, stop))
    } else {
        let mut w = wire::BitWriter::new();
        w.write(loss.to_bits(), 64);
        t.send(ldr, round, Tag::Loss, w.finish())?;
        let m = t
            .recv_deadline(ldr, round, Tag::Verdict, None)?
            .ok_or_else(|| TransportError::failed("verdict missed with no deadline"))?;
        if m.bit_len != 65 {
            return Err(TransportError::failed(format!(
                "verdict frame is {} bits, expected 65",
                m.bit_len
            )));
        }
        let mut r = m.reader();
        let mean = f64::from_bits(r.read(64));
        Ok((mean, r.read(1) == 1))
    }
}

/// True iff every peer passed the same value.  Integer exchange — a float
/// mean would re-round under f32/f64 and reject legitimately equal values
/// for most non-power-of-two fleets.  Used to validate that a restarted
/// fleet resumed from matching checkpoints.
pub fn all_equal(
    t: &mut dyn PeerTransport,
    value: u64,
    round: u64,
) -> Result<bool, TransportError> {
    let n = t.n();
    if n == 1 {
        return Ok(true);
    }
    let _s = obs::Span::enter(Phase::BarrierWait);
    loop {
        let ldr = t.leader();
        match all_equal_at(t, value, round, ldr) {
            Err(e) if leader_loss_absorbed(t, &e, ldr) => continue,
            r => return r,
        }
    }
}

fn all_equal_at(
    t: &mut dyn PeerTransport,
    value: u64,
    round: u64,
    ldr: usize,
) -> Result<bool, TransportError> {
    let n = t.n();
    if t.rank() == ldr {
        // Censored ranks abstain: agreement is over the responders.
        let mut same = true;
        for j in 0..n {
            if j == ldr {
                continue;
            }
            let Some(m) = recv_or_censor(t, j, round, Tag::Flag)? else {
                continue;
            };
            if m.bit_len != 64 {
                return Err(TransportError::failed(format!(
                    "value frame is {} bits, expected 64",
                    m.bit_len
                )));
            }
            same &= m.reader().read(64) == value;
        }
        let mut w = wire::BitWriter::new();
        w.write(same as u64, 1);
        t.broadcast(round, Tag::Flag, w.finish())?;
        Ok(same)
    } else {
        let mut w = wire::BitWriter::new();
        w.write(value, 64);
        t.send(ldr, round, Tag::Flag, w.finish())?;
        let m = t
            .recv_deadline(ldr, round, Tag::Flag, None)?
            .ok_or_else(|| TransportError::failed("flag missed with no deadline"))?;
        if m.bit_len != 1 {
            return Err(TransportError::failed(format!(
                "verdict frame is {} bits, expected 1",
                m.bit_len
            )));
        }
        Ok(m.reader().read(1) == 1)
    }
}

/// Boolean OR across the fleet (e.g. "did anyone diverge this epoch?") —
/// keeps every process on the same control-flow path, which is what keeps
/// the synchronous collectives live.
pub fn agree(t: &mut dyn PeerTransport, flag: bool, round: u64) -> Result<bool, TransportError> {
    let n = t.n();
    if n == 1 {
        return Ok(flag);
    }
    let _s = obs::Span::enter(Phase::BarrierWait);
    loop {
        let ldr = t.leader();
        match agree_at(t, flag, round, ldr) {
            Err(e) if leader_loss_absorbed(t, &e, ldr) => continue,
            r => return r,
        }
    }
}

fn agree_at(
    t: &mut dyn PeerTransport,
    flag: bool,
    round: u64,
    ldr: usize,
) -> Result<bool, TransportError> {
    let n = t.n();
    let bit = |b: bool| {
        let mut w = wire::BitWriter::new();
        w.write(b as u64, 1);
        w.finish()
    };
    if t.rank() == ldr {
        // Censored ranks abstain from the OR.
        let mut any = flag;
        for j in 0..n {
            if j == ldr {
                continue;
            }
            let Some(m) = recv_or_censor(t, j, round, Tag::Flag)? else {
                continue;
            };
            if m.bit_len != 1 {
                return Err(TransportError::failed(format!(
                    "flag frame is {} bits, expected 1",
                    m.bit_len
                )));
            }
            any |= m.reader().read(1) == 1;
        }
        t.broadcast(round, Tag::Flag, bit(any))?;
        Ok(any)
    } else {
        t.send(ldr, round, Tag::Flag, bit(flag))?;
        let m = t
            .recv_deadline(ldr, round, Tag::Flag, None)?
            .ok_or_else(|| TransportError::failed("flag missed with no deadline"))?;
        if m.bit_len != 1 {
            return Err(TransportError::failed(format!(
                "flag frame is {} bits, expected 1",
                m.bit_len
            )));
        }
        Ok(m.reader().read(1) == 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    /// A transport that only answers the view questions — enough to probe
    /// the ring-order derivation without any wire.
    struct StubView {
        rank: usize,
        n: usize,
        mask: Option<u64>,
    }

    impl PeerTransport for StubView {
        fn rank(&self) -> usize {
            self.rank
        }
        fn n(&self) -> usize {
            self.n
        }
        fn send(
            &mut self,
            _to: usize,
            _round: u64,
            _tag: Tag,
            _msg: WireMsg,
        ) -> Result<(), TransportError> {
            Err(TransportError::failed("stub"))
        }
        fn recv(
            &mut self,
            _from: usize,
            _round: u64,
            _tag: Tag,
        ) -> Result<Arc<WireMsg>, TransportError> {
            Err(TransportError::failed("stub"))
        }
        fn view_mask(&self) -> u64 {
            match self.mask {
                Some(m) => m,
                None if self.n >= 64 => u64::MAX,
                None => (1u64 << self.n) - 1,
            }
        }
    }

    #[test]
    fn ring_members_follows_the_view() {
        // Full view: every rank, in order — the historical fixed ring.
        let t = StubView { rank: 0, n: 4, mask: None };
        assert_eq!(ring_members(&t), vec![0, 1, 2, 3]);
        // Masked view: only live bits participate, order preserved.
        let t = StubView { rank: 0, n: 4, mask: Some(0b1011) };
        assert_eq!(ring_members(&t), vec![0, 1, 3]);
        // Wider than the mask: high ranks are treated as always live.
        let t = StubView { rank: 0, n: 70, mask: None };
        assert_eq!(ring_members(&t).len(), 70);
    }

    #[test]
    fn prop_chunk_bounds_partition_any_m_n() {
        // chunk_bounds must tile [0, m) exactly for every (m, n), including
        // m < n (some chunks empty), m = 0 (all empty), and uneven splits
        // (sizes differing by at most one).
        forall(200, 0xC0B1, |g: &mut Gen| {
            let n = g.usize_in(1, 12);
            let m = match g.usize_in(0, 4) {
                0 => 0,                     // nothing to split
                1 => g.usize_in(1, n),      // fewer values than chunks
                _ => g.usize_in(1, 10_000), // generic (usually uneven)
            };
            let mut cursor = 0usize;
            let (mut min_len, mut max_len) = (usize::MAX, 0usize);
            for k in 0..n {
                let (s, e) = chunk_bounds(m, n, k);
                crate::prop_assert!(s == cursor, "m={m} n={n} k={k}: gap/overlap at {s} (expected {cursor})");
                crate::prop_assert!(e >= s, "m={m} n={n} k={k}: negative chunk");
                crate::prop_assert!(e <= m, "m={m} n={n} k={k}: end {e} past m");
                min_len = min_len.min(e - s);
                max_len = max_len.max(e - s);
                cursor = e;
            }
            crate::prop_assert!(cursor == m, "m={m} n={n}: chunks cover {cursor}, not m");
            crate::prop_assert!(
                max_len - min_len <= 1,
                "m={m} n={n}: unbalanced chunks (sizes {min_len}..{max_len})"
            );
            Ok(())
        });
    }

    #[test]
    fn chunk_bounds_edge_cases() {
        // m = 0: every chunk is empty.
        for k in 0..5 {
            assert_eq!(chunk_bounds(0, 5, k), (0, 0));
        }
        // m < n: exactly m unit chunks, the rest empty.
        let lens: Vec<usize> = (0..5).map(|k| {
            let (s, e) = chunk_bounds(3, 5, k);
            e - s
        }).collect();
        assert_eq!(lens.iter().sum::<usize>(), 3);
        assert!(lens.iter().all(|&l| l <= 1));
        // uneven: 10 over 4 -> 2/3/2/3 (sizes differ by at most one).
        let lens: Vec<usize> = (0..4).map(|k| {
            let (s, e) = chunk_bounds(10, 4, k);
            e - s
        }).collect();
        assert_eq!(lens.iter().sum::<usize>(), 10);
        assert!(lens.iter().all(|&l| l == 2 || l == 3));
    }
}
