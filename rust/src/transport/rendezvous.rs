//! Job bootstrap: rank-0-hosted rendezvous + full-mesh socket setup.
//!
//! N independent OS processes become one training job in two phases:
//!
//! 1. **Rendezvous** — rank 0 listens at the job address every process was
//!    launched with.  Each other rank binds its own *data* listener on an
//!    ephemeral port, dials the rendezvous, and registers
//!    `(rank, data_addr)`.  Once all `n` ranks are present, rank 0 answers
//!    every registration with the complete peer table (data addresses in
//!    rank order, rank 0's own included) and closes the rendezvous.
//! 2. **Mesh** — for every pair `{i, j}` the *higher* rank dials the lower
//!    rank's data listener and introduces itself with a one-shot handshake
//!    frame carrying its rank; the lower rank accepts `n − 1 − rank`
//!    such connections.  Deterministic direction ⇒ no glare, exactly one
//!    persistent connection per pair, `TCP_NODELAY` everywhere.
//!
//! All bootstrap messages are magic-tagged and length-prefixed; a process
//! joining the wrong job (or a stray port scanner) fails validation loudly
//! instead of wedging the fleet.  Dials retry until a deadline so workers
//! may start in any order.
//!
//! # Rendezvous v2: elastic membership
//!
//! [`establish_v2`] runs the same two phases but *keeps the listeners
//! alive* inside a [`Session`], turning the one-shot bootstrap into a
//! standing control plane:
//!
//! - rank 0's rendezvous listener stays bound (non-blocking) so evicted
//!   ranks can dial back in ([`Session::poll_join`]);
//! - every rank's data listener stays bound so a granted joiner can
//!   re-dial the mesh ([`Session::accept_rejoin`]).
//!
//! The join protocol is three magic-tagged messages: the joiner registers
//! with `CSER-JN2` (rank, n, fresh data address), the leader answers — at a
//! round boundary of its choosing — with a `CSER-GR2` grant carrying the
//! leader generation, epoch id, resume step, live mask, checkpoint blob,
//! and refreshed peer table, and the joiner then dials every live peer's
//! data listener with a `CSER-HS2` handshake.  Survivors never dial a
//! joiner: the join request advertises the joiner's *new* listener address,
//! which the leader folds into its authoritative table for any later
//! grants.
//!
//! Rank 0 hosts the rendezvous at bootstrap, but under `--failover` the
//! listener is a *role*, not an address owner: when the leader dies, its
//! deterministic successor re-binds the same advertised address
//! ([`Session::assume_rendezvous`]) so joiners and `cser top` keep dialing
//! the address they were launched with (DESIGN.md §10).  Grants are
//! stamped with the granting leader's generation so a joiner resuming
//! through a zombie ex-leader is fenced by the membership layer.

use super::peer::TransportError;
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

const RV_MAGIC: &[u8; 8] = b"CSER-RV1";
const TABLE_MAGIC: &[u8; 8] = b"CSER-TB1";
const HANDSHAKE_MAGIC: &[u8; 8] = b"CSER-HS1";
/// v2 mid-job control plane: join request, join grant, rejoin handshake.
const JOIN_MAGIC: &[u8; 8] = b"CSER-JN2";
const GRANT_MAGIC: &[u8; 8] = b"CSER-GR2";
const REJOIN_MAGIC: &[u8; 8] = b"CSER-HS2";

/// How long dials retry and accepts wait before declaring the fleet dead.
const BOOTSTRAP_TIMEOUT: Duration = Duration::from_secs(30);
const IO_TIMEOUT: Duration = Duration::from_secs(10);
/// Checkpoint blobs ride the grant message; cap them well below anything a
/// loopback-scale job could produce so a corrupt length fails loudly.
const MAX_GRANT_BLOB_BYTES: u64 = 1 << 31;

fn io_err(ctx: &str, e: std::io::Error) -> TransportError {
    TransportError::failed(format!("{ctx}: {e}"))
}

/// Reserve a loopback address for a new job: bind an ephemeral port, read
/// it back, release it.  Used by `cser launch`, tests, and benches to pick
/// a rendezvous address before spawning workers.  The reservation is
/// advisory — another process could grab the port in the window before
/// rank 0 re-binds it — but kernels cycle the ephemeral range rather than
/// reusing fresh releases, and rank 0's bind retries transient collisions
/// ([`establish`]).
pub fn free_loopback_addr() -> std::io::Result<String> {
    let l = TcpListener::bind((Ipv4Addr::LOCALHOST, 0))?;
    Ok(l.local_addr()?.to_string())
}

/// Bind with retry: the rendezvous port comes from an advisory
/// reservation (or, on failover, from the dead leader's just-released
/// socket), so a transient holder (e.g. the reserving socket's own
/// release racing this bind, or TIME_WAIT debris) should be waited out
/// rather than failing the whole job.
fn bind_retry(addr: SocketAddr, deadline: Instant) -> Result<TcpListener, TransportError> {
    let mut attempt = 0u32;
    loop {
        match TcpListener::bind(addr) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse && Instant::now() < deadline => {
                std::thread::sleep(backoff_delay(attempt));
                attempt += 1;
            }
            Err(e) => return Err(io_err(&format!("binding rendezvous {addr}"), e)),
        }
    }
}

/// Capped exponential backoff with deterministic jitter for control-plane
/// dials and binds: `25ms * 2^min(attempt, 5)`, capped at 800ms, plus a
/// jitter of up to a quarter of the base derived by hashing the attempt
/// number.  No RNG on purpose — retry timing must not perturb seeded chaos
/// schedules, and two ranks at different attempt counts decorrelate
/// through the hash anyway.
pub fn backoff_delay(attempt: u32) -> Duration {
    let base = (25u64 << attempt.min(5)).min(800);
    let hashed = (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 56;
    Duration::from_millis(base + hashed % (base / 4).max(1))
}

fn resolve(addr: &str) -> Result<SocketAddr, TransportError> {
    addr.to_socket_addrs()
        .map_err(|e| TransportError::failed(format!("cannot resolve '{addr}': {e}")))?
        .next()
        .ok_or_else(|| TransportError::failed(format!("'{addr}' resolved to no address")))
}

fn connect_retry(addr: SocketAddr, what: &str, deadline: Instant) -> Result<TcpStream, TransportError> {
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(TransportError::failed(format!(
                        "dialing {what} at {addr} timed out after {:?}: {e}",
                        BOOTSTRAP_TIMEOUT
                    )));
                }
                std::thread::sleep(backoff_delay(attempt));
                attempt += 1;
            }
        }
    }
}

fn accept_retry(l: &TcpListener, what: &str, deadline: Instant) -> Result<TcpStream, TransportError> {
    l.set_nonblocking(true).map_err(|e| io_err("listener setup", e))?;
    loop {
        match l.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false).map_err(|e| io_err("socket setup", e))?;
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(TransportError::failed(format!(
                        "waiting for {what} timed out after {:?}",
                        BOOTSTRAP_TIMEOUT
                    )));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(io_err("accept", e)),
        }
    }
}

fn read_exact(s: &mut TcpStream, buf: &mut [u8], ctx: &str) -> Result<(), TransportError> {
    s.read_exact(buf).map_err(|e| io_err(ctx, e))
}

fn read_u64(s: &mut TcpStream, ctx: &str) -> Result<u64, TransportError> {
    let mut b = [0u8; 8];
    read_exact(s, &mut b, ctx)?;
    Ok(u64::from_le_bytes(b))
}

fn write_addr(s: &mut TcpStream, addr: &SocketAddr) -> Result<(), TransportError> {
    let text = addr.to_string();
    let bytes = text.as_bytes();
    let len = bytes.len() as u16;
    s.write_all(&len.to_le_bytes()).map_err(|e| io_err("writing address", e))?;
    s.write_all(bytes).map_err(|e| io_err("writing address", e))
}

fn read_addr(s: &mut TcpStream) -> Result<SocketAddr, TransportError> {
    let mut len = [0u8; 2];
    read_exact(s, &mut len, "reading address length")?;
    let len = u16::from_le_bytes(len) as usize;
    if len == 0 || len > 256 {
        return Err(TransportError::failed(format!("implausible address length {len}")));
    }
    let mut buf = vec![0u8; len];
    read_exact(s, &mut buf, "reading address")?;
    let text = String::from_utf8(buf)
        .map_err(|_| TransportError::failed("address is not valid UTF-8"))?;
    resolve(&text)
}

/// Run the two bootstrap phases.  Returns the per-peer data streams indexed
/// by rank (`None` at the caller's own slot), each with `TCP_NODELAY` set.
pub fn establish(
    rendezvous: &str,
    rank: usize,
    n: usize,
) -> Result<Vec<Option<TcpStream>>, TransportError> {
    // Dropping the Session closes both listeners, restoring v1's one-shot
    // bootstrap semantics exactly.
    establish_v2(rendezvous, rank, n).map(|(links, _session)| links)
}

/// [`establish`], but the bootstrap listeners survive as a [`Session`] so
/// membership can change after the job starts (rendezvous v2).
pub fn establish_v2(
    rendezvous: &str,
    rank: usize,
    n: usize,
) -> Result<(Vec<Option<TcpStream>>, Session), TransportError> {
    if n == 0 || rank >= n {
        return Err(TransportError::failed(format!("rank {rank} out of range for {n} workers")));
    }
    let mut links: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    if n == 1 {
        // single-process job: no peers, no sockets, nothing to rejoin
        let session = Session { rank, n, rendezvous: None, data: None, table: Vec::new() };
        return Ok((links, session));
    }
    let rv_addr = resolve(rendezvous)?;
    let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;

    // Every rank owns a data listener on an ephemeral port.  Rank 0 binds
    // the rendezvous interface (it owns that address by construction);
    // other ranks may live on *different hosts*, so they bind the
    // unspecified address of the matching family and advertise the
    // interface their rendezvous connection actually used — routable by
    // definition, loopback for loopback jobs.
    let data = TcpListener::bind((data_bind_ip(rank, rv_addr), 0))
        .map_err(|e| io_err("binding data listener", e))?;
    let data_addr = data.local_addr().map_err(|e| io_err("reading data address", e))?;

    // ---- phase 1: the peer table ----
    let mut server = None;
    let table: Vec<SocketAddr> = if rank == 0 {
        let rv = bind_retry(rv_addr, deadline)?;
        let mut table: Vec<Option<SocketAddr>> = (0..n).map(|_| None).collect();
        table[0] = Some(data_addr);
        let mut registrants: Vec<(usize, TcpStream)> = Vec::with_capacity(n - 1);
        while registrants.len() < n - 1 {
            let mut s = accept_retry(&rv, "worker registrations", deadline)?;
            s.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
            let mut magic = [0u8; 8];
            read_exact(&mut s, &mut magic, "reading rendezvous magic")?;
            if &magic != RV_MAGIC {
                return Err(TransportError::failed("rendezvous contacted by a non-worker"));
            }
            let mut hdr = [0u8; 8];
            read_exact(&mut s, &mut hdr, "reading registration")?;
            let peer = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
            let peer_n = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as usize;
            if peer_n != n {
                return Err(TransportError::failed(format!(
                    "worker {peer} was launched with --workers {peer_n}, this job has {n}"
                )));
            }
            if peer == 0 || peer >= n || table[peer].is_some() {
                return Err(TransportError::failed(format!("invalid or duplicate rank {peer}")));
            }
            table[peer] = Some(read_addr(&mut s)?);
            registrants.push((peer, s));
        }
        let table: Vec<SocketAddr> = table.into_iter().map(|a| a.unwrap()).collect();
        for (_, mut s) in registrants {
            s.write_all(TABLE_MAGIC).map_err(|e| io_err("writing peer table", e))?;
            s.write_all(&(n as u32).to_le_bytes()).map_err(|e| io_err("writing peer table", e))?;
            for a in &table {
                write_addr(&mut s, a)?;
            }
        }
        server = Some(rv);
        table
    } else {
        let mut s = connect_retry(rv_addr, "rendezvous", deadline)?;
        s.set_read_timeout(Some(BOOTSTRAP_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
        // Advertise the interface this connection used, with the data
        // listener's port (the listener itself is bound to the unspecified
        // address, which no peer could dial).
        let advertised = SocketAddr::new(
            s.local_addr().map_err(|e| io_err("reading local address", e))?.ip(),
            data_addr.port(),
        );
        s.write_all(RV_MAGIC).map_err(|e| io_err("registering", e))?;
        let mut hdr = [0u8; 8];
        hdr[..4].copy_from_slice(&(rank as u32).to_le_bytes());
        hdr[4..].copy_from_slice(&(n as u32).to_le_bytes());
        s.write_all(&hdr).map_err(|e| io_err("registering", e))?;
        write_addr(&mut s, &advertised)?;
        let mut magic = [0u8; 8];
        read_exact(&mut s, &mut magic, "reading peer table magic")?;
        if &magic != TABLE_MAGIC {
            return Err(TransportError::failed("rendezvous answered with a non-table"));
        }
        let mut cnt = [0u8; 4];
        read_exact(&mut s, &mut cnt, "reading peer table size")?;
        if u32::from_le_bytes(cnt) as usize != n {
            return Err(TransportError::failed("peer table size mismatch"));
        }
        let mut table = Vec::with_capacity(n);
        for _ in 0..n {
            table.push(read_addr(&mut s)?);
        }
        table
    };

    // ---- phase 2: the mesh ----
    // Higher ranks dial lower ranks; the handshake names the dialer.
    for (j, addr) in table.iter().enumerate().take(rank) {
        let mut s = connect_retry(*addr, &format!("peer {j}"), deadline)?;
        s.write_all(HANDSHAKE_MAGIC).map_err(|e| io_err("handshaking", e))?;
        s.write_all(&(rank as u32).to_le_bytes()).map_err(|e| io_err("handshaking", e))?;
        s.set_nodelay(true).map_err(|e| io_err("socket setup", e))?;
        links[j] = Some(s);
    }
    for _ in rank + 1..n {
        let mut s = accept_retry(&data, "peer connections", deadline)?;
        s.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
        let mut magic = [0u8; 8];
        read_exact(&mut s, &mut magic, "reading handshake magic")?;
        if &magic != HANDSHAKE_MAGIC {
            return Err(TransportError::failed("data listener contacted by a non-worker"));
        }
        let mut rb = [0u8; 4];
        read_exact(&mut s, &mut rb, "reading handshake rank")?;
        let peer = u32::from_le_bytes(rb) as usize;
        if peer <= rank || peer >= n || links[peer].is_some() {
            return Err(TransportError::failed(format!("invalid or duplicate handshake rank {peer}")));
        }
        s.set_read_timeout(None).map_err(|e| io_err("socket setup", e))?;
        s.set_nodelay(true).map_err(|e| io_err("socket setup", e))?;
        links[peer] = Some(s);
    }
    let session = Session { rank, n, rendezvous: server, data: Some(data), table };
    Ok((links, session))
}

/// Which interface a rank's data listener binds (see [`establish_v2`]).
fn data_bind_ip(rank: usize, rv_addr: SocketAddr) -> IpAddr {
    if rank == 0 {
        if rv_addr.ip().is_unspecified() {
            IpAddr::V4(Ipv4Addr::LOCALHOST)
        } else {
            rv_addr.ip()
        }
    } else {
        match rv_addr {
            SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::UNSPECIFIED),
            SocketAddr::V6(_) => IpAddr::V6(std::net::Ipv6Addr::UNSPECIFIED),
        }
    }
}

/// The standing control plane left behind by [`establish_v2`]: the
/// bootstrap listeners, kept alive so membership can change mid-job.
///
/// Rank 0 polls its rendezvous listener for join requests between rounds;
/// every rank's data listener stands ready to accept a granted joiner's
/// mesh re-dial.  Dropping the session closes both.
pub struct Session {
    rank: usize,
    n: usize,
    /// The leader only: the rendezvous listener, non-blocking.  Rank 0
    /// holds it from bootstrap; a successor acquires it through
    /// [`Session::assume_rendezvous`] after a handover.
    rendezvous: Option<TcpListener>,
    /// This rank's data listener (absent for single-rank jobs).
    data: Option<TcpListener>,
    /// Authoritative on rank 0 (refreshed by join requests); a bootstrap
    /// snapshot elsewhere.
    table: Vec<SocketAddr>,
}

/// A joiner parked at rank 0's rendezvous, waiting for a round boundary.
/// Produced by [`Session::poll_join`], consumed by [`Session::grant_join`].
pub struct JoinRequest {
    pub rank: usize,
    stream: TcpStream,
}

/// What a rejoining rank receives in exchange for its [`JoinRequest`]:
/// where the job is (epoch, step, live mask) and the checkpoint bytes to
/// resume from bit-exactly.
pub struct JoinGrant {
    /// Leader generation of the granting leader (0 until the first
    /// handover).  The joiner seeds its membership layer with this so a
    /// grant issued by a zombie ex-leader is fenced at the first epoch
    /// frame instead of silently forking the view.
    pub generation: u64,
    pub epoch: u64,
    pub step: u64,
    /// Bit `r` set ⇔ rank `r` is live in the granted epoch (joiner
    /// included).  Caps elastic jobs at 64 ranks.
    pub live_mask: u64,
    /// Every rank admitted at this boundary (this rank's bit included).
    /// Co-joiners cannot be dialed like survivors — nobody is accepting on
    /// their behalf yet — so [`rejoin`] links joiner pairs directly:
    /// the higher rank dials, the lower rank accepts.
    pub joiners: u64,
    pub blob: Vec<u8>,
}

impl Session {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The leader: non-blocking check for a parked joiner.  `Ok(None)` when
    /// no one is dialing (or this rank does not host the rendezvous).  The
    /// request's advertised data address replaces the joiner's stale table
    /// entry immediately, so later grants hand out current addresses.
    pub fn poll_join(&mut self) -> Result<Option<JoinRequest>, TransportError> {
        let Some(server) = &self.rendezvous else {
            return Ok(None);
        };
        let mut s = match server.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(None),
            Err(e) => return Err(io_err("polling for joiners", e)),
        };
        s.set_nonblocking(false).map_err(|e| io_err("socket setup", e))?;
        s.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
        let mut magic = [0u8; 8];
        read_exact(&mut s, &mut magic, "reading join magic")?;
        if &magic != JOIN_MAGIC {
            return Err(TransportError::failed("rendezvous contacted mid-job by a non-joiner"));
        }
        let mut hdr = [0u8; 8];
        read_exact(&mut s, &mut hdr, "reading join request")?;
        let peer = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
        let peer_n = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as usize;
        if peer_n != self.n {
            return Err(TransportError::failed(format!(
                "joiner {peer} believes the job has {peer_n} workers, it has {}",
                self.n
            )));
        }
        if peer == self.rank || peer >= self.n {
            return Err(TransportError::failed(format!("invalid join request from rank {peer}")));
        }
        let addr = read_addr(&mut s)?;
        self.table[peer] = addr;
        Ok(Some(JoinRequest { rank: peer, stream: s }))
    }

    /// [`Session::poll_join`], but willing to wait up to `grace` for a
    /// joiner to park.  Rank 0 uses this at boundaries where the fleet is
    /// short-handed, so an evicted rank restarting promptly is readmitted
    /// at the very next boundary instead of racing a one-shot poll.
    pub fn poll_join_deadline(
        &mut self,
        grace: Duration,
    ) -> Result<Option<JoinRequest>, TransportError> {
        let deadline = Instant::now() + grace;
        loop {
            if let Some(req) = self.poll_join()? {
                return Ok(Some(req));
            }
            if Instant::now() >= deadline {
                return Ok(None);
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// The leader, at a round boundary: admit a parked joiner by sending
    /// the grant (leader generation, epoch, resume step, live mask, joiner
    /// mask, checkpoint blob, peer table).  The joiner dials the live mesh
    /// on receipt; every survivor must pair this with an
    /// [`Session::accept_rejoin`] per joiner.  When a batch of joiners is
    /// granted under one epoch frame, every grant in the batch must carry
    /// the identical `joiners` mask — it is what tells each joiner which
    /// live ranks to link peer-to-peer instead of dialing.
    #[allow(clippy::too_many_arguments)]
    pub fn grant_join(
        &mut self,
        req: JoinRequest,
        generation: u64,
        epoch: u64,
        step: u64,
        live_mask: u64,
        joiners: u64,
        blob: &[u8],
    ) -> Result<(), TransportError> {
        let mut s = req.stream;
        s.write_all(GRANT_MAGIC).map_err(|e| io_err("writing join grant", e))?;
        for v in [generation, epoch, step, live_mask, joiners, blob.len() as u64] {
            s.write_all(&v.to_le_bytes()).map_err(|e| io_err("writing join grant", e))?;
        }
        s.write_all(blob).map_err(|e| io_err("writing join grant checkpoint", e))?;
        s.write_all(&(self.n as u32).to_le_bytes()).map_err(|e| io_err("writing join grant", e))?;
        for a in &self.table {
            write_addr(&mut s, a)?;
        }
        Ok(())
    }

    /// Any survivor: block (with the bootstrap deadline) until the granted
    /// joiner re-dials this rank's data listener; returns the joiner's
    /// rank and the fresh stream, ready for `TcpTransport::install_link`.
    pub fn accept_rejoin(&mut self) -> Result<(usize, TcpStream), TransportError> {
        let data = self
            .data
            .as_ref()
            .ok_or_else(|| TransportError::failed("single-rank session has no data listener"))?;
        let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;
        let mut s = accept_retry(data, "a rejoining peer", deadline)?;
        s.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
        let mut magic = [0u8; 8];
        read_exact(&mut s, &mut magic, "reading rejoin magic")?;
        if &magic != REJOIN_MAGIC {
            return Err(TransportError::failed("data listener contacted mid-job by a non-joiner"));
        }
        let mut rb = [0u8; 4];
        read_exact(&mut s, &mut rb, "reading rejoin rank")?;
        let peer = u32::from_le_bytes(rb) as usize;
        if peer >= self.n || peer == self.rank {
            return Err(TransportError::failed(format!("invalid rejoin handshake rank {peer}")));
        }
        s.set_read_timeout(None).map_err(|e| io_err("socket setup", e))?;
        s.set_nodelay(true).map_err(|e| io_err("socket setup", e))?;
        Ok((peer, s))
    }

    /// Failover: the deterministic successor takes over the rendezvous
    /// *role* by re-binding the job's advertised address, freed by the
    /// dead leader's process exit.  Retries `AddrInUse` with backoff to
    /// ride out the kernel releasing the old leader's socket.  After this
    /// returns, [`Session::poll_join`] answers on this rank and joiners /
    /// `cser top` keep dialing the address they were launched with.
    pub fn assume_rendezvous(&mut self, addr: &str) -> Result<(), TransportError> {
        let rv_addr = resolve(addr)?;
        let l = bind_retry(rv_addr, Instant::now() + BOOTSTRAP_TIMEOUT)?;
        l.set_nonblocking(true).map_err(|e| io_err("listener setup", e))?;
        self.rendezvous = Some(l);
        Ok(())
    }
}

/// An evicted (or restarted) rank dials back into a running job: register
/// at the rendezvous with `CSER-JN2`, wait for the leader's grant — which
/// only arrives at a round boundary, so this blocks up to the bootstrap
/// deadline — then re-dial every live peer.  Returns the per-peer streams
/// (indexed by rank, `None` for self and non-live ranks), the grant to
/// resume from, and this rank's fresh [`Session`].  Rank 0 itself may
/// rejoin after a failover handover: the rendezvous address it dials is
/// then hosted by its successor, and it comes back as an ordinary worker
/// (leadership returns to it at the next boundary by the lowest-live-rank
/// rule).
pub fn rejoin(
    rendezvous: &str,
    rank: usize,
    n: usize,
) -> Result<(Vec<Option<TcpStream>>, JoinGrant, Session), TransportError> {
    if n == 0 || rank >= n {
        return Err(TransportError::failed(format!("rank {rank} cannot rejoin a {n}-worker job")));
    }
    let rv_addr = resolve(rendezvous)?;
    let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;
    let data = TcpListener::bind((data_bind_ip(rank, rv_addr), 0))
        .map_err(|e| io_err("binding data listener", e))?;
    let data_addr = data.local_addr().map_err(|e| io_err("reading data address", e))?;

    let mut s = connect_retry(rv_addr, "rendezvous (rejoin)", deadline)?;
    s.set_read_timeout(Some(BOOTSTRAP_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
    let advertised = SocketAddr::new(
        s.local_addr().map_err(|e| io_err("reading local address", e))?.ip(),
        data_addr.port(),
    );
    s.write_all(JOIN_MAGIC).map_err(|e| io_err("requesting join", e))?;
    let mut hdr = [0u8; 8];
    hdr[..4].copy_from_slice(&(rank as u32).to_le_bytes());
    hdr[4..].copy_from_slice(&(n as u32).to_le_bytes());
    s.write_all(&hdr).map_err(|e| io_err("requesting join", e))?;
    write_addr(&mut s, &advertised)?;

    let mut magic = [0u8; 8];
    read_exact(&mut s, &mut magic, "reading join grant magic")?;
    if &magic != GRANT_MAGIC {
        return Err(TransportError::failed("rendezvous answered the join with a non-grant"));
    }
    let generation = read_u64(&mut s, "reading grant generation")?;
    let epoch = read_u64(&mut s, "reading grant epoch")?;
    let step = read_u64(&mut s, "reading grant step")?;
    let live_mask = read_u64(&mut s, "reading grant live mask")?;
    let joiners = read_u64(&mut s, "reading grant joiner mask")?;
    let blob_len = read_u64(&mut s, "reading grant checkpoint length")?;
    if blob_len > MAX_GRANT_BLOB_BYTES {
        return Err(TransportError::failed(format!(
            "implausible grant checkpoint length {blob_len}"
        )));
    }
    let mut blob = vec![0u8; blob_len as usize];
    read_exact(&mut s, &mut blob, "reading grant checkpoint")?;
    let mut cnt = [0u8; 4];
    read_exact(&mut s, &mut cnt, "reading grant peer table size")?;
    if u32::from_le_bytes(cnt) as usize != n {
        return Err(TransportError::failed("grant peer table size mismatch"));
    }
    let mut table = Vec::with_capacity(n);
    for _ in 0..n {
        table.push(read_addr(&mut s)?);
    }

    // Re-dial the live mesh.  Survivors only ever accept, so the joiner
    // dials every one of them regardless of rank order.  Co-joiners granted
    // at the same boundary have no survivor accepting for them, so joiner
    // pairs link directly under the v1 bootstrap convention: the higher
    // rank dials the lower rank's data listener, the lower rank accepts.
    let mut links: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    for (j, addr) in table.iter().enumerate() {
        if j == rank || (live_mask >> j) & 1 == 0 {
            continue;
        }
        if (joiners >> j) & 1 == 1 && j > rank {
            continue; // higher co-joiner: it dials us below
        }
        let mut p = connect_retry(*addr, &format!("peer {j} (rejoin)"), deadline)?;
        p.write_all(REJOIN_MAGIC).map_err(|e| io_err("rejoin handshaking", e))?;
        p.write_all(&(rank as u32).to_le_bytes()).map_err(|e| io_err("rejoin handshaking", e))?;
        p.set_nodelay(true).map_err(|e| io_err("socket setup", e))?;
        links[j] = Some(p);
    }
    // Accept each higher co-joiner's dial (arrival order — the handshake
    // names the rank).
    let mut expect = if rank + 1 >= 64 {
        0 // shift guard: rank 63 has no higher co-joiners in a 64-bit mask
    } else {
        joiners & live_mask & !((1u64 << (rank + 1)) - 1)
    };
    while expect != 0 {
        let mut p = accept_retry(&data, "a co-joiner", deadline)?;
        p.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
        let mut magic = [0u8; 8];
        read_exact(&mut p, &mut magic, "reading co-joiner magic")?;
        if &magic != REJOIN_MAGIC {
            return Err(TransportError::failed("data listener contacted by a non-joiner"));
        }
        let mut rb = [0u8; 4];
        read_exact(&mut p, &mut rb, "reading co-joiner rank")?;
        let peer = u32::from_le_bytes(rb) as usize;
        if peer >= n || peer <= rank || (expect >> peer) & 1 == 0 {
            return Err(TransportError::failed(format!(
                "unexpected co-joiner handshake from rank {peer}"
            )));
        }
        p.set_read_timeout(None).map_err(|e| io_err("socket setup", e))?;
        p.set_nodelay(true).map_err(|e| io_err("socket setup", e))?;
        expect &= !(1u64 << peer);
        links[peer] = Some(p);
    }
    let grant = JoinGrant { generation, epoch, step, live_mask, joiners, blob };
    let session = Session { rank, n, rendezvous: None, data: Some(data), table };
    Ok((links, grant, session))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_ranks_form_a_full_mesh_over_loopback() {
        let addr = free_loopback_addr().unwrap();
        let n = 4;
        let meshes: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let addr = addr.clone();
                    s.spawn(move || establish(&addr, r, n).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (r, links) in meshes.iter().enumerate() {
            assert!(links[r].is_none(), "rank {r} must not link to itself");
            for (j, l) in links.iter().enumerate() {
                assert_eq!(l.is_some(), j != r, "rank {r} link to {j}");
            }
        }
    }

    #[test]
    fn single_rank_needs_no_sockets() {
        let links = establish("127.0.0.1:1", 0, 1).unwrap();
        assert_eq!(links.len(), 1);
        assert!(links[0].is_none());
    }

    #[test]
    fn bad_rank_is_rejected() {
        assert!(establish("127.0.0.1:1", 3, 2).is_err());
    }

    #[test]
    fn evicted_rank_rejoins_through_the_session() {
        let addr = free_loopback_addr().unwrap();
        let n = 3;
        std::thread::scope(|scope| {
            let a0 = addr.clone();
            let r0 = scope.spawn(move || {
                let (links, mut sess) = establish_v2(&a0, 0, n).unwrap();
                drop(links); // this test exercises the control plane only
                let req = loop {
                    match sess.poll_join().unwrap() {
                        Some(r) => break r,
                        None => std::thread::sleep(Duration::from_millis(10)),
                    }
                };
                assert_eq!(req.rank, 2);
                sess.grant_join(req, 2, 7, 42, 0b111, 0b100, b"ckpt").unwrap();
                let (peer, mut s) = sess.accept_rejoin().unwrap();
                assert_eq!(peer, 2);
                let mut b = [0u8; 4];
                s.read_exact(&mut b).unwrap();
                assert_eq!(&b, b"ping");
            });
            let a1 = addr.clone();
            let r1 = scope.spawn(move || {
                let (links, mut sess) = establish_v2(&a1, 1, n).unwrap();
                drop(links);
                let (peer, _s) = sess.accept_rejoin().unwrap();
                assert_eq!(peer, 2);
            });
            let a2 = addr.clone();
            let r2 = scope.spawn(move || {
                let (links, sess) = establish_v2(&a2, 2, n).unwrap();
                drop(links);
                drop(sess); // rank 2 "dies": its listeners close
                let (mut links, grant, _sess) = rejoin(&a2, 2, n).unwrap();
                assert_eq!(grant.generation, 2);
                assert_eq!(grant.epoch, 7);
                assert_eq!(grant.step, 42);
                assert_eq!(grant.live_mask, 0b111);
                assert_eq!(grant.joiners, 0b100);
                assert_eq!(grant.blob, b"ckpt");
                assert!(links[0].is_some() && links[1].is_some() && links[2].is_none());
                links[0].as_mut().unwrap().write_all(b"ping").unwrap();
            });
            r0.join().unwrap();
            r1.join().unwrap();
            r2.join().unwrap();
        });
    }

    #[test]
    fn backoff_is_deterministic_capped_and_exponential() {
        for a in 0..12u32 {
            let d = backoff_delay(a).as_millis() as u64;
            let base = (25u64 << a.min(5)).min(800);
            assert!(d >= base, "attempt {a}: {d}ms under base {base}ms");
            assert!(d < base + base / 4 + 1, "attempt {a}: {d}ms jitters past base/4");
            assert_eq!(backoff_delay(a), backoff_delay(a), "retry timing must be deterministic");
        }
        assert_eq!((backoff_delay(63).as_millis() as u64) / 100, 8, "capped at 800ms + jitter");
    }

    #[test]
    fn a_successor_assumes_the_rendezvous_and_grants_joins() {
        let addr = free_loopback_addr().unwrap();
        let n = 3;
        let (dead_tx, dead_rx) = std::sync::mpsc::channel::<()>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
        std::thread::scope(|scope| {
            let a0 = addr.clone();
            let r0 = scope.spawn(move || {
                let (links, sess) = establish_v2(&a0, 0, n).unwrap();
                drop(links);
                drop(sess); // the leader dies: its rendezvous listener closes
                dead_tx.send(()).unwrap();
            });
            let a1 = addr.clone();
            let r1 = scope.spawn(move || {
                let (links, mut sess) = establish_v2(&a1, 1, n).unwrap();
                drop(links);
                dead_rx.recv().unwrap();
                // The successor re-binds the job's advertised address and
                // answers joins from there, stamped with its generation.
                sess.assume_rendezvous(&a1).unwrap();
                ready_tx.send(()).unwrap();
                let req = loop {
                    match sess.poll_join().unwrap() {
                        Some(r) => break r,
                        None => std::thread::sleep(Duration::from_millis(10)),
                    }
                };
                assert_eq!(req.rank, 2);
                sess.grant_join(req, 1, 4, 17, 0b110, 0b100, b"cs").unwrap();
                let (peer, _s) = sess.accept_rejoin().unwrap();
                assert_eq!(peer, 2);
            });
            let a2 = addr.clone();
            let r2 = scope.spawn(move || {
                let (links, sess) = establish_v2(&a2, 2, n).unwrap();
                drop(links);
                drop(sess);
                ready_rx.recv().unwrap();
                let (links, grant, _sess) = rejoin(&a2, 2, n).unwrap();
                assert_eq!(grant.generation, 1, "grants carry the successor's generation");
                assert_eq!(grant.live_mask, 0b110, "the dead leader is not in the view");
                assert!(links[0].is_none() && links[1].is_some());
            });
            r0.join().unwrap();
            r1.join().unwrap();
            r2.join().unwrap();
        });
    }

    #[test]
    fn two_joiners_admitted_under_one_boundary_link_each_other() {
        // Ranks 2 and 3 both park, rank 0 grants the batch in rank order
        // under one joiner mask, and the co-joiner pair links directly
        // (3 dials 2) — the bytes prove the pair shares one socket.
        let addr = free_loopback_addr().unwrap();
        let n = 4;
        std::thread::scope(|scope| {
            let a0 = addr.clone();
            let r0 = scope.spawn(move || {
                let (links, mut sess) = establish_v2(&a0, 0, n).unwrap();
                drop(links);
                let mut reqs = Vec::new();
                while reqs.len() < 2 {
                    match sess.poll_join().unwrap() {
                        Some(r) => reqs.push(r),
                        None => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
                reqs.sort_by_key(|r| r.rank);
                assert_eq!(reqs.iter().map(|r| r.rank).collect::<Vec<_>>(), vec![2, 3]);
                for req in reqs {
                    sess.grant_join(req, 0, 9, 64, 0b1111, 0b1100, b"ck2").unwrap();
                    let (peer, _s) = sess.accept_rejoin().unwrap();
                    assert!(peer == 2 || peer == 3);
                }
            });
            let a1 = addr.clone();
            let r1 = scope.spawn(move || {
                let (links, mut sess) = establish_v2(&a1, 1, n).unwrap();
                drop(links);
                let mut seen = [false; 4];
                for _ in 0..2 {
                    let (peer, _s) = sess.accept_rejoin().unwrap();
                    seen[peer] = true;
                }
                assert!(seen[2] && seen[3], "both joiners must re-dial every survivor");
            });
            let a2 = addr.clone();
            let r2 = scope.spawn(move || {
                let (links, sess) = establish_v2(&a2, 2, n).unwrap();
                drop(links);
                drop(sess);
                let (mut links, grant, _sess) = rejoin(&a2, 2, n).unwrap();
                assert_eq!((grant.live_mask, grant.joiners), (0b1111, 0b1100));
                // Survivors dialed, higher co-joiner accepted.
                assert!(links[0].is_some() && links[1].is_some() && links[3].is_some());
                let mut b = [0u8; 4];
                links[3].as_mut().unwrap().read_exact(&mut b).unwrap();
                assert_eq!(&b, b"pear");
            });
            let a3 = addr.clone();
            let r3 = scope.spawn(move || {
                let (links, sess) = establish_v2(&a3, 3, n).unwrap();
                drop(links);
                drop(sess);
                let (mut links, grant, _sess) = rejoin(&a3, 3, n).unwrap();
                assert_eq!((grant.live_mask, grant.joiners), (0b1111, 0b1100));
                assert!(links[0].is_some() && links[1].is_some() && links[2].is_some());
                links[2].as_mut().unwrap().write_all(b"pear").unwrap();
            });
            r0.join().unwrap();
            r1.join().unwrap();
            r2.join().unwrap();
            r3.join().unwrap();
        });
    }
}
