//! Job bootstrap: rank-0-hosted rendezvous + full-mesh socket setup.
//!
//! N independent OS processes become one training job in two phases:
//!
//! 1. **Rendezvous** — rank 0 listens at the job address every process was
//!    launched with.  Each other rank binds its own *data* listener on an
//!    ephemeral port, dials the rendezvous, and registers
//!    `(rank, data_addr)`.  Once all `n` ranks are present, rank 0 answers
//!    every registration with the complete peer table (data addresses in
//!    rank order, rank 0's own included) and closes the rendezvous.
//! 2. **Mesh** — for every pair `{i, j}` the *higher* rank dials the lower
//!    rank's data listener and introduces itself with a one-shot handshake
//!    frame carrying its rank; the lower rank accepts `n − 1 − rank`
//!    such connections.  Deterministic direction ⇒ no glare, exactly one
//!    persistent connection per pair, `TCP_NODELAY` everywhere.
//!
//! All bootstrap messages are magic-tagged and length-prefixed; a process
//! joining the wrong job (or a stray port scanner) fails validation loudly
//! instead of wedging the fleet.  Dials retry until a deadline so workers
//! may start in any order.

use super::peer::TransportError;
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

const RV_MAGIC: &[u8; 8] = b"CSER-RV1";
const TABLE_MAGIC: &[u8; 8] = b"CSER-TB1";
const HANDSHAKE_MAGIC: &[u8; 8] = b"CSER-HS1";

/// How long dials retry and accepts wait before declaring the fleet dead.
const BOOTSTRAP_TIMEOUT: Duration = Duration::from_secs(30);
const IO_TIMEOUT: Duration = Duration::from_secs(10);

fn io_err(ctx: &str, e: std::io::Error) -> TransportError {
    TransportError(format!("{ctx}: {e}"))
}

/// Reserve a loopback address for a new job: bind an ephemeral port, read
/// it back, release it.  Used by `cser launch`, tests, and benches to pick
/// a rendezvous address before spawning workers.  The reservation is
/// advisory — another process could grab the port in the window before
/// rank 0 re-binds it — but kernels cycle the ephemeral range rather than
/// reusing fresh releases, and rank 0's bind retries transient collisions
/// ([`establish`]).
pub fn free_loopback_addr() -> std::io::Result<String> {
    let l = TcpListener::bind((Ipv4Addr::LOCALHOST, 0))?;
    Ok(l.local_addr()?.to_string())
}

/// Bind with retry: the rendezvous port comes from an advisory
/// reservation, so a transient holder (e.g. the reserving socket's own
/// release racing this bind, or TIME_WAIT debris) should be waited out
/// rather than failing the whole job.
fn bind_retry(addr: SocketAddr, deadline: Instant) -> Result<TcpListener, TransportError> {
    loop {
        match TcpListener::bind(addr) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse && Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => return Err(io_err(&format!("rank 0 binding rendezvous {addr}"), e)),
        }
    }
}

fn resolve(addr: &str) -> Result<SocketAddr, TransportError> {
    addr.to_socket_addrs()
        .map_err(|e| TransportError(format!("cannot resolve '{addr}': {e}")))?
        .next()
        .ok_or_else(|| TransportError(format!("'{addr}' resolved to no address")))
}

fn connect_retry(addr: SocketAddr, what: &str, deadline: Instant) -> Result<TcpStream, TransportError> {
    loop {
        match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(TransportError(format!(
                        "dialing {what} at {addr} timed out after {:?}: {e}",
                        BOOTSTRAP_TIMEOUT
                    )));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn accept_retry(l: &TcpListener, what: &str, deadline: Instant) -> Result<TcpStream, TransportError> {
    l.set_nonblocking(true).map_err(|e| io_err("listener setup", e))?;
    loop {
        match l.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false).map_err(|e| io_err("socket setup", e))?;
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(TransportError(format!(
                        "waiting for {what} timed out after {:?}",
                        BOOTSTRAP_TIMEOUT
                    )));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(io_err("accept", e)),
        }
    }
}

fn read_exact(s: &mut TcpStream, buf: &mut [u8], ctx: &str) -> Result<(), TransportError> {
    s.read_exact(buf).map_err(|e| io_err(ctx, e))
}

fn write_addr(s: &mut TcpStream, addr: &SocketAddr) -> Result<(), TransportError> {
    let text = addr.to_string();
    let bytes = text.as_bytes();
    let len = bytes.len() as u16;
    s.write_all(&len.to_le_bytes()).map_err(|e| io_err("writing address", e))?;
    s.write_all(bytes).map_err(|e| io_err("writing address", e))
}

fn read_addr(s: &mut TcpStream) -> Result<SocketAddr, TransportError> {
    let mut len = [0u8; 2];
    read_exact(s, &mut len, "reading address length")?;
    let len = u16::from_le_bytes(len) as usize;
    if len == 0 || len > 256 {
        return Err(TransportError(format!("implausible address length {len}")));
    }
    let mut buf = vec![0u8; len];
    read_exact(s, &mut buf, "reading address")?;
    let text = String::from_utf8(buf)
        .map_err(|_| TransportError("address is not valid UTF-8".into()))?;
    resolve(&text)
}

/// Run the two bootstrap phases.  Returns the per-peer data streams indexed
/// by rank (`None` at the caller's own slot), each with `TCP_NODELAY` set.
pub fn establish(
    rendezvous: &str,
    rank: usize,
    n: usize,
) -> Result<Vec<Option<TcpStream>>, TransportError> {
    if n == 0 || rank >= n {
        return Err(TransportError(format!("rank {rank} out of range for {n} workers")));
    }
    let mut links: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    if n == 1 {
        return Ok(links); // single-process job: no peers, no sockets
    }
    let rv_addr = resolve(rendezvous)?;
    let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;

    // Every rank owns a data listener on an ephemeral port.  Rank 0 binds
    // the rendezvous interface (it owns that address by construction);
    // other ranks may live on *different hosts*, so they bind the
    // unspecified address of the matching family and advertise the
    // interface their rendezvous connection actually used — routable by
    // definition, loopback for loopback jobs.
    let bind_ip: IpAddr = if rank == 0 {
        if rv_addr.ip().is_unspecified() {
            IpAddr::V4(Ipv4Addr::LOCALHOST)
        } else {
            rv_addr.ip()
        }
    } else {
        match rv_addr {
            SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::UNSPECIFIED),
            SocketAddr::V6(_) => IpAddr::V6(std::net::Ipv6Addr::UNSPECIFIED),
        }
    };
    let data = TcpListener::bind((bind_ip, 0)).map_err(|e| io_err("binding data listener", e))?;
    let data_addr = data.local_addr().map_err(|e| io_err("reading data address", e))?;

    // ---- phase 1: the peer table ----
    let table: Vec<SocketAddr> = if rank == 0 {
        let server = bind_retry(rv_addr, deadline)?;
        let mut table: Vec<Option<SocketAddr>> = (0..n).map(|_| None).collect();
        table[0] = Some(data_addr);
        let mut registrants: Vec<(usize, TcpStream)> = Vec::with_capacity(n - 1);
        while registrants.len() < n - 1 {
            let mut s = accept_retry(&server, "worker registrations", deadline)?;
            s.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
            let mut magic = [0u8; 8];
            read_exact(&mut s, &mut magic, "reading rendezvous magic")?;
            if &magic != RV_MAGIC {
                return Err(TransportError("rendezvous contacted by a non-worker".into()));
            }
            let mut hdr = [0u8; 8];
            read_exact(&mut s, &mut hdr, "reading registration")?;
            let peer = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
            let peer_n = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as usize;
            if peer_n != n {
                return Err(TransportError(format!(
                    "worker {peer} was launched with --workers {peer_n}, this job has {n}"
                )));
            }
            if peer == 0 || peer >= n || table[peer].is_some() {
                return Err(TransportError(format!("invalid or duplicate rank {peer}")));
            }
            table[peer] = Some(read_addr(&mut s)?);
            registrants.push((peer, s));
        }
        let table: Vec<SocketAddr> = table.into_iter().map(|a| a.unwrap()).collect();
        for (_, mut s) in registrants {
            s.write_all(TABLE_MAGIC).map_err(|e| io_err("writing peer table", e))?;
            s.write_all(&(n as u32).to_le_bytes()).map_err(|e| io_err("writing peer table", e))?;
            for a in &table {
                write_addr(&mut s, a)?;
            }
        }
        table
    } else {
        let mut s = connect_retry(rv_addr, "rendezvous", deadline)?;
        s.set_read_timeout(Some(BOOTSTRAP_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
        // Advertise the interface this connection used, with the data
        // listener's port (the listener itself is bound to the unspecified
        // address, which no peer could dial).
        let advertised = SocketAddr::new(
            s.local_addr().map_err(|e| io_err("reading local address", e))?.ip(),
            data_addr.port(),
        );
        s.write_all(RV_MAGIC).map_err(|e| io_err("registering", e))?;
        let mut hdr = [0u8; 8];
        hdr[..4].copy_from_slice(&(rank as u32).to_le_bytes());
        hdr[4..].copy_from_slice(&(n as u32).to_le_bytes());
        s.write_all(&hdr).map_err(|e| io_err("registering", e))?;
        write_addr(&mut s, &advertised)?;
        let mut magic = [0u8; 8];
        read_exact(&mut s, &mut magic, "reading peer table magic")?;
        if &magic != TABLE_MAGIC {
            return Err(TransportError("rendezvous answered with a non-table".into()));
        }
        let mut cnt = [0u8; 4];
        read_exact(&mut s, &mut cnt, "reading peer table size")?;
        if u32::from_le_bytes(cnt) as usize != n {
            return Err(TransportError("peer table size mismatch".into()));
        }
        let mut table = Vec::with_capacity(n);
        for _ in 0..n {
            table.push(read_addr(&mut s)?);
        }
        table
    };

    // ---- phase 2: the mesh ----
    // Higher ranks dial lower ranks; the handshake names the dialer.
    for (j, addr) in table.iter().enumerate().take(rank) {
        let mut s = connect_retry(*addr, &format!("peer {j}"), deadline)?;
        s.write_all(HANDSHAKE_MAGIC).map_err(|e| io_err("handshaking", e))?;
        s.write_all(&(rank as u32).to_le_bytes()).map_err(|e| io_err("handshaking", e))?;
        s.set_nodelay(true).map_err(|e| io_err("socket setup", e))?;
        links[j] = Some(s);
    }
    for _ in rank + 1..n {
        let mut s = accept_retry(&data, "peer connections", deadline)?;
        s.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| io_err("socket setup", e))?;
        let mut magic = [0u8; 8];
        read_exact(&mut s, &mut magic, "reading handshake magic")?;
        if &magic != HANDSHAKE_MAGIC {
            return Err(TransportError("data listener contacted by a non-worker".into()));
        }
        let mut rb = [0u8; 4];
        read_exact(&mut s, &mut rb, "reading handshake rank")?;
        let peer = u32::from_le_bytes(rb) as usize;
        if peer <= rank || peer >= n || links[peer].is_some() {
            return Err(TransportError(format!("invalid or duplicate handshake rank {peer}")));
        }
        s.set_read_timeout(None).map_err(|e| io_err("socket setup", e))?;
        s.set_nodelay(true).map_err(|e| io_err("socket setup", e))?;
        links[peer] = Some(s);
    }
    Ok(links)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_ranks_form_a_full_mesh_over_loopback() {
        let addr = free_loopback_addr().unwrap();
        let n = 4;
        let meshes: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let addr = addr.clone();
                    s.spawn(move || establish(&addr, r, n).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (r, links) in meshes.iter().enumerate() {
            assert!(links[r].is_none(), "rank {r} must not link to itself");
            for (j, l) in links.iter().enumerate() {
                assert_eq!(l.is_some(), j != r, "rank {r} link to {j}");
            }
        }
    }

    #[test]
    fn single_rank_needs_no_sockets() {
        let links = establish("127.0.0.1:1", 0, 1).unwrap();
        assert_eq!(links.len(), 1);
        assert!(links[0].is_none());
    }

    #[test]
    fn bad_rank_is_rejected() {
        assert!(establish("127.0.0.1:1", 3, 2).is_err());
    }
}
