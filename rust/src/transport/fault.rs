//! Fault-injecting transport wrapper: the network half of the chaos
//! matrix.
//!
//! [`FaultTransport`] wraps any [`PeerTransport`] and perturbs its *send*
//! side only:
//!
//! * **drop** — each outgoing frame is discarded with probability `p`
//!   *before* it reaches the inner transport.  A dropped frame is never
//!   sent and never counted, so per-link bit accounting stays exactly
//!   balanced (`sent(a→b) == received(b from a)` still holds — the frame
//!   simply does not exist on the wire).  The receiver's round deadline
//!   censors the silent peer, which is precisely the production lossy-
//!   network behavior the elastic membership layer exists to absorb.
//! * **delay** — each outgoing frame sleeps `ms + U[0, jitter]`
//!   milliseconds on the sending thread first, modeling a congested or
//!   distant link.  Because sends on one link are serialized, sustained
//!   delay backs up the whole rank — intended: that is what a slow NIC
//!   does.
//!
//! Receives pass through untouched: with send-side-only faults and one
//! seeded RNG per wrapper, a chaos run's fault schedule is a
//! deterministic function of `(seed, send sequence)` regardless of
//! receiver timing.  The wrapper composes under
//! [`crate::membership::Elastic`] (`Elastic<FaultTransport<TcpTransport>>`)
//! so the membership layer sees faults exactly as it would see a flaky
//! network: missed deadlines and stalled rings.  Every membership hook
//! (`view_mask`, `ring_degraded`, `on_ring_stall`, ...) forwards to the
//! inner transport; the default `broadcast` loop is inherited on purpose
//! so per-destination drop decisions apply to fan-outs too.
//!
//! Without `--failover` the chaos CLI forbids `drop:`/`flap:`/`kill:`
//! on rank 0 — rank 0 is the control plane (epoch frames, aggregate
//! broadcasts), and workers wait on it without a deadline by design.
//! With `--failover`, rank-0 faults are unlocked: the membership layer
//! absorbs the leader's death like any other and hands leadership to a
//! deterministic successor (DESIGN.md §10).

use super::peer::{PeerTransport, Tag, TransportError};
use super::wire::WireMsg;
use crate::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

/// A [`PeerTransport`] decorator that drops and/or delays outgoing
/// frames.  Construct with [`FaultTransport::new`], then chain
/// [`with_drop`](FaultTransport::with_drop) /
/// [`with_delay`](FaultTransport::with_delay).
pub struct FaultTransport<T: PeerTransport> {
    inner: T,
    /// Per-frame drop probability in `[0, 1]`; 0 disables.
    drop_prob: f64,
    /// `(base_ms, jitter_ms)` pre-send latency; `None` disables.
    delay: Option<(u64, u64)>,
    rng: Rng,
    /// Frames discarded by the drop fault (never reached the inner
    /// transport).
    pub dropped_frames: u64,
    /// Frames that served a delay before being sent.
    pub delayed_frames: u64,
}

impl<T: PeerTransport> FaultTransport<T> {
    /// Wrap `inner` with no faults armed; `seed` fixes the fault
    /// schedule (use the rank so fleets don't correlate).
    pub fn new(inner: T, seed: u64) -> FaultTransport<T> {
        FaultTransport {
            inner,
            drop_prob: 0.0,
            delay: None,
            rng: Rng::stream(seed, 0xFA17),
            dropped_frames: 0,
            delayed_frames: 0,
        }
    }

    /// Arm the drop fault.  `p` must already be validated into `[0, 1]`
    /// (the chaos parser rejects anything else).
    pub fn with_drop(mut self, p: f64) -> FaultTransport<T> {
        debug_assert!((0.0..=1.0).contains(&p), "drop probability out of range");
        self.drop_prob = p;
        self
    }

    /// Arm the delay fault: `ms + U[0, jitter_ms]` before every send.
    pub fn with_delay(mut self, ms: u64, jitter_ms: u64) -> FaultTransport<T> {
        self.delay = Some((ms, jitter_ms));
        self
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut T {
        &mut self.inner
    }

    pub fn into_inner(self) -> T {
        self.inner
    }

    /// Roll the fault dice for one outgoing frame: `true` means drop it.
    /// Serving the delay happens here too so drop-and-delay compose the
    /// way a real lossy slow link does (latency is paid either way).
    fn faults_swallow_frame(&mut self) -> bool {
        if let Some((ms, jitter)) = self.delay {
            let extra = if jitter == 0 { 0 } else { self.rng.below(jitter as usize + 1) as u64 };
            std::thread::sleep(Duration::from_millis(ms + extra));
            self.delayed_frames += 1;
        }
        if self.drop_prob > 0.0 && self.rng.f64() < self.drop_prob {
            self.dropped_frames += 1;
            return true;
        }
        false
    }
}

impl<T: PeerTransport> PeerTransport for FaultTransport<T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn send(&mut self, to: usize, round: u64, tag: Tag, msg: WireMsg) -> Result<(), TransportError> {
        if self.faults_swallow_frame() {
            return Ok(()); // dropped: unsent, unaccounted, invisible
        }
        self.inner.send(to, round, tag, msg)
    }

    // `broadcast` deliberately stays the default per-peer loop so each
    // destination gets an independent drop roll.

    fn recv(&mut self, from: usize, round: u64, tag: Tag) -> Result<Arc<WireMsg>, TransportError> {
        self.inner.recv(from, round, tag)
    }

    fn is_live(&self, rank: usize) -> bool {
        self.inner.is_live(rank)
    }

    fn live_count(&self) -> usize {
        self.inner.live_count()
    }

    fn on_peer_down(&mut self, rank: usize) -> bool {
        self.inner.on_peer_down(rank)
    }

    fn round_timeout(&self) -> Option<Duration> {
        self.inner.round_timeout()
    }

    fn recv_deadline(
        &mut self,
        from: usize,
        round: u64,
        tag: Tag,
        timeout: Option<Duration>,
    ) -> Result<Option<Arc<WireMsg>>, TransportError> {
        self.inner.recv_deadline(from, round, tag, timeout)
    }

    fn view_mask(&self) -> u64 {
        self.inner.view_mask()
    }

    fn ring_degraded(&self) -> bool {
        self.inner.ring_degraded()
    }

    fn on_ring_stall(&mut self) {
        self.inner.on_ring_stall();
    }

    fn leader(&self) -> usize {
        self.inner.leader()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::mesh::channel_mesh;
    use crate::transport::wire::encode_f32s;

    #[test]
    fn drop_one_swallows_frames_and_the_receiver_censors() {
        let mut eps = channel_mesh(2);
        let e0 = eps.remove(0);
        let mut faulty = FaultTransport::new(eps.remove(0), 7).with_drop(1.0);
        let mut clean = e0;
        // p = 1: every send vanishes before the wire; the call still
        // succeeds from the sender's point of view.
        faulty.send(0, 3, Tag::Upload, encode_f32s(&[1.0, 2.0])).unwrap();
        assert_eq!(faulty.dropped_frames, 1);
        let got = clean
            .recv_deadline(1, 3, Tag::Upload, Some(Duration::from_millis(20)))
            .unwrap();
        assert!(got.is_none(), "a dropped frame must surface as a censoring deadline miss");
        // p = 0 on the same wrapper: frames flow again.
        let mut faulty = FaultTransport::new(faulty.into_inner(), 7).with_drop(0.0);
        faulty.send(0, 4, Tag::Upload, encode_f32s(&[3.0])).unwrap();
        assert_eq!(faulty.dropped_frames, 0);
        let got = clean.recv(1, 4, Tag::Upload).unwrap();
        assert_eq!(got.bit_len, 32);
    }

    #[test]
    fn fault_schedule_is_deterministic_in_the_seed() {
        let rolls = |seed: u64| -> Vec<bool> {
            let mut eps = channel_mesh(2);
            let mut f = FaultTransport::new(eps.remove(1), seed).with_drop(0.5);
            (0..64).map(|_| f.faults_swallow_frame()).collect()
        };
        assert_eq!(rolls(11), rolls(11), "same seed, same schedule");
        assert_ne!(rolls(11), rolls(12), "different seeds must decorrelate");
        let hits = rolls(11).iter().filter(|&&d| d).count();
        assert!((16..=48).contains(&hits), "p = 0.5 should drop roughly half, got {hits}/64");
    }

    #[test]
    fn membership_hooks_forward_to_the_inner_transport() {
        let mut eps = channel_mesh(3);
        let f = FaultTransport::new(eps.remove(1), 0);
        assert_eq!(f.rank(), 1);
        assert_eq!(f.n(), 3);
        assert_eq!(f.view_mask(), 0b111);
        assert!(!f.ring_degraded());
        assert_eq!(f.live_count(), 3);
        assert!(f.is_live(2));
        assert!(f.round_timeout().is_none());
        assert_eq!(f.leader(), 0, "leadership forwards through the fault layer");
    }
}
