//! Bit-packed wire codecs for every compressor payload.
//!
//! The seed repo *accounted* payload bits (`compressor::payload_bits`,
//! `Compressor::compress_into`) without ever materializing a message.  This
//! module makes the bytes real: [`encode`] turns `C(v)` into a bit-packed
//! [`WireMsg`] and [`decode`] reconstructs `C(v)` exactly on the receiver.
//!
//! **Invariant (tested):** `encode(c, ctx, v).bit_len` equals the bits the
//! compressor reports via `compress_into` — i.e. `payload_bits(sel, d)` for
//! sparsifiers, `32 + ceil(d·log2(2s+1))` for QSGD and `32 + d` for
//! sign-SGD.  The accounting that drives every figure is therefore the
//! *measured* size of a real message, not a formula that could drift.
//!
//! Layouts by [`WireScheme`]:
//!
//! * `SharedSupport` — selected values only, 32 bits each, in range order.
//!   The receiver re-derives the selection from `(ctx, d)` (shared-seed GRBS,
//!   per-worker seeded blocks); zero index metadata — the paper's §3.3
//!   AllReduce-compatibility argument made literal.
//! * `IndexValue` — `(ceil(log2 d)`-bit index, 32-bit value)` pairs for
//!   value-dependent supports (top-k, rand-k accounting).  The pair count is
//!   derived from the transport frame length (all pairs are equal width), so
//!   no count header is spent.  Note: `BlockTopK` routes through this scheme
//!   by expanding blocks to elements — its *wire* cost honestly includes the
//!   index metadata that `payload_bits` (which prices `Selection::Blocks` at
//!   zero index bits) does not charge it.
//! * `QsgdLevels` — 32-bit ℓ2 norm, then the signed levels packed as one
//!   big integer in radix `B = 2s+1`: exactly `ceil(d·log2 B)` bits, the
//!   information-theoretic size the accounting already claimed.  (Radix
//!   conversion is O(d²/64) in the worst case — fine at the message sizes
//!   the parameter-server path carries; documented trade-off.)
//! * `SignBitmap` — 32-bit scale + one sign bit per coordinate.
//!
//! Decoded values are **bit-identical** to `compress_into` output (the same
//! f32 expressions are evaluated on both ends), with the single documented
//! exception that a negative zero produced by quantizing a negative
//! coordinate to level 0 decodes as `+0.0` (`==`-equal, one sign bit of
//! information below the accounted budget).

use crate::compressor::{Compressor, Ctx, Selection, WireScheme};

/// A serialized message: `bit_len` bits stored little-endian in `words`.
#[derive(Clone, Debug, PartialEq)]
pub struct WireMsg {
    pub words: Vec<u64>,
    pub bit_len: u64,
}

impl WireMsg {
    pub fn reader(&self) -> BitReader<'_> {
        BitReader { words: &self.words, pos: 0 }
    }

    /// Bytes this message occupies on the wire (bit length rounded up).
    pub fn byte_len(&self) -> u64 {
        self.bit_len.div_ceil(8)
    }
}

/// Append-only bit sink (LSB-first within each u64 word).
#[derive(Default)]
pub struct BitWriter {
    words: Vec<u64>,
    bit_len: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `bits` bits of `value` (high bits must be zero).
    pub fn write(&mut self, value: u64, bits: u32) {
        if bits == 0 {
            return;
        }
        debug_assert!(bits <= 64);
        debug_assert!(bits == 64 || value >> bits == 0, "value wider than {bits} bits");
        let off = (self.bit_len % 64) as u32;
        if off == 0 {
            self.words.push(value);
        } else {
            *self.words.last_mut().unwrap() |= value << off;
            if off + bits > 64 {
                self.words.push(value >> (64 - off));
            }
        }
        self.bit_len += bits as u64;
    }

    pub fn write_f32(&mut self, v: f32) {
        self.write(v.to_bits() as u64, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.bit_len
    }

    pub fn finish(self) -> WireMsg {
        WireMsg { words: self.words, bit_len: self.bit_len }
    }
}

/// Cursor over a [`WireMsg`]'s bits.
pub struct BitReader<'a> {
    words: &'a [u64],
    pos: u64,
}

impl BitReader<'_> {
    pub fn read(&mut self, bits: u32) -> u64 {
        if bits == 0 {
            return 0;
        }
        debug_assert!(bits <= 64);
        let off = (self.pos % 64) as u32;
        let idx = (self.pos / 64) as usize;
        let mut v = self.words[idx] >> off;
        if off + bits > 64 {
            v |= self.words[idx + 1] << (64 - off);
        }
        self.pos += bits as u64;
        if bits < 64 {
            v & ((1u64 << bits) - 1)
        } else {
            v
        }
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read(32) as u32)
    }
}

/// Bits per explicit index in a d-vector — identical expression to
/// `compressor::payload_bits` so the codec and the accounting cannot drift.
pub fn index_width(d: usize) -> u32 {
    usize::BITS - (d.max(2) - 1).leading_zeros()
}

/// Encode `C(v)` for transmission.  `ctx` must be the sender's (round,
/// worker) pair — the receiver needs the same pair to decode.
pub fn encode(c: &dyn Compressor, ctx: Ctx, v: &[f32]) -> WireMsg {
    encode_with_selection(c, ctx, v, None)
}

/// Like [`encode`], reusing a caller-precomputed selection for the two
/// selection-based schemes — callers that also need the selection (the
/// parameter-server path) avoid running `select` twice (top-k is O(d)).
/// Dense schemes ignore `sel`.
pub fn encode_with_selection(
    c: &dyn Compressor,
    ctx: Ctx,
    v: &[f32],
    sel: Option<&Selection>,
) -> WireMsg {
    let d = v.len();
    let mut w = BitWriter::new();
    let owned;
    match c.wire_scheme() {
        WireScheme::SharedSupport => {
            debug_assert!(!c.is_dense());
            let sel = match sel {
                Some(s) => s,
                None => {
                    owned = c.select(ctx, v);
                    &owned
                }
            };
            sel.for_each_range(d, |s, e| {
                for &x in &v[s..e] {
                    w.write_f32(x);
                }
            });
        }
        WireScheme::IndexValue => {
            debug_assert!(!c.is_dense());
            let iw = index_width(d);
            let sel = match sel {
                Some(s) => s,
                None => {
                    owned = c.select(ctx, v);
                    &owned
                }
            };
            sel.for_each_range(d, |s, e| {
                for (i, &x) in (s..e).zip(&v[s..e]) {
                    w.write(i as u64, iw);
                    w.write_f32(x);
                }
            });
        }
        WireScheme::QsgdLevels { levels } => encode_qsgd(c, ctx, v, levels, &mut w),
        WireScheme::SignBitmap => {
            // Same scale expression as SignSgd::compress_into — bit-identical.
            let l1: f64 = v.iter().map(|x| x.abs() as f64).sum();
            let scale = (l1 / d as f64) as f32;
            w.write_f32(scale);
            for &x in v {
                // Same predicate as SignSgd::compress_into (x >= 0.0 → +scale).
                let bit = if x >= 0.0 { 0 } else { 1 };
                w.write(bit, 1);
            }
        }
    }
    w.finish()
}

/// Decode a message produced by [`encode`] with the same `(c, ctx)` into
/// `out` (length d, fully overwritten): `out == C(v)`.
pub fn decode(c: &dyn Compressor, ctx: Ctx, msg: &WireMsg, out: &mut [f32]) {
    let d = out.len();
    out.iter_mut().for_each(|x| *x = 0.0);
    let mut r = msg.reader();
    match c.wire_scheme() {
        WireScheme::SharedSupport => {
            // Selection must depend only on (ctx, d) for this scheme; `out`
            // is zeroed, so value-dependent selections would be wrong here by
            // construction (enforced by the codec roundtrip property tests).
            let sel = c.select(ctx, out);
            sel.for_each_range(d, |s, e| {
                for x in &mut out[s..e] {
                    *x = r.read_f32();
                }
            });
        }
        WireScheme::IndexValue => {
            let iw = index_width(d);
            let pair = (iw + 32) as u64;
            debug_assert_eq!(msg.bit_len % pair, 0, "frame not a whole number of pairs");
            for _ in 0..msg.bit_len / pair {
                let i = r.read(iw) as usize;
                out[i] = r.read_f32();
            }
        }
        WireScheme::QsgdLevels { levels } => decode_qsgd(levels, &mut r, msg.bit_len, out),
        WireScheme::SignBitmap => {
            let scale = r.read_f32();
            for x in out.iter_mut() {
                *x = if r.read(1) == 1 { -scale } else { scale };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// QSGD: norm + radix-packed signed levels.
// ---------------------------------------------------------------------------

/// Exact bit count of the QSGD level block for d coordinates — the same
/// float expression as `Qsgd::compress_into`'s accounting.
fn qsgd_level_bits(d: usize, levels: u32) -> u64 {
    (d as f64 * ((2 * levels + 1) as f64).log2()).ceil() as u64
}

fn encode_qsgd(c: &dyn Compressor, ctx: Ctx, v: &[f32], levels: u32, w: &mut BitWriter) {
    let d = v.len();
    // Same norm expression as Qsgd::compress_into.
    let norm = crate::util::math::norm2(v).sqrt() as f32;
    w.write_f32(norm);
    if norm == 0.0 {
        return; // 32 bits total — matches the compressor's early-out account
    }
    let s = levels as f32;
    let base = (2 * levels + 1) as u64;
    // Recover the stochastic levels from the quantized output itself: with
    // o = sign·norm·level/s in f32, |o|/norm·s is within a few ulp of the
    // integer level, so round() is exact for any realistic level count.
    let mut dense = vec![0.0f32; d];
    c.compress_into(ctx, v, &mut dense);
    let digits: Vec<u64> = dense
        .iter()
        .map(|&o| {
            let lv = ((o.abs() / norm * s).round() as i64).min(levels as i64);
            let signed = if o.is_sign_negative() { -lv } else { lv };
            (signed + levels as i64) as u64
        })
        .collect();
    let limbs = radix_pack(&digits, base);
    write_limbs(w, &limbs, qsgd_level_bits(d, levels));
}

fn decode_qsgd(levels: u32, r: &mut BitReader, bit_len: u64, out: &mut [f32]) {
    let d = out.len();
    let norm = r.read_f32();
    if norm == 0.0 {
        debug_assert_eq!(bit_len, 32);
        return; // out already zeroed
    }
    let s = levels as f32;
    let base = (2 * levels + 1) as u64;
    let limbs = read_limbs(r, qsgd_level_bits(d, levels));
    let digits = radix_unpack(&limbs, d, base);
    for (x, &dg) in out.iter_mut().zip(&digits) {
        let signed = dg as i64 - levels as i64;
        let sgn = if signed < 0 { -1.0f32 } else { 1.0f32 };
        let level = signed.unsigned_abs() as f32;
        // Same expression shape as Qsgd::compress_into — bit-identical.
        *x = sgn * norm * level / s;
    }
}

fn write_limbs(w: &mut BitWriter, limbs: &[u64], bits: u64) {
    let need = bits.div_ceil(64) as usize;
    assert!(limbs.len() <= need, "radix block overflow: {} limbs > {} bits", limbs.len(), bits);
    if limbs.len() == need && bits % 64 != 0 {
        assert!(limbs[need - 1] >> (bits % 64) == 0, "radix block overflow in top limb");
    }
    for i in 0..need {
        let word = limbs.get(i).copied().unwrap_or(0);
        let b = if (i as u64 + 1) * 64 <= bits { 64 } else { (bits - i as u64 * 64) as u32 };
        w.write(word, b);
    }
}

fn read_limbs(r: &mut BitReader, bits: u64) -> Vec<u64> {
    let need = bits.div_ceil(64) as usize;
    (0..need)
        .map(|i| {
            let b = if (i as u64 + 1) * 64 <= bits { 64 } else { (bits - i as u64 * 64) as u32 };
            r.read(b)
        })
        .collect()
}

/// Largest (group size k, base^k) with base^k representable in u64.
fn superdigit(base: u64) -> (usize, u64) {
    let mut k = 1usize;
    let mut sb = base as u128;
    while sb * base as u128 <= u64::MAX as u128 {
        sb *= base as u128;
        k += 1;
    }
    (k, sb as u64)
}

/// Pack base-`base` digits (most-significant first) into a little-endian
/// u64-limb big integer.  Exact: the result is the integer
/// Σ digits[i]·base^(n-1-i), using ceil(n·log2 base) bits or fewer.
fn radix_pack(digits: &[u64], base: u64) -> Vec<u64> {
    let (k, sb) = superdigit(base);
    let mut limbs: Vec<u64> = Vec::new();
    // limbs = limbs * mul + add
    fn mul_add(limbs: &mut Vec<u64>, mul: u64, add: u64) {
        let mut carry = add as u128;
        for l in limbs.iter_mut() {
            let t = *l as u128 * mul as u128 + carry;
            *l = t as u64;
            carry = t >> 64;
        }
        if carry > 0 {
            limbs.push(carry as u64);
        }
    }
    let r = digits.len() % k;
    if r > 0 {
        let mut val = 0u64;
        for &dg in &digits[..r] {
            val = val * base + dg;
        }
        mul_add(&mut limbs, 1, val);
    }
    let mut pos = r;
    while pos < digits.len() {
        let mut val = 0u64;
        for &dg in &digits[pos..pos + k] {
            val = val * base + dg;
        }
        mul_add(&mut limbs, sb, val);
        pos += k;
    }
    limbs
}

/// Inverse of [`radix_pack`] for a known digit count.
fn radix_unpack(limbs: &[u64], count: usize, base: u64) -> Vec<u64> {
    let (k, sb) = superdigit(base);
    let mut limbs: Vec<u64> = limbs.to_vec();
    while limbs.last() == Some(&0) {
        limbs.pop();
    }
    // big-int divmod by a u64: returns remainder, truncates quotient in place
    fn div_rem_small(limbs: &mut Vec<u64>, div: u64) -> u64 {
        let mut rem: u128 = 0;
        for l in limbs.iter_mut().rev() {
            let cur = (rem << 64) | *l as u128;
            *l = (cur / div as u128) as u64;
            rem = cur % div as u128;
        }
        while limbs.last() == Some(&0) {
            limbs.pop();
        }
        rem as u64
    }
    let mut digits = vec![0u64; count];
    let mut pos = count;
    for _ in 0..count / k {
        let mut v = div_rem_small(&mut limbs, sb);
        for j in (pos - k..pos).rev() {
            digits[j] = v % base;
            v /= base;
        }
        pos -= k;
    }
    if pos > 0 {
        // leading partial group: whatever remains is its value (< base^pos)
        debug_assert!(limbs.len() <= 1);
        let mut v = limbs.first().copied().unwrap_or(0);
        for j in (0..pos).rev() {
            digits[j] = v % base;
            v /= base;
        }
    }
    digits
}

// ---------------------------------------------------------------------------
// Aggregate codecs for the parameter-server downlink and the ring payload.
// ---------------------------------------------------------------------------

/// Raw f32 values (used for ring chunks and dense-quantizer aggregates).
pub fn encode_f32s(xs: &[f32]) -> WireMsg {
    let mut w = BitWriter::new();
    for &x in xs {
        w.write_f32(x);
    }
    w.finish()
}

/// Overwrite `out` with the values of an [`encode_f32s`] message.
pub fn decode_f32s(msg: &WireMsg, out: &mut [f32]) {
    debug_assert_eq!(msg.bit_len, out.len() as u64 * 32);
    let mut r = msg.reader();
    for x in out.iter_mut() {
        *x = r.read_f32();
    }
}

/// Accumulate (`out[i] += v_i`) the values of an [`encode_f32s`] message —
/// the reduce half of the ring's reduce-scatter.
pub fn decode_f32s_add(msg: &WireMsg, out: &mut [f32]) {
    debug_assert_eq!(msg.bit_len, out.len() as u64 * 32);
    let mut r = msg.reader();
    for x in out.iter_mut() {
        *x += r.read_f32();
    }
}

/// Union-support aggregate: (index, value) pairs for every `true` in `mask`.
/// This is the parameter server's broadcast for sparsifier inputs — its size
/// is the *actual* union of the worker supports, the quantity the α-β cost
/// model approximates with a union factor.
pub fn encode_union(v: &[f32], mask: &[bool]) -> WireMsg {
    let d = v.len();
    let iw = index_width(d);
    let mut w = BitWriter::new();
    for (i, (&x, &m)) in v.iter().zip(mask).enumerate() {
        if m {
            w.write(i as u64, iw);
            w.write_f32(x);
        }
    }
    w.finish()
}

/// Zero-fill `out` and scatter a union-support aggregate into it.
pub fn decode_union(msg: &WireMsg, out: &mut [f32]) {
    let d = out.len();
    out.iter_mut().for_each(|x| *x = 0.0);
    let iw = index_width(d);
    let pair = (iw + 32) as u64;
    debug_assert_eq!(msg.bit_len % pair, 0);
    let mut r = msg.reader();
    for _ in 0..msg.bit_len / pair {
        let i = r.read(iw) as usize;
        out[i] = r.read_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{
        payload_bits, BlockTopK, Grbs, Identity, Qsgd, RandBlock, RandK, SignSgd, TopK, Zero,
    };
    use crate::util::prop::{forall, Gen};

    #[test]
    fn bit_writer_reader_roundtrip_mixed_widths() {
        forall(50, 0xB17, |g: &mut Gen| {
            let n = g.usize_in(1, 200);
            let items: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let bits = g.usize_in(1, 65) as u32;
                    let v = if bits == 64 {
                        g.rng.next_u64()
                    } else {
                        g.rng.next_u64() & ((1u64 << bits) - 1)
                    };
                    (v, bits)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, b) in &items {
                w.write(v, b);
            }
            let msg = w.finish();
            crate::prop_assert!(
                msg.bit_len == items.iter().map(|&(_, b)| b as u64).sum::<u64>(),
                "bit length mismatch"
            );
            let mut r = msg.reader();
            for (i, &(v, b)) in items.iter().enumerate() {
                let got = r.read(b);
                crate::prop_assert!(got == v, "item {i}: {got} != {v} ({b} bits)");
            }
            Ok(())
        });
    }

    #[test]
    fn radix_roundtrip_property() {
        forall(60, 0x4Ad1, |g: &mut Gen| {
            let base = g.usize_in(2, 40) as u64;
            let count = g.usize_in(1, 400);
            let digits: Vec<u64> = (0..count).map(|_| g.rng.below(base as usize) as u64).collect();
            let limbs = radix_pack(&digits, base);
            // packed size within the information-theoretic bound
            let max_bits = (count as f64 * (base as f64).log2()).ceil() as usize;
            crate::prop_assert!(
                limbs.len() <= max_bits.div_ceil(64),
                "{} limbs for {max_bits} bits",
                limbs.len()
            );
            let back = radix_unpack(&limbs, count, base);
            crate::prop_assert!(back == digits, "radix roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn radix_leading_zero_digits_preserved() {
        let digits = vec![0, 0, 0, 5, 0, 2];
        let limbs = radix_pack(&digits, 9);
        assert_eq!(radix_unpack(&limbs, 6, 9), digits);
        // all-zero stream
        let z = vec![0u64; 17];
        assert_eq!(radix_unpack(&radix_pack(&z, 3), 17, 3), z);
    }

    /// The tentpole invariant: decode∘encode == C(·) exactly, and the
    /// encoded length equals the bits the compressor reports (which for
    /// sparsifiers is `payload_bits(sel, d)`).
    #[test]
    fn prop_codec_roundtrip_and_exact_bits() {
        forall(40, 0xC0DEC, |g: &mut Gen| {
            let d = g.usize_in(4, 300);
            let v = g.vec(d);
            let ctx = Ctx { round: g.rng.next_u64() % 999, worker: g.usize_in(0, 6) as u32 };
            let comps: Vec<Box<dyn Compressor>> = vec![
                Box::new(Grbs::new(4.0, (d / 8).max(1), 0x6EB)),
                Box::new(RandBlock::new(4.0, (d / 8).max(1))),
                Box::new(RandK::new(8.0)),
                Box::new(TopK::new(8.0)),
                Box::new(Qsgd::new(4)),
                Box::new(SignSgd),
                Box::new(Identity),
                Box::new(Zero),
            ];
            for c in comps {
                let mut expect = vec![0.0f32; d];
                let bits = c.compress_into(ctx, &v, &mut expect);
                let msg = encode(c.as_ref(), ctx, &v);
                crate::prop_assert!(
                    msg.bit_len == bits,
                    "{}: encoded {} bits, accounted {bits}",
                    c.name(),
                    msg.bit_len
                );
                // For sparsifiers the accounted size is payload_bits(sel, d).
                if !c.is_dense() {
                    let sel = c.select(ctx, &v);
                    crate::prop_assert!(
                        msg.bit_len == payload_bits(&sel, d),
                        "{}: wire {} != payload_bits",
                        c.name(),
                        msg.bit_len
                    );
                }
                let mut out = vec![7.0f32; d]; // poisoned: decode must overwrite
                decode(c.as_ref(), ctx, &msg, &mut out);
                for i in 0..d {
                    crate::prop_assert!(
                        out[i] == expect[i],
                        "{}: coord {i}: {} != {}",
                        c.name(),
                        out[i],
                        expect[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blocktopk_wire_pays_for_its_indices() {
        // Value-dependent block selections cannot ride the shared-seed trick:
        // the wire message expands to (index, value) pairs, strictly larger
        // than payload_bits' zero-index-bit price for Selection::Blocks.
        let d = 128;
        let mut g = Gen::replay(0xB70, 0);
        let v = g.vec(d);
        let ctx = Ctx { round: 3, worker: 1 };
        let c = BlockTopK::new(4.0, 16);
        let sel = c.select(ctx, &v);
        let msg = encode(&c, ctx, &v);
        let k = sel.count(d) as u64;
        assert_eq!(msg.bit_len, k * (index_width(d) as u64 + 32));
        assert!(msg.bit_len > payload_bits(&sel, d));
        let mut expect = vec![0.0f32; d];
        c.compress_into(ctx, &v, &mut expect);
        let mut out = vec![0.0f32; d];
        decode(&c, ctx, &msg, &mut out);
        assert_eq!(out, expect);
    }

    #[test]
    fn qsgd_zero_vector_is_32_bits() {
        let c = Qsgd::new(4);
        let v = vec![0.0f32; 50];
        let ctx = Ctx { round: 0, worker: 0 };
        let msg = encode(&c, ctx, &v);
        assert_eq!(msg.bit_len, 32);
        let mut out = vec![1.0f32; 50];
        decode(&c, ctx, &msg, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn qsgd_many_levels_roundtrip() {
        // larger level counts stress the radix grouping (smaller k per limb)
        let mut g = Gen::replay(0x5D, 1);
        let d = 257;
        let v = g.vec_smooth(d);
        for levels in [1u32, 2, 7, 255, 1024] {
            let c = Qsgd::new(levels);
            let ctx = Ctx { round: 12, worker: 3 };
            let mut expect = vec![0.0f32; d];
            let bits = c.compress_into(ctx, &v, &mut expect);
            let msg = encode(&c, ctx, &v);
            assert_eq!(msg.bit_len, bits, "levels={levels}");
            let mut out = vec![0.0f32; d];
            decode(&c, ctx, &msg, &mut out);
            assert_eq!(out, expect, "levels={levels}");
        }
    }

    #[test]
    fn union_codec_roundtrip() {
        let d = 64;
        let v: Vec<f32> = (0..d).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mask: Vec<bool> = (0..d).map(|i| i % 3 == 0).collect();
        let msg = encode_union(&v, &mask);
        let k = mask.iter().filter(|&&m| m).count() as u64;
        assert_eq!(msg.bit_len, k * (index_width(d) as u64 + 32));
        let mut out = vec![9.0f32; d];
        decode_union(&msg, &mut out);
        for i in 0..d {
            assert_eq!(out[i], if mask[i] { v[i] } else { 0.0 });
        }
    }

    #[test]
    fn f32_chunk_codecs() {
        let xs = [1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let msg = encode_f32s(&xs);
        assert_eq!(msg.bit_len, 5 * 32);
        let mut out = [0.0f32; 5];
        decode_f32s(&msg, &mut out);
        assert_eq!(out, xs);
        decode_f32s_add(&msg, &mut out);
        for (o, x) in out.iter().zip(&xs) {
            assert_eq!(*o, x + x);
        }
    }
}
