//! Bit-packed wire codecs for every compressor payload.
//!
//! The seed repo *accounted* payload bits (`compressor::payload_bits`,
//! `Compressor::compress_into`) without ever materializing a message.  This
//! module makes the bytes real: [`encode`] turns `C(v)` into a bit-packed
//! [`WireMsg`] and [`decode`] reconstructs `C(v)` exactly on the receiver.
//!
//! **Invariant (tested):** `encode(c, ctx, v).bit_len` equals the bits the
//! compressor reports via `compress_into` — i.e. `payload_bits_wire(scheme,
//! sel, d)` for sparsifiers, `32 + qsgd_level_bits(d, s)` for QSGD and
//! `32 + d` for sign-SGD.  The accounting that drives every figure is
//! therefore the *measured* size of a real message, not a formula that could
//! drift.
//!
//! Layouts by [`WireScheme`]:
//!
//! * `SharedSupport` — selected values only, 32 bits each, in range order.
//!   The receiver re-derives the selection from `(ctx, d)` (shared-seed GRBS,
//!   per-worker seeded blocks); zero index metadata — the paper's §3.3
//!   AllReduce-compatibility argument made literal.
//! * `IndexValue` — `(ceil(log2 d)`-bit index, 32-bit value)` pairs for
//!   value-dependent supports (top-k, rand-k accounting).  The pair count is
//!   derived from the transport frame length (all pairs are equal width), so
//!   no count header is spent.
//! * `BlockIndex` — value-dependent *block* supports (`BlockTopK`): one
//!   `ceil(log2 B)`-bit block id per selected block followed by that block's
//!   values.  The ids are real metadata and `payload_bits_wire` charges them
//!   — accounted == encoded here too, unlike the seed-derivable
//!   `SharedSupport` blocks which ship zero index bits.
//! * `QsgdLevels` — 32-bit ℓ2 norm, then the signed levels packed chunkwise
//!   in radix `B = 2s+1`: `k` digits per u64 chunk (`B^k ≤ u64::MAX`),
//!   wasting under one bit per chunk vs the information-theoretic size while
//!   staying O(d) (`compressor::quantize::qsgd_level_bits` is the exact
//!   accounted size).
//! * `SignBitmap` — 32-bit scale + one sign bit per coordinate.
//!
//! Decoded values are **bit-identical** to `compress_into` output (the same
//! f32 expressions are evaluated on both ends), with the single documented
//! exception that a negative zero produced by quantizing a negative
//! coordinate to level 0 decodes as `+0.0` (`==`-equal, one sign bit of
//! information below the accounted budget).

use crate::compressor::{Compressor, Ctx, Selection, WireScheme};

/// A malformed frame: truncated, misaligned, or carrying out-of-range
/// metadata.  Decoders return this instead of panicking — the TCP backend
/// feeds them bytes from the network, exactly the place `debug_assert!`
/// guards would vanish in release builds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError(pub String);

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "malformed wire frame: {}", self.0)
    }
}

impl std::error::Error for WireError {}

macro_rules! frame_err {
    ($($arg:tt)*) => { return Err(WireError(format!($($arg)*))) };
}

/// A serialized message: `bit_len` bits stored little-endian in `words`.
#[derive(Clone, Debug, PartialEq)]
pub struct WireMsg {
    pub words: Vec<u64>,
    pub bit_len: u64,
}

impl WireMsg {
    pub fn reader(&self) -> BitReader<'_> {
        BitReader { words: &self.words, pos: 0 }
    }

    /// Bytes this message occupies on the wire (bit length rounded up).
    pub fn byte_len(&self) -> u64 {
        self.bit_len.div_ceil(8)
    }

    /// Structural sanity: the word buffer must cover `bit_len` exactly.
    /// Every decoder calls this first so a frame with a lying length header
    /// (truncated or oversized payload) fails loudly instead of reading out
    /// of bounds or silently ignoring trailing bytes.
    pub fn check(&self) -> Result<(), WireError> {
        let need = self.bit_len.div_ceil(64);
        if self.words.len() as u64 != need {
            return Err(WireError(format!(
                "payload holds {} words, bit length {} needs {}",
                self.words.len(),
                self.bit_len,
                need
            )));
        }
        Ok(())
    }
}

/// Append-only bit sink (LSB-first within each u64 word).
#[derive(Default)]
pub struct BitWriter {
    words: Vec<u64>,
    bit_len: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `bits` bits of `value` (high bits must be zero).
    pub fn write(&mut self, value: u64, bits: u32) {
        if bits == 0 {
            return;
        }
        debug_assert!(bits <= 64);
        debug_assert!(bits == 64 || value >> bits == 0, "value wider than {bits} bits");
        let off = (self.bit_len % 64) as u32;
        if off == 0 {
            self.words.push(value);
        } else {
            *self.words.last_mut().unwrap() |= value << off;
            if off + bits > 64 {
                self.words.push(value >> (64 - off));
            }
        }
        self.bit_len += bits as u64;
    }

    pub fn write_f32(&mut self, v: f32) {
        self.write(v.to_bits() as u64, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.bit_len
    }

    pub fn finish(self) -> WireMsg {
        WireMsg { words: self.words, bit_len: self.bit_len }
    }
}

/// Cursor over a [`WireMsg`]'s bits.
pub struct BitReader<'a> {
    words: &'a [u64],
    pos: u64,
}

impl BitReader<'_> {
    pub fn read(&mut self, bits: u32) -> u64 {
        if bits == 0 {
            return 0;
        }
        debug_assert!(bits <= 64);
        let off = (self.pos % 64) as u32;
        let idx = (self.pos / 64) as usize;
        let mut v = self.words[idx] >> off;
        if off + bits > 64 {
            v |= self.words[idx + 1] << (64 - off);
        }
        self.pos += bits as u64;
        if bits < 64 {
            v & ((1u64 << bits) - 1)
        } else {
            v
        }
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read(32) as u32)
    }
}

/// Bits per explicit index in a d-vector — the same function the accounting
/// (`compressor::payload_bits_wire`) uses, so codec and accounting cannot
/// drift.
pub fn index_width(d: usize) -> u32 {
    crate::compressor::index_bits(d)
}

/// Encode `C(v)` for transmission.  `ctx` must be the sender's (round,
/// worker) pair — the receiver needs the same pair to decode.
pub fn encode(c: &dyn Compressor, ctx: Ctx, v: &[f32]) -> WireMsg {
    encode_with_selection(c, ctx, v, None)
}

/// Like [`encode`], reusing a caller-precomputed selection for the two
/// selection-based schemes — callers that also need the selection (the
/// parameter-server path) avoid running `select` twice (top-k is O(d)).
/// Dense schemes ignore `sel`.
pub fn encode_with_selection(
    c: &dyn Compressor,
    ctx: Ctx,
    v: &[f32],
    sel: Option<&Selection>,
) -> WireMsg {
    let d = v.len();
    let mut w = BitWriter::new();
    let owned;
    match c.wire_scheme() {
        WireScheme::SharedSupport => {
            debug_assert!(!c.is_dense());
            let sel = match sel {
                Some(s) => s,
                None => {
                    owned = c.select(ctx, v);
                    &owned
                }
            };
            sel.for_each_range(d, |s, e| {
                for &x in &v[s..e] {
                    w.write_f32(x);
                }
            });
        }
        WireScheme::IndexValue => {
            debug_assert!(!c.is_dense());
            let iw = index_width(d);
            let sel = match sel {
                Some(s) => s,
                None => {
                    owned = c.select(ctx, v);
                    &owned
                }
            };
            sel.for_each_range(d, |s, e| {
                for (i, &x) in (s..e).zip(&v[s..e]) {
                    w.write(i as u64, iw);
                    w.write_f32(x);
                }
            });
        }
        WireScheme::BlockIndex { num_blocks } => {
            debug_assert!(!c.is_dense());
            let iw = index_width(num_blocks as usize);
            let sel = match sel {
                Some(s) => s,
                None => {
                    owned = c.select(ctx, v);
                    &owned
                }
            };
            match sel {
                Selection::Blocks { block_size, blocks } => {
                    for &b in blocks {
                        w.write(b as u64, iw);
                        let s = b as usize * block_size;
                        if s < d {
                            let e = (s + block_size).min(d);
                            for &x in &v[s..e] {
                                w.write_f32(x);
                            }
                        }
                    }
                }
                Selection::Nothing => {}
                _ => unreachable!("BlockIndex scheme requires block selections"),
            }
        }
        WireScheme::QsgdLevels { levels } => encode_qsgd(c, ctx, v, levels, &mut w),
        WireScheme::SignBitmap => {
            // Same scale expression as SignSgd::compress_into — bit-identical.
            let l1: f64 = v.iter().map(|x| x.abs() as f64).sum();
            let scale = (l1 / d as f64) as f32;
            w.write_f32(scale);
            for &x in v {
                // Same predicate as SignSgd::compress_into (x >= 0.0 → +scale).
                let bit = if x >= 0.0 { 0 } else { 1 };
                w.write(bit, 1);
            }
        }
    }
    w.finish()
}

/// Decode a message produced by [`encode`] with the same `(c, ctx)` into
/// `out` (length d, fully overwritten): `out == C(v)`.
///
/// Frames are validated before any read — truncated, misaligned, or
/// out-of-range frames return [`WireError`] (release-mode safe; the TCP
/// backend decodes untrusted bytes through this path).  `out` contents are
/// unspecified on error.
pub fn decode(
    c: &dyn Compressor,
    ctx: Ctx,
    msg: &WireMsg,
    out: &mut [f32],
) -> Result<(), WireError> {
    msg.check()?;
    let d = out.len();
    out.iter_mut().for_each(|x| *x = 0.0);
    let mut r = msg.reader();
    match c.wire_scheme() {
        WireScheme::SharedSupport => {
            // Selection must depend only on (ctx, d) for this scheme; `out`
            // is zeroed, so value-dependent selections would be wrong here by
            // construction (enforced by the codec roundtrip property tests).
            let sel = c.select(ctx, out);
            let expect = 32 * sel.count(d) as u64;
            if msg.bit_len != expect {
                frame_err!(
                    "shared-support frame is {} bits, selection needs {expect}",
                    msg.bit_len
                );
            }
            sel.for_each_range(d, |s, e| {
                for x in &mut out[s..e] {
                    *x = r.read_f32();
                }
            });
        }
        WireScheme::IndexValue => {
            let iw = index_width(d);
            let pair = (iw + 32) as u64;
            if msg.bit_len % pair != 0 {
                frame_err!("index-value frame {} bits, not a multiple of {pair}", msg.bit_len);
            }
            let pairs = msg.bit_len / pair;
            if pairs > d as u64 {
                frame_err!("index-value frame carries {pairs} pairs for a {d}-vector");
            }
            for _ in 0..pairs {
                let i = r.read(iw) as usize;
                if i >= d {
                    frame_err!("index {i} out of range for a {d}-vector");
                }
                out[i] = r.read_f32();
            }
        }
        WireScheme::BlockIndex { num_blocks } => {
            // Self-describing given the frame length: each entry is a block
            // id followed by that block's values (the trailing block may be
            // short, or empty when `num_blocks·block_size > d`).
            let nb = num_blocks as usize;
            let iw = index_width(nb);
            let block_size = (d + nb - 1) / nb;
            let mut consumed = 0u64;
            while consumed < msg.bit_len {
                if msg.bit_len - consumed < iw as u64 {
                    frame_err!("block-index frame ends mid-id ({} trailing bits)", msg.bit_len - consumed);
                }
                let b = r.read(iw) as usize;
                consumed += iw as u64;
                if b >= nb {
                    frame_err!("block id {b} out of range for {nb} blocks");
                }
                let s = b * block_size;
                if s < d {
                    let e = (s + block_size).min(d);
                    let need = 32 * (e - s) as u64;
                    if msg.bit_len - consumed < need {
                        frame_err!("block-index frame truncated inside block {b}");
                    }
                    for x in &mut out[s..e] {
                        *x = r.read_f32();
                    }
                    consumed += need;
                }
            }
        }
        WireScheme::QsgdLevels { levels } => decode_qsgd(levels, &mut r, msg.bit_len, out)?,
        WireScheme::SignBitmap => {
            if msg.bit_len != 32 + d as u64 {
                frame_err!("sign frame is {} bits, expected {}", msg.bit_len, 32 + d as u64);
            }
            let scale = r.read_f32();
            for x in out.iter_mut() {
                *x = if r.read(1) == 1 { -scale } else { scale };
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// QSGD: norm + chunk-packed signed levels.
//
// Digits in radix B = 2s+1 are grouped k at a time (k the largest group with
// B^k ≤ u64::MAX, `quantize::qsgd_chunk`) and each group is written as one
// integer of exactly bit_length(B^k − 1) bits — at most one wasted bit per
// chunk over the information-theoretic size, and O(d) end to end (the old
// whole-message big-integer radix conversion was O(d²/64); DESIGN.md §5).
// ---------------------------------------------------------------------------

use crate::compressor::quantize::{qsgd_chunk, qsgd_chunk_bits, qsgd_level_bits};

fn encode_qsgd(c: &dyn Compressor, ctx: Ctx, v: &[f32], levels: u32, w: &mut BitWriter) {
    let d = v.len();
    // Same norm expression as Qsgd::compress_into.
    let norm = crate::util::math::norm2(v).sqrt() as f32;
    w.write_f32(norm);
    if norm == 0.0 {
        return; // 32 bits total — matches the compressor's early-out account
    }
    let s = levels as f32;
    let base = (2 * levels + 1) as u64;
    // Recover the stochastic levels from the quantized output itself: with
    // o = sign·norm·level/s in f32, |o|/norm·s is within a few ulp of the
    // integer level, so round() is exact for any realistic level count.
    let mut dense = vec![0.0f32; d];
    c.compress_into(ctx, v, &mut dense);
    let digits: Vec<u64> = dense
        .iter()
        .map(|&o| {
            let lv = ((o.abs() / norm * s).round() as i64).min(levels as i64);
            let signed = if o.is_sign_negative() { -lv } else { lv };
            (signed + levels as i64) as u64
        })
        .collect();
    let (k, full_bits) = qsgd_chunk(levels);
    let start = w.bit_len();
    for chunk in digits.chunks(k) {
        let mut val = 0u64;
        for &dg in chunk {
            val = val * base + dg;
        }
        let bits = if chunk.len() == k { full_bits } else { qsgd_chunk_bits(chunk.len(), levels) };
        w.write(val, bits);
    }
    debug_assert_eq!(w.bit_len() - start, qsgd_level_bits(d, levels));
}

fn decode_qsgd(
    levels: u32,
    r: &mut BitReader,
    bit_len: u64,
    out: &mut [f32],
) -> Result<(), WireError> {
    let d = out.len();
    if bit_len < 32 {
        frame_err!("qsgd frame is {bit_len} bits, shorter than its norm header");
    }
    let norm = r.read_f32();
    if norm == 0.0 {
        if bit_len != 32 {
            frame_err!("qsgd zero-norm frame is {bit_len} bits, expected 32");
        }
        return Ok(()); // out already zeroed
    }
    let expect = 32 + qsgd_level_bits(d, levels);
    if bit_len != expect {
        frame_err!("qsgd frame is {bit_len} bits, expected {expect} for d={d}, s={levels}");
    }
    let s = levels as f32;
    let base = (2 * levels + 1) as u64;
    let (k, full_bits) = qsgd_chunk(levels);
    let mut idx = 0usize;
    while idx < d {
        let len = k.min(d - idx);
        let bits = if len == k { full_bits } else { qsgd_chunk_bits(len, levels) };
        let mut val = r.read(bits);
        for j in (idx..idx + len).rev() {
            let dg = val % base;
            val /= base;
            let signed = dg as i64 - levels as i64;
            let sgn = if signed < 0 { -1.0f32 } else { 1.0f32 };
            let level = signed.unsigned_abs() as f32;
            // Same expression shape as Qsgd::compress_into — bit-identical.
            out[j] = sgn * norm * level / s;
        }
        idx += len;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Aggregate codecs for the parameter-server downlink and the ring payload.
// ---------------------------------------------------------------------------

/// Raw f32 values (used for ring chunks and dense-quantizer aggregates).
pub fn encode_f32s(xs: &[f32]) -> WireMsg {
    let mut w = BitWriter::new();
    for &x in xs {
        w.write_f32(x);
    }
    w.finish()
}

/// Overwrite `out` with the values of an [`encode_f32s`] message.
pub fn decode_f32s(msg: &WireMsg, out: &mut [f32]) -> Result<(), WireError> {
    msg.check()?;
    if msg.bit_len != out.len() as u64 * 32 {
        frame_err!("raw-f32 frame is {} bits, expected {}", msg.bit_len, out.len() * 32);
    }
    let mut r = msg.reader();
    for x in out.iter_mut() {
        *x = r.read_f32();
    }
    Ok(())
}

/// Accumulate (`out[i] += v_i`) the values of an [`encode_f32s`] message —
/// the reduce half of the ring's reduce-scatter.
pub fn decode_f32s_add(msg: &WireMsg, out: &mut [f32]) -> Result<(), WireError> {
    msg.check()?;
    if msg.bit_len != out.len() as u64 * 32 {
        frame_err!("raw-f32 frame is {} bits, expected {}", msg.bit_len, out.len() * 32);
    }
    let mut r = msg.reader();
    for x in out.iter_mut() {
        *x += r.read_f32();
    }
    Ok(())
}

/// Union-support aggregate: (index, value) pairs for every `true` in `mask`.
/// This is the parameter server's broadcast for sparsifier inputs — its size
/// is the *actual* union of the worker supports, the quantity the α-β cost
/// model approximates with a union factor.
pub fn encode_union(v: &[f32], mask: &[bool]) -> WireMsg {
    let d = v.len();
    let iw = index_width(d);
    let mut w = BitWriter::new();
    for (i, (&x, &m)) in v.iter().zip(mask).enumerate() {
        if m {
            w.write(i as u64, iw);
            w.write_f32(x);
        }
    }
    w.finish()
}

/// Zero-fill `out` and scatter a union-support aggregate into it.
pub fn decode_union(msg: &WireMsg, out: &mut [f32]) -> Result<(), WireError> {
    msg.check()?;
    let d = out.len();
    out.iter_mut().for_each(|x| *x = 0.0);
    let iw = index_width(d);
    let pair = (iw + 32) as u64;
    if msg.bit_len % pair != 0 {
        frame_err!("union frame {} bits, not a multiple of {pair}", msg.bit_len);
    }
    let pairs = msg.bit_len / pair;
    if pairs > d as u64 {
        frame_err!("union frame carries {pairs} pairs for a {d}-vector");
    }
    let mut r = msg.reader();
    for _ in 0..pairs {
        let i = r.read(iw) as usize;
        if i >= d {
            frame_err!("union index {i} out of range for a {d}-vector");
        }
        out[i] = r.read_f32();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{
        payload_bits, payload_bits_wire, BlockTopK, Grbs, Identity, Qsgd, RandBlock, RandK,
        SignSgd, TopK, Zero,
    };
    use crate::util::prop::{forall, Gen};

    #[test]
    fn bit_writer_reader_roundtrip_mixed_widths() {
        forall(50, 0xB17, |g: &mut Gen| {
            let n = g.usize_in(1, 200);
            let items: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let bits = g.usize_in(1, 65) as u32;
                    let v = if bits == 64 {
                        g.rng.next_u64()
                    } else {
                        g.rng.next_u64() & ((1u64 << bits) - 1)
                    };
                    (v, bits)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, b) in &items {
                w.write(v, b);
            }
            let msg = w.finish();
            crate::prop_assert!(
                msg.bit_len == items.iter().map(|&(_, b)| b as u64).sum::<u64>(),
                "bit length mismatch"
            );
            let mut r = msg.reader();
            for (i, &(v, b)) in items.iter().enumerate() {
                let got = r.read(b);
                crate::prop_assert!(got == v, "item {i}: {got} != {v} ({b} bits)");
            }
            Ok(())
        });
    }

    /// The chunked level codec roundtrips arbitrary digit streams exactly
    /// and its size is the accounted `qsgd_level_bits` (leading-zero digits
    /// included — a digit stream is fixed-length, not a bare integer).
    #[test]
    fn chunked_digit_roundtrip_property() {
        forall(60, 0x4Ad1, |g: &mut Gen| {
            let levels = g.usize_in(1, 600) as u32;
            let base = 2 * levels as u64 + 1;
            let count = g.usize_in(1, 400);
            let mut digits: Vec<u64> =
                (0..count).map(|_| g.rng.below(base as usize) as u64).collect();
            if g.bool() {
                // leading zeros must survive (they would vanish in a bare
                // big-integer encoding)
                digits[0] = 0;
            }
            let (k, full_bits) = qsgd_chunk(levels);
            let mut w = BitWriter::new();
            for chunk in digits.chunks(k) {
                let mut val = 0u64;
                for &dg in chunk {
                    val = val * base + dg;
                }
                let bits =
                    if chunk.len() == k { full_bits } else { qsgd_chunk_bits(chunk.len(), levels) };
                w.write(val, bits);
            }
            let msg = w.finish();
            crate::prop_assert!(
                msg.bit_len == qsgd_level_bits(count, levels),
                "encoded {} bits, accounted {}",
                msg.bit_len,
                qsgd_level_bits(count, levels)
            );
            let mut r = msg.reader();
            let mut back = vec![0u64; count];
            let mut idx = 0usize;
            while idx < count {
                let len = k.min(count - idx);
                let bits = if len == k { full_bits } else { qsgd_chunk_bits(len, levels) };
                let mut val = r.read(bits);
                for j in (idx..idx + len).rev() {
                    back[j] = val % base;
                    val /= base;
                }
                idx += len;
            }
            crate::prop_assert!(back == digits, "chunked roundtrip mismatch");
            Ok(())
        });
    }

    /// The tentpole invariant: decode∘encode == C(·) exactly, and the
    /// encoded length equals the bits the compressor reports (which for
    /// sparsifiers is `payload_bits_wire(scheme, sel, d)`).
    #[test]
    fn prop_codec_roundtrip_and_exact_bits() {
        forall(40, 0xC0DEC, |g: &mut Gen| {
            let d = g.usize_in(4, 300);
            let v = g.vec(d);
            let ctx = Ctx { round: g.rng.next_u64() % 999, worker: g.usize_in(0, 6) as u32 };
            let comps: Vec<Box<dyn Compressor>> = vec![
                Box::new(Grbs::new(4.0, (d / 8).max(1), 0x6EB)),
                Box::new(RandBlock::new(4.0, (d / 8).max(1))),
                Box::new(RandK::new(8.0)),
                Box::new(TopK::new(8.0)),
                Box::new(BlockTopK::new(4.0, (d / 8).max(1))),
                Box::new(Qsgd::new(4)),
                Box::new(SignSgd),
                Box::new(Identity),
                Box::new(Zero),
            ];
            for c in comps {
                let mut expect = vec![0.0f32; d];
                let bits = c.compress_into(ctx, &v, &mut expect);
                let msg = encode(c.as_ref(), ctx, &v);
                crate::prop_assert!(
                    msg.bit_len == bits,
                    "{}: encoded {} bits, accounted {bits}",
                    c.name(),
                    msg.bit_len
                );
                // For sparsifiers the accounted size is payload_bits_wire.
                if !c.is_dense() {
                    let sel = c.select(ctx, &v);
                    crate::prop_assert!(
                        msg.bit_len == payload_bits_wire(c.wire_scheme(), &sel, d),
                        "{}: wire {} != payload_bits_wire",
                        c.name(),
                        msg.bit_len
                    );
                }
                let mut out = vec![7.0f32; d]; // poisoned: decode must overwrite
                decode(c.as_ref(), ctx, &msg, &mut out).unwrap();
                for i in 0..d {
                    crate::prop_assert!(
                        out[i] == expect[i],
                        "{}: coord {i}: {} != {}",
                        c.name(),
                        out[i],
                        expect[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blocktopk_wire_pays_for_its_indices() {
        // Value-dependent block selections cannot ride the shared-seed trick:
        // the message ships one block id per selected block — strictly more
        // than the zero-index-bit SharedSupport price of the same selection,
        // and exactly what `compress_into` accounts (DESIGN.md §3 closure).
        let d = 128;
        let mut g = Gen::replay(0xB70, 0);
        let v = g.vec(d);
        let ctx = Ctx { round: 3, worker: 1 };
        let c = BlockTopK::new(4.0, 16);
        let sel = c.select(ctx, &v);
        let msg = encode(&c, ctx, &v);
        let kept = sel.count(d) as u64; // 4 blocks of 8
        assert_eq!(msg.bit_len, kept * 32 + 4 * index_width(16) as u64);
        assert!(msg.bit_len > payload_bits(&sel, d));
        let mut expect = vec![0.0f32; d];
        let accounted = c.compress_into(ctx, &v, &mut expect);
        assert_eq!(msg.bit_len, accounted, "accounted bits must equal encoded bits");
        let mut out = vec![0.0f32; d];
        decode(&c, ctx, &msg, &mut out).unwrap();
        assert_eq!(out, expect);
    }

    #[test]
    fn blocktopk_short_last_block_roundtrips() {
        // d not a multiple of the block size: the trailing block is short and
        // the frame stays self-describing.
        // 16 blocks of ceil(45/16)=3: blocks 0..14 cover 45 coords exactly,
        // so block 15 starts at 45 — an empty (id-only) trailing entry.
        let d = 45;
        let mut g = Gen::replay(0xB71, 1);
        let v = g.vec(d);
        let ctx = Ctx { round: 9, worker: 0 };
        let c = BlockTopK::new(2.0, 16);
        let mut expect = vec![0.0f32; d];
        let accounted = c.compress_into(ctx, &v, &mut expect);
        let msg = encode(&c, ctx, &v);
        assert_eq!(msg.bit_len, accounted);
        let mut out = vec![7.0f32; d];
        decode(&c, ctx, &msg, &mut out).unwrap();
        assert_eq!(out, expect);
    }

    #[test]
    fn qsgd_large_d_roundtrip_chunked() {
        // The chunked codec is O(d): a WRN-scale message encodes/decodes in
        // milliseconds (the old big-integer radix was O(d²/64) — minutes at
        // this size) and stays exact.
        let d = 1 << 17;
        let mut g = Gen::replay(0x1A26E, 0);
        let v = g.vec_smooth(d);
        let c = Qsgd::new(4);
        let ctx = Ctx { round: 2, worker: 1 };
        let mut expect = vec![0.0f32; d];
        let bits = c.compress_into(ctx, &v, &mut expect);
        let msg = encode(&c, ctx, &v);
        assert_eq!(msg.bit_len, bits);
        let mut out = vec![0.0f32; d];
        decode(&c, ctx, &msg, &mut out).unwrap();
        assert_eq!(out, expect);
    }

    #[test]
    fn qsgd_zero_vector_is_32_bits() {
        let c = Qsgd::new(4);
        let v = vec![0.0f32; 50];
        let ctx = Ctx { round: 0, worker: 0 };
        let msg = encode(&c, ctx, &v);
        assert_eq!(msg.bit_len, 32);
        let mut out = vec![1.0f32; 50];
        decode(&c, ctx, &msg, &mut out).unwrap();
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn qsgd_many_levels_roundtrip() {
        // larger level counts stress the radix grouping (smaller k per limb)
        let mut g = Gen::replay(0x5D, 1);
        let d = 257;
        let v = g.vec_smooth(d);
        for levels in [1u32, 2, 7, 255, 1024] {
            let c = Qsgd::new(levels);
            let ctx = Ctx { round: 12, worker: 3 };
            let mut expect = vec![0.0f32; d];
            let bits = c.compress_into(ctx, &v, &mut expect);
            let msg = encode(&c, ctx, &v);
            assert_eq!(msg.bit_len, bits, "levels={levels}");
            let mut out = vec![0.0f32; d];
            decode(&c, ctx, &msg, &mut out).unwrap();
            assert_eq!(out, expect, "levels={levels}");
        }
    }

    #[test]
    fn union_codec_roundtrip() {
        let d = 64;
        let v: Vec<f32> = (0..d).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mask: Vec<bool> = (0..d).map(|i| i % 3 == 0).collect();
        let msg = encode_union(&v, &mask);
        let k = mask.iter().filter(|&&m| m).count() as u64;
        assert_eq!(msg.bit_len, k * (index_width(d) as u64 + 32));
        let mut out = vec![9.0f32; d];
        decode_union(&msg, &mut out).unwrap();
        for i in 0..d {
            assert_eq!(out[i], if mask[i] { v[i] } else { 0.0 });
        }
    }

    /// Hardened decode: corrupt frames (lying bit lengths, truncated word
    /// buffers, misaligned payloads) must return `WireError` — never panic,
    /// never read out of bounds — for every compressor scheme.  This is the
    /// release-mode guarantee the TCP transport depends on; the old
    /// `debug_assert!` guards vanished exactly there.
    #[test]
    fn prop_corrupt_frames_error_instead_of_panicking() {
        forall(40, 0xBAD0, |g: &mut Gen| {
            // d >= 16 keeps every scheme's valid lengths > 31 bits apart, so
            // the +1..31-bit misalignment below can never land on one.
            let d = g.usize_in(16, 200);
            let v = g.vec(d);
            let ctx = Ctx { round: g.rng.next_u64() % 999, worker: g.usize_in(0, 6) as u32 };
            let comps: Vec<Box<dyn Compressor>> = vec![
                Box::new(Grbs::new(4.0, (d / 8).max(1), 0x6EB)),
                Box::new(RandK::new(8.0)),
                Box::new(TopK::new(8.0)),
                Box::new(BlockTopK::new(4.0, (d / 8).max(1))),
                Box::new(Qsgd::new(4)),
                Box::new(SignSgd),
                Box::new(Identity),
            ];
            for c in comps {
                let msg = encode(c.as_ref(), ctx, &v);
                let mut out = vec![0.0f32; d];

                // (a) lying length header: word buffer no longer covers it
                let mut lying = msg.clone();
                lying.bit_len += 64 * (1 + g.usize_in(0, 3) as u64);
                crate::prop_assert!(
                    decode(c.as_ref(), ctx, &lying, &mut out).is_err(),
                    "{}: oversized bit_len accepted",
                    c.name()
                );

                // (b) truncated word buffer under an unchanged header
                if !msg.words.is_empty() {
                    let mut short = msg.clone();
                    short.words.truncate(short.words.len() - 1);
                    crate::prop_assert!(
                        decode(c.as_ref(), ctx, &short, &mut out).is_err(),
                        "{}: truncated words accepted",
                        c.name()
                    );
                }

                // (c) off-by-a-few bit length with a consistent word buffer:
                // every scheme's layout checks must reject the misalignment
                // (the word count only changes at 64-bit boundaries, so the
                // structural check alone cannot catch this one).
                let delta = g.usize_in(1, 31) as u64;
                let grown = WireMsg {
                    bit_len: msg.bit_len + delta,
                    words: {
                        let mut w = msg.words.clone();
                        w.resize(((msg.bit_len + delta).div_ceil(64)) as usize, 0);
                        w
                    },
                };
                crate::prop_assert!(
                    decode(c.as_ref(), ctx, &grown, &mut out).is_err(),
                    "{}: misaligned frame (+{delta} bits) accepted",
                    c.name()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_out_of_range_indices() {
        // A hand-built index-value frame whose index points past d: the
        // release build must refuse it (the index would previously have
        // panicked on slice access — or worse, aliased coordinate d-1).
        let d = 40; // index width 6, so index 63 is representable but invalid
        let iw = index_width(d);
        let mut w = BitWriter::new();
        w.write(63, iw);
        w.write_f32(1.5);
        let msg = w.finish();
        let mut out = vec![0.0f32; d];
        let c = TopK::new(4.0);
        let ctx = Ctx { round: 1, worker: 0 };
        assert!(decode(&c, ctx, &msg, &mut out).is_err());
        // same for the union aggregate codec
        assert!(decode_union(&msg, &mut out).is_err());
    }

    #[test]
    fn decode_rejects_bad_block_frames() {
        let d = 64;
        let ctx = Ctx { round: 1, worker: 0 };
        let mut out = vec![0.0f32; d];

        // 10 blocks → 4-bit ids, so ids 10..15 are representable but invalid.
        let c = BlockTopK::new(4.0, 10); // block_size ceil(64/10) = 7
        let mut w = BitWriter::new();
        w.write(12, index_width(10));
        for _ in 0..7 {
            w.write_f32(1.0);
        }
        assert!(
            decode(&c, ctx, &w.finish(), &mut out).is_err(),
            "block id beyond num_blocks must be rejected"
        );

        // An id-only frame for a non-empty block: truncated mid-entry.
        let c = BlockTopK::new(4.0, 8); // block_size 8
        let mut w = BitWriter::new();
        w.write(7, index_width(8));
        assert!(
            decode(&c, ctx, &w.finish(), &mut out).is_err(),
            "id-only frame for a non-empty block must be rejected as truncated"
        );
    }

    #[test]
    fn f32_chunk_codecs() {
        let xs = [1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let msg = encode_f32s(&xs);
        assert_eq!(msg.bit_len, 5 * 32);
        let mut out = [0.0f32; 5];
        decode_f32s(&msg, &mut out).unwrap();
        assert_eq!(out, xs);
        decode_f32s_add(&msg, &mut out).unwrap();
        for (o, x) in out.iter().zip(&xs) {
            assert_eq!(*o, x + x);
        }
    }
}
