//! Executable artifacts on the PJRT CPU client.
//!
//! Pattern (from /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Text is the interchange format because
//! xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos.
//!
//! All artifacts in this repo are lowered with `return_tuple=True`, so every
//! execution returns one tuple literal that we decompose.

use anyhow::{Context, Result};
use std::path::Path;

// Offline build: the PJRT binding is stubbed in-tree.  Swap this `use` for
// the real `xla` extern crate when the environment provides it (see
// xla_stub.rs module docs).
use crate::runtime::xla_stub as xla;

pub struct Runtime {
    client: xla::PjRtClient,
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_name().unwrap_or_default().to_string_lossy().into_owned(),
        })
    }
}

/// Typed input for an execution.
pub enum Input<'a> {
    F32(&'a [f32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
}

impl Executable {
    /// Execute with typed host inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                Ok(match inp {
                    Input::F32(v, dims) => xla::Literal::vec1(v).reshape(dims)?,
                    Input::I32(v, dims) => xla::Literal::vec1(v).reshape(dims)?,
                })
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Convenience: (loss, grad) from a train_step artifact.
    pub fn train_step(
        &self,
        flat: &[f32],
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, Vec<f32>)> {
        let bs = [batch as i64, seq as i64];
        let out = self.run(&[
            Input::F32(flat, vec![flat.len() as i64]),
            Input::I32(tokens, bs.to_vec()),
            Input::I32(targets, bs.to_vec()),
        ])?;
        anyhow::ensure!(out.len() == 2, "train_step must return (loss, grad)");
        let loss = out[0].get_first_element::<f32>()?;
        let grad = out[1].to_vec::<f32>()?;
        Ok((loss, grad))
    }

    /// Convenience: scalar loss from an eval_loss artifact.
    pub fn eval_loss(
        &self,
        flat: &[f32],
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<f32> {
        let bs = [batch as i64, seq as i64];
        let out = self.run(&[
            Input::F32(flat, vec![flat.len() as i64]),
            Input::I32(tokens, bs.to_vec()),
            Input::I32(targets, bs.to_vec()),
        ])?;
        Ok(out[0].get_first_element::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn runtime_and_manifest() -> Option<(Runtime, Manifest)> {
        let m = Manifest::load("artifacts").ok()?;
        let r = Runtime::cpu().ok()?;
        Some((r, m))
    }

    #[test]
    fn tiny_train_step_runs_and_descends() {
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = m.model("tiny").unwrap();
        let exe = rt.load(&info.train_step).unwrap();
        let mut flat = m.load_init(info).unwrap();
        let (b, s) = (info.batch, info.seq_len);
        let tokens: Vec<i32> = (0..b * s).map(|i| (i % info.vocab) as i32).collect();
        let targets: Vec<i32> = (0..b * s).map(|i| ((i + 1) % info.vocab) as i32).collect();
        let (l0, g) = exe.train_step(&flat, &tokens, &targets, b, s).unwrap();
        assert!(l0.is_finite() && l0 > 0.0);
        assert_eq!(g.len(), info.params);
        // one SGD step decreases this batch's loss
        for (x, gi) in flat.iter_mut().zip(&g) {
            *x -= 0.5 * gi;
        }
        let (l1, _) = exe.train_step(&flat, &tokens, &targets, b, s).unwrap();
        assert!(l1 < l0, "loss did not decrease: {l0} -> {l1}");
    }

    #[test]
    fn pallas_artifact_matches_jnp_artifact() {
        // The tiny_pallas train_step (flash-attention Pallas kernels lowered
        // into the HLO) must agree with the pure-jnp tiny artifact.
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (a, b) = (m.model("tiny").unwrap(), m.model("tiny_pallas").unwrap());
        assert_eq!(a.params, b.params);
        let flat = m.load_init(a).unwrap();
        let (bt, s) = (a.batch, a.seq_len);
        let tokens: Vec<i32> = (0..bt * s).map(|i| ((i * 7) % a.vocab) as i32).collect();
        let targets: Vec<i32> = (0..bt * s).map(|i| ((i * 7 + 1) % a.vocab) as i32).collect();
        let ea = rt.load(&a.train_step).unwrap();
        let eb = rt.load(&b.train_step).unwrap();
        let (la, ga) = ea.train_step(&flat, &tokens, &targets, bt, s).unwrap();
        let (lb, gb) = eb.train_step(&flat, &tokens, &targets, bt, s).unwrap();
        assert!((la - lb).abs() < 1e-3, "loss mismatch {la} vs {lb}");
        let mut max_rel = 0f32;
        for (x, y) in ga.iter().zip(&gb) {
            let rel = (x - y).abs() / (1e-3 + x.abs().max(y.abs()));
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 0.05, "grad mismatch: max rel {max_rel}");
    }

    #[test]
    fn block_mask_kernel_artifact_matches_rust_grbs_semantics() {
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = m.block_mask.clone().unwrap();
        let exe = rt.load(&info.file).unwrap();
        let d = info.d;
        let nb = d / info.block_size;
        let v: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
        let mask: Vec<f32> = (0..nb).map(|b| (b % 3 == 0) as u8 as f32).collect();
        let out = exe
            .run(&[
                Input::F32(&v, vec![d as i64]),
                Input::F32(&mask, vec![nb as i64]),
            ])
            .unwrap();
        let kept = out[0].to_vec::<f32>().unwrap();
        let resid = out[1].to_vec::<f32>().unwrap();
        // Same semantics as compressor::Selection::apply with those blocks.
        use crate::compressor::Selection;
        let blocks: Vec<u32> = (0..nb as u32).filter(|b| b % 3 == 0).collect();
        let sel = Selection::Blocks { block_size: info.block_size, blocks };
        let mut kept_rs = vec![0.0f32; d];
        sel.apply(&v, &mut kept_rs);
        for i in 0..d {
            assert_eq!(kept[i], kept_rs[i], "kept mismatch at {i}");
            assert_eq!(resid[i], v[i] - kept_rs[i], "resid mismatch at {i}");
        }
    }

    #[test]
    fn fused_update_artifact_matches_formula() {
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let info = m.fused_update.clone().unwrap();
        let exe = rt.load(&info.file).unwrap();
        let d = info.d;
        let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).cos()).collect();
        let e: Vec<f32> = (0..d).map(|i| (i as f32 * 0.11).sin()).collect();
        let g: Vec<f32> = (0..d).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
        let r: Vec<f32> = (0..d).map(|i| ((i % 5) as f32 - 2.0) / 2.0).collect();
        let eta = [0.1f32];
        let out = exe
            .run(&[
                Input::F32(&eta, vec![1]),
                Input::F32(&x, vec![d as i64]),
                Input::F32(&e, vec![d as i64]),
                Input::F32(&g, vec![d as i64]),
                Input::F32(&r, vec![d as i64]),
            ])
            .unwrap();
        let xo = out[0].to_vec::<f32>().unwrap();
        let eo = out[1].to_vec::<f32>().unwrap();
        for i in 0..d {
            let xe = x[i] - 0.1 * (g[i] + r[i]);
            let ee = e[i] - 0.1 * r[i];
            assert!((xo[i] - xe).abs() < 1e-6);
            assert!((eo[i] - ee).abs() < 1e-6);
        }
    }
}
