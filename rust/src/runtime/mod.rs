//! PJRT runtime: load AOT artifacts (HLO text emitted by python/compile/aot.py)
//! and execute them from the Rust hot path.  Python is never on this path —
//! the artifacts are self-contained after `make artifacts`.

pub mod artifact;
pub mod manifest;
pub mod xla_stub;

pub use artifact::{Executable, Runtime};
pub use manifest::{KernelInfo, Manifest, ModelInfo};
