//! artifacts/manifest.json — the contract between aot.py and the Rust side.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub params: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub use_pallas: bool,
    pub train_step: PathBuf,
    pub eval_loss: PathBuf,
    pub init: PathBuf,
    /// (name, element count) per parameter tensor, in flat order.
    pub param_table: Vec<(String, usize)>,
}

#[derive(Debug, Clone)]
pub struct KernelInfo {
    pub d: usize,
    pub block_size: usize,
    pub file: PathBuf,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelInfo>,
    pub fused_update: Option<KernelInfo>,
    pub block_mask: Option<KernelInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;

        let mut models = Vec::new();
        let mobj = j.get("models").and_then(|m| m.as_obj()).ok_or_else(|| anyhow!("no models"))?;
        for (name, m) in mobj {
            let get = |k: &str| -> Result<usize> {
                m.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("model {name}: missing {k}"))
            };
            let gets = |k: &str| -> Result<PathBuf> {
                Ok(dir.join(
                    m.get(k).and_then(|v| v.as_str()).ok_or_else(|| anyhow!("model {name}: missing {k}"))?,
                ))
            };
            let param_table = m
                .get("param_table")
                .and_then(|t| t.as_arr())
                .map(|arr| {
                    arr.iter()
                        .filter_map(|e| {
                            let nm = e.get("name")?.as_str()?.to_string();
                            let count: usize = e
                                .get("shape")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize().unwrap_or(0))
                                .product();
                            Some((nm, count))
                        })
                        .collect()
                })
                .unwrap_or_default();
            models.push(ModelInfo {
                name: name.clone(),
                params: get("params")?,
                batch: get("batch")?,
                seq_len: get("seq_len")?,
                vocab: get("vocab")?,
                d_model: get("d_model")?,
                n_layers: get("n_layers")?,
                use_pallas: m.get("use_pallas").and_then(|v| v.as_bool()).unwrap_or(false),
                train_step: gets("train_step")?,
                eval_loss: gets("eval_loss")?,
                init: gets("init")?,
                param_table,
            });
        }

        let kernel = |key: &str| -> Option<KernelInfo> {
            let k = j.get("kernels")?.get(key)?;
            Some(KernelInfo {
                d: k.get("d")?.as_usize()?,
                block_size: k.get("block_size").and_then(|v| v.as_usize()).unwrap_or(0),
                file: dir.join(k.get("file")?.as_str()?),
            })
        };

        let fused_update = kernel("fused_update");
        let block_mask = kernel("block_mask");
        Ok(Manifest { dir, models, fused_update, block_mask })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("model preset '{name}' not in manifest (have: {:?})",
                self.models.iter().map(|m| &m.name).collect::<Vec<_>>()))
    }

    /// Read the f32 init vector for a model.
    pub fn load_init(&self, m: &ModelInfo) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&m.init)
            .with_context(|| format!("reading {}", m.init.display()))?;
        anyhow::ensure!(bytes.len() == m.params * 4, "init size mismatch");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        // Tests run from the crate root; artifacts exist after `make artifacts`.
        Manifest::load("artifacts").ok()
    }

    #[test]
    fn parses_generated_manifest() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let tiny = m.model("tiny").unwrap();
        assert!(tiny.params > 0);
        assert!(tiny.train_step.exists());
        assert!(tiny.eval_loss.exists());
        let total: usize = tiny.param_table.iter().map(|(_, c)| c).sum();
        assert_eq!(total, tiny.params, "param table must cover the flat vector");
        let init = m.load_init(tiny).unwrap();
        assert_eq!(init.len(), tiny.params);
        assert!(init.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kernel_entries_present() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let bm = m.block_mask.unwrap();
        assert!(bm.file.exists());
        assert!(bm.d % bm.block_size == 0);
        assert!(m.fused_update.unwrap().file.exists());
    }

    #[test]
    fn missing_model_is_a_clear_error() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let err = m.model("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
    }
}
