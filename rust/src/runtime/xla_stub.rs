//! Offline stub of the `xla` (PJRT) binding used by [`super::artifact`].
//!
//! The build environment has no crates.io registry, so the real
//! `xla`/xla_extension binding cannot be resolved.  This module mirrors the
//! exact API surface `artifact.rs` uses; every entry point that would touch
//! the PJRT client returns a clean [`Error`] ("PJRT runtime unavailable"),
//! so:
//!
//! * the crate builds and unit-tests from a clean checkout with no network;
//! * runtime-dependent tests skip gracefully (they already treat
//!   `Runtime::cpu()` failure as "artifacts not built");
//! * CLI subcommands that need PJRT (`quickstart`, `train-lm`,
//!   `kernel-check`) fail with an actionable message instead of panicking.
//!
//! To re-enable the real client: add the `xla` crate to Cargo.toml and in
//! `artifact.rs` swap `use crate::runtime::xla_stub as xla;` for the extern
//! crate.  No other code changes are required — the types and signatures
//! below match the binding as used.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: this build uses the offline xla stub \
         (rust/src/runtime/xla_stub.rs); link the real `xla` binding to run \
         AOT artifacts"
            .into(),
    )
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub must not create a client");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }
}
