//! Dense (value-quantizing) compressors: QSGD and scaled sign-SGD.
//!
//! Definition 1 covers *arbitrary* δ-approximate operators, and CSER's pitch
//! is that error reset "adapts arbitrary compressors" — not just sparsifiers.
//! These two quantizers exercise that generality end-to-end:
//!
//! * **QSGD** (Alistarh et al. 2017): stochastic uniform quantization to
//!   `s` levels per half-axis; unbiased, δ ≥ 1/(1 + min(d/s², √d/s)).
//!   Payload: 32-bit norm + ~(log2(2s+1)) bits per coordinate.
//! * **Sign-SGD with scale** (Karimireddy et al. 2019's EF-fixable form):
//!   C(v) = (‖v‖₁/d)·sign(v) — 1 bit per coordinate + one scale.  This is
//!   the compressor Definition 1's δ was originally stated for:
//!   δ = ‖v‖₁²/(d‖v‖₂²) ∈ (0, 1].
//!
//! They implement [`Compressor::compress_into_with`] directly (the selection
//! API is meaningless for value quantization); `select` returns
//! `Selection::All` so selection-based fast paths are bypassed and PSync
//! routes them through the dense generic path.  Neither is
//! AllReduce-compatible in the value domain (sums of quantized values are
//! not quantized), matching `globally_synchronized() == false`.

use super::{Compressor, Ctx, Scratch, Selection, WireScheme};
use crate::util::rng::Rng;

/// Chunk geometry of the QSGD level codec (DESIGN.md §5): digits in radix
/// `B = 2·levels + 1` are packed `k` at a time into one u64, where `k` is the
/// largest group size with `B^k ≤ u64::MAX`.  Returns `(k, bits)` with `bits`
/// the exact width of one full chunk.  Each chunk wastes
/// `bits − k·log2 B < 1` bit, so the codec is within one bit per chunk of the
/// information-theoretic size while staying O(d) (no big-integer radix
/// conversion).
pub fn qsgd_chunk(levels: u32) -> (usize, u32) {
    let base = 2 * levels as u64 + 1;
    let mut k = 1usize;
    let mut pow = base as u128;
    while pow * base as u128 <= u64::MAX as u128 {
        pow *= base as u128;
        k += 1;
    }
    (k, qsgd_chunk_bits(k, levels))
}

/// Exact bits needed for one chunk of `digits` radix-`2·levels+1` digits:
/// the bit length of `B^digits − 1`, computed in integer arithmetic so the
/// codec and the accounting can never disagree by a float-rounding ulp.
pub fn qsgd_chunk_bits(digits: usize, levels: u32) -> u32 {
    let base = 2 * levels as u64 + 1;
    let mut max: u128 = 1;
    for _ in 0..digits {
        max = max.checked_mul(base as u128).expect("qsgd chunk exceeds one machine word");
    }
    debug_assert!(max - 1 <= u64::MAX as u128);
    128 - (max - 1).leading_zeros()
}

/// Exact size in bits of the chunked QSGD level block for `d` coordinates —
/// what `transport::wire` serializes and what `Qsgd::compress_into` accounts
/// (on top of the 32-bit norm header).
pub fn qsgd_level_bits(d: usize, levels: u32) -> u64 {
    let (k, full_bits) = qsgd_chunk(levels);
    let full = (d / k) as u64;
    let rem = d % k;
    full * full_bits as u64
        + if rem > 0 { qsgd_chunk_bits(rem, levels) as u64 } else { 0 }
}

/// QSGD stochastic uniform quantizer with `s` levels.
#[derive(Clone, Debug)]
pub struct Qsgd {
    pub levels: u32,
    seed: u64,
}

impl Qsgd {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 1);
        Qsgd { levels, seed: 0x95D }
    }

    /// Nominal compression ratio vs f32: 32 bits -> log2(2s+1) + norm share.
    fn bits_per_coord(&self) -> f64 {
        ((2 * self.levels + 1) as f64).log2()
    }
}

impl Compressor for Qsgd {
    fn select_with(&self, _ctx: Ctx, _v: &[f32], _s: &mut Scratch) -> Selection {
        Selection::All // dense: the whole vector is touched
    }

    fn compress_into_with(&self, ctx: Ctx, v: &[f32], out: &mut [f32], _s: &mut Scratch) -> u64 {
        let norm = crate::util::math::norm2(v).sqrt() as f32;
        if norm == 0.0 {
            out.iter_mut().for_each(|o| *o = 0.0);
            return 32;
        }
        let s = self.levels as f32;
        let mut rng = Rng::stream(self.seed ^ ((ctx.worker as u64) << 32), ctx.round);
        for (o, &x) in out.iter_mut().zip(v) {
            let u = x.abs() / norm * s; // in [0, s]
            let l = u.floor();
            // stochastic rounding: unbiased level choice
            let level = if rng.f32() < u - l { l + 1.0 } else { l };
            *o = x.signum() * norm * level / s;
        }
        32 + qsgd_level_bits(v.len(), self.levels)
    }

    fn ratio(&self) -> f64 {
        32.0 / self.bits_per_coord()
    }

    fn delta(&self) -> f64 {
        // conservative lower bound; exact delta depends on d (Alistarh eq. 3.2)
        0.1
    }

    fn is_dense(&self) -> bool {
        true
    }

    fn globally_synchronized(&self) -> bool {
        false
    }

    fn wire_scheme(&self) -> WireScheme {
        WireScheme::QsgdLevels { levels: self.levels }
    }

    fn name(&self) -> String {
        format!("qsgd(s={})", self.levels)
    }
}

/// Scaled sign compressor: C(v) = (‖v‖₁/d)·sign(v).
#[derive(Clone, Copy, Debug, Default)]
pub struct SignSgd;

impl Compressor for SignSgd {
    fn select_with(&self, _ctx: Ctx, _v: &[f32], _s: &mut Scratch) -> Selection {
        Selection::All
    }

    fn compress_into_with(&self, _ctx: Ctx, v: &[f32], out: &mut [f32], _s: &mut Scratch) -> u64 {
        let d = v.len();
        let l1: f64 = v.iter().map(|x| x.abs() as f64).sum();
        let scale = (l1 / d as f64) as f32;
        for (o, &x) in out.iter_mut().zip(v) {
            *o = if x >= 0.0 { scale } else { -scale };
        }
        32 + d as u64 // one sign bit per coordinate + the scale
    }

    fn ratio(&self) -> f64 {
        32.0
    }

    fn delta(&self) -> f64 {
        // data-dependent: ||v||_1^2 / (d ||v||_2^2); worst case ~ 1/d, typical
        // (gaussian) 2/pi. Report the gaussian-typical value.
        2.0 / std::f64::consts::PI
    }

    fn is_dense(&self) -> bool {
        true
    }

    fn globally_synchronized(&self) -> bool {
        false
    }

    fn wire_scheme(&self) -> WireScheme {
        WireScheme::SignBitmap
    }

    fn name(&self) -> String {
        "signsgd".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::norm2;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn prop_qsgd_unbiased() {
        // E[C(v)] = v coordinate-wise over rounds (stochastic rounding).
        let d = 64;
        let mut g = Gen::replay(0x45D, 0);
        let v = g.vec_smooth(d);
        let q = Qsgd::new(4);
        let mut acc = vec![0.0f64; d];
        let rounds = 4000;
        let mut out = vec![0.0f32; d];
        for t in 0..rounds {
            q.compress_into(Ctx { round: t, worker: 0 }, &v, &mut out);
            for (a, &o) in acc.iter_mut().zip(&out) {
                *a += o as f64;
            }
        }
        for (j, (&a, &x)) in acc.iter().zip(&v).enumerate() {
            let mean = a / rounds as f64;
            assert!(
                (mean - x as f64).abs() < 0.08 * (1.0 + x.abs() as f64),
                "coord {j}: E[C(v)]={mean} vs {x}"
            );
        }
    }

    #[test]
    fn prop_qsgd_contraction() {
        // ||C(v) - v||^2 <= (2 - delta-ish) * ||v||^2 would be weak; QSGD with
        // s>=sqrt(d) keeps the residual below ||v||^2 comfortably in practice.
        forall(20, 0x45E, |g: &mut Gen| {
            let d = g.usize_in(8, 128);
            let v = g.vec_smooth(d);
            let q = Qsgd::new(16);
            let mut out = vec![0.0f32; d];
            q.compress_into(Ctx { round: g.case, worker: 0 }, &v, &mut out);
            let resid: Vec<f32> = v.iter().zip(&out).map(|(a, b)| a - b).collect();
            crate::prop_assert!(
                norm2(&resid) <= norm2(&v) + 1e-6,
                "residual {} vs {}", norm2(&resid), norm2(&v)
            );
            Ok(())
        });
    }

    #[test]
    fn signsgd_delta_identity() {
        // ||C(v)-v||^2 = ||v||^2 - ||v||_1^2/d exactly (Pythagoras for the
        // scaled-sign projection).
        let v: Vec<f32> = (0..32).map(|i| ((i * 13 % 7) as f32 - 3.0) / 3.0).collect();
        let c = SignSgd;
        let mut out = vec![0.0f32; 32];
        c.compress_into(Ctx { round: 0, worker: 0 }, &v, &mut out);
        let resid2: f64 = v.iter().zip(&out).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let l1: f64 = v.iter().map(|x| x.abs() as f64).sum();
        let expect = norm2(&v) - l1 * l1 / 32.0;
        assert!((resid2 - expect).abs() < 1e-6, "{resid2} vs {expect}");
    }

    #[test]
    fn qsgd_level_bits_within_one_bit_per_chunk() {
        // The chunked codec's promise: at most one wasted bit per chunk above
        // the information-theoretic size d·log2(2s+1), never below it.
        for levels in [1u32, 4, 7, 255, 1024] {
            let base = (2 * levels + 1) as f64;
            let (k, full_bits) = qsgd_chunk(levels);
            assert!(full_bits <= 64);
            for d in [1usize, 5, 63, 64, 1000, 12345] {
                let bits = qsgd_level_bits(d, levels) as f64;
                let info = d as f64 * base.log2();
                let chunks = d.div_ceil(k) as f64;
                assert!(bits >= info - 1e-6, "levels={levels} d={d}: {bits} < {info}");
                assert!(
                    bits < info + chunks + 1e-6,
                    "levels={levels} d={d}: {bits} vs {info} + {chunks} chunks"
                );
            }
        }
    }

    #[test]
    fn qsgd_zero_vector() {
        let q = Qsgd::new(4);
        let v = vec![0.0f32; 8];
        let mut out = vec![1.0f32; 8];
        let bits = q.compress_into(Ctx { round: 0, worker: 0 }, &v, &mut out);
        assert!(out.iter().all(|&o| o == 0.0));
        assert_eq!(bits, 32);
    }

    #[test]
    fn payload_bits_sane() {
        let q = Qsgd::new(4); // 9 levels -> ~3.17 bits
        let v = vec![1.0f32; 100];
        let mut out = vec![0.0f32; 100];
        let bits = q.compress_into(Ctx { round: 1, worker: 0 }, &v, &mut out);
        assert!(bits > 32 && bits < 32 + 100 * 4, "{bits}");
        assert!(q.ratio() > 8.0);
        assert_eq!(SignSgd.ratio(), 32.0);
    }
}
