//! Globally-Randomized Blockwise Sparsifier (paper §3.3, Definition 2).
//!
//! GRBS partitions a flat tensor into `num_blocks` blocks and, each round,
//! picks `num_blocks / R` blocks uniformly at random using a seed schedule
//! shared by all workers.  Consequences (paper's two bullets):
//!
//!   * **AllReduce / parameter-server compatibility** — every worker selects
//!     the *same* blocks, so compressed messages can be summed directly and
//!     no index metadata travels on the wire;
//!   * **`1/R`-approximate in expectation** — E‖C(v)−v‖² = (1−k/B)‖v‖² for
//!     uniformly chosen k-of-B blocks (verified by a property test below).
//!
//! The draw for round `t` is `Rng::stream(seed, t)`, a pure function of the
//! shared `(seed, round)` pair — the Rust equivalent of the paper's
//! "synchronized random seed".

use super::{Compressor, Ctx, Scratch, Selection};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Grbs {
    ratio: f64,
    num_blocks: usize,
    keep: usize,
    seed: u64,
}

impl Grbs {
    /// `ratio` = R_C (keep B/R blocks); `num_blocks` = B; `seed` shared by
    /// all workers. `keep` is rounded to at least 1 block so R ≤ B.
    pub fn new(ratio: f64, num_blocks: usize, seed: u64) -> Self {
        assert!(ratio >= 1.0, "compression ratio must be >= 1");
        assert!(num_blocks >= 1);
        let keep = ((num_blocks as f64 / ratio).round() as usize).clamp(1, num_blocks);
        Grbs { ratio, num_blocks, keep, seed }
    }

    /// Convenience: pick a block count so each block is ~`target_block` long.
    pub fn with_block_len(ratio: f64, d: usize, target_block: usize, seed: u64) -> Self {
        let nb = (d + target_block - 1) / target_block.max(1);
        // Need at least `ratio` blocks so that keep=1 is a valid R:1 draw.
        let nb = nb.max(ratio.ceil() as usize).max(1);
        Self::new(ratio, nb, seed)
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }
    pub fn keep(&self) -> usize {
        self.keep
    }

    /// Effective ratio after rounding keep to an integer block count.
    pub fn effective_ratio(&self) -> f64 {
        self.num_blocks as f64 / self.keep as f64
    }
}

impl Compressor for Grbs {
    fn select_with(&self, ctx: Ctx, v: &[f32], scratch: &mut Scratch) -> Selection {
        let block_size = (v.len() + self.num_blocks - 1) / self.num_blocks;
        let mut rng = Rng::stream(self.seed, ctx.round); // worker-independent
        let mut blocks = rng.choose_k_with(self.num_blocks, self.keep, &mut scratch.ix);
        blocks.sort_unstable();
        Selection::Blocks { block_size, blocks }
    }

    fn ratio(&self) -> f64 {
        self.ratio
    }

    fn delta(&self) -> f64 {
        self.keep as f64 / self.num_blocks as f64
    }

    fn globally_synchronized(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("grbs(R={}, B={})", self.ratio, self.num_blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::norm2;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn keeps_expected_block_count() {
        let g = Grbs::new(4.0, 64, 1);
        assert_eq!(g.keep(), 16);
        let g = Grbs::new(1024.0, 1024, 1);
        assert_eq!(g.keep(), 1);
        // rounding: R larger than B clamps to 1 block
        let g = Grbs::new(256.0, 64, 1);
        assert_eq!(g.keep(), 1);
    }

    #[test]
    fn same_selection_on_all_workers_and_rounds_vary() {
        let g = Grbs::new(8.0, 32, 42);
        let v = vec![1.0f32; 320];
        let s0 = g.select(Ctx { round: 7, worker: 0 }, &v);
        let s1 = g.select(Ctx { round: 7, worker: 3 }, &v);
        assert_eq!(s0, s1);
        let s2 = g.select(Ctx { round: 8, worker: 0 }, &v);
        assert_ne!(s0, s2, "different rounds should (generically) differ");
    }

    #[test]
    fn prop_expected_contraction_is_one_minus_delta() {
        // E||C(v)-v||^2 = (1 - k/B) ||v||^2 averaged over rounds.
        forall(5, 0x6EB5, |g: &mut Gen| {
            let nb = 32;
            let bs = 8;
            let d = nb * bs;
            let v = g.vec(d);
            let c = Grbs::new(4.0, nb, g.rng.next_u64());
            let rounds = 3000;
            let mut acc = 0.0f64;
            let mut kept = vec![0.0f32; d];
            for t in 0..rounds {
                let sel = c.select(Ctx { round: t, worker: 0 }, &v);
                sel.apply(&v, &mut kept);
                let resid2: f64 = v
                    .iter()
                    .zip(&kept)
                    .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
                    .sum();
                acc += resid2;
            }
            let mean = acc / rounds as f64;
            let expect = (1.0 - c.delta()) * norm2(&v);
            crate::prop_assert!(
                (mean - expect).abs() < 0.05 * expect.max(1e-9),
                "E resid^2 = {mean}, expected {expect}"
            );
            Ok(())
        });
    }

    #[test]
    fn blocks_uniformly_covered() {
        let c = Grbs::new(8.0, 64, 9);
        let v = vec![0.0f32; 64 * 4];
        let mut counts = vec![0u32; 64];
        let rounds = 8000;
        for t in 0..rounds {
            if let Selection::Blocks { blocks, .. } = c.select(Ctx { round: t, worker: 0 }, &v) {
                for b in blocks {
                    counts[b as usize] += 1;
                }
            }
        }
        let p_expect = c.keep() as f64 / 64.0;
        for (b, &cnt) in counts.iter().enumerate() {
            let p = cnt as f64 / rounds as f64;
            assert!((p - p_expect).abs() < 0.03, "block {b}: p={p} vs {p_expect}");
        }
    }

    #[test]
    fn with_block_len_handles_small_d() {
        let c = Grbs::with_block_len(1024.0, 512, 1024, 7);
        // d smaller than a block: must still have >= ratio blocks
        assert!(c.num_blocks() >= 1024);
        let v = vec![1.0f32; 512];
        let sel = c.select(Ctx { round: 0, worker: 0 }, &v);
        assert!(sel.count(512) <= 1); // many blocks are empty past d
    }
}
