//! δ-approximate compressors (paper Definition 1) as *sparsifiers*.
//!
//! All compressors used by the paper's experiments (GRBS) — and the classic
//! ones it compares to conceptually (random-k, top-k, blockwise top-k) —
//! are selection-based: `C(v)` equals `v` on a selected index set and 0
//! elsewhere.  Representing the selection explicitly keeps the synchronization
//! path O(|selection|) and makes bit accounting exact:
//!
//!   * `Selection::Blocks`  — contiguous blocks; no index metadata on the
//!     wire when the selection is globally synchronized (GRBS);
//!   * `Selection::Indices` — scattered elements; each costs `log2(d)` index
//!     bits in addition to the 32-bit payload;
//!   * `Selection::All` / `Selection::Nothing` — the identity / zero
//!     compressors (δ=1 / δ=0; the paper explicitly extends Definition 1 to
//!     allow δ=0, which is what `C2 = 0` configurations use).
//!
//! The contraction property ‖C(v)−v‖² ≤ (1−δ)‖v‖² holds by construction for
//! any selection (residual is a sub-vector); the per-compressor δ values are
//! documented on each type and verified by property tests.

pub mod grbs;
pub mod quantize;
pub mod randk;
pub mod topk;

pub use grbs::Grbs;
pub use quantize::{Qsgd, SignSgd};
pub use randk::{RandBlock, RandK};
pub use topk::{BlockTopK, TopK};

pub use crate::kernel::scratch::Scratch;

/// Context identifying one compression call.
///
/// `round` drives globally-synchronized randomness (all workers pass the same
/// round); `worker` lets per-worker compressors (rand-k, top-k) decorrelate.
#[derive(Clone, Copy, Debug)]
pub struct Ctx {
    pub round: u64,
    pub worker: u32,
}

/// The support of C(v).
#[derive(Clone, Debug, PartialEq)]
pub enum Selection {
    All,
    Nothing,
    /// Contiguous blocks of `block_size` elements; the last block may be
    /// shorter if `d % block_size != 0`. `blocks` are block indices.
    Blocks { block_size: usize, blocks: Vec<u32> },
    /// Explicit element indices (sorted, unique).
    Indices(Vec<u32>),
}

impl Selection {
    /// Number of selected elements in a vector of length `d`.
    pub fn count(&self, d: usize) -> usize {
        match self {
            Selection::All => d,
            Selection::Nothing => 0,
            Selection::Blocks { block_size, blocks } => {
                let bs = *block_size;
                blocks
                    .iter()
                    .map(|&b| {
                        let start = b as usize * bs;
                        bs.min(d.saturating_sub(start))
                    })
                    .sum()
            }
            Selection::Indices(ix) => ix.len(),
        }
    }

    /// Visit selected ranges as (start, end) pairs, coalescing indices.
    pub fn for_each_range<F: FnMut(usize, usize)>(&self, d: usize, mut f: F) {
        match self {
            Selection::All => f(0, d),
            Selection::Nothing => {}
            Selection::Blocks { block_size, blocks } => {
                for &b in blocks {
                    let start = b as usize * block_size;
                    if start < d {
                        f(start, (start + block_size).min(d));
                    }
                }
            }
            Selection::Indices(ix) => {
                for &i in ix {
                    f(i as usize, i as usize + 1);
                }
            }
        }
    }

    /// Materialize C(v) into `kept` (must be zero-filled or will be overwritten
    /// fully): kept = v on selection, 0 elsewhere.
    pub fn apply(&self, v: &[f32], kept: &mut [f32]) {
        kept.iter_mut().for_each(|k| *k = 0.0);
        self.for_each_range(v.len(), |s, e| kept[s..e].copy_from_slice(&v[s..e]));
    }

    /// Membership mask (for tests / slow paths).
    pub fn mask(&self, d: usize) -> Vec<bool> {
        let mut m = vec![false; d];
        self.for_each_range(d, |s, e| m[s..e].iter_mut().for_each(|b| *b = true));
        m
    }
}

/// How a compressed message is laid out on the wire (see `transport::wire`
/// for the codecs).  The scheme determines both the exact bit layout and
/// what the receiver needs in order to decode: `SharedSupport` messages are
/// decodable from `(ctx, d)` alone (the selection is re-drawn from the seed
/// schedule), everything else is self-describing given the transport frame
/// length.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireScheme {
    /// Selection re-derivable on the receiver from `(ctx, d)` alone — GRBS's
    /// shared-seed draw and per-worker seeded block draws.  Only the selected
    /// values travel; zero index metadata (the paper's §3.3 argument).
    SharedSupport,
    /// Explicit `(index, value)` pairs — value-dependent, per-worker supports
    /// (top-k and friends) that must ship their indices.
    IndexValue,
    /// Value-dependent *block* selections (blockwise top-k): each selected
    /// block ships its `ceil(log2 num_blocks)`-bit block id followed by that
    /// block's values.  Cheaper than expanding to per-element pairs, but the
    /// ids are real metadata — `payload_bits_wire` charges them (unlike the
    /// seed-derivable `SharedSupport` blocks).
    BlockIndex { num_blocks: u32 },
    /// QSGD: 32-bit ℓ2 norm followed by the signed quantization levels packed
    /// chunkwise in radix `2·levels + 1` (one u64 chunk of base-B digits per
    /// `ceil(k·log2 B)`-bit group, ≤1 bit overhead per chunk — see
    /// [`quantize::qsgd_level_bits`] for the exact accounted size).
    QsgdLevels { levels: u32 },
    /// Scaled sign-SGD: 32-bit scale + one sign bit per coordinate.
    SignBitmap,
}

/// Bits needed to address one of `count` items (the index width used by every
/// explicit-index wire layout; `transport::wire::index_width` is this same
/// expression, kept in one place so codec and accounting cannot drift).
pub fn index_bits(count: usize) -> u32 {
    usize::BITS - (count.max(2) - 1).leading_zeros()
}

/// Payload + metadata bits one worker uploads for its compressed message,
/// assuming seed-derivable block supports (zero index metadata for
/// `Selection::Blocks`).  This is the *shared-support* price; compressors
/// whose wire layout ships real metadata are charged via
/// [`payload_bits_wire`], which takes the layout into account.
pub fn payload_bits(sel: &Selection, d: usize) -> u64 {
    let elems = sel.count(d) as u64;
    let value_bits = elems * 32;
    let index_bits_total = match sel {
        Selection::All | Selection::Nothing => 0,
        // Globally-seeded block choices are reproducible from the shared
        // seed: zero metadata. (This is GRBS's AllReduce-compatibility
        // argument, §3.3.)
        Selection::Blocks { .. } => 0,
        Selection::Indices(ix) => ix.len() as u64 * index_bits(d) as u64,
    };
    value_bits + index_bits_total
}

/// Exact bits of the wire message a sparsifier ships for `sel` under the
/// given layout — the accounted size every harness prices, equal by
/// construction to what `transport::wire::encode` emits (tested invariant).
/// Dense value-coded schemes (QSGD, sign bitmap) don't go through selections;
/// their sizes come from `Compressor::compress_into` directly.
pub fn payload_bits_wire(scheme: WireScheme, sel: &Selection, d: usize) -> u64 {
    match scheme {
        WireScheme::SharedSupport => sel.count(d) as u64 * 32,
        WireScheme::IndexValue => sel.count(d) as u64 * (32 + index_bits(d) as u64),
        WireScheme::BlockIndex { num_blocks } => {
            let ids = match sel {
                Selection::Blocks { blocks, .. } => blocks.len() as u64,
                // An empty message has a real (zero-bit) encoding; any other
                // selection kind has no BlockIndex wire format, and pricing
                // one would silently break the accounted == encoded
                // invariant — fail exactly like the codec does.
                Selection::Nothing => 0,
                Selection::All | Selection::Indices(_) => {
                    unreachable!("BlockIndex scheme requires block selections")
                }
            };
            sel.count(d) as u64 * 32 + ids * index_bits(num_blocks as usize) as u64
        }
        WireScheme::QsgdLevels { levels } => 32 + quantize::qsgd_level_bits(d, levels),
        WireScheme::SignBitmap => 32 + d as u64,
    }
}

/// A δ-approximate compressor (Definition 1).
///
/// Sparsifiers implement [`Compressor::select_with`] (the scratch-threaded
/// hot-path entry; [`Compressor::select`] is a fresh-scratch convenience);
/// dense value-quantizers (QSGD, sign-SGD — see [`quantize`]) override
/// [`Compressor::compress_into_with`] and report `is_dense() == true` so
/// callers route them through the dense path.
pub trait Compressor: Send + Sync {
    /// Choose the support of C(v), reusing the caller's [`Scratch`] for any
    /// working buffers (top-k's `0..d` index permutation, random-draw pools,
    /// block-mass tables).  Implementations must be deterministic in
    /// `(ctx, v)` — the scratch only relocates working memory between calls,
    /// it never carries selection state.  Dense compressors return
    /// `Selection::All`.
    fn select_with(&self, ctx: Ctx, v: &[f32], scratch: &mut Scratch) -> Selection;

    /// Scratch-oblivious convenience over [`Compressor::select_with`]
    /// (allocates a fresh scratch per call — cold paths and tests; the hot
    /// paths hold a per-worker / per-thread scratch and call `select_with`).
    fn select(&self, ctx: Ctx, v: &[f32]) -> Selection {
        self.select_with(ctx, v, &mut Scratch::new())
    }

    /// Materialize C(v) into `out` (fully overwritten) reusing the caller's
    /// scratch; returns the payload bits one worker uploads for this message
    /// — the exact size of the wire message `transport::wire::encode` would
    /// emit for this compressor.  Dense value-quantizers override this (the
    /// selection default below is meaningless for them).
    fn compress_into_with(
        &self,
        ctx: Ctx,
        v: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) -> u64 {
        let sel = self.select_with(ctx, v, scratch);
        sel.apply(v, out);
        payload_bits_wire(self.wire_scheme(), &sel, v.len())
    }

    /// Scratch-oblivious convenience over [`Compressor::compress_into_with`].
    fn compress_into(&self, ctx: Ctx, v: &[f32], out: &mut [f32]) -> u64 {
        self.compress_into_with(ctx, v, out, &mut Scratch::new())
    }

    /// True for value-quantizing compressors whose support is the whole
    /// vector (selection fast paths don't apply).
    fn is_dense(&self) -> bool {
        false
    }

    /// Nominal compression ratio R (d / expected selected count).
    fn ratio(&self) -> f64;

    /// δ in Definition 1 (expectation for randomized compressors).
    fn delta(&self) -> f64 {
        1.0 / self.ratio()
    }

    /// True if `select` ignores `worker` and `v` (same support on every
    /// worker) — the precondition for AllReduce-style aggregation.
    fn globally_synchronized(&self) -> bool;

    /// Wire layout for this compressor's messages (`transport::wire`).
    ///
    /// Default: globally-synchronized selections need no metadata
    /// (`SharedSupport`); everything else ships explicit indices.  Seeded
    /// per-worker draws whose support depends only on `(ctx, d)` (e.g.
    /// `RandBlock`) override to `SharedSupport`; dense quantizers override to
    /// their value-coded layouts.
    fn wire_scheme(&self) -> WireScheme {
        if self.globally_synchronized() {
            WireScheme::SharedSupport
        } else {
            WireScheme::IndexValue
        }
    }

    fn name(&self) -> String;
}

/// Identity compressor: C(v) = v (δ = 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn select_with(&self, _ctx: Ctx, _v: &[f32], _s: &mut Scratch) -> Selection {
        Selection::All
    }
    fn ratio(&self) -> f64 {
        1.0
    }
    fn globally_synchronized(&self) -> bool {
        true
    }
    fn name(&self) -> String {
        "identity".into()
    }
}

/// Zero compressor: C(v) = 0 (δ = 0; paper's extension of Definition 1).
/// `C2 = Zero` turns CSER into CSER-PL, and with H=1 into CSEA.
#[derive(Clone, Copy, Debug, Default)]
pub struct Zero;

impl Compressor for Zero {
    fn select_with(&self, _ctx: Ctx, _v: &[f32], _s: &mut Scratch) -> Selection {
        Selection::Nothing
    }
    fn ratio(&self) -> f64 {
        f64::INFINITY
    }
    fn delta(&self) -> f64 {
        0.0
    }
    fn globally_synchronized(&self) -> bool {
        true
    }
    fn name(&self) -> String {
        "zero".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::norm2;
    use crate::util::prop::{forall, Gen};

    fn compressors(d: usize) -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(Identity),
            Box::new(Zero),
            Box::new(Grbs::new(4.0, (d / 8).max(1), 0xC5E7)),
            Box::new(RandK::new(8.0)),
            Box::new(RandBlock::new(4.0, (d / 8).max(1))),
            Box::new(TopK::new(8.0)),
            Box::new(BlockTopK::new(4.0, (d / 8).max(1))),
        ]
    }

    #[test]
    fn prop_contraction_all_compressors() {
        // Definition 1: ||C(v) - v||^2 <= ||v||^2 (selection-based => trivially,
        // but this also catches indexing bugs that duplicate/lose mass).
        forall(60, 0xA11, |g: &mut Gen| {
            let d = g.usize_in(8, 300);
            let v = g.vec(d);
            let ctx = Ctx { round: g.rng.next_u64() % 1000, worker: g.usize_in(0, 8) as u32 };
            for c in compressors(d) {
                let sel = c.select(ctx, &v);
                let mut kept = vec![0.0; d];
                sel.apply(&v, &mut kept);
                let resid: Vec<f32> = v.iter().zip(&kept).map(|(a, b)| a - b).collect();
                crate::prop_assert!(
                    norm2(&resid) <= norm2(&v) * (1.0 + 1e-6) + 1e-9,
                    "{}: contraction violated", c.name()
                );
                // kept + resid == v exactly
                for i in 0..d {
                    crate::prop_assert!(
                        kept[i] + resid[i] == v[i],
                        "{}: partition identity broken at {i}", c.name()
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_global_compressors_agree_across_workers() {
        forall(40, 0xA12, |g: &mut Gen| {
            let d = g.usize_in(16, 257);
            let v1 = g.vec(d);
            let v2 = g.vec(d);
            let round = g.rng.next_u64() % 512;
            for c in compressors(d) {
                if !c.globally_synchronized() {
                    continue;
                }
                let s1 = c.select(Ctx { round, worker: 0 }, &v1);
                let s2 = c.select(Ctx { round, worker: 5 }, &v2);
                crate::prop_assert!(s1 == s2, "{}: selection differs across workers", c.name());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_select_with_reused_scratch_matches_select() {
        // The scratch only relocates working memory: a scratch reused across
        // many calls (the hot-path pattern) must produce the identical
        // selection as the fresh-allocation convenience path.
        let mut scratch = Scratch::new();
        forall(30, 0xA14, |g: &mut Gen| {
            let d = g.usize_in(8, 300);
            let v = g.vec(d);
            let ctx = Ctx { round: g.rng.next_u64() % 512, worker: g.usize_in(0, 8) as u32 };
            for c in compressors(d) {
                let a = c.select(ctx, &v);
                let b = c.select_with(ctx, &v, &mut scratch);
                crate::prop_assert!(a == b, "{}: scratch path diverged", c.name());
            }
            Ok(())
        });
    }

    #[test]
    fn selection_count_and_ranges_consistent() {
        forall(40, 0xA13, |g: &mut Gen| {
            let d = g.usize_in(4, 200);
            let v = g.vec(d);
            let ctx = Ctx { round: 3, worker: 1 };
            for c in compressors(d) {
                let sel = c.select(ctx, &v);
                let mut n = 0usize;
                sel.for_each_range(d, |s, e| {
                    assert!(s < e && e <= d);
                    n += e - s;
                });
                crate::prop_assert!(n == sel.count(d), "{}: count mismatch", c.name());
            }
            Ok(())
        });
    }

    #[test]
    fn payload_bits_examples() {
        // 100 elements, blocks of 10, 2 blocks kept: 20 values, no indices.
        let sel = Selection::Blocks { block_size: 10, blocks: vec![0, 5] };
        assert_eq!(payload_bits(&sel, 100), 20 * 32);
        // 5 scattered indices in d=1000: 32 value bits + 10 index bits each.
        let sel = Selection::Indices(vec![1, 10, 100, 500, 999]);
        assert_eq!(payload_bits(&sel, 1000), 5 * (32 + 10));
        assert_eq!(payload_bits(&Selection::All, 64), 64 * 32);
        assert_eq!(payload_bits(&Selection::Nothing, 64), 0);
    }

    #[test]
    fn last_short_block_handled() {
        // d=10, block_size=4 -> blocks of sizes 4,4,2
        let sel = Selection::Blocks { block_size: 4, blocks: vec![2] };
        assert_eq!(sel.count(10), 2);
        let v: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let mut kept = vec![0.0; 10];
        sel.apply(&v, &mut kept);
        assert_eq!(&kept[8..], &[8.0, 9.0]);
        assert!(kept[..8].iter().all(|&x| x == 0.0));
    }
}
