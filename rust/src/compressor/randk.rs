//! Random-k element sparsifier and per-worker random-block sparsifier.
//!
//! `RandK` is the classic random sparsifier the paper contrasts GRBS with:
//! each *worker* draws its own k random coordinates (decorrelated via the
//! worker id), so messages carry index metadata and cannot be AllReduced
//! without decompression.  Used in ablations (DESIGN.md ABL).

use super::{Compressor, Ctx, Scratch, Selection, WireScheme};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandK {
    ratio: f64,
    seed: u64,
}

impl RandK {
    pub fn new(ratio: f64) -> Self {
        Self::with_seed(ratio, 0x7A4D)
    }
    pub fn with_seed(ratio: f64, seed: u64) -> Self {
        assert!(ratio >= 1.0);
        RandK { ratio, seed }
    }
}

impl Compressor for RandK {
    fn select_with(&self, ctx: Ctx, v: &[f32], scratch: &mut Scratch) -> Selection {
        let d = v.len();
        let k = ((d as f64 / self.ratio).round() as usize).clamp(1, d);
        let mut rng = Rng::stream(self.seed ^ ((ctx.worker as u64) << 32), ctx.round);
        let mut ix = rng.choose_k_with(d, k, &mut scratch.ix);
        ix.sort_unstable();
        Selection::Indices(ix)
    }

    fn ratio(&self) -> f64 {
        self.ratio
    }

    fn globally_synchronized(&self) -> bool {
        false
    }

    fn name(&self) -> String {
        format!("randk(R={})", self.ratio)
    }
}

/// Per-worker random *block* sparsifier: like GRBS but the draw also depends
/// on the worker id.  Isolates the value of GRBS's "globally synchronized"
/// property in ablations: same blockwise structure, no shared seed.
#[derive(Clone, Debug)]
pub struct RandBlock {
    ratio: f64,
    num_blocks: usize,
    keep: usize,
    seed: u64,
}

impl RandBlock {
    pub fn new(ratio: f64, num_blocks: usize) -> Self {
        assert!(ratio >= 1.0);
        let keep = ((num_blocks as f64 / ratio).round() as usize).clamp(1, num_blocks);
        RandBlock { ratio, num_blocks, keep, seed: 0xB10C }
    }
}

impl Compressor for RandBlock {
    fn select_with(&self, ctx: Ctx, v: &[f32], scratch: &mut Scratch) -> Selection {
        let block_size = (v.len() + self.num_blocks - 1) / self.num_blocks;
        let mut rng = Rng::stream(self.seed ^ ((ctx.worker as u64) << 32), ctx.round);
        let mut blocks = rng.choose_k_with(self.num_blocks, self.keep, &mut scratch.ix);
        blocks.sort_unstable();
        Selection::Blocks { block_size, blocks }
    }

    fn ratio(&self) -> f64 {
        self.ratio
    }

    fn delta(&self) -> f64 {
        self.keep as f64 / self.num_blocks as f64
    }

    fn globally_synchronized(&self) -> bool {
        false
    }

    fn wire_scheme(&self) -> WireScheme {
        // The block draw depends only on (seed, worker, round) — any receiver
        // that knows the sender's rank can re-derive the support, so no index
        // metadata travels (consistent with `payload_bits` counting zero
        // index bits for `Selection::Blocks`).
        WireScheme::SharedSupport
    }

    fn name(&self) -> String {
        format!("randblock(R={}, B={})", self.ratio, self.num_blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn randk_selects_k_unique_sorted() {
        let c = RandK::new(8.0);
        let v = vec![0.5f32; 256];
        if let Selection::Indices(ix) = c.select(Ctx { round: 1, worker: 2 }, &v) {
            assert_eq!(ix.len(), 32);
            let mut s = ix.clone();
            s.dedup();
            assert_eq!(s.len(), 32);
            assert!(ix.windows(2).all(|w| w[0] < w[1]));
        } else {
            panic!("expected indices");
        }
    }

    #[test]
    fn randk_workers_decorrelated() {
        let c = RandK::new(8.0);
        let v = vec![0.5f32; 256];
        let a = c.select(Ctx { round: 1, worker: 0 }, &v);
        let b = c.select(Ctx { round: 1, worker: 1 }, &v);
        assert_ne!(a, b);
    }

    #[test]
    fn randblock_workers_decorrelated() {
        let c = RandBlock::new(4.0, 32);
        let v = vec![0.5f32; 320];
        let a = c.select(Ctx { round: 9, worker: 0 }, &v);
        let b = c.select(Ctx { round: 9, worker: 1 }, &v);
        assert_ne!(a, b);
    }

    #[test]
    fn randk_deterministic_per_ctx() {
        let c = RandK::new(4.0);
        let v = vec![0.5f32; 64];
        let ctx = Ctx { round: 5, worker: 3 };
        assert_eq!(c.select(ctx, &v), c.select(ctx, &v));
    }
}
