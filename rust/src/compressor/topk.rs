//! Top-k element and blockwise top-k sparsifiers.
//!
//! Top-k selects the k largest-magnitude coordinates; the paper (§3.3, citing
//! Stich et al.) notes it converges better than random-k but costs more and
//! is not AllReduce-compatible (per-worker supports).  `TopK` is a true
//! δ ≥ k/d compressor *deterministically*, not just in expectation.
//!
//! `BlockTopK` ranks whole blocks by their l2 mass — the deterministic cousin
//! of GRBS used in ablations.

use super::{Compressor, Ctx, Scratch, Selection, WireScheme};

#[derive(Clone, Debug)]
pub struct TopK {
    ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> Self {
        assert!(ratio >= 1.0);
        TopK { ratio }
    }
}

impl Compressor for TopK {
    fn select_with(&self, _ctx: Ctx, v: &[f32], scratch: &mut Scratch) -> Selection {
        let d = v.len();
        let k = ((d as f64 / self.ratio).round() as usize).clamp(1, d);
        // The O(d) `0..d` permutation lives in the caller's scratch —
        // rebuilt in place, never reallocated across steps.  Only the
        // k-element result is owned by the returned selection.
        let ix = scratch.iota(d);
        // partial selection by |v|, then sort the chosen k for range iteration
        ix.select_nth_unstable_by(k - 1, |&a, &b| {
            v[b as usize]
                .abs()
                .partial_cmp(&v[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut kept: Vec<u32> = ix[..k].to_vec();
        kept.sort_unstable();
        Selection::Indices(kept)
    }

    fn ratio(&self) -> f64 {
        self.ratio
    }

    fn globally_synchronized(&self) -> bool {
        false
    }

    fn name(&self) -> String {
        format!("topk(R={})", self.ratio)
    }
}

#[derive(Clone, Debug)]
pub struct BlockTopK {
    ratio: f64,
    num_blocks: usize,
    keep: usize,
}

impl BlockTopK {
    pub fn new(ratio: f64, num_blocks: usize) -> Self {
        assert!(ratio >= 1.0);
        let keep = ((num_blocks as f64 / ratio).round() as usize).clamp(1, num_blocks);
        BlockTopK { ratio, num_blocks, keep }
    }
}

impl Compressor for BlockTopK {
    fn select_with(&self, _ctx: Ctx, v: &[f32], scratch: &mut Scratch) -> Selection {
        let d = v.len();
        let block_size = (d + self.num_blocks - 1) / self.num_blocks;
        // Block-mass ranking table reused from the scratch.  The sort must
        // stay *stable* (equal-mass ties resolve to the lower block id, the
        // behavior every pinned trajectory was recorded under), so the small
        // merge buffer `sort_by` allocates is kept — the scratch removes the
        // per-call table itself.
        let mass = &mut scratch.mass;
        mass.clear();
        mass.extend((0..self.num_blocks as u32).map(|b| {
            let s = b as usize * block_size;
            let m: f64 = if s < d {
                let e = (s + block_size).min(d);
                v[s..e].iter().map(|x| (*x as f64) * (*x as f64)).sum()
            } else {
                0.0
            };
            (m, b)
        }));
        mass.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        let mut blocks: Vec<u32> = mass[..self.keep].iter().map(|&(_, b)| b).collect();
        blocks.sort_unstable();
        Selection::Blocks { block_size, blocks }
    }

    fn ratio(&self) -> f64 {
        self.ratio
    }

    fn delta(&self) -> f64 {
        // Deterministically >= keep/B of the mass (top blocks): delta at least
        // the uniform share.
        self.keep as f64 / self.num_blocks as f64
    }

    fn globally_synchronized(&self) -> bool {
        false
    }

    fn wire_scheme(&self) -> WireScheme {
        // The block choice is value-dependent, so unlike GRBS/RandBlock the
        // ids must travel: one `ceil(log2 B)`-bit id per selected block, then
        // that block's values.  `payload_bits_wire` charges exactly this.
        WireScheme::BlockIndex { num_blocks: self.num_blocks as u32 }
    }

    fn name(&self) -> String {
        format!("blocktopk(R={}, B={})", self.ratio, self.num_blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::norm2;

    #[test]
    fn topk_picks_largest() {
        let v = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 4.0, 0.0, 1.0];
        let c = TopK::new(8.0 / 3.0); // k = 3
        if let Selection::Indices(ix) = c.select(Ctx { round: 0, worker: 0 }, &v) {
            assert_eq!(ix, vec![1, 3, 5]);
        } else {
            panic!();
        }
    }

    #[test]
    fn topk_residual_at_most_uniform_share() {
        // ||C(v)-v||^2 <= (1 - k/d)||v||^2 deterministically for top-k.
        let v: Vec<f32> = (0..128).map(|i| ((i * 37 % 61) as f32 - 30.0) / 7.0).collect();
        let c = TopK::new(4.0);
        let sel = c.select(Ctx { round: 0, worker: 0 }, &v);
        let mut kept = vec![0.0; v.len()];
        sel.apply(&v, &mut kept);
        let resid: Vec<f32> = v.iter().zip(&kept).map(|(a, b)| a - b).collect();
        assert!(norm2(&resid) <= (1.0 - 0.25) * norm2(&v) + 1e-9);
    }

    #[test]
    fn blocktopk_prefers_heavy_blocks() {
        let mut v = vec![0.01f32; 40]; // 4 blocks of 10
        for x in &mut v[20..30] {
            *x = 5.0;
        }
        let c = BlockTopK::new(4.0, 4); // keep 1 block
        if let Selection::Blocks { blocks, .. } = c.select(Ctx { round: 0, worker: 0 }, &v) {
            assert_eq!(blocks, vec![2]);
        } else {
            panic!();
        }
    }

    #[test]
    fn blocktopk_accounting_charges_block_ids() {
        // DESIGN.md §3 closure: the accounted size must include the block-id
        // metadata the wire actually ships — strictly more than the
        // seed-derivable (SharedSupport) price of the same selection.
        use crate::compressor::{index_bits, payload_bits, payload_bits_wire};
        let d = 128;
        let v: Vec<f32> = (0..d).map(|i| ((i * 29 % 97) as f32 - 48.0) / 13.0).collect();
        let c = BlockTopK::new(4.0, 16); // keep 4 of 16 blocks of 8
        let ctx = Ctx { round: 1, worker: 0 };
        let sel = c.select(ctx, &v);
        let mut out = vec![0.0f32; d];
        let accounted = c.compress_into(ctx, &v, &mut out);
        let expect = sel.count(d) as u64 * 32 + 4 * index_bits(16) as u64;
        assert_eq!(accounted, expect);
        assert_eq!(accounted, payload_bits_wire(c.wire_scheme(), &sel, d));
        assert!(accounted > payload_bits(&sel, d), "ids must be charged");
    }

    #[test]
    fn blocktopk_beats_or_matches_random_share() {
        let v: Vec<f32> = (0..160).map(|i| if i % 50 == 0 { 10.0 } else { 0.1 }).collect();
        let c = BlockTopK::new(4.0, 16);
        let sel = c.select(Ctx { round: 0, worker: 0 }, &v);
        let mut kept = vec![0.0; v.len()];
        sel.apply(&v, &mut kept);
        assert!(norm2(&kept) >= norm2(&v) * 0.25);
    }
}
